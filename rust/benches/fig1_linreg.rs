//! Bench: regenerate Fig. 1 (a-d) — linear regression on the 8-ring.
//! `cargo bench --bench fig1_linreg`
fn main() {
    let t = std::time::Instant::now();
    let recs = lead::experiments::fig1(Some(std::path::Path::new("results")), 1500).expect("fig1");
    // Paper-shape assertions: LEAD exact, ~10x bit saving vs NIDS.
    let lead_rec = recs.iter().find(|r| r.algo.starts_with("LEAD")).unwrap();
    let nids = recs.iter().find(|r| r.algo == "NIDS").unwrap();
    assert!(lead_rec.last().dist_opt < 1e-6);
    if let (Some(lb), Some(nb)) = (lead_rec.bits_to_tol(1e-6), nids.bits_to_tol(1e-6)) {
        println!("\nLEAD bit saving vs NIDS at 1e-6: {:.1}x", nb / lb);
    }
    println!("fig1 total: {:.1}s", t.elapsed().as_secs_f64());
}
