//! Bench: regenerate Fig. 2 — logistic regression, heterogeneous split,
//! full-batch gradients.
use lead::problems::DataSplit;
fn main() {
    let t = std::time::Instant::now();
    lead::experiments::fig_logreg(DataSplit::Heterogeneous, false,
        Some(std::path::Path::new("results")), 400, 4000)
        .expect("fig2");
    println!("fig2 total: {:.1}s", t.elapsed().as_secs_f64());
}
