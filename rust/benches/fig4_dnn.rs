//! Bench: regenerate Fig. 4 — "deep net" (MLP via PJRT), homo + hetero.
use lead::problems::DataSplit;
fn main() {
    let t = std::time::Instant::now();
    for split in [DataSplit::Homogeneous, DataSplit::Heterogeneous] {
        if let Err(e) = lead::experiments::fig4(split, Some(std::path::Path::new("results")), 40) {
            eprintln!("fig4 requires `make artifacts`: {e}");
            return;
        }
    }
    println!("fig4 total: {:.1}s", t.elapsed().as_secs_f64());
}
