//! Bench: regenerate Fig. 6 — q∞ vs top-k vs random-k error per bit.
fn main() {
    let t = std::time::Instant::now();
    let rows = lead::experiments::fig6(Some(std::path::Path::new("results"))).expect("fig6");
    // Shape assertion: at ~3 bits/elem, q∞ beats both sparsifiers at
    // comparable budgets (the paper's Fig. 6 conclusion).
    let q2 = rows.iter().find(|(n, _, _)| n.contains("2bit")).unwrap();
    for (name, bits, err) in &rows {
        if !name.starts_with('q') && *bits <= q2.1 * 1.5 {
            assert!(*err > q2.2, "{name} ({bits} b/e) beat q∞-2bit — unexpected");
        }
    }
    println!("fig6 total: {:.1}s", t.elapsed().as_secs_f64());
}
