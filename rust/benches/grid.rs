//! Grid-throughput A/B (§Perf): the fig7 25-cell (α, γ) sensitivity
//! sweep executed by the serial per-cell baseline vs the sharded
//! scenario [`Driver`] on a shared worker pool.
//!
//! Every fig7 cell is a *small* run (n = 8, d = 200 — far below the
//! engine's inner fan-out threshold), so the serial baseline cannot use
//! any parallelism; the driver shards whole runs across pool workers
//! instead. Trajectories are bitwise-identical by construction (pinned by
//! `scenarios::tests::sharded_grid_bitwise_equals_serial` and re-checked
//! here), so the A/B measures scheduling alone. Acceptance target:
//! ≥ 2× wall-clock at 8 threads.
//!
//! Writes the machine-readable `BENCH_grid.json` at the repo root (the
//! committed perf-trajectory baseline for `lead bench-diff`); smoke runs
//! (`-- --smoke`, wired into CI) write a throwaway
//! `BENCH_grid_smoke.json` so they can never clobber the baseline.

use lead::coordinator::metrics::RunRecord;
use lead::experiments::fig7_grid;
use lead::scenarios::{Driver, RunSpec};

fn run_grid(specs: &[RunSpec], threads: usize) -> (f64, Vec<RunRecord>) {
    let t = std::time::Instant::now();
    let recs = Driver::new(threads).run("fig7_bench", specs).expect("grid run failed");
    (t.elapsed().as_secs_f64(), recs)
}

fn bitwise_identical(a: &[RunRecord], b: &[RunRecord]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(ra, rb)| {
            ra.series.len() == rb.series.len()
                && ra.series.iter().zip(&rb.series).all(|(ma, mb)| {
                    ma.dist_opt.to_bits() == mb.dist_opt.to_bits()
                        && ma.consensus.to_bits() == mb.consensus.to_bits()
                        && ma.bits_per_agent == mb.bits_per_agent
                })
        })
}

struct GridAb {
    name: String,
    threads: usize,
    cells: usize,
    rounds: usize,
    serial_s: f64,
    sharded_s: f64,
    identical: bool,
}

impl GridAb {
    fn speedup(&self) -> f64 {
        self.serial_s / self.sharded_s
    }

    fn to_json(&self) -> String {
        let fin = |x: f64| if x.is_finite() { format!("{x:.3}") } else { "null".into() };
        format!(
            "{{\"name\":\"{}\",\"threads\":{},\"cells\":{},\"rounds\":{},\
             \"serial_s\":{},\"sharded_s\":{},\"speedup\":{},\"identical\":{}}}",
            self.name,
            self.threads,
            self.cells,
            self.rounds,
            fin(self.serial_s),
            fin(self.sharded_s),
            fin(self.speedup()),
            self.identical
        )
    }
}

fn bench_fig7(rounds: usize, threads: usize) -> GridAb {
    let specs = fig7_grid(rounds).expand().expect("fig7 grid");
    // Warm (problem construction, page cache) outside the timed region:
    // the driver builds/dedupes the shared problem inside run(), so time
    // both sides the same way after one throwaway pass.
    let _ = run_grid(&specs[..2.min(specs.len())], 1);
    let (serial_s, serial) = run_grid(&specs, 1);
    let (sharded_s, sharded) = run_grid(&specs, threads);
    let r = GridAb {
        name: format!("fig7-25cell r={rounds} t={threads}"),
        threads,
        cells: specs.len(),
        rounds,
        serial_s,
        sharded_s,
        identical: bitwise_identical(&serial, &sharded),
    };
    println!(
        "grid A/B {:<28} serial {serial_s:7.2}s  sharded {sharded_s:7.2}s  speedup {:5.2}x  bitwise-identical: {}",
        r.name,
        r.speedup(),
        r.identical
    );
    r
}

/// Write the bench record at the repository root (one level above the
/// crate manifest) — same convention as `benches/hotpath.rs`.
fn write_json(results: &[GridAb], smoke: bool) {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives one level below the repo root")
        .to_path_buf();
    let configs: Vec<String> = results.iter().map(|r| r.to_json()).collect();
    let json = format!(
        "{{\"schema\":1,\"bench\":\"grid\",\"smoke\":{},\"configs\":[{}]}}\n",
        smoke,
        configs.join(",")
    );
    let name = if smoke { "BENCH_grid_smoke.json" } else { "BENCH_grid.json" };
    let path = root.join(name);
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => {
            // A silently missing artifact would let the CI perf gate
            // compare a stale baseline against its own copy — fail loud.
            eprintln!("could not write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        // CI smoke: a short sweep proving the sharded driver, the
        // bitwise check, and the JSON emission work end to end.
        let r = bench_fig7(40, 4);
        assert!(r.identical, "sharded grid diverged from serial baseline");
        write_json(&[r], true);
        return;
    }

    let mut results = Vec::new();
    for threads in [2usize, 4, 8] {
        results.push(bench_fig7(800, threads));
    }
    for r in &results {
        assert!(r.identical, "{}: sharded grid diverged from serial baseline", r.name);
    }
    write_json(&results, false);
    let headline = results.iter().find(|r| r.threads == 8).unwrap();
    println!(
        "headline: fig7 25-cell sweep at 8 threads — {:.2}x (target >= 2x)",
        headline.speedup()
    );
}
