//! Bench: regenerate Fig. 3 — logistic regression, heterogeneous split,
//! mini-batch 512 gradients.
use lead::problems::DataSplit;
fn main() {
    let t = std::time::Instant::now();
    lead::experiments::fig_logreg(DataSplit::Heterogeneous, true,
        Some(std::path::Path::new("results")), 400, 4000)
        .expect("fig3");
    println!("fig3 total: {:.1}s", t.elapsed().as_secs_f64());
}
