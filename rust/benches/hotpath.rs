//! Microbench: end-to-end coordinator rounds/sec (§Perf, L3), plus the
//! sparse-mixing benchmark for the paper's headline regime.
//!
//! Part 1 — mix phase, dense vs sparse: ring n = 32, d = 10⁵, top-k with
//! k = d/100. The dense path decodes every message to a d-vector and
//! accumulates O(deg·d) per agent; the sparse path scatter-adds the
//! k-entry view in O(deg·k). Same messages, bitwise-identical output —
//! the speedup is pure representation (target ≥5×, typically ≫).
//!
//! Part 2 — full engine rounds/s on the same shape, old hot path (dense
//! mix + sequential apply) vs new (sparse mix + parallel mix/apply pool),
//! plus the original LEAD + 2-bit q∞ shapes at 1/4/8 threads.

use lead::algorithms::lead::Lead;
use lead::compress::quantize::QuantizeP;
use lead::compress::topk::TopK;
use lead::compress::{CompressedMsg, Compressor, StripSparse};
use lead::coordinator::engine::{mix_msgs, Engine, EngineConfig};
use lead::problems::{linreg::LinReg, logreg::LogReg, DataSplit, Problem};
use lead::rng::Rng;
use lead::topology::{MixingRule, Topology};

/// Separable quadratic ½‖x − b_i‖² — an O(d) gradient oracle so the
/// d = 10⁵ engine benches time the communication path, not the problem.
struct Quad {
    n: usize,
    d: usize,
    targets: Vec<Vec<f64>>,
}

impl Quad {
    fn new(n: usize, d: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let targets = (0..n)
            .map(|_| {
                let mut b = vec![0.0f64; d];
                rng.fill_normal(&mut b, 1.0);
                b
            })
            .collect();
        Quad { n, d, targets }
    }
}

impl Problem for Quad {
    fn dim(&self) -> usize {
        self.d
    }
    fn n_agents(&self) -> usize {
        self.n
    }
    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        let b = &self.targets[agent];
        for t in 0..x.len() {
            out[t] = x[t] - b[t];
        }
    }
    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        0.5 * lead::linalg::dist_sq(x, &self.targets[agent])
    }
    fn optimum(&self) -> Option<&[f64]> {
        None
    }
    fn name(&self) -> String {
        format!("quad(n={}, d={})", self.n, self.d)
    }
}

/// Part 1: isolated mix phase, all agents, dense vs sparse representation.
fn bench_mix_phase() {
    let n = 32usize;
    let d = 100_000usize;
    let k = d / 100;
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let topk = TopK::new(k);
    let mut rng = Rng::new(7);
    let msgs_sparse: Vec<CompressedMsg> = (0..n)
        .map(|_| {
            let mut x = vec![0.0f64; d];
            rng.fill_normal(&mut x, 1.0);
            topk.compress_alloc(&x, &mut rng)
        })
        .collect();
    let msgs_dense: Vec<CompressedMsg> = msgs_sparse
        .iter()
        .map(|m| {
            let mut m = m.clone();
            m.sparse = None;
            m
        })
        .collect();

    let mut out = vec![0.0f64; d];
    let time_all = |msgs: &[CompressedMsg], out: &mut Vec<f64>, reps: usize| -> f64 {
        let t = std::time::Instant::now();
        for _ in 0..reps {
            for i in 0..n {
                out.fill(0.0);
                mix_msgs(&mix, i, msgs, out);
            }
        }
        t.elapsed().as_secs_f64() / reps as f64
    };
    // Warmup + measure (one "round" = mixing for all n agents).
    time_all(&msgs_dense, &mut out, 1);
    let dense_s = time_all(&msgs_dense, &mut out, 10);
    time_all(&msgs_sparse, &mut out, 1);
    let sparse_s = time_all(&msgs_sparse, &mut out, 10);
    // Sanity: identical output on the last agent mixed.
    let mut dense_out = vec![0.0f64; d];
    mix_msgs(&mix, n - 1, &msgs_dense, &mut dense_out);
    out.fill(0.0);
    mix_msgs(&mix, n - 1, &msgs_sparse, &mut out);
    let identical = dense_out.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "mix phase  ring n={n} d={d} top-k k={k}:  dense {:8.3} ms/round   sparse {:8.3} ms/round   speedup {:6.1}x   bitwise-identical: {identical}",
        dense_s * 1e3,
        sparse_s * 1e3,
        dense_s / sparse_s
    );
}

/// Part 2: full engine rounds/s, old hot path vs new, same numerics.
fn bench_engine_sparse() {
    let n = 32usize;
    let d = 100_000usize;
    let k = d / 100;
    let rounds = 15usize;
    let run = |name: &str, threads: usize, comp: Box<dyn Compressor>| -> f64 {
        let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig {
                eta: 0.05,
                threads,
                record_every: usize::MAX / 2,
                ..Default::default()
            },
            mix,
            Box::new(Quad::new(n, d, 3)),
        );
        let t = std::time::Instant::now();
        let rec = e.run(Box::new(Lead::paper_default()), Some(comp), rounds);
        let secs = t.elapsed().as_secs_f64();
        println!(
            "engine     {name:<34} threads={threads}  {:8.2} rounds/s  (consensus {:.2e})",
            rounds as f64 / secs,
            rec.last().consensus
        );
        secs
    };
    let dense_seq =
        run("quad d=1e5 top-k dense (old path)", 1, Box::new(StripSparse(TopK::new(k))));
    let sparse_seq = run("quad d=1e5 top-k sparse", 1, Box::new(TopK::new(k)));
    let dense_par = run("quad d=1e5 top-k dense", 8, Box::new(StripSparse(TopK::new(k))));
    let sparse_par = run("quad d=1e5 top-k sparse", 8, Box::new(TopK::new(k)));
    println!(
        "engine     sparse speedup: {:4.2}x sequential, {:4.2}x at 8 threads, {:4.2}x combined (old 1-thread dense vs new 8-thread sparse)",
        dense_seq / sparse_seq,
        dense_par / sparse_par,
        dense_seq / sparse_par
    );
}

fn bench(name: &str, problem: Box<dyn lead::problems::Problem>, threads: usize, rounds: usize) {
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig { threads, record_every: usize::MAX / 2, ..Default::default() },
        mix,
        problem,
    );
    let t = std::time::Instant::now();
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::paper_default())),
        rounds,
    );
    let secs = t.elapsed().as_secs_f64();
    println!(
        "{name:<40} threads={threads}  {:8.1} rounds/s  ({rounds} rounds in {secs:.2}s, dist {:.1e})",
        rounds as f64 / secs,
        rec.last().dist_opt
    );
}

fn main() {
    bench_mix_phase();
    bench_engine_sparse();
    for threads in [1usize, 4, 8] {
        bench(
            "linreg d=200 (fig1 shape)",
            Box::new(LinReg::synthetic(8, 200, 0.1, 1)),
            threads,
            400,
        );
    }
    for threads in [1usize, 4, 8] {
        bench(
            "logreg d=7850 full-batch (fig2 shape)",
            Box::new(LogReg::synthetic(8, 4000, 784, 10, 1e-4, DataSplit::Heterogeneous, 1, false)),
            threads,
            60,
        );
    }
}
