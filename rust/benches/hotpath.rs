//! Microbench: end-to-end coordinator rounds/sec (§Perf, L3) with the
//! old-vs-new scheduler A/B, per-phase timing breakdown, and a
//! machine-readable `BENCH_hotpath.json` at the repo root so the perf
//! trajectory is tracked across PRs.
//!
//! Part 1 — mix phase, dense vs sparse: ring n = 32, d = 10⁵, top-k with
//! k = d/100. The dense path decodes every message to a d-vector and
//! accumulates O(deg·d) per agent; the sparse path scatter-adds the
//! k-entry view in O(deg·k). Same messages, bitwise-identical output —
//! the speedup is pure representation (target ≥5×, typically ≫).
//!
//! Part 2 — engine A/B: the pre-PR loop ([`Scheduler::SpawnPerPhase`]:
//! scoped thread spawns per phase, sequential send, per-round alloc +
//! comp-err pass) vs the persistent pool loop ([`Scheduler::Persistent`]:
//! fused produce, zero-alloc steady state). The headline config is
//! n = 32, d ≈ 10⁴ where spawn/alloc overhead dominates FLOPs (target
//! ≥1.5× rounds/s); a d = 10⁵ sparse config covers the paper's
//! large-model regime. Trajectories are bitwise-identical
//! (`scheduler_modes_bitwise_identical` in the engine tests), so the A/B
//! measures scheduling alone.
//!
//! Part 3 — sparse-own own-decode A/B (both runs on the persistent
//! scheduler): `EagerDense`-wrapped top-k decodes every agent's own
//! message to a dense d-vector each round (the pre-sparse-own engine
//! behavior) vs the sparse-own apply path consuming the k published
//! entries directly through `Inbox::own_view`. Trajectories are
//! bitwise-identical (asserted here on a short config and pinned by
//! `rust/tests/sparse_own.rs`), so the A/B isolates the own-decode cost:
//! the decode itself drops from O(d) to O(k) per agent, but the apply
//! kernel still sweeps all d coordinates, so the end-to-end win is a
//! modest constant factor (one fewer O(n·d) fill+scatter pass and one
//! fewer d-length stream per agent), NOT ~d/k. The result ships in
//! `BENCH_hotpath.json` as the `sparse-own` config so `lead bench-diff`
//! gates regressions on it.
//!
//! Part 4 — simnet overhead A/B: the legacy uniform round-time formula
//! vs the discrete-event network simulator (`lead::simnet`) on the same
//! run. The degenerate homogeneous model isolates pure event-queue cost
//! (n·deg binary-heap ops per round); a straggler+drop model adds
//! retransmit events. Trajectories are bitwise-identical and the
//! degenerate model reproduces the legacy `sim_time` exactly
//! (`assert_simnet_timing_only`, pinned harder by
//! `rust/tests/simnet.rs`), so the A/B measures the overlay alone; the
//! configs ship in `BENCH_hotpath.json` (smoke: one short lossy config)
//! so `lead bench-diff` gates the subsystem once baselines land.
//!
//! Part 5 — kernel microbenches + pool wake latency: the 4-lane chunked
//! `linalg::simd` kernels (axpy / scatter_axpy / dot) and the quantize
//! encode/decode burst loops vs their pre-SIMD scalar references
//! (`linalg::simd::reference`, plus bench-local replicas of the old
//! per-element quantize loops), at d = 10⁵; and the pool's per-worker
//! wake path vs the legacy one-condvar-wakes-all broadcast
//! (`WorkerPool::new_broadcast`), measured as empty-dispatch round-trip
//! latency. Elementwise kernels and the quantize wire bytes are asserted
//! bitwise/byte-identical across arms in-release before timing;
//! reductions are pinned to the scalar emulation of the fixed 4-lane
//! tree (`reference::dot_tree`), so every config's A/B compares
//! identical computations. Ships as `kernel …` / `pool wake` configs in
//! `BENCH_hotpath.json` so `lead bench-diff` gates kernel-level
//! regressions forever after.
//!
//! Part 6 — transport A/B: the shared-memory mix (`TransportMode::Mem`)
//! vs the framed in-process channel exchange (`TransportMode::Channel`)
//! on the same run — the only delta is encoding each neighbor message
//! into an envelope, queueing it through `mpsc`, and decoding it on the
//! receive side. Trajectories are bitwise-identical
//! (`assert_transport_bitwise`, pinned harder by
//! `rust/tests/transport.rs`), so `old` = shared memory, `new` =
//! channel, speedup ≲ 1 measures pure serialization + queueing overhead;
//! the config ships in `BENCH_hotpath.json` so `lead bench-diff` gates
//! the transport's cost.
//!
//! Run `cargo bench --bench hotpath` (full) or
//! `cargo bench --bench hotpath -- --smoke` (one short config; wired
//! into CI so regressions in the harness itself are caught early).

use lead::algorithms::lead::Lead;
use lead::compress::quantize::QuantizeP;
use lead::compress::topk::TopK;
use lead::compress::{CompressedMsg, Compressor, EagerDense, StripSparse};
use lead::coordinator::engine::{mix_msgs, Engine, EngineConfig, Scheduler};
use lead::coordinator::metrics::PhaseTimes;
use lead::problems::{linreg::LinReg, logreg::LogReg, quad::Quad, DataSplit};
use lead::rng::Rng;
use lead::simnet::NetModel;
use lead::topology::{MixingRule, Topology};
use lead::transport::TransportMode;

/// Part 1: isolated mix phase, all agents, dense vs sparse representation.
fn bench_mix_phase() {
    let n = 32usize;
    let d = 100_000usize;
    let k = d / 100;
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let topk = TopK::new(k);
    let mut rng = Rng::new(7);
    let msgs_sparse: Vec<CompressedMsg> = (0..n)
        .map(|_| {
            let mut x = vec![0.0f64; d];
            rng.fill_normal(&mut x, 1.0);
            topk.compress_alloc(&x, &mut rng)
        })
        .collect();
    let msgs_dense: Vec<CompressedMsg> = msgs_sparse
        .iter()
        .map(|m| {
            let mut m = m.clone();
            m.sparse = None;
            m
        })
        .collect();

    let mut out = vec![0.0f64; d];
    let time_all = |msgs: &[CompressedMsg], out: &mut Vec<f64>, reps: usize| -> f64 {
        let t = std::time::Instant::now();
        for _ in 0..reps {
            for i in 0..n {
                out.fill(0.0);
                mix_msgs(&mix, i, msgs, out);
            }
        }
        t.elapsed().as_secs_f64() / reps as f64
    };
    // Warmup + measure (one "round" = mixing for all n agents).
    time_all(&msgs_dense, &mut out, 1);
    let dense_s = time_all(&msgs_dense, &mut out, 10);
    time_all(&msgs_sparse, &mut out, 1);
    let sparse_s = time_all(&msgs_sparse, &mut out, 10);
    // Sanity: identical output on the last agent mixed.
    let mut dense_out = vec![0.0f64; d];
    mix_msgs(&mix, n - 1, &msgs_dense, &mut dense_out);
    out.fill(0.0);
    mix_msgs(&mix, n - 1, &msgs_sparse, &mut out);
    let identical = dense_out.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "mix phase  ring n={n} d={d} top-k k={k}:  dense {:8.3} ms/round   sparse {:8.3} ms/round   speedup {:6.1}x   bitwise-identical: {identical}",
        dense_s * 1e3,
        sparse_s * 1e3,
        dense_s / sparse_s
    );
}

/// One engine run under the given scheduler; returns (rounds/s, phases).
fn timed_run(
    n: usize,
    d: usize,
    rounds: usize,
    threads: usize,
    scheduler: Scheduler,
    comp: Box<dyn Compressor>,
) -> (f64, PhaseTimes) {
    timed_run_net(n, d, rounds, threads, scheduler, comp, None)
}

/// [`timed_run`] with an optional simnet overlay (None ⇒ legacy uniform
/// round-time formula).
fn timed_run_net(
    n: usize,
    d: usize,
    rounds: usize,
    threads: usize,
    scheduler: Scheduler,
    comp: Box<dyn Compressor>,
    net: Option<NetModel>,
) -> (f64, PhaseTimes) {
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig {
            eta: 0.05,
            threads,
            record_every: usize::MAX / 2,
            scheduler,
            net,
            ..Default::default()
        },
        mix,
        std::sync::Arc::new(Quad::new(n, d, 3)),
    );
    let t = std::time::Instant::now();
    let rec = e.run(Box::new(Lead::paper_default()), Some(comp), rounds);
    let secs = t.elapsed().as_secs_f64();
    let _ = rec.last().consensus; // keep the run observable
    (rounds as f64 / secs, rec.phases)
}

struct AbResult {
    name: String,
    n: usize,
    d: usize,
    threads: usize,
    rounds: usize,
    old_rps: f64,
    new_rps: f64,
    old_phases: PhaseTimes,
    new_phases: PhaseTimes,
}

impl AbResult {
    fn speedup(&self) -> f64 {
        self.new_rps / self.old_rps
    }

    fn to_json(&self) -> String {
        // Config names are static ASCII literals (no escaping needed);
        // numbers map non-finite to null so the file always parses.
        let fin = |x: f64| if x.is_finite() { format!("{x:.3}") } else { "null".into() };
        format!(
            "{{\"name\":\"{}\",\"n\":{},\"d\":{},\"threads\":{},\"rounds\":{},\
             \"old_rounds_per_s\":{},\"new_rounds_per_s\":{},\"speedup\":{},\
             \"old_phases\":{},\"new_phases\":{}}}",
            self.name,
            self.n,
            self.d,
            self.threads,
            self.rounds,
            fin(self.old_rps),
            fin(self.new_rps),
            fin(self.speedup()),
            self.old_phases.to_json(),
            self.new_phases.to_json()
        )
    }
}

/// Part 2: full-engine A/B, pre-PR spawn-per-phase loop vs persistent
/// pool loop, with the legacy run doubling as the per-phase breakdown
/// (its gradient/send/compress/mix/apply buckets are split; the new
/// loop fuses the first three into `produce`).
fn bench_engine_ab(
    name: &str,
    n: usize,
    d: usize,
    rounds: usize,
    threads: usize,
    make_comp: &dyn Fn() -> Box<dyn Compressor>,
) -> AbResult {
    // Warm the CPU/allocator on the new path first.
    let _ = timed_run(n, d, rounds.min(5), threads, Scheduler::Persistent, make_comp());
    let (old_rps, old_phases) =
        timed_run(n, d, rounds, threads, Scheduler::SpawnPerPhase, make_comp());
    let (new_rps, new_phases) = timed_run(n, d, rounds, threads, Scheduler::Persistent, make_comp());
    let r = AbResult {
        name: name.to_string(),
        n,
        d,
        threads,
        rounds,
        old_rps,
        new_rps,
        old_phases,
        new_phases,
    };
    println!(
        "engine A/B {name:<34} threads={threads}  old {old_rps:8.2} r/s  new {new_rps:8.2} r/s  speedup {:5.2}x",
        r.speedup()
    );
    let p = &old_phases;
    println!(
        "           old per-phase totals (s): gradient {:.3}  send {:.3}  compress {:.3}  mix {:.3}  apply {:.3}",
        p.gradient, p.send, p.compress, p.mix, p.apply
    );
    let p = &new_phases;
    println!(
        "           new per-phase totals (s): produce {:.3} (fused grad+send+compress)  mix {:.3}  apply {:.3}",
        p.produce, p.mix, p.apply
    );
    r
}

fn bench(name: &str, problem: std::sync::Arc<dyn lead::problems::Problem>, threads: usize, rounds: usize) {
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig { threads, record_every: usize::MAX / 2, ..Default::default() },
        mix,
        problem,
    );
    let t = std::time::Instant::now();
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::paper_default())),
        rounds,
    );
    let secs = t.elapsed().as_secs_f64();
    println!(
        "{name:<40} threads={threads}  {:8.1} rounds/s  ({rounds} rounds in {secs:.2}s, dist {:.1e})",
        rounds as f64 / secs,
        rec.last().dist_opt
    );
}

/// Write the bench record at the repository root (one level above the
/// crate's manifest, so it lands in the same place regardless of the
/// invocation directory). The full sweep owns `BENCH_hotpath.json` — the
/// committed perf-trajectory baseline; smoke runs write a separate
/// throwaway file so a CI/local smoke can never clobber the baseline.
fn write_json(results: &[AbResult], smoke: bool) {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives one level below the repo root")
        .to_path_buf();
    let configs: Vec<String> = results.iter().map(|r| r.to_json()).collect();
    let json = format!(
        "{{\"schema\":1,\"bench\":\"hotpath\",\"smoke\":{},\"configs\":[{}]}}\n",
        smoke,
        configs.join(",")
    );
    let name = if smoke { "BENCH_hotpath_smoke.json" } else { "BENCH_hotpath.json" };
    let path = root.join(name);
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => {
            // A silently missing artifact would let the CI perf gate
            // compare a stale baseline against its own copy — fail loud.
            eprintln!("could not write {}: {e}", path.display());
            std::process::exit(1);
        }
    }
}

/// Part 4: simnet event-queue overhead vs the legacy uniform formula —
/// same scheduler, same codec, the only delta is the per-round
/// discrete-event simulation of all n·deg transfers. `old` = legacy
/// formula, `new` = simnet, so speedup ≲ 1 and the config's entry in
/// `BENCH_hotpath.json` gates the overlay's cost via `lead bench-diff`.
fn bench_simnet_ab(
    name: &str,
    n: usize,
    d: usize,
    rounds: usize,
    threads: usize,
    link: &str,
) -> AbResult {
    let model = NetModel::parse(link).expect("bench link spec");
    let comp = || -> Box<dyn Compressor> { Box::new(TopK::new((d / 100).max(1))) };
    let _ = timed_run(n, d, rounds.min(5), threads, Scheduler::Persistent, comp());
    let (legacy_rps, legacy_phases) =
        timed_run(n, d, rounds, threads, Scheduler::Persistent, comp());
    let (sim_rps, sim_phases) =
        timed_run_net(n, d, rounds, threads, Scheduler::Persistent, comp(), Some(model));
    let r = AbResult {
        name: name.to_string(),
        n,
        d,
        threads,
        rounds,
        old_rps: legacy_rps,
        new_rps: sim_rps,
        old_phases: legacy_phases,
        new_phases: sim_phases,
    };
    println!(
        "simnet A/B {name:<34} threads={threads}  legacy {legacy_rps:8.2} r/s  simnet {sim_rps:8.2} r/s  overhead {:5.3}x  ({link})",
        r.speedup()
    );
    r
}

/// Bitwise guard for the simnet overlay: a heterogeneous lossy model
/// must leave the trajectory untouched, and the degenerate homogeneous
/// model must reproduce the legacy sim_time exactly (release-mode
/// counterpart of `rust/tests/simnet.rs` — a drift here means the A/B
/// above is comparing different computations).
fn assert_simnet_timing_only() {
    let run = |link: Option<&str>| {
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig {
                eta: 0.05,
                threads: 2,
                record_every: 11,
                net: link.map(|s| NetModel::parse(s).expect("guard link spec")),
                ..Default::default()
            },
            mix,
            std::sync::Arc::new(Quad::new(8, 200, 3)),
        );
        let rec = e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(20))), 60);
        let m = rec.last();
        (m.dist_opt.to_bits(), m.consensus.to_bits(), m.sim_time.to_bits())
    };
    let legacy = run(None);
    // EngineConfig's default LinkModel is 1e-4 s / 1e9 bps.
    let degenerate = run(Some("uniform:1e-4:1e9"));
    let lossy = run(Some("straggler:1e-4:1e9:0.25:10:drop=0.05"));
    assert_eq!(
        (legacy.0, legacy.1),
        (degenerate.0, degenerate.1),
        "simnet perturbed the trajectory"
    );
    assert_eq!((legacy.0, legacy.1), (lossy.0, lossy.1), "lossy simnet perturbed the trajectory");
    assert_eq!(legacy.2, degenerate.2, "degenerate simnet drifted from the legacy sim_time");
    println!("simnet bitwise guard: timing-only overlay, degenerate model == legacy formula");
}

/// [`timed_run`] over an explicit transport mode (persistent scheduler).
fn timed_run_transport(
    n: usize,
    d: usize,
    rounds: usize,
    threads: usize,
    transport: TransportMode,
    comp: Box<dyn Compressor>,
) -> (f64, PhaseTimes) {
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig {
            eta: 0.05,
            threads,
            record_every: usize::MAX / 2,
            transport,
            ..Default::default()
        },
        mix,
        std::sync::Arc::new(Quad::new(n, d, 3)),
    );
    let t = std::time::Instant::now();
    let rec = e.run(Box::new(Lead::paper_default()), Some(comp), rounds);
    let secs = t.elapsed().as_secs_f64();
    let _ = rec.last().consensus; // keep the run observable
    (rounds as f64 / secs, rec.phases)
}

/// Part 6: transport A/B — shared-memory mix vs framed channel exchange.
/// `old` = `Mem`, `new` = `Channel`, so speedup ≲ 1 and the config's
/// entry in `BENCH_hotpath.json` gates the encode+queue+decode overhead
/// via `lead bench-diff`.
fn bench_transport_ab(
    name: &str,
    n: usize,
    d: usize,
    rounds: usize,
    threads: usize,
    make_comp: &dyn Fn() -> Box<dyn Compressor>,
) -> AbResult {
    let _ = timed_run_transport(n, d, rounds.min(5), threads, TransportMode::Mem, make_comp());
    let (mem_rps, mem_phases) =
        timed_run_transport(n, d, rounds, threads, TransportMode::Mem, make_comp());
    let (chan_rps, chan_phases) =
        timed_run_transport(n, d, rounds, threads, TransportMode::Channel, make_comp());
    let r = AbResult {
        name: name.to_string(),
        n,
        d,
        threads,
        rounds,
        old_rps: mem_rps,
        new_rps: chan_rps,
        old_phases: mem_phases,
        new_phases: chan_phases,
    };
    println!(
        "transport A/B {name:<31} threads={threads}  mem {mem_rps:8.2} r/s  channel {chan_rps:8.2} r/s  overhead {:5.3}x",
        r.speedup()
    );
    r
}

/// Release-mode bitwise guard for the transport A/B: the channel and
/// multiplexed exchanges must report identical final metrics to shared
/// memory (release counterpart of the `rust/tests/transport.rs`
/// harness — a drift here means the A/B above compares different
/// computations).
fn assert_transport_bitwise() {
    let final_bits = |transport: TransportMode| {
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig { eta: 0.05, threads: 2, record_every: 11, transport, ..Default::default() },
            mix,
            std::sync::Arc::new(Quad::new(8, 200, 3)),
        );
        let rec = e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(20))), 60);
        (rec.last().dist_opt.to_bits(), rec.last().consensus.to_bits())
    };
    let mem = final_bits(TransportMode::Mem);
    assert_eq!(mem, final_bits(TransportMode::Channel), "channel transport perturbed the trajectory");
    assert_eq!(
        mem,
        final_bits(TransportMode::Mux { per_worker: 4 }),
        "multiplexed transport perturbed the trajectory"
    );
    println!("transport bitwise guard: channel/mux exchange == shared-memory mix");
}

/// Bitwise guard for the sparse-own A/B: the lazy sparse-own run and the
/// eager dense-own run must report identical final metrics (release-mode
/// counterpart of the `rust/tests/sparse_own.rs` harness — a drift here
/// means the A/B below is comparing different computations).
fn assert_sparse_own_bitwise() {
    let final_bits = |comp: Box<dyn Compressor>| {
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig { eta: 0.05, threads: 2, record_every: 11, ..Default::default() },
            mix,
            std::sync::Arc::new(Quad::new(8, 200, 3)),
        );
        let rec = e.run(Box::new(Lead::paper_default()), Some(comp), 60);
        (rec.last().dist_opt.to_bits(), rec.last().consensus.to_bits())
    };
    let lazy = final_bits(Box::new(TopK::new(20)));
    let eager = final_bits(Box::new(EagerDense(TopK::new(20))));
    assert_eq!(lazy, eager, "sparse-own apply drifted from the dense own-decode path");
    println!("sparse-own bitwise guard: lazy == eager dense own decode");
}

// ---------------------------------------------------------------------------
// Part 5: kernel microbenches + pool wake latency
// ---------------------------------------------------------------------------

/// Bench-local replica of the pre-SIMD per-element quantize encoder
/// (norm per block, one fused `sign | level<<1` push per element) — the
/// "old" arm of the `kernel quantize encode` A/B. Must stay RNG-stream-
/// and byte-identical to `QuantizeP::compress` (asserted by
/// [`assert_kernels_bitwise`]); it only lacks the 4-lane `push4` bursts.
fn quantize_encode_reference(
    q: &QuantizeP,
    x: &[f64],
    rng: &mut Rng,
    w: &mut lead::compress::wire::BitWriter,
    vals: &mut [f64],
) {
    for (xb, vb) in x.chunks(q.block).zip(vals.chunks_mut(q.block)) {
        let norm_f32 = lead::linalg::norm_inf(xb) as f32;
        w.push_f32(norm_f32);
        let norm = norm_f32 as f64;
        if norm <= 0.0 || !norm.is_finite() {
            for out in vb.iter_mut() {
                *out = 0.0;
                w.push(0, 1 + q.bits);
            }
            continue;
        }
        let scale = (1u64 << (q.bits - 1)) as f64;
        let unit = norm / scale;
        let inv = scale / norm;
        for (xi, out) in xb.iter().zip(vb.iter_mut()) {
            let sign = u64::from(xi.is_sign_negative());
            let level = ((xi.abs() * inv) + rng.uniform_f64()).floor() as u64;
            let level = level.min(scale as u64);
            w.push(sign | (level << 1), 1 + q.bits);
            let mag = unit * level as f64;
            *out = if sign == 1 { -mag } else { mag };
        }
    }
}

/// Bench-local replica of the pre-SIMD per-element quantize decoder
/// (separate sign/level reads) — the "old" arm of `kernel quantize
/// decode`.
fn quantize_decode_reference(q: &QuantizeP, payload: &[u8], d: usize, out: &mut Vec<f64>) {
    out.clear();
    let mut r = lead::compress::wire::BitReader::new(payload);
    let scale = (1u64 << (q.bits - 1)) as f64;
    let mut remaining = d;
    while remaining > 0 {
        let blk = remaining.min(q.block);
        let norm = r.read_f32() as f64;
        let unit = if norm > 0.0 && norm.is_finite() { norm / scale } else { 0.0 };
        for _ in 0..blk {
            let sign = r.read(1);
            let level = r.read(q.bits);
            let mag = unit * level as f64;
            out.push(if sign == 1 { -mag } else { mag });
        }
        remaining -= blk;
    }
}

/// Release-mode bitwise guard for every Part 5 A/B: the chunked kernels
/// must equal their scalar references (elementwise exactly; reductions
/// via the pinned-tree emulation), and the burst quantize encoder must
/// produce byte-identical wire (and identical values) to the
/// per-element replica under the same RNG seed. A drift here means the
/// microbenches compare different computations — fail before timing.
fn assert_kernels_bitwise(d: usize) {
    use lead::linalg::simd::reference;
    let mut rng = Rng::new(0xBE7C);
    let mut x = vec![0.0f64; d];
    let mut y = vec![0.0f64; d];
    rng.fill_normal(&mut x, 2.0);
    rng.fill_normal(&mut y, 2.0);

    let (mut ya, mut yb) = (y.clone(), y.clone());
    lead::linalg::axpy(0.37, &x, &mut ya);
    reference::axpy(0.37, &x, &mut yb);
    assert!(ya.iter().zip(&yb).all(|(a, b)| a.to_bits() == b.to_bits()), "axpy drifted");

    let entries: Vec<(u32, f64)> =
        (0..d / 100).map(|_| (rng.below(d) as u32, rng.normal_f64())).collect();
    let (mut sa, mut sb) = (y.clone(), y.clone());
    lead::linalg::scatter_axpy(-0.5, &entries, &mut sa);
    reference::scatter_axpy(-0.5, &entries, &mut sb);
    assert!(sa.iter().zip(&sb).all(|(a, b)| a.to_bits() == b.to_bits()), "scatter_axpy drifted");

    assert_eq!(
        lead::linalg::dot(&x, &y).to_bits(),
        reference::dot_tree(&x, &y).to_bits(),
        "dot drifted from the pinned-tree emulation"
    );

    let q = QuantizeP::paper_default();
    let msg = q.compress_alloc(&x, &mut Rng::new(0x0123));
    let mut w = lead::compress::wire::BitWriter::new();
    let mut vals = vec![0.0f64; d];
    quantize_encode_reference(&q, &x, &mut Rng::new(0x0123), &mut w, &mut vals);
    assert_eq!(msg.payload, w.bytes, "burst quantize encoder changed the wire bytes");
    assert!(
        msg.values.iter().zip(&vals).all(|(a, b)| a.to_bits() == b.to_bits()),
        "burst quantize encoder changed the dequantized values"
    );
    let (mut da, mut db) = (Vec::new(), Vec::new());
    lead::compress::quantize::decode(&q, &msg.payload, d, &mut da);
    quantize_decode_reference(&q, &msg.payload, d, &mut db);
    assert!(da.iter().zip(&db).all(|(a, b)| a.to_bits() == b.to_bits()), "decode drifted");
    println!("kernel bitwise guard: chunked/burst kernels == scalar references (d={d})");
}

/// Time `reps` repetitions of `f`, returning seconds per repetition.
fn time_reps(reps: usize, mut f: impl FnMut()) -> f64 {
    let t = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t.elapsed().as_secs_f64() / reps as f64
}

fn kernel_ab(name: &str, d: usize, reps: usize, old_s: f64, new_s: f64) -> AbResult {
    let r = AbResult {
        name: name.to_string(),
        n: 1,
        d,
        threads: 1,
        rounds: reps,
        old_rps: 1.0 / old_s,
        new_rps: 1.0 / new_s,
        old_phases: PhaseTimes::default(),
        new_phases: PhaseTimes::default(),
    };
    println!(
        "kernel A/B {name:<34} d={d:<7}  old {:10.1} passes/s  new {:10.1} passes/s  speedup {:5.2}x",
        r.old_rps,
        r.new_rps,
        r.speedup()
    );
    r
}

/// Per-kernel microbenches: one "round" = one full pass over a d-vector
/// (or one encode/decode of it). Old arms are the scalar references;
/// see [`assert_kernels_bitwise`] for why the comparison is honest.
fn bench_kernels(d: usize, reps: usize) -> Vec<AbResult> {
    use lead::linalg::simd::reference;
    use std::hint::black_box;
    assert_kernels_bitwise(d);
    let mut rng = Rng::new(0x1234);
    let mut x = vec![0.0f64; d];
    let mut y = vec![0.0f64; d];
    rng.fill_normal(&mut x, 2.0);
    rng.fill_normal(&mut y, 2.0);
    let mut results = Vec::new();

    let warm = (reps / 10).max(1);
    let _ = time_reps(warm, || lead::linalg::axpy(1e-9, black_box(&x), black_box(&mut y)));
    let old = time_reps(reps, || reference::axpy(1e-9, black_box(&x), black_box(&mut y)));
    let new = time_reps(reps, || lead::linalg::axpy(1e-9, black_box(&x), black_box(&mut y)));
    results.push(kernel_ab("kernel axpy", d, reps, old, new));

    let entries: Vec<(u32, f64)> =
        (0..(d / 100).max(1)).map(|_| (rng.below(d) as u32, rng.normal_f64())).collect();
    let sreps = reps * 20; // O(d/100) work per pass — more reps for signal
    let _ = time_reps(warm, || lead::linalg::scatter_axpy(1e-9, black_box(&entries), black_box(&mut y)));
    let old = time_reps(sreps, || reference::scatter_axpy(1e-9, black_box(&entries), black_box(&mut y)));
    let new = time_reps(sreps, || lead::linalg::scatter_axpy(1e-9, black_box(&entries), black_box(&mut y)));
    results.push(kernel_ab("kernel scatter_axpy", d, sreps, old, new));

    let _ = time_reps(warm, || {
        black_box(lead::linalg::dot(black_box(&x), black_box(&y)));
    });
    let old = time_reps(reps, || {
        black_box(reference::dot_seq(black_box(&x), black_box(&y)));
    });
    let new = time_reps(reps, || {
        black_box(lead::linalg::dot(black_box(&x), black_box(&y)));
    });
    results.push(kernel_ab("kernel dot", d, reps, old, new));

    let q = QuantizeP::paper_default();
    let qreps = (reps / 4).max(1);
    let mut msg = CompressedMsg::with_dim(d);
    let mut w = lead::compress::wire::BitWriter::new();
    let mut vals = vec![0.0f64; d];
    let _ = time_reps(warm, || q.compress(black_box(&x), &mut Rng::new(0xAB), &mut msg));
    let old = time_reps(qreps, || {
        w.clear();
        quantize_encode_reference(&q, black_box(&x), &mut Rng::new(0xAB), &mut w, &mut vals);
    });
    let new = time_reps(qreps, || q.compress(black_box(&x), &mut Rng::new(0xAB), &mut msg));
    results.push(kernel_ab("kernel quantize encode", d, qreps, old, new));

    let mut dec = Vec::with_capacity(d);
    let _ = time_reps(warm, || lead::compress::quantize::decode(&q, black_box(&msg.payload), d, &mut dec));
    let old = time_reps(qreps, || quantize_decode_reference(&q, black_box(&msg.payload), d, &mut dec));
    let new = time_reps(qreps, || lead::compress::quantize::decode(&q, black_box(&msg.payload), d, &mut dec));
    results.push(kernel_ab("kernel quantize decode", d, qreps, old, new));

    results
}

/// Pool wake latency: empty-dispatch round trips (wake + join, no work)
/// on the legacy broadcast pool vs the per-worker wake path. This is the
/// §Wake path A/B — per-dispatch latency, so `rounds_per_s` here is
/// dispatches/s.
fn bench_pool_wake(threads: usize, dispatches: usize) -> AbResult {
    use lead::pool::WorkerPool;
    let time_pool = |pool: &WorkerPool, reps: usize| {
        time_reps(reps, || {
            pool.run(threads, &|w| {
                std::hint::black_box(w);
            });
        })
    };
    let old_pool = WorkerPool::new_broadcast(threads);
    let new_pool = WorkerPool::new(threads);
    let warm = (dispatches / 10).max(1);
    let _ = time_pool(&old_pool, warm);
    let _ = time_pool(&new_pool, warm);
    let old_s = time_pool(&old_pool, dispatches);
    let new_s = time_pool(&new_pool, dispatches);
    let r = AbResult {
        name: "pool wake".to_string(),
        n: threads,
        d: 0,
        threads,
        rounds: dispatches,
        old_rps: 1.0 / old_s,
        new_rps: 1.0 / new_s,
        old_phases: PhaseTimes::default(),
        new_phases: PhaseTimes::default(),
    };
    println!(
        "pool wake  threads={threads} {dispatches} empty dispatches:  broadcast {:7.2} µs/dispatch  per-worker {:7.2} µs/dispatch  speedup {:5.2}x",
        old_s * 1e6,
        new_s * 1e6,
        r.speedup()
    );
    r
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        // CI smoke: one short config proving the A/B harness, the phase
        // breakdown, the JSON emission, and both bitwise guards
        // (sparse-own + simnet timing-only) all work end to end.
        assert_sparse_own_bitwise();
        assert_simnet_timing_only();
        assert_transport_bitwise();
        let r = bench_engine_ab("smoke quad d=2e3 q∞-2bit", 16, 2_000, 10, 4, &|| {
            Box::new(QuantizeP::paper_default())
        });
        // Event-queue overhead on a lossy model: exercises the heap +
        // retransmit path under the bench-diff gate.
        let s = bench_simnet_ab(
            "smoke simnet straggler+drop d=2e3",
            16,
            2_000,
            10,
            4,
            "straggler:1e-4:1e9:0.25:10:drop=0.01",
        );
        // Transport encode+queue+decode overhead under the bench-diff gate.
        let t = bench_transport_ab("smoke transport channel d=2e3", 16, 2_000, 10, 4, &|| {
            Box::new(TopK::new(20))
        });
        let mut results = vec![r, s, t];
        // Part 5 smoke: tiny kernel + wake configs so CI proves the
        // bitwise guards and the JSON plumbing for the `kernel …` /
        // `pool wake` names without a long run.
        results.extend(bench_kernels(10_000, 200));
        results.push(bench_pool_wake(4, 1_000));
        write_json(&results, true);
        return;
    }

    bench_mix_phase();
    let mut results = Vec::new();
    // Headline acceptance config: small-d, spawn/alloc overhead dominates.
    results.push(bench_engine_ab("quad n=32 d=1e4 q∞-2bit (headline)", 32, 10_000, 40, 8, &|| {
        Box::new(QuantizeP::paper_default())
    }));
    results.push(bench_engine_ab("quad n=32 d=1e4 top-k k=100", 32, 10_000, 40, 8, &|| {
        Box::new(TopK::new(100))
    }));
    // Large-d sparse regime (the paper's many-rounds/large-model axis).
    results.push(bench_engine_ab("quad n=32 d=1e5 top-k k=1000", 32, 100_000, 15, 8, &|| {
        Box::new(TopK::new(1000))
    }));
    // Dense-vs-sparse representation on the new scheduler (old Part 2).
    {
        let (dense_rps, _) = timed_run(
            32,
            100_000,
            15,
            8,
            Scheduler::Persistent,
            Box::new(StripSparse(TopK::new(1000))),
        );
        let (sparse_rps, _) =
            timed_run(32, 100_000, 15, 8, Scheduler::Persistent, Box::new(TopK::new(1000)));
        println!(
            "engine     d=1e5 dense {dense_rps:8.2} r/s vs sparse {sparse_rps:8.2} r/s  ({:4.2}x from the sparse view)",
            sparse_rps / dense_rps
        );
    }
    // Part 3: sparse-own own-decode A/B — eager dense own decode every
    // round (pre-sparse-own behavior) vs the OwnView sparse apply path.
    // Both runs use the persistent scheduler and sparse mixing, so the
    // delta is exactly the per-round O(n·d) own-decode pass the sparse
    // contract eliminates — expect a modest constant-factor produce/apply
    // win (the kernels still sweep all d coordinates), not ~d/k.
    {
        assert_sparse_own_bitwise();
        let (n, d, k, rounds, threads) = (32, 100_000, 1000, 15, 8);
        let _ = timed_run(n, d, rounds.min(5), threads, Scheduler::Persistent, Box::new(TopK::new(k)));
        let (eager_rps, eager_phases) = timed_run(
            n,
            d,
            rounds,
            threads,
            Scheduler::Persistent,
            Box::new(EagerDense(TopK::new(k))),
        );
        let (lazy_rps, lazy_phases) =
            timed_run(n, d, rounds, threads, Scheduler::Persistent, Box::new(TopK::new(k)));
        let r = AbResult {
            name: "sparse-own d=1e5 top-k k=1000".to_string(),
            n,
            d,
            threads,
            rounds,
            old_rps: eager_rps,
            new_rps: lazy_rps,
            old_phases: eager_phases,
            new_phases: lazy_phases,
        };
        println!(
            "sparse-own A/B d={d} k={k}: eager dense own {eager_rps:8.2} r/s  sparse own {lazy_rps:8.2} r/s  speedup {:5.2}x",
            r.speedup()
        );
        println!(
            "           eager phases (s): produce {:.3}  mix {:.3}  apply {:.3}   |   sparse phases (s): produce {:.3}  mix {:.3}  apply {:.3}",
            r.old_phases.produce, r.old_phases.mix, r.old_phases.apply,
            r.new_phases.produce, r.new_phases.mix, r.new_phases.apply
        );
        results.push(r);
    }
    // Part 4: discrete-event network simulation overhead vs the legacy
    // uniform formula — the degenerate homogeneous model isolates pure
    // event-queue cost (n·deg heap ops/round), the lossy straggler model
    // adds retransmit events.
    assert_simnet_timing_only();
    results.push(bench_simnet_ab(
        "simnet uniform overhead n=32 d=1e4",
        32,
        10_000,
        40,
        8,
        "uniform:1e-4:1e9",
    ));
    results.push(bench_simnet_ab(
        "simnet straggler+drop n=32 d=1e4",
        32,
        10_000,
        40,
        8,
        "straggler:1e-4:1e9:0.25:10:drop=0.01",
    ));
    // Part 6: transport serialization + queueing overhead vs the
    // shared-memory mix, on both codec families.
    assert_transport_bitwise();
    results.push(bench_transport_ab(
        "transport channel n=32 d=1e4 top-k",
        32,
        10_000,
        40,
        8,
        &|| Box::new(TopK::new(100)),
    ));
    results.push(bench_transport_ab(
        "transport channel n=32 d=1e4 q∞-2bit",
        32,
        10_000,
        40,
        8,
        &|| Box::new(QuantizeP::paper_default()),
    ));
    // Part 5: kernel microbenches + pool wake latency (module docs).
    results.extend(bench_kernels(100_000, 2_000));
    results.push(bench_pool_wake(8, 10_000));
    write_json(&results, false);

    for threads in [1usize, 4, 8] {
        bench(
            "linreg d=200 (fig1 shape)",
            std::sync::Arc::new(LinReg::synthetic(8, 200, 0.1, 1)),
            threads,
            400,
        );
    }
    for threads in [1usize, 4, 8] {
        bench(
            "logreg d=7850 full-batch (fig2 shape)",
            std::sync::Arc::new(LogReg::synthetic(8, 4000, 784, 10, 1e-4, DataSplit::Heterogeneous, 1, false)),
            threads,
            60,
        );
    }
}
