//! Microbench: end-to-end coordinator rounds/sec (§Perf, L3).
//! LEAD + 2-bit q∞ on the paper's logreg shape (d = 7850), native oracle,
//! 1 vs 4 worker threads; plus the linreg Fig. 1 shape.
use lead::algorithms::lead::Lead;
use lead::compress::quantize::QuantizeP;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::problems::{linreg::LinReg, logreg::LogReg, DataSplit};
use lead::topology::{MixingRule, Topology};

fn bench(name: &str, problem: Box<dyn lead::problems::Problem>, threads: usize, rounds: usize) {
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig { threads, record_every: usize::MAX / 2, ..Default::default() },
        mix,
        problem,
    );
    let t = std::time::Instant::now();
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::paper_default())),
        rounds,
    );
    let secs = t.elapsed().as_secs_f64();
    println!(
        "{name:<40} threads={threads}  {:8.1} rounds/s  ({rounds} rounds in {secs:.2}s, dist {:.1e})",
        rounds as f64 / secs,
        rec.last().dist_opt
    );
}

fn main() {
    for threads in [1usize, 4, 8] {
        bench(
            "linreg d=200 (fig1 shape)",
            Box::new(LinReg::synthetic(8, 200, 0.1, 1)),
            threads,
            400,
        );
    }
    for threads in [1usize, 4, 8] {
        bench(
            "logreg d=7850 full-batch (fig2 shape)",
            Box::new(LogReg::synthetic(8, 4000, 784, 10, 1e-4, DataSplit::Heterogeneous, 1, false)),
            threads,
            60,
        );
    }
}
