//! Microbench: compression codec throughput (§Perf, L3 hot path).
//! Reports median MB/s for compress (quantize+pack) and wire decode.
use lead::compress::quantize::{decode, PNorm, QuantizeP};
use lead::compress::{CompressedMsg, Compressor};
use lead::rng::Rng;

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() {
    let d = 1 << 20; // 1M elements = 8 MB of f64 state
    let mut rng = Rng::new(1);
    let mut x = vec![0.0f64; d];
    rng.fill_normal(&mut x, 1.0);
    for bits in [2u32, 4, 8] {
        let q = QuantizeP::new(bits, PNorm::Inf, 512);
        let mut msg = CompressedMsg::with_dim(d);
        // warmup
        q.compress(&x, &mut rng, &mut msg);
        let reps = 20;
        let mut enc_times = Vec::new();
        for _ in 0..reps {
            let t = std::time::Instant::now();
            q.compress(&x, &mut rng, &mut msg);
            enc_times.push(t.elapsed().as_secs_f64());
        }
        let mut dec = Vec::new();
        let mut dec_times = Vec::new();
        for _ in 0..reps {
            let t = std::time::Instant::now();
            decode(&q, &msg.payload, d, &mut dec);
            dec_times.push(t.elapsed().as_secs_f64());
        }
        let mb = (d * 4) as f64 / 1e6; // payload-side MB (f32 equivalent)
        println!(
            "q∞-{bits}bit/512 d=1M: compress {:8.1} MB/s   decode {:8.1} MB/s   ({} wire bits)",
            mb / median(enc_times.clone()),
            mb / median(dec_times.clone()),
            msg.wire_bits
        );
    }
}
