//! Bench: regenerate Fig. 5 — p-norm b-bit quantization error.
fn main() {
    let t = std::time::Instant::now();
    let rows = lead::experiments::fig5(Some(std::path::Path::new("results"))).expect("fig5");
    // Shape assertion: inf-norm strictly dominates p=1 at every bit width.
    for bits in [2u32, 4, 6, 8] {
        let p1 = rows.iter().find(|(l, b, _)| l == "p=1" && *b == bits).unwrap().2;
        let pinf = rows.iter().find(|(l, b, _)| l == "inf" && *b == bits).unwrap().2;
        assert!(pinf < p1, "∞-norm must beat p=1 at {bits} bits");
    }
    println!("fig5 total: {:.1}s", t.elapsed().as_secs_f64());
}
