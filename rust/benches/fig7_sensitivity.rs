//! Bench: regenerate Fig. 7 — LEAD (α, γ) sensitivity grid.
fn main() {
    let t = std::time::Instant::now();
    let rows = lead::experiments::fig7(Some(std::path::Path::new("results")), 1200).expect("fig7");
    let ok = rows.iter().filter(|r| r.2.is_some()).count();
    println!("\nconverged cells: {ok}/{}", rows.len());
    println!("fig7 total: {:.1}s", t.elapsed().as_secs_f64());
}
