//! Persistent worker pool and the engine's parallel-dispatch primitives.
//!
//! # Why a pool
//!
//! The coordinator runs three to five parallel phases *per round*, for
//! thousands of rounds. `std::thread::scope` re-spawns OS threads on every
//! phase, which costs tens of microseconds per thread — at n = 32 agents
//! and d ≈ 10⁴ the spawns dominate the actual FLOPs. [`WorkerPool`] spawns
//! its workers once; each phase dispatch is then two condvar hops (wake +
//! join) and zero heap allocations, which is what makes the engine's
//! steady-state round loop allocation-free (see
//! `coordinator::engine` §Perf).
//!
//! # Wake path
//!
//! A dispatch must wake `workers − 1` sleeping threads. The original
//! design parked every worker on ONE `Mutex`+`Condvar` pair and
//! `notify_all`'d it: every spawned worker — including those above the
//! dispatch `bound`, which only idle-ack — woke and then serialized on
//! the single slot mutex to re-read the epoch (a thundering herd; wake
//! latency grows with pool size regardless of how many workers the
//! dispatch actually needs). The default wake path now gives every
//! spawned worker its own [`WakeCell`] (`Mutex<JobSlot>` + `Condvar`):
//! the dispatcher writes the job into exactly the cells of the workers
//! that will run it and `notify_one`s each, so no wake lock is ever
//! contended by more than two threads and workers above the bound stay
//! asleep entirely. Completion still joins on the one shared ack counter
//! (the dispatcher is its only waiter). The broadcast path survives as
//! [`WorkerPool::new_broadcast`] for the `benches/hotpath.rs`
//! "pool wake" A/B; both modes implement the identical scheduling
//! contract below, so the wake mechanism is a pure performance knob and
//! can never affect a trajectory.
//!
//! # Scheduling contract
//!
//! All dispatch primitives ([`par_chunks`], [`par_agents`],
//! [`par_agents2`]) partition `n` items into `ceil(n / t)`-sized
//! contiguous chunks, one chunk per worker index — the same chunking the
//! old scoped-spawn helpers used. The per-item closure must be
//! independent across items (no cross-item data flow, no shared RNG), so
//! the assignment of items to workers can never affect results: thread
//! count and backend are pure performance knobs, pinned bitwise by the
//! `parallel_equals_sequential*` tests.
//!
//! A dispatch blocks the caller until every worker has finished its chunk
//! (barrier semantics). Worker panics are captured and re-raised on the
//! caller. Nested dispatches (a job that itself dispatches) degrade to
//! inline execution rather than deadlocking.
//!
//! # Run-level dispatch and the nested-budget rule
//!
//! [`par_dynamic`] is the *outer* (run-level) dispatch mode used by the
//! scenario driver (`crate::scenarios`): `count` coarse, independent,
//! variable-duration tasks — whole engine runs — are handed out by an
//! atomic work counter instead of static chunking, so a worker that
//! finishes a fast run immediately picks up the next one. Item-to-worker
//! assignment is therefore *not* deterministic, which is only sound for
//! tasks that are fully independent and write results through disjoint
//! per-index slots; each task must derive all randomness from its own
//! seed (every engine run does), so the *results* remain bitwise
//! deterministic even though the schedule is not.
//!
//! The thread budget is shared between the two levels by construction: an
//! outer task occupies exactly one pool worker, and any inner dispatch it
//! issues on the same pool hits the nested-dispatch guard and runs inline
//! (an inner budget of 1). Callers that want *inner* parallelism for a
//! run instead execute it on the dispatching thread with the full pool
//! budget — never both at once, so `threads` total units of parallelism
//! are never exceeded.
//!
//! # Backends
//!
//! [`Exec`] is a copyable handle selecting the backend per call site:
//!
//! * `Exec::seq()` — inline, single-threaded;
//! * `Exec::spawn(t)` — scoped `std::thread` spawn per dispatch (the
//!   pre-pool behavior, kept as the A/B baseline for `benches/hotpath.rs`
//!   and [`crate::coordinator::engine::Scheduler::SpawnPerPhase`]);
//! * `Exec::pool(&pool)` — the persistent pool.
//!
//! # Observability
//!
//! An [`Exec`] can carry a trace [`Recorder`](crate::trace::Recorder)
//! ([`Exec::with_trace`], `crate::trace` §Observability contract): each
//! multi-worker dispatch is then timed as a `pool_dispatch` span on the
//! coordinator lane, and every woken worker records its wake-to-start
//! latency (a `pool_wake` span in its own lane plus a log₂-ns histogram
//! bucket). The wrapper is a stack closure over `Copy` captures and the
//! recorder's rings are pre-allocated, so tracing preserves both the
//! zero-alloc dispatch path and — being observation-only — every
//! trajectory bit (`rust/tests/trace.rs`,
//! `rust/tests/alloc_steady_state.rs`).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::linalg::Mat;

/// Maximum number of state matrices a single [`par_agents`] /
/// [`par_agents2`] dispatch can carry. Bounded so per-agent row bundles
/// live on the stack (no per-round heap allocation); the largest in-tree
/// user (LEAD) needs 4.
pub const MAX_MATS: usize = 8;

/// Raw-pointer wrapper that lets dispatch closures hand each worker the
/// disjoint per-item `&mut` it owns. Safety rests on the chunking
/// contract: no two workers ever receive the same index.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);
// SAFETY: SendPtr wraps pointers into buffers whose `&mut` borrow the
// dispatching caller holds across the whole barrier (dispatches block
// until every worker finishes), so the pointee outlives every use; each
// use site derives disjoint per-index references under the chunking /
// unique-claim contracts documented on the dispatch helpers below.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared by reference across workers; see the Send impl directly
// above — lifetime and disjoint-index access are the same argument.
unsafe impl<T> Sync for SendPtr<T> {}

/// Type-erased job pointer parked in the pool's dispatch slot. The
/// lifetime erasure is sound because [`WorkerPool::run`] does not return
/// until every worker has acknowledged the dispatch.
#[derive(Clone, Copy)]
struct RawJob(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls are fine) and the pointer
// is only dereferenced by workers between dispatch and ack, while
// [`WorkerPool::run`] provably keeps the closure alive (its JoinGuard
// blocks until every spawned worker has acknowledged the epoch).
unsafe impl Send for RawJob {}

struct JobSlot {
    /// Dispatch generation; workers run one job per increment. In
    /// per-worker mode each [`WakeCell`] counts its own generations.
    epoch: u64,
    /// Worker indices `< bound` execute the job; the rest just ack
    /// (broadcast mode) or are never woken (per-worker mode).
    bound: usize,
    job: Option<RawJob>,
    shutdown: bool,
}

impl JobSlot {
    fn idle() -> Self {
        JobSlot { epoch: 0, bound: 0, job: None, shutdown: false }
    }
}

/// One spawned worker's private wake channel (see module docs, §Wake
/// path): worker `w` sleeps on `cells[w − 1]` and nothing else, so a
/// dispatch wakes exactly the workers it needs, one uncontended
/// `notify_one` each.
struct WakeCell {
    msg: Mutex<JobSlot>,
    wake: Condvar,
}

/// Which wake path a pool uses. Pure performance knob — the scheduling
/// contract is identical in both modes (§Wake path).
#[derive(Clone, Copy, PartialEq, Eq)]
enum WakeMode {
    /// Per-worker wake cells, `notify_one` each (default).
    PerWorker,
    /// Single shared slot + `notify_all` (legacy; bench A/B arm).
    Broadcast,
}

struct DoneState {
    acked: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct Shared {
    mode: WakeMode,
    /// Broadcast-mode dispatch slot (also carries shutdown in that mode).
    slot: Mutex<JobSlot>,
    start: Condvar,
    /// Per-worker wake channels, one per spawned worker (index `w − 1`).
    cells: Vec<WakeCell>,
    done: Mutex<DoneState>,
    finish: Condvar,
}

/// Long-lived worker threads with barrier-synchronized phase dispatch.
///
/// The pool represents `threads` units of parallelism: the caller of
/// [`WorkerPool::run`] participates as worker 0 and `threads − 1` spawned
/// threads serve indices `1..threads`. Workers sleep on their wake
/// channel between dispatches; a dispatch publishes a borrowed job
/// closure, wakes the workers it needs (§Wake path), runs the caller's
/// own share, and blocks until every woken worker acknowledges — so the
/// borrowed closure provably outlives every use, and per-dispatch cost
/// is two condvar hops with no allocation.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
    /// Guards against nested dispatch (a job dispatching on the same
    /// pool): the inner call runs inline instead of deadlocking.
    busy: AtomicBool,
}

impl WorkerPool {
    /// Create a pool representing `threads` total units of parallelism
    /// (spawns `threads − 1` OS threads; the dispatching thread is
    /// worker 0). Uses the per-worker wake path (§Wake path).
    pub fn new(threads: usize) -> Self {
        Self::with_mode(threads, WakeMode::PerWorker)
    }

    /// [`WorkerPool::new`] with the legacy one-condvar-wakes-all dispatch.
    /// Kept as the "old" arm of the `benches/hotpath.rs` "pool wake"
    /// microbench; identical scheduling contract, slower wakes.
    pub fn new_broadcast(threads: usize) -> Self {
        Self::with_mode(threads, WakeMode::Broadcast)
    }

    fn with_mode(threads: usize, mode: WakeMode) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            mode,
            slot: Mutex::new(JobSlot::idle()),
            start: Condvar::new(),
            cells: (1..threads)
                .map(|_| WakeCell { msg: Mutex::new(JobSlot::idle()), wake: Condvar::new() })
                .collect(),
            done: Mutex::new(DoneState { acked: 0, panic: None }),
            finish: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lead-pool-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("WorkerPool: failed to spawn worker")
            })
            .collect();
        WorkerPool { shared, handles, threads, busy: AtomicBool::new(false) }
    }

    /// Total units of parallelism (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `job(w)` for every worker index `w in 0..workers`, distributed
    /// over the pool, and return once all have finished. The caller
    /// executes `job(0)`; spawned workers whose index is `>= workers`
    /// idle-ack. Panics inside `job` propagate to the caller after all
    /// workers have stopped touching it.
    pub fn run(&self, workers: usize, job: &(dyn Fn(usize) + Sync)) {
        let workers = workers.clamp(1, self.threads);
        if workers == 1 || self.handles.is_empty() {
            for w in 0..workers {
                job(w);
            }
            return;
        }
        // ORDERING: Acquire pairs with the Release store in
        // JoinGuard::drop — a dispatcher that wins the flag observes the
        // previous dispatch's slot cleanup. Mutual exclusion itself needs
        // only the swap's atomicity; the job handoff to workers is
        // synchronized by the slot mutex, not by this flag.
        if self.busy.swap(true, Ordering::Acquire) {
            // Nested dispatch from inside a running job: run inline.
            for w in 0..workers {
                job(w);
            }
            return;
        }
        let raw = job as *const (dyn Fn(usize) + Sync);
        // SAFETY: lifetime erasure of the borrowed job closure — sound
        // because the JoinGuard below blocks until every woken worker
        // acknowledged this dispatch, so no worker can hold the pointer
        // past the borrow; every cell/slot entry is cleared again
        // (job = None) before the guard releases.
        let raw = RawJob(unsafe { std::mem::transmute(raw) });
        let expect = match self.shared.mode {
            WakeMode::PerWorker => {
                // Wake exactly the workers that will run — indices
                // 1..workers, i.e. cells[..workers − 1] — one uncontended
                // notify_one each; the rest stay asleep (§Wake path).
                for cell in &self.shared.cells[..workers - 1] {
                    let mut msg = cell.msg.lock().unwrap();
                    msg.epoch += 1;
                    msg.bound = workers;
                    msg.job = Some(raw);
                    drop(msg);
                    cell.wake.notify_one();
                }
                workers - 1
            }
            WakeMode::Broadcast => {
                {
                    let mut slot = self.shared.slot.lock().unwrap();
                    slot.epoch += 1;
                    slot.bound = workers;
                    slot.job = Some(raw);
                }
                self.shared.start.notify_all();
                self.handles.len()
            }
        };
        // Even if the caller's own share panics, the guard still waits for
        // the woken workers before unwinding past the job's borrow.
        let guard = JoinGuard { pool: self, expect };
        job(0);
        drop(guard);
    }
}

struct JoinGuard<'a> {
    pool: &'a WorkerPool,
    /// How many worker acks this dispatch produces: the woken workers in
    /// per-worker mode (`workers − 1`), every spawned worker in broadcast
    /// mode (idle workers ack too).
    expect: usize,
}

impl Drop for JoinGuard<'_> {
    fn drop(&mut self) {
        let shared = &self.pool.shared;
        let panic = {
            let mut done = shared.done.lock().unwrap();
            while done.acked < self.expect {
                done = shared.finish.wait(done).unwrap();
            }
            done.acked = 0;
            done.panic.take()
        };
        match shared.mode {
            WakeMode::PerWorker => {
                for cell in &shared.cells[..self.expect] {
                    cell.msg.lock().unwrap().job = None;
                }
            }
            WakeMode::Broadcast => shared.slot.lock().unwrap().job = None,
        }
        // ORDERING: Release publishes the job cleanup above to the next
        // dispatcher's busy.swap(Acquire).
        self.pool.busy.store(false, Ordering::Release);
        if let Some(p) = panic {
            if !std::thread::panicking() {
                resume_unwind(p);
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        match self.shared.mode {
            WakeMode::PerWorker => {
                for cell in &self.shared.cells {
                    let mut msg = cell.msg.lock().unwrap();
                    msg.shutdown = true;
                    drop(msg);
                    cell.wake.notify_one();
                }
            }
            WakeMode::Broadcast => {
                self.shared.slot.lock().unwrap().shutdown = true;
                self.shared.start.notify_all();
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, w: usize) {
    let mut seen = 0u64;
    loop {
        let (job, bound) = match shared.mode {
            WakeMode::PerWorker => {
                let cell = &shared.cells[w - 1];
                let mut msg = cell.msg.lock().unwrap();
                loop {
                    if msg.shutdown {
                        return;
                    }
                    if msg.epoch != seen {
                        break;
                    }
                    msg = cell.wake.wait(msg).unwrap();
                }
                seen = msg.epoch;
                (msg.job.expect("dispatch without job"), msg.bound)
            }
            WakeMode::Broadcast => {
                let mut slot = shared.slot.lock().unwrap();
                loop {
                    if slot.shutdown {
                        return;
                    }
                    if slot.epoch != seen {
                        break;
                    }
                    slot = shared.start.wait(slot).unwrap();
                }
                seen = slot.epoch;
                (slot.job.expect("dispatch without job"), slot.bound)
            }
        };
        if w < bound {
            // SAFETY: the dispatcher blocks until this worker acks below,
            // so the borrowed closure is still alive.
            let f = unsafe { &*job.0 };
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(w))) {
                let mut done = shared.done.lock().unwrap();
                done.panic.get_or_insert(p);
            }
        }
        let mut done = shared.done.lock().unwrap();
        done.acked += 1;
        drop(done);
        shared.finish.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Exec: per-call-site backend handle
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Backend<'a> {
    Seq,
    Spawn,
    Pool(&'a WorkerPool),
}

/// Copyable execution handle passed down to every parallel phase: which
/// backend to dispatch on and how many units of parallelism to use.
/// Trajectories never depend on it (see module docs). A trace
/// [`Recorder`](crate::trace::Recorder) may ride along
/// ([`Exec::with_trace`]); it observes dispatches but never schedules
/// them, so it cannot affect trajectories either (pinned by
/// `rust/tests/trace.rs`).
#[derive(Clone, Copy)]
pub struct Exec<'a> {
    backend: Backend<'a>,
    threads: usize,
    trace: Option<&'a crate::trace::Recorder>,
}

impl<'a> Exec<'a> {
    /// Inline execution (no parallelism).
    pub fn seq() -> Exec<'static> {
        Exec { backend: Backend::Seq, threads: 1, trace: None }
    }

    /// Scoped-spawn backend: every dispatch spawns `threads` OS threads
    /// (the pre-pool behavior; kept for A/B benchmarking).
    pub fn spawn(threads: usize) -> Exec<'static> {
        Exec { backend: Backend::Spawn, threads: threads.max(1), trace: None }
    }

    /// Persistent-pool backend.
    pub fn pool(pool: &'a WorkerPool) -> Exec<'a> {
        Exec { backend: Backend::Pool(pool), threads: pool.threads(), trace: None }
    }

    /// Units of parallelism this handle will use.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Same backend, gated to at most `threads` units (phase-size gating;
    /// never below 1, never above the backend's configured capacity).
    pub fn with_threads(self, threads: usize) -> Exec<'a> {
        let cap = match self.backend {
            Backend::Seq => 1,
            Backend::Spawn => self.threads,
            Backend::Pool(p) => p.threads(),
        };
        Exec {
            backend: self.backend,
            threads: threads.clamp(1, cap.max(1)),
            trace: self.trace,
        }
    }

    /// Same backend and budget, with trace recording attached: every
    /// multi-worker dispatch records a `pool_dispatch` span and per-worker
    /// wake-to-start latencies, and downstream consumers (the transport
    /// receive phase) read the recorder back via [`Exec::trace`].
    pub fn with_trace<'b>(self, rec: &'b crate::trace::Recorder) -> Exec<'b>
    where
        'a: 'b,
    {
        Exec { backend: self.backend, threads: self.threads, trace: Some(rec) }
    }

    /// The attached trace recorder, if any.
    pub fn trace(&self) -> Option<&'a crate::trace::Recorder> {
        self.trace
    }

    /// Dispatch primitive: run `job(w)` for `w in 0..workers` across the
    /// backend and return when all are done. With a recorder attached
    /// ([`Exec::with_trace`]), multi-worker dispatches are wrapped in a
    /// stack-allocated closure that tags each worker's trace lane and
    /// records its wake latency — no heap allocation, so the zero-alloc
    /// dispatch contract holds with tracing on
    /// (`rust/tests/alloc_steady_state.rs`).
    pub fn run_workers(&self, workers: usize, job: &(dyn Fn(usize) + Sync)) {
        let workers = workers.clamp(1, self.threads);
        match self.trace {
            Some(rec) if workers > 1 => {
                let t0 = crate::trace::clock::now();
                let wrapped = move |w: usize| {
                    if w != 0 {
                        crate::trace::set_lane(w);
                        rec.wake(t0, w);
                    }
                    job(w)
                };
                self.dispatch(workers, &wrapped);
                rec.dispatch_span(t0, workers as u64);
            }
            _ => self.dispatch(workers, job),
        }
    }

    fn dispatch(&self, workers: usize, job: &(dyn Fn(usize) + Sync)) {
        match self.backend {
            _ if workers == 1 => job(0),
            Backend::Seq => job(0),
            Backend::Spawn => {
                std::thread::scope(|s| {
                    for w in 0..workers {
                        let job = &job;
                        s.spawn(move || job(w));
                    }
                });
            }
            Backend::Pool(p) => p.run(workers, job),
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch helpers (the chunking contract lives here)
// ---------------------------------------------------------------------------

/// Run `f(i, &mut items[i])` for every item, chunked contiguously across
/// the backend. `f` must be independent per item for the schedule to be
/// trajectory-invariant. Allocation-free for any backend.
pub fn par_chunks<T, F>(exec: Exec<'_>, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let t = exec.threads().min(n).max(1);
    if t == 1 {
        for (i, it) in items.iter_mut().enumerate() {
            f(i, it);
        }
        return;
    }
    let chunk = n.div_ceil(t);
    let base = SendPtr(items.as_mut_ptr());
    exec.run_workers(t, &|w| {
        let start = w * chunk;
        let end = (start + chunk).min(n);
        for i in start..end {
            // SAFETY: workers cover disjoint contiguous index ranges.
            f(i, unsafe { &mut *base.0.add(i) });
        }
    });
}

/// Run-level dispatch: execute `f(i)` for every `i in 0..count` across
/// the backend with *dynamic* assignment — workers pull the next index
/// from a shared atomic counter, so long and short tasks pack tightly
/// (see the module docs, "Run-level dispatch and the nested-budget
/// rule"). `f` must be independent across indices; each index is claimed
/// by exactly one worker. Inner dispatches issued from inside `f` on the
/// same pool degrade to inline execution (nested-dispatch guard), which
/// is what keeps the total thread budget bounded.
pub fn par_dynamic<F>(exec: Exec<'_>, count: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let t = exec.threads().min(count).max(1);
    if t == 1 {
        for i in 0..count {
            f(i);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    exec.run_workers(t, &|_w| loop {
        // ORDERING: pure work counter — each index is claimed exactly
        // once by the fetch_add's atomicity alone; the data tasks write
        // is published to the caller by the dispatch barrier, not by
        // this counter, so Relaxed suffices.
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= count {
            break;
        }
        f(i);
    });
}

/// Collect `(base pointer, cols)` for each state mat onto the stack.
fn mat_bases(mats: &mut [&mut Mat], n: usize) -> [(SendPtr<f64>, usize); MAX_MATS] {
    assert!(mats.len() <= MAX_MATS, "par_agents: too many state mats ({} > {MAX_MATS})", mats.len());
    // Hard assert (once per dispatch): a row-count mismatch would turn the
    // raw-pointer row slicing below into out-of-bounds access in release
    // builds, not just wrong results.
    assert!(mats.iter().all(|mm| mm.rows == n), "par_agents: agent-count mismatch");
    let mut bases = [(SendPtr(std::ptr::null_mut::<f64>()), 0usize); MAX_MATS];
    for (slot, mm) in bases.iter_mut().zip(mats.iter_mut()) {
        *slot = (SendPtr(mm.data.as_mut_ptr()), mm.cols);
    }
    bases
}

/// Run `f(i, rows)` for every agent i, where `rows[m]` is agent i's row
/// of `mats[m]` — the apply-phase fan-out. Rows of distinct agents are
/// disjoint, so workers never alias state; combined with the no-RNG
/// contract of [`crate::algorithms::Algorithm::recv_all`], the parallel
/// schedule is bitwise-equal to the sequential one. Row bundles live on
/// the stack (≤ [`MAX_MATS`] mats): no allocation per call.
pub fn par_agents<F>(exec: Exec<'_>, mats: &mut [&mut Mat], f: F)
where
    F: Fn(usize, &mut [&mut [f64]]) + Sync,
{
    let n = mats.first().map_or(0, |m| m.rows);
    if n == 0 {
        return;
    }
    let m = mats.len();
    let bases = mat_bases(mats, n);
    let t = exec.threads().min(n).max(1);
    let chunk = n.div_ceil(t);
    exec.run_workers(t, &|w| {
        let start = w * chunk;
        let end = (start + chunk).min(n);
        // Stack storage for the row bundle (`&mut []` needs no backing
        // memory): allocation-free, lifetime inferred locally.
        let mut rows: [&mut [f64]; MAX_MATS] =
            [&mut [], &mut [], &mut [], &mut [], &mut [], &mut [], &mut [], &mut []];
        for i in start..end {
            for (slot, &(ptr, cols)) in rows[..m].iter_mut().zip(&bases[..m]) {
                // SAFETY: agent i's row of each mat; disjoint across
                // workers by the chunking contract.
                *slot = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * cols), cols) };
            }
            f(i, &mut rows[..m]);
        }
    });
}

/// [`par_agents`] with two extra per-agent values zipped in: `f(i, rows,
/// &mut a[i], &mut b[i])`. This is what lets an algorithm's fused
/// [`crate::algorithms::Algorithm::produce_all`] hand each agent its
/// gradient buffer and payload alongside its state rows in one dispatch.
/// The agent count is `a.len()`; `b` and every mat must match it (`mats`
/// may be empty for algorithms whose send path mutates no state).
pub fn par_agents2<A, B, F>(exec: Exec<'_>, mats: &mut [&mut Mat], a: &mut [A], b: &mut [B], f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [&mut [f64]], &mut A, &mut B) + Sync,
{
    let n = a.len();
    assert_eq!(b.len(), n, "par_agents2: extra-slice length mismatch");
    if n == 0 {
        return;
    }
    let m = mats.len();
    let bases = mat_bases(mats, n);
    let (ap, bp) = (SendPtr(a.as_mut_ptr()), SendPtr(b.as_mut_ptr()));
    let t = exec.threads().min(n).max(1);
    let chunk = n.div_ceil(t);
    exec.run_workers(t, &|w| {
        let start = w * chunk;
        let end = (start + chunk).min(n);
        // Stack storage for the row bundle (`&mut []` needs no backing
        // memory): allocation-free, lifetime inferred locally.
        let mut rows: [&mut [f64]; MAX_MATS] =
            [&mut [], &mut [], &mut [], &mut [], &mut [], &mut [], &mut [], &mut []];
        for i in start..end {
            for (slot, &(ptr, cols)) in rows[..m].iter_mut().zip(&bases[..m]) {
                // SAFETY: agent i's row of each mat; disjoint across
                // workers by the chunking contract.
                *slot = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(i * cols), cols) };
            }
            // SAFETY: per-agent extras; same disjointness argument.
            let (ai, bi) = unsafe { (&mut *ap.0.add(i), &mut *bp.0.add(i)) };
            f(i, &mut rows[..m], ai, bi);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Both wake modes must satisfy every pool contract test.
    fn both_modes(threads: usize) -> [WorkerPool; 2] {
        [WorkerPool::new(threads), WorkerPool::new_broadcast(threads)]
    }

    #[test]
    fn pool_runs_every_worker_index_once() {
        for pool in both_modes(4) {
            for bound in [1usize, 2, 3, 4, 7] {
                let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
                let h = &hits;
                pool.run(bound, &|w| {
                    h[w].fetch_add(1, Ordering::Relaxed);
                });
                let expect = bound.min(4);
                for (w, c) in hits.iter().enumerate() {
                    let want = usize::from(w < expect);
                    assert_eq!(c.load(Ordering::Relaxed), want, "bound={bound} w={w}");
                }
            }
        }
    }

    #[test]
    fn pool_reused_across_many_dispatches() {
        // The point of the pool: thousands of dispatches on the same
        // workers. Sum 0..n once per dispatch and check the total.
        for pool in both_modes(3) {
            let total = AtomicUsize::new(0);
            for _ in 0..2000 {
                let t = &total;
                pool.run(3, &|w| {
                    t.fetch_add(w + 1, Ordering::Relaxed);
                });
            }
            assert_eq!(total.load(Ordering::Relaxed), 2000 * 6);
        }
    }

    #[test]
    fn partial_dispatches_leave_unneeded_workers_asleep_but_usable() {
        // Per-worker mode never wakes workers >= bound; interleave
        // partial and full dispatches to prove their cells stay
        // consistent (per-cell epochs advance independently).
        let pool = WorkerPool::new(4);
        let total = AtomicUsize::new(0);
        let t = &total;
        for bound in [2usize, 4, 2, 3, 4, 2] {
            pool.run(bound, &|w| {
                t.fetch_add(w + 1, Ordering::Relaxed);
            });
        }
        // Σ over dispatches of Σ_{w<bound} (w+1) = 3+10+3+6+10+3.
        assert_eq!(total.load(Ordering::Relaxed), 35);
    }

    #[test]
    fn nested_dispatch_runs_inline() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        let c = &count;
        let p = &pool;
        pool.run(2, &|_w| {
            // Nested dispatch must not deadlock; it degrades to inline.
            p.run(2, &|_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn worker_panic_propagates() {
        for pool in both_modes(2) {
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.run(2, &|w| {
                    if w == 1 {
                        panic!("boom");
                    }
                });
            }));
            assert!(r.is_err(), "worker panic must reach the caller");
            // The pool must still be usable afterwards.
            let ok = AtomicUsize::new(0);
            let o = &ok;
            pool.run(2, &|_| {
                o.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(ok.load(Ordering::Relaxed), 2);
        }
    }

    #[test]
    fn par_chunks_matches_inline_on_all_backends() {
        let n = 257usize;
        let mut want: Vec<f64> = (0..n).map(|i| (i * 3 + 1) as f64).collect();
        for v in want.iter_mut() {
            *v = v.sin();
        }
        let compute = |exec: Exec<'_>| {
            let mut xs: Vec<f64> = (0..n).map(|i| (i * 3 + 1) as f64).collect();
            par_chunks(exec, &mut xs, |_i, x| *x = x.sin());
            xs
        };
        let pool = WorkerPool::new(5);
        for exec in [Exec::seq(), Exec::spawn(3), Exec::pool(&pool), Exec::pool(&pool).with_threads(2)] {
            let got = compute(exec);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn par_agents2_zips_state_and_extras() {
        let pool = WorkerPool::new(4);
        for n in [1usize, 5, 8] {
            let mut m1 = Mat::zeros(n, 3);
            let mut m2 = Mat::zeros(n, 2);
            let mut extra_a: Vec<f64> = vec![0.0; n];
            let mut extra_b: Vec<usize> = vec![0; n];
            par_agents2(
                Exec::pool(&pool),
                &mut [&mut m1, &mut m2],
                &mut extra_a,
                &mut extra_b,
                |i, rows, a, b| match rows {
                    [r1, r2] => {
                        for v in r1.iter_mut() {
                            *v = i as f64;
                        }
                        for v in r2.iter_mut() {
                            *v = 2.0 * i as f64;
                        }
                        *a = i as f64 + 0.5;
                        *b = i * 10;
                    }
                    _ => unreachable!(),
                },
            );
            for i in 0..n {
                assert!(m1.row(i).iter().all(|&v| v == i as f64));
                assert!(m2.row(i).iter().all(|&v| v == 2.0 * i as f64));
                assert_eq!(extra_a[i], i as f64 + 0.5);
                assert_eq!(extra_b[i], i * 10);
            }
        }
    }

    #[test]
    fn par_dynamic_claims_every_index_once() {
        let pool = WorkerPool::new(4);
        for count in [0usize, 1, 3, 17, 100] {
            let hits: Vec<AtomicUsize> = (0..count).map(|_| AtomicUsize::new(0)).collect();
            let h = &hits;
            for exec in [Exec::seq(), Exec::spawn(3), Exec::pool(&pool)] {
                for a in h.iter() {
                    a.store(0, Ordering::Relaxed);
                }
                par_dynamic(exec, count, |i| {
                    h[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, c) in h.iter().enumerate() {
                    assert_eq!(c.load(Ordering::Relaxed), 1, "count={count} i={i}");
                }
            }
        }
    }

    #[test]
    fn par_dynamic_nested_inner_dispatch_runs_inline() {
        // An outer run-level task that itself dispatches on the same pool
        // must not deadlock and must still cover all inner items (the
        // nested-budget rule: inner budget degrades to 1).
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        let t = &total;
        let p = &pool;
        par_dynamic(Exec::pool(&pool), 5, |_run| {
            let mut xs = [0u8; 7];
            par_chunks(Exec::pool(p), &mut xs, |_, x| *x += 1);
            t.fetch_add(xs.iter().map(|&x| x as usize).sum(), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 35);
    }

    #[test]
    fn exec_with_threads_gates() {
        let pool = WorkerPool::new(8);
        assert_eq!(Exec::pool(&pool).threads(), 8);
        assert_eq!(Exec::pool(&pool).with_threads(3).threads(), 3);
        assert_eq!(Exec::pool(&pool).with_threads(100).threads(), 8);
        assert_eq!(Exec::seq().with_threads(4).threads(), 1);
        assert_eq!(Exec::spawn(4).threads(), 4);
        // Gating can never raise parallelism above the configured budget.
        assert_eq!(Exec::spawn(2).with_threads(8).threads(), 2);
        assert_eq!(Exec::spawn(4).with_threads(3).threads(), 3);
    }
}
