//! # LEAD — Linear Convergent Decentralized Optimization with Compression
//!
//! Full-system reproduction of Liu, Li, Wang, Tang & Yan (ICLR 2021).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! - **L3 (this crate)**: decentralized-training runtime — communication
//!   topologies and mixing matrices, compression codecs with exact wire-bit
//!   accounting, the LEAD algorithm plus eight baselines, a coordinator
//!   engine driven by a persistent worker pool ([`pool`]) with a
//!   steady-state allocation-free round loop, declarative scenario grids
//!   with a sharded multi-run executor ([`scenarios`]), a discrete-event
//!   heterogeneous network simulator for time-to-accuracy studies
//!   ([`simnet`]), deterministic fault injection with a
//!   graceful-degradation engine path ([`faults`]), pluggable
//!   message-passing transports that move framed wire bytes over
//!   in-process channels bitwise-identically to shared memory
//!   ([`transport`]), a deterministic trajectory-invisible tracing and
//!   metrics layer with Chrome-trace export ([`trace`], `lead trace`),
//!   an in-tree determinism & unsafe-soundness auditor
//!   ([`audit`], `lead audit`), experiment drivers for every figure in
//!   the paper, metrics, and a CLI.
//! - **L2 (python/compile)**: JAX compute graphs (linear/logistic
//!   regression, MLP, transformer LM forward+backward) lowered once to HLO
//!   text artifacts.
//! - **L1 (python/compile/kernels)**: Pallas kernels for the paper's
//!   quantization operator and the fused LEAD local step.
//!
//! At runtime the rust binary loads `artifacts/*.hlo.txt` through PJRT
//! ([`runtime`]); Python is never on the round path.
//!
//! Quickstart (see also `examples/quickstart.rs`):
//! ```no_run
//! use lead::prelude::*;
//! use std::sync::Arc;
//! let topo = Topology::Ring.build(8, MixingRule::UniformNeighbors);
//! let problem = LinReg::synthetic(8, 200, 0.1, 42);
//! let algo = Lead::new(LeadParams { gamma: 1.0, alpha: 0.5 });
//! let compressor = QuantizeP::new(2, PNorm::Inf, 512);
//! let mut engine = Engine::new(EngineConfig::default(), topo, Arc::new(problem));
//! let record = engine.run(Box::new(algo), Some(Box::new(compressor)), 300);
//! println!("final distance to x*: {:.3e}", record.last().dist_opt);
//! ```
//!
//! Scenario grids (declarative batches over a shared worker pool):
//! ```no_run
//! use lead::scenarios::{Driver, Grid};
//! let grid = Grid::from_toml("[axes]\nalpha = [0.1, 0.5, 0.9]\n").unwrap();
//! let specs = grid.expand().unwrap();
//! let records = Driver::new(8).run(&grid.name, &specs).unwrap();
//! ```

// Unsafe code inside `unsafe fn` bodies must still be wrapped in explicit
// `unsafe {}` blocks, which the auditor (`audit` rule `safety_comment`)
// then forces to be individually justified.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod algorithms;
pub mod audit;
pub mod bench;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod faults;
pub mod linalg;
pub mod pool;
pub mod problems;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod scenarios;
pub mod serialize;
pub mod simnet;
pub mod topology;
pub mod trace;
pub mod transport;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::algorithms::{
        choco::ChocoSgd,
        d2::D2,
        deepsqueeze::DeepSqueeze,
        dgd::Dgd,
        diging::DiGing,
        exact_diffusion::ExactDiffusion,
        lead::{Lead, LeadParams},
        nids::Nids,
        qdgd::Qdgd,
        Algorithm,
    };
    pub use crate::compress::{
        identity::Identity, quantize::{PNorm, QuantizeP}, randk::RandK, topk::TopK, Compressor,
    };
    pub use crate::coordinator::engine::{Engine, EngineConfig, Schedule, Scheduler};
    pub use crate::coordinator::metrics::{PhaseTimes, RoundMetrics, RunRecord};
    pub use crate::faults::{FaultPlan, FaultSchedule, FaultSummary};
    pub use crate::pool::{Exec, WorkerPool};
    pub use crate::problems::{linreg::LinReg, logreg::LogReg, DataSplit, Problem};
    pub use crate::scenarios::{Driver, Grid, ProblemSpec, RunSpec};
    pub use crate::simnet::{NetModel, NetSummary, RoundTimer};
    pub use crate::rng::Rng;
    pub use crate::topology::{MixingMatrix, MixingRule, Topology};
    pub use crate::trace::{Recorder, TraceCapture, TraceSummary};
    pub use crate::transport::{TransportMode, TransportSummary};
}
