//! A tiny property-based testing harness (proptest is not in the offline
//! vendor set).
//!
//! Usage:
//! ```
//! use lead::prop::forall;
//! use lead::prop_assert;
//! forall(64, 0xC0FFEE, |g| {
//!     let v = g.vec_f64(1..=100, 10.0);
//!     let doubled: Vec<f64> = v.iter().map(|x| 2.0 * x).collect();
//!     for (a, b) in v.iter().zip(&doubled) {
//!         prop_assert!((b - 2.0 * a).abs() < 1e-6, "case failed");
//!     }
//!     Ok(())
//! });
//! ```
//!
//! On failure the harness reports the case index and the failing seed so the
//! exact case can be replayed with `forall(1, seed, ...)`.

use crate::rng::Rng;

/// Per-case generator handle: wraps an RNG and offers common generators.
pub struct Gen {
    pub rng: Rng,
    /// Seed that reproduces this exact case.
    pub case_seed: u64,
}

impl Gen {
    /// Uniform usize in an inclusive range.
    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    /// Vector of f64 with entries uniform in [-scale, scale), random length.
    pub fn vec_f64(&mut self, len: std::ops::RangeInclusive<usize>, scale: f64) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| (self.rng.uniform() * 2.0 - 1.0) * scale)
            .collect()
    }

    /// Vector of f64 with standard normal entries.
    pub fn vec_normal(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.rng.normal()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }
}

/// Result type for property bodies: Err(msg) fails the case.
pub type PropResult = Result<(), String>;

/// Run `cases` randomized cases of `prop`. Panics (test failure) on the
/// first failing case, printing the case index and replay seed.
pub fn forall<F>(cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    // audit:allow(rng_stream): property-harness root — each case derives its own replayable child stream below
    let root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: root.derive(case as u64), case_seed };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case}/{cases} (replay: forall(1, {case_seed:#x}, ..)):\n  {msg}"
            );
        }
    }
}

/// Assert inside a property body, producing an Err with context instead of
/// panicking (so the harness can attach the replay seed).
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(100, 1, |g| {
            let v = g.vec_f64(0..=50, 5.0);
            let s: f64 = v.iter().sum();
            let s2: f64 = v.iter().rev().sum();
            // Reverse-order sums can differ in the last ulp; allow slack.
            prop_assert!((s - s2).abs() <= 1e-9, "s={s} s2={s2}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        forall(100, 2, |g| {
            let n = g.usize_in(0..=10);
            prop_assert!(n < 10, "n was {n}");
            Ok(())
        });
    }

    #[test]
    fn generators_cover_ranges() {
        forall(200, 3, |g| {
            let n = g.usize_in(3..=7);
            prop_assert!((3..=7).contains(&n));
            let x = g.f64_in(-1.0, 2.0);
            prop_assert!((-1.0..2.0).contains(&x));
            let v = g.vec_f64(1..=4, 1.0);
            prop_assert!(!v.is_empty() && v.len() <= 4);
            prop_assert!(v.iter().all(|x| x.abs() <= 1.0));
            Ok(())
        });
    }
}
