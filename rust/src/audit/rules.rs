//! The auditor's rule engine: pragma parsing, `#[cfg(test)]`-region
//! tracking, justification-comment lookup, and the seven rules R1–R7
//! (see `super` for the invariant each one protects).
//!
//! Every rule works on the lexed line model from [`super::lexer`], so
//! string literals and commented-out code can never trigger a rule, and
//! justifications are read from real comments only.

use super::lexer::{lex, Line};

/// One finding, rendered as `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub file: String,
    /// 1-indexed physical source line.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Static description of one rule, for `lead audit --list-rules`.
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

/// Rule ids, in the order they are listed and applied. `pragma` is the
/// meta-rule validating the escape hatch itself and cannot be allowed
/// away.
pub const R_SAFETY: &str = "safety_comment";
pub const R_NONDET: &str = "nondeterminism";
pub const R_RNG: &str = "rng_stream";
pub const R_THREAD: &str = "thread_spawn";
pub const R_ATOMIC: &str = "atomic_ordering";
pub const R_ARCH: &str = "arch_intrinsics";
pub const R_WALL: &str = "wall_clock_choke_point";
pub const R_PRAGMA: &str = "pragma";

pub fn rules() -> &'static [RuleInfo] {
    &[
        RuleInfo {
            id: R_SAFETY,
            summary: "every `unsafe` block/fn/impl carries a `SAFETY:` comment on or \
                      directly above the line (applies to test code too)",
        },
        RuleInfo {
            id: R_NONDET,
            summary: "no nondeterminism sources in trajectory-affecting code: HashMap/HashSet \
                      (unordered iteration), Instant::now/SystemTime (wall clock), \
                      thread_rng/rand::random (unseeded RNG)",
        },
        RuleInfo {
            id: R_RNG,
            summary: "Rng construction must seed a named purpose stream on the same \
                      statement (`Rng::new(seed).derive(streams::…)`)",
        },
        RuleInfo {
            id: R_THREAD,
            summary: "no thread spawning (`thread::spawn`/`thread::Builder`/`thread::scope`) \
                      outside pool.rs — all parallelism goes through the worker pool",
        },
        RuleInfo {
            id: R_ATOMIC,
            summary: "every atomic memory ordering carries an `ORDERING:` comment on or \
                      directly above the line",
        },
        RuleInfo {
            id: R_ARCH,
            summary: "no `core::arch`/`std::arch` (CPU intrinsics) outside linalg/simd.rs — \
                      unsafe SIMD stays confined to the one reviewed kernel module \
                      (applies to test code too)",
        },
        RuleInfo {
            id: R_WALL,
            summary: "no wall-clock reads (`Instant::now`/`SystemTime`) outside \
                      trace/clock.rs — all wall time funnels through the one \
                      pragma-certified choke point (`crate::trace::clock`)",
        },
        RuleInfo {
            id: R_PRAGMA,
            summary: "meta-rule: `audit:allow(rule): reason` pragmas must name a known \
                      rule and give a non-empty reason (cannot itself be allowed away)",
        },
    ]
}

fn known_rule(id: &str) -> bool {
    rules().iter().any(|r| r.id == id && r.id != R_PRAGMA)
}

/// A parsed `audit:allow(rule): reason` pragma.
struct Pragma {
    line: usize,
    rule: String,
    /// Err(msg) when malformed (unknown rule / missing reason).
    ok: Result<(), String>,
    /// Whether the pragma line itself carries code (then it covers that
    /// line; otherwise it covers the next line with code).
    own_line: bool,
}

/// Parse the pragma on `comment`, if any. Only recognized when the
/// comment *starts* with `audit:allow(` (after trimming), so prose that
/// merely mentions the syntax mid-sentence is not a pragma.
fn parse_pragma(comment: &str) -> Option<(String, Result<(), String>)> {
    let t = comment.trim_start();
    let rest = t.strip_prefix("audit:allow(")?;
    let Some(close) = rest.find(')') else {
        return Some((String::new(), Err("unclosed `audit:allow(`".into())));
    };
    let rule = rest[..close].trim().to_string();
    let tail = &rest[close + 1..];
    if !known_rule(&rule) {
        return Some((rule.clone(), Err(format!("unknown rule {rule:?} (see `lead audit --list-rules`)"))));
    }
    let reason_ok = tail
        .strip_prefix(':')
        .map(|r| !r.trim().is_empty())
        .unwrap_or(false);
    if !reason_ok {
        return Some((rule, Err("missing reason — write `audit:allow(rule): why this is sound`".into())));
    }
    Some((rule, Ok(())))
}

/// Per-file analysis context computed once from the lexed lines.
struct FileCtx {
    lines: Vec<Line>,
    /// 0-indexed: line is inside a `#[cfg(test)]` item (attribute line
    /// included). Test code cannot affect trajectories, so R2–R5 skip it.
    in_test: Vec<bool>,
    /// 0-indexed: rules allowed on this line via pragma.
    allowed: Vec<Vec<String>>,
    pragma_diags: Vec<(usize, String)>,
}

fn build_ctx(src: &str) -> FileCtx {
    let lines = lex(src);
    let n = lines.len();

    // --- #[cfg(test)] regions: attribute → next `{` → matching `}` ---
    let mut in_test = vec![false; n];
    let mut depth = 0i64;
    let mut pending = false; // saw the attribute, waiting for the item's `{`
    let mut close_at: Option<i64> = None;
    for (i, l) in lines.iter().enumerate() {
        if close_at.is_some() || pending {
            in_test[i] = true;
        }
        if l.code.replace(' ', "").contains("#[cfg(test)]") {
            pending = true;
            in_test[i] = true;
        }
        for c in l.code.chars() {
            match c {
                '{' => {
                    if pending && close_at.is_none() {
                        close_at = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if close_at == Some(depth) {
                        close_at = None;
                    }
                }
                _ => {}
            }
        }
    }

    // --- pragmas ---
    let mut pragmas = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if let Some((rule, ok)) = parse_pragma(&l.comment) {
            pragmas.push(Pragma { line: i, rule, ok, own_line: !l.code.trim().is_empty() });
        }
    }
    let mut allowed: Vec<Vec<String>> = vec![Vec::new(); n];
    let mut pragma_diags = Vec::new();
    for p in pragmas {
        match p.ok {
            Err(msg) => pragma_diags.push((p.line, msg)),
            Ok(()) => {
                let target = if p.own_line {
                    Some(p.line)
                } else {
                    // Standalone pragma covers the next line carrying code.
                    (p.line + 1..n).find(|&j| !lines[j].code.trim().is_empty())
                };
                match target {
                    Some(t) => allowed[t].push(p.rule),
                    None => pragma_diags.push((p.line, "pragma covers no code line".into())),
                }
            }
        }
    }

    FileCtx { lines, in_test, allowed, pragma_diags }
}

impl FileCtx {
    /// `needle` present in the comment on line `i` or in the contiguous
    /// run of comment-only lines directly above it (a blank line or code
    /// breaks the run — justifications must sit *on* the site).
    fn justified(&self, i: usize, needle: &str) -> bool {
        if self.lines[i].comment.contains(needle) {
            return true;
        }
        let mut j = i;
        while j > 0 {
            j -= 1;
            let l = &self.lines[j];
            if !l.code.trim().is_empty() || l.comment.trim().is_empty() {
                return false;
            }
            if l.comment.contains(needle) {
                return true;
            }
        }
        false
    }

    fn is_allowed(&self, i: usize, rule: &str) -> bool {
        self.allowed[i].iter().any(|r| r == rule)
    }
}

/// `needle` occurs in `code` as a full word (not as part of a longer
/// identifier, so `unsafe_op_in_unsafe_fn` does not count as `unsafe`).
fn contains_word(code: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(needle) {
        let at = start + pos;
        let before = code[..at].chars().next_back();
        let after = code[at + needle.len()..].chars().next();
        let b_ok = before.is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        let a_ok = after.is_none_or(|c| !(c.is_alphanumeric() || c == '_'));
        if b_ok && a_ok {
            return true;
        }
        start = at + needle.len();
    }
    false
}

const ATOMIC_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// True when the line uses an *atomic* memory ordering (and not
/// `cmp::Ordering::{Less,Equal,Greater}`).
fn has_atomic_ordering(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find("Ordering::") {
        let at = start + pos + "Ordering::".len();
        let rest = &code[at..];
        if ATOMIC_ORDERINGS.iter().any(|v| rest.starts_with(v)) {
            return true;
        }
        start = at;
    }
    false
}

/// Run all rules over one file's source. `file` is used for diagnostics
/// and for the R4 pool.rs exemption (matched on file name).
pub fn check_file(file: &str, src: &str) -> Vec<Diagnostic> {
    let ctx = build_ctx(src);
    let file_name = std::path::Path::new(file)
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| file.to_string());
    // R6 exemption is matched on the path suffix, not the bare file name,
    // so an unrelated `simd.rs` elsewhere cannot claim it.
    let in_simd_module = file.replace('\\', "/").ends_with("linalg/simd.rs");
    // R7 exemption, same suffix convention: only the clock choke-point
    // module may read the wall clock.
    let in_clock_module = file.replace('\\', "/").ends_with("trace/clock.rs");
    let mut out = Vec::new();
    let mut diag = |line: usize, rule: &'static str, msg: String| {
        out.push(Diagnostic { file: file.to_string(), line: line + 1, rule, msg });
    };

    for (i, msg) in &ctx.pragma_diags {
        diag(*i, R_PRAGMA, msg.clone());
    }

    for (i, l) in ctx.lines.iter().enumerate() {
        let code = l.code.as_str();
        if code.trim().is_empty() {
            continue;
        }

        // R1 — SAFETY comments. Applies everywhere, tests included: an
        // unsound unsafe block in a test corrupts the process like any
        // other.
        if contains_word(code, "unsafe")
            && !ctx.justified(i, "SAFETY:")
            && !ctx.is_allowed(i, R_SAFETY)
        {
            diag(i, R_SAFETY, "`unsafe` without a `// SAFETY:` comment on or directly above this line".into());
        }

        // R6 — intrinsics confinement. Applies everywhere, tests
        // included: the determinism contract on the SIMD kernels only
        // holds while every `core::arch` use sits in the one module
        // whose reduction shapes are reviewed and pinned.
        if !in_simd_module
            && !ctx.is_allowed(i, R_ARCH)
            && (contains_word(code, "core::arch") || contains_word(code, "std::arch"))
        {
            diag(i, R_ARCH, "`core::arch`/`std::arch` outside linalg/simd.rs — CPU intrinsics live only in the reviewed SIMD kernel module (see its §Determinism docs), or justify with a pragma".into());
        }

        if ctx.in_test[i] {
            continue; // R2–R5 guard trajectory-affecting code only.
        }

        // R2 — nondeterminism sources.
        if !ctx.is_allowed(i, R_NONDET) {
            let hits: &[(&str, bool, &str)] = &[
                ("HashMap", true, "unordered iteration order leaks into float reductions"),
                ("HashSet", true, "unordered iteration order leaks into float reductions"),
                ("Instant::now", false, "wall clock is nondeterministic"),
                ("SystemTime", true, "wall clock is nondeterministic"),
                ("thread_rng", true, "unseeded OS-entropy RNG"),
                ("rand::random", false, "unseeded OS-entropy RNG"),
            ];
            for (pat, word, why) in hits {
                let found = if *word { contains_word(code, pat) } else { code.contains(pat) };
                if found {
                    diag(i, R_NONDET, format!("`{pat}` in trajectory-affecting code — {why}; use ordered containers / the engine's seeded streams, or justify with a pragma"));
                    break;
                }
            }
        }

        // R7 — wall-clock choke point. Narrower than R2's blanket
        // nondeterminism screen: even a *metrics-only* wall-clock read
        // must route through `trace::clock` so the determinism story
        // stays auditable from one reviewed source (`crate::trace`
        // §Observability contract).
        if !in_clock_module && !ctx.is_allowed(i, R_WALL) {
            let hit = if code.contains("Instant::now") {
                Some("Instant::now")
            } else if contains_word(code, "SystemTime") {
                Some("SystemTime")
            } else {
                None
            };
            if let Some(pat) = hit {
                diag(i, R_WALL, format!("`{pat}` outside trace/clock.rs — take stamps from the `crate::trace::clock` choke point (audit R7), or justify with a pragma"));
            }
        }

        // R3 — RNG stream discipline.
        if code.contains("Rng::new(")
            && !code.contains("streams::")
            && !ctx.is_allowed(i, R_RNG)
        {
            diag(i, R_RNG, "`Rng::new` without a named purpose stream — derive one on the same statement (`Rng::new(seed).derive(streams::…)`) or justify with a pragma".into());
        }

        // R4 — threading discipline.
        if file_name != "pool.rs" && !ctx.is_allowed(i, R_THREAD) {
            for pat in ["thread::spawn", "thread::Builder", "thread::scope"] {
                if code.contains(pat) {
                    diag(i, R_THREAD, format!("`{pat}` outside pool.rs — all parallelism goes through the worker pool (`crate::pool`)"));
                    break;
                }
            }
        }

        // R5 — atomic ordering justification.
        if has_atomic_ordering(code)
            && !ctx.justified(i, "ORDERING:")
            && !ctx.is_allowed(i, R_ATOMIC)
        {
            diag(i, R_ATOMIC, "atomic `Ordering::…` without an `// ORDERING:` comment on or directly above this line".into());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn audit(src: &str) -> Vec<Diagnostic> {
        check_file("fixture.rs", src)
    }

    fn lines_for(diags: &[Diagnostic], rule: &str) -> Vec<usize> {
        diags.iter().filter(|d| d.rule == rule).map(|d| d.line).collect()
    }

    // ---- R1: safety_comment ----

    #[test]
    fn r1_fires_with_correct_line() {
        let src = "fn f(p: *mut u8) {\n    let v = unsafe { *p };\n}\n";
        let d = audit(src);
        assert_eq!(lines_for(&d, R_SAFETY), vec![2], "{d:?}");
    }

    #[test]
    fn r1_quiet_with_safety_comment_same_line_or_above() {
        let above = "fn f(p: *mut u8) {\n    // SAFETY: caller guarantees p is valid.\n    let v = unsafe { *p };\n}\n";
        assert!(audit(above).is_empty(), "{:?}", audit(above));
        let multi = "// SAFETY: the pointer is
// valid for the whole dispatch.
unsafe impl Send for X {}
";
        assert!(audit(multi).is_empty());
        let same = "unsafe impl Send for X {} // SAFETY: lock-serialized.\n";
        assert!(audit(same).is_empty());
    }

    #[test]
    fn r1_blank_line_breaks_the_comment_run() {
        let src = "// SAFETY: stale justification far above.\n\nunsafe impl Send for X {}\n";
        assert_eq!(lines_for(&audit(src), R_SAFETY), vec![3]);
    }

    #[test]
    fn r1_allowed_via_pragma() {
        let src = "// audit:allow(safety_comment): justified in the module docs above\nunsafe impl Send for X {}\n";
        assert!(audit(src).is_empty());
    }

    #[test]
    fn r1_applies_inside_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *mut u8) -> u8 {\n        unsafe { *p }\n    }\n}\n";
        assert_eq!(lines_for(&audit(src), R_SAFETY), vec![4]);
    }

    #[test]
    fn r1_word_boundary_ignores_lint_name() {
        assert!(audit("#![deny(unsafe_op_in_unsafe_fn)]\n").is_empty());
    }

    #[test]
    fn r1_ignores_strings_and_comments() {
        assert!(audit("let s = \"unsafe\"; // unsafe in prose\n").is_empty());
    }

    // ---- R2: nondeterminism ----

    #[test]
    fn r2_fires_on_hashmap_and_wall_clock() {
        let src = "use std::collections::HashMap;\nfn f() {\n    let t = Instant::now();\n}\n";
        assert_eq!(lines_for(&audit(src), R_NONDET), vec![1, 3]);
    }

    #[test]
    fn r2_quiet_in_test_code_and_via_pragma() {
        let test = "#[cfg(test)]\nmod tests {\n    fn t() { let x = Instant::now(); }\n}\n";
        assert!(audit(test).is_empty());
        // An R2 pragma silences R2 only — the same read still owes R7
        // its choke-point justification (separate pragma).
        let pragma = "let t = Instant::now(); // audit:allow(nondeterminism): metrics only\n";
        assert!(lines_for(&audit(pragma), R_NONDET).is_empty());
    }

    #[test]
    fn r2_clean_code_is_quiet() {
        assert!(audit("use std::collections::BTreeMap;\nlet m = BTreeMap::new();\n").is_empty());
    }

    // ---- R3: rng_stream ----

    #[test]
    fn r3_fires_on_unnamed_stream() {
        let src = "fn f(seed: u64) {\n    let mut rng = Rng::new(seed);\n}\n";
        assert_eq!(lines_for(&audit(src), R_RNG), vec![2]);
    }

    #[test]
    fn r3_quiet_with_named_stream_or_pragma() {
        let named = "let mut rng = Rng::new(seed).derive(streams::DATA);\n";
        assert!(audit(named).is_empty());
        let pragma = "// audit:allow(rng_stream): root of the stream tree\nlet root = Rng::new(seed);\n";
        assert!(audit(pragma).is_empty());
    }

    #[test]
    fn r3_quiet_in_tests() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let mut r = Rng::new(42); }\n}\n";
        assert!(audit(src).is_empty());
    }

    // ---- R4: thread_spawn ----

    #[test]
    fn r4_fires_outside_pool_rs() {
        let src = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(lines_for(&audit(src), R_THREAD), vec![2]);
        let scope = "std::thread::scope(|s| {});\n";
        assert_eq!(lines_for(&audit(scope), R_THREAD), vec![1]);
    }

    #[test]
    fn r4_quiet_in_pool_rs_and_via_pragma() {
        let src = "std::thread::Builder::new();\n";
        assert!(check_file("rust/src/pool.rs", src).is_empty());
        let pragma = "std::thread::spawn(f); // audit:allow(thread_spawn): watchdog, never touches run state\n";
        assert!(audit(pragma).is_empty());
    }

    // ---- R5: atomic_ordering ----

    #[test]
    fn r5_fires_without_ordering_comment() {
        let src = "fn f(a: &AtomicUsize) {\n    a.store(1, Ordering::Release);\n}\n";
        assert_eq!(lines_for(&audit(src), R_ATOMIC), vec![2]);
    }

    #[test]
    fn r5_quiet_with_comment_or_pragma() {
        let above = "// ORDERING: publishes init to the Acquire load in f().\na.store(1, Ordering::Release);\n";
        assert!(audit(above).is_empty());
        let pragma = "a.store(1, Ordering::Relaxed); // audit:allow(atomic_ordering): covered by module invariants doc\n";
        assert!(audit(pragma).is_empty());
    }

    #[test]
    fn r5_ignores_cmp_ordering() {
        let src = "fn c(a: u32, b: u32) -> bool { a.cmp(&b) == Ordering::Equal }\n";
        assert!(audit(src).is_empty());
        let qualified = "use std::cmp::Ordering;\nmatch x.cmp(&y) { Ordering::Less => {} _ => {} }\n";
        assert!(audit(qualified).is_empty());
    }

    // ---- R6: arch_intrinsics ----

    #[test]
    fn r6_fires_outside_the_simd_module() {
        let src = "// SAFETY: avx2 checked by caller.\nunsafe { std::arch::x86_64::_mm256_add_pd(a, b) }\n";
        assert_eq!(lines_for(&audit(src), R_ARCH), vec![2]);
        let import = "use core::arch::x86_64::*;\n";
        assert_eq!(lines_for(&audit(import), R_ARCH), vec![1]);
    }

    #[test]
    fn r6_applies_inside_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::arch::x86_64::*;\n}\n";
        assert_eq!(lines_for(&audit(src), R_ARCH), vec![3]);
    }

    #[test]
    fn r6_quiet_in_linalg_simd_and_via_pragma() {
        let src = "use std::arch::x86_64::*;\n";
        assert!(check_file("rust/src/linalg/simd.rs", src).is_empty());
        // Windows-style separators normalize before the suffix match.
        assert!(check_file("rust\\src\\linalg\\simd.rs", src).is_empty());
        // A stray simd.rs elsewhere does NOT inherit the exemption.
        assert_eq!(lines_for(&check_file("rust/src/other/simd.rs", src), R_ARCH), vec![1]);
        let pragma = "use std::arch::x86_64::*; // audit:allow(arch_intrinsics): scalar-identical fallback proven above\n";
        assert!(audit(pragma).is_empty());
    }

    #[test]
    fn r6_word_boundary_and_clean_code_quiet() {
        // Identifier containing the needle as a substring must not fire.
        assert!(audit("let mystd::arch_like = 1;\n").is_empty());
        assert!(audit("fn plain() -> u32 { 7 }\n").is_empty());
    }

    // ---- R7: wall_clock_choke_point ----

    #[test]
    fn r7_fires_outside_clock_module() {
        let src = "fn f() {\n    let t = std::time::Instant::now();\n}\n";
        assert_eq!(lines_for(&audit(src), R_WALL), vec![2]);
        let sys = "let epoch = SystemTime::now();\n";
        assert_eq!(lines_for(&audit(sys), R_WALL), vec![1]);
    }

    #[test]
    fn r7_quiet_in_trace_clock_and_via_pragma() {
        let src = "let t = Instant::now();\n";
        assert!(lines_for(&check_file("rust/src/trace/clock.rs", src), R_WALL).is_empty());
        // Windows-style separators normalize before the suffix match.
        assert!(lines_for(&check_file("rust\\src\\trace\\clock.rs", src), R_WALL).is_empty());
        // A stray clock.rs elsewhere does NOT inherit the exemption.
        assert_eq!(lines_for(&check_file("rust/src/other/clock.rs", src), R_WALL), vec![1]);
        let pragma =
            "let t = Instant::now(); // audit:allow(wall_clock_choke_point): bench harness, off the run path\n";
        assert!(lines_for(&audit(pragma), R_WALL).is_empty());
    }

    #[test]
    fn r7_quiet_in_tests_and_on_instant_type_uses() {
        // Test code is exempt, like R2–R5.
        let test = "#[cfg(test)]\nmod tests {\n    fn t() { let x = Instant::now(); }\n}\n";
        assert!(lines_for(&audit(test), R_WALL).is_empty());
        // Passing `Instant` stamps around (no clock read) is fine.
        assert!(audit("pub fn secs(t0: Instant) -> f64 { t0.stamp() }\n").is_empty());
    }

    // ---- pragma meta-rule ----

    #[test]
    fn pragma_missing_reason_is_flagged() {
        let src = "let t = Instant::now(); // audit:allow(nondeterminism)\n";
        let d = audit(src);
        assert_eq!(lines_for(&d, R_PRAGMA), vec![1], "{d:?}");
        // The underlying violation is NOT suppressed by a malformed pragma.
        assert_eq!(lines_for(&d, R_NONDET), vec![1]);
        let empty = "let t = Instant::now(); // audit:allow(nondeterminism):   \n";
        assert_eq!(lines_for(&audit(empty), R_PRAGMA), vec![1]);
    }

    #[test]
    fn pragma_unknown_rule_is_flagged() {
        let src = "// audit:allow(made_up_rule): because\nlet x = 1;\n";
        assert_eq!(lines_for(&audit(src), R_PRAGMA), vec![1]);
    }

    #[test]
    fn pragma_on_own_line_covers_next_code_line_only() {
        let src = "// audit:allow(rng_stream): root stream\nlet a = Rng::new(s);\nlet b = Rng::new(s);\n";
        assert_eq!(lines_for(&audit(src), R_RNG), vec![3]);
    }

    #[test]
    fn pragma_mentioned_mid_prose_is_not_parsed() {
        let src = "// The escape hatch is `audit:allow(rule): reason` on the line.\nlet x = 1;\n";
        assert!(audit(src).is_empty());
    }

    #[test]
    fn pragma_covering_nothing_is_flagged() {
        let src = "let x = 1;\n// audit:allow(rng_stream): dangling at EOF\n";
        assert_eq!(lines_for(&audit(src), R_PRAGMA), vec![2]);
    }

    #[test]
    fn pragma_cannot_allow_the_pragma_rule() {
        // `audit:allow(pragma): …` names a rule the engine refuses to
        // treat as known — the meta-rule cannot be allowed away.
        let src = "// audit:allow(pragma): nope\nlet x = 1;\n";
        assert_eq!(lines_for(&audit(src), R_PRAGMA), vec![1]);
    }

    // ---- test-region tracking ----

    #[test]
    fn cfg_test_region_ends_at_matching_brace() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let x = Instant::now(); }\n}\nfn live() { let y = Instant::now(); }\n";
        assert_eq!(lines_for(&audit(src), R_NONDET), vec![5]);
    }
}
