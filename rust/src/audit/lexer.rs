//! Line lexer for the in-tree auditor: split rust source into per-line
//! *code* and *comment* channels so the rules in [`super::rules`] can
//! pattern-match code without being fooled by string literals or
//! commented-out snippets, and can read justification comments
//! (`SAFETY:` / `ORDERING:` / `audit:allow` pragmas) without matching
//! code.
//!
//! This is deliberately not a full rust lexer — it only has to get four
//! things right, and has unit tests for each:
//!
//! 1. line comments (`//`, `///`, `//!`) and *nested* block comments
//!    (`/* /* */ */`), including multi-line ones;
//! 2. string literals — plain (`"…"` with escapes), byte (`b"…"`), and
//!    raw (`r"…"`, `r#"…"#`, `br##"…"##`) — whose *contents* are blanked
//!    from the code channel (the delimiting quotes survive so the code
//!    still reads naturally in diagnostics);
//! 3. char literals vs lifetimes: `'a'` is a literal (blanked), `&'a T`
//!    is code;
//! 4. physical line numbering: every `\n` produces exactly one [`Line`],
//!    even inside multi-line strings and block comments, so rule
//!    diagnostics carry exact `file:line` positions.

/// One physical source line, split into channels.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Source text with comments removed and string/char contents
    /// blanked (delimiters kept).
    pub code: String,
    /// Concatenated comment text on this line (both `//…` and the part
    /// of a `/* … */` that falls on this line), without the `//` that
    /// introduced it.
    pub comment: String,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Split `src` into per-line code/comment channels (see module docs).
pub fn lex(src: &str) -> Vec<Line> {
    let ch: Vec<char> = src.chars().collect();
    let n = ch.len();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    // Carried across physical lines:
    let mut block_depth = 0usize; // nested /* */ depth
    let mut in_str = false; // inside a "…" / b"…" literal
    let mut raw_hashes: Option<usize> = None; // inside r#…#"…"#…# with k hashes
    let mut prev_ident = false; // last code char was identifier-ish
    let mut i = 0usize;

    while i < n {
        let c = ch[i];
        // Physical line breaks always produce a Line, whatever the state.
        if c == '\n' {
            lines.push(Line { code: std::mem::take(&mut code), comment: std::mem::take(&mut comment) });
            prev_ident = false;
            i += 1;
            continue;
        }
        if block_depth > 0 {
            if c == '/' && ch.get(i + 1) == Some(&'*') {
                block_depth += 1;
                i += 2;
            } else if c == '*' && ch.get(i + 1) == Some(&'/') {
                block_depth -= 1;
                i += 2;
            } else {
                comment.push(c);
                i += 1;
            }
            continue;
        }
        if let Some(h) = raw_hashes {
            // Raw string: ends at `"` followed by exactly `h` hashes.
            if c == '"' && ch[i + 1..].iter().take(h).filter(|&&x| x == '#').count() == h {
                code.push('"');
                i += 1 + h;
                raw_hashes = None;
            } else {
                i += 1;
            }
            continue;
        }
        if in_str {
            match c {
                '\\' => {
                    // Escape: swallow the next char unless it is the
                    // newline (handled by the top-of-loop line break).
                    if ch.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                }
                '"' => {
                    code.push('"');
                    in_str = false;
                    i += 1;
                }
                _ => i += 1,
            }
            continue;
        }
        // --- code state ---
        match c {
            '/' if ch.get(i + 1) == Some(&'/') => {
                // Line comment: rest of the line is comment text. Strip
                // the introducing slashes and any doc-comment marker.
                let mut j = i + 2;
                if ch.get(j) == Some(&'/') || ch.get(j) == Some(&'!') {
                    j += 1;
                }
                while j < n && ch[j] != '\n' {
                    comment.push(ch[j]);
                    j += 1;
                }
                i = j;
            }
            '/' if ch.get(i + 1) == Some(&'*') => {
                block_depth = 1;
                i += 2;
            }
            'r' | 'b' if !prev_ident => {
                // Possible raw-string / byte-string start: `r…`, `br…`,
                // or `b"…"`.
                let mut j = i + 1;
                if c == 'b' && ch.get(j) == Some(&'r') {
                    j += 1;
                }
                let mut hashes = 0usize;
                while ch.get(j + hashes) == Some(&'#') {
                    hashes += 1;
                }
                let rawish = j > i + 1 || c == 'r'; // an `r` is present
                if rawish && ch.get(j + hashes) == Some(&'"') {
                    for k in i..j {
                        code.push(ch[k]);
                    }
                    code.push('"');
                    raw_hashes = Some(hashes);
                    i = j + hashes + 1;
                } else if c == 'b' && ch.get(i + 1) == Some(&'"') {
                    code.push('b');
                    code.push('"');
                    in_str = true;
                    i += 2;
                } else {
                    code.push(c);
                    prev_ident = true;
                    i += 1;
                }
            }
            '"' => {
                code.push('"');
                in_str = true;
                i += 1;
            }
            '\'' => {
                // Char literal vs lifetime. `'\…'` and `'x'` are
                // literals; anything else (`'a`, `'static`, `'_`) is a
                // lifetime and stays in the code channel.
                if ch.get(i + 1) == Some(&'\\') {
                    // Escaped char literal: the backslash escapes exactly
                    // the next char (`'\\'`, `'\''`); longer escapes
                    // (`'\u{…}'`) extend to the closing quote.
                    let mut j = i + 3;
                    while j < n && ch[j] != '\'' && ch[j] != '\n' {
                        j += 1;
                    }
                    code.push_str("''");
                    i = (j + 1).min(n);
                } else if i + 2 < n && ch[i + 2] == '\'' && ch[i + 1] != '\n' {
                    code.push_str("''");
                    i += 3;
                } else {
                    code.push('\'');
                    i += 1;
                }
                prev_ident = false;
            }
            _ => {
                code.push(c);
                prev_ident = is_ident(c);
                i += 1;
            }
        }
    }
    lines.push(Line { code, comment });
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_split_channels() {
        let ls = lex("let x = 1; // trailing note\n/// doc\nlet y = 2;");
        assert_eq!(ls.len(), 3);
        assert_eq!(ls[0].code.trim(), "let x = 1;");
        assert_eq!(ls[0].comment.trim(), "trailing note");
        assert!(ls[1].code.trim().is_empty());
        assert_eq!(ls[1].comment.trim(), "doc");
        assert_eq!(ls[2].code.trim(), "let y = 2;");
    }

    #[test]
    fn nested_block_comments() {
        let ls = lex("a /* one /* two */ still */ b\nc");
        assert_eq!(ls[0].code.replace(' ', ""), "ab");
        assert!(ls[0].comment.contains("one"));
        assert!(ls[0].comment.contains("still"));
        assert_eq!(ls[1].code, "c");
    }

    #[test]
    fn multiline_block_comment_keeps_line_count() {
        let ls = lex("x\n/* a\nb\nc */ y\nz");
        assert_eq!(ls.len(), 5);
        assert_eq!(ls[0].code, "x");
        assert!(ls[1].code.trim().is_empty());
        assert!(ls[2].code.trim().is_empty());
        assert_eq!(ls[2].comment, "b");
        assert_eq!(ls[3].code.trim(), "y");
        assert_eq!(ls[4].code, "z");
    }

    #[test]
    fn string_contents_blanked() {
        let ls = lex("let s = \"unsafe // HashMap\"; f();");
        assert!(!ls[0].code.contains("unsafe"));
        assert!(!ls[0].code.contains("HashMap"));
        assert!(ls[0].comment.is_empty(), "comment chars inside strings are not comments");
        assert!(ls[0].code.contains("f();"));
    }

    #[test]
    fn escaped_quotes_stay_inside_string() {
        let ls = lex(r#"let s = "a\"b"; g();"#);
        assert!(ls[0].code.contains("g();"));
        assert!(!ls[0].code.contains('a'), "string contents must be blanked: {}", ls[0].code);
    }

    #[test]
    fn raw_strings_blanked() {
        let src = "let s = r#\"unsafe \"quoted\" HashMap\"#; h();";
        let ls = lex(src);
        assert!(!ls[0].code.contains("unsafe"));
        assert!(!ls[0].code.contains("HashMap"));
        assert!(ls[0].code.contains("h();"));
        // Byte strings too.
        let ls = lex("let b = b\"unsafe\"; k();");
        assert!(!ls[0].code.contains("unsafe"));
        assert!(ls[0].code.contains("k();"));
    }

    #[test]
    fn multiline_string_keeps_line_count() {
        let ls = lex("let s = \"line one\nline two unsafe\n\"; tail();");
        assert_eq!(ls.len(), 3);
        assert!(!ls[1].code.contains("unsafe"));
        assert!(ls[2].code.contains("tail();"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let ls = lex("let c = 'x'; let n = '\\n'; fn f<'a>(v: &'a str) -> &'static str { v }");
        let code = &ls[0].code;
        assert!(!code.contains('x'), "char literal contents blanked: {code}");
        assert!(code.contains("<'a>"), "lifetimes survive: {code}");
        assert!(code.contains("&'static str"), "lifetimes survive: {code}");
    }

    #[test]
    fn tricky_escaped_char_literals() {
        // `'\\'` and `'\''` must not swallow their closing quote (a
        // mis-scan here would blank the rest of the file as "string").
        let ls = lex("let a = '\\\\'; let b = '\\''; let c = '\\u{7f}'; tail();");
        assert!(ls[0].code.contains("tail();"), "lexer resynced: {}", ls[0].code);
    }

    #[test]
    fn identifier_ending_in_r_is_not_raw_string() {
        // `var` followed by a string must not eat the `r` as a raw-string
        // prefix; the string opens normally and blanks its contents.
        let ls = lex("foo(var, \"unsafe\");");
        assert!(ls[0].code.contains("var"));
        assert!(!ls[0].code.contains("unsafe"));
    }

    #[test]
    fn comment_markers_inside_strings_ignored() {
        let ls = lex("let s = \"// not a comment /* nope */\"; end();");
        assert!(ls[0].comment.is_empty());
        assert!(ls[0].code.contains("end();"));
    }

    #[test]
    fn line_numbers_are_physical() {
        let src = "a\nb\nc\n";
        assert_eq!(codes(src), vec!["a", "b", "c", ""]);
    }
}
