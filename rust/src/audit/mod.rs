//! In-tree determinism & unsafe-soundness auditor (`lead audit`).
//!
//! Every correctness claim in this repo is a bitwise differential pin:
//! sparse mixing equals dense (`sparse_mixing_bitwise_equals_dense`),
//! scheduler modes are interchangeable
//! (`scheduler_modes_bitwise_identical`), the sparse-own apply path
//! equals eager decode (`rust/tests/sparse_own.rs`), and simnet is a
//! timing-only overlay (`rust/tests/simnet.rs`). One nondeterministic
//! float ordering or RNG-stream leak silently invalidates all of them —
//! the trajectories would still *look* plausible. This module makes the
//! rules those pins rely on mechanical: a hand-rolled, zero-dependency
//! static-analysis pass over the repo's own sources, run both as
//! `lead audit [path]` (CI) and as the `tree_audits_clean` test below.
//!
//! # Determinism invariants (the enforced rules)
//!
//! * **`safety_comment`** (R1) — every `unsafe` block/fn/impl carries a
//!   `// SAFETY:` comment on, or directly above, its line. The raw-pointer
//!   fan-out in `pool.rs` and the Send/Sync story in `runtime`/`problems`
//!   are soundness *arguments*; this rule keeps them written down where
//!   they are used (cross-checked in CI by
//!   `clippy::undocumented_unsafe_blocks`).
//! * **`nondeterminism`** (R2) — trajectory-affecting code must not use
//!   `HashMap`/`HashSet` (unordered iteration feeding float reductions is
//!   the classic silent pin-breaker; banning the types subsumes the
//!   reduction-order hazard), `Instant::now`/`SystemTime` (wall clock), or
//!   `thread_rng`/`rand::random` (unseeded entropy). Indexed `Vec`s and
//!   `BTreeMap` are the sanctioned alternatives; wall-clock metrics go
//!   through one pragma-certified choke point
//!   ([`crate::trace::clock`]).
//! * **`rng_stream`** (R3) — `Rng` construction must name its purpose
//!   stream on the same statement: `Rng::new(seed).derive(streams::…)`.
//!   Purpose-separated streams ([`crate::rng::streams`]) are why enabling
//!   one feature (e.g. the simnet overlay, seeded from `streams::NET`)
//!   cannot shift the draws of another; an anonymous `Rng::new` is where
//!   that contract leaks.
//! * **`thread_spawn`** (R4) — no `thread::spawn`/`thread::Builder`/
//!   `thread::scope` outside `pool.rs`. All parallelism goes through the
//!   worker pool's dispatch primitives, whose chunking contract is what
//!   makes thread count a pure performance knob.
//! * **`atomic_ordering`** (R5) — every atomic `Ordering::{Relaxed,
//!   Acquire, Release, AcqRel, SeqCst}` carries an `// ORDERING:` comment
//!   justifying the choice (`cmp::Ordering` is recognized and exempt).
//! * **`arch_intrinsics`** (R6) — no `core::arch`/`std::arch` outside
//!   `linalg/simd.rs`. CPU intrinsics are where a "harmless" FMA or a
//!   CPU-dependent reduction shape would fork trajectories between
//!   machines; confining them to the one module whose §Determinism
//!   contract pins every accumulation shape keeps that review surface
//!   minimal.
//! * **`wall_clock_choke_point`** (R7) — no `Instant::now`/`SystemTime`
//!   outside `trace/clock.rs`. R2 already bans wall clocks from
//!   trajectory code; R7 is the stronger structural rule that even
//!   metrics-only readings funnel through the one reviewed source
//!   ([`crate::trace::clock`], the §Observability contract's dual
//!   timeline), so "is wall time ever read back?" stays a one-module
//!   review.
//!
//! Rules R2–R5 and R7 skip `#[cfg(test)]` regions (tests do not affect
//! trajectories); R1 and R6 apply everywhere. String literals and comments
//! can never trigger a rule — sources are lexed first
//! ([`lexer`]), which is also what makes the auditor self-clean: its own
//! pattern tables are string literals.
//!
//! # The escape hatch
//!
//! A violation that is genuinely sound is *annotated, not silenced*: put
//! `audit:allow(rule): reason` in a `//` comment on the offending line,
//! or on its own line directly above. The reason is mandatory — a pragma
//! without one (or naming an unknown rule) is itself a diagnostic, so
//! every exemption in the tree is a reviewed sentence of justification.
//! `lead audit --list-rules` prints the rule ids.
//!
//! # Relation to the bitwise-pin test strategy
//!
//! The differential harnesses prove *today's* tree deterministic on the
//! configurations they run. The auditor complements them: it bounds the
//! ways a *future* change (the algorithm-zoo arc multiplies the kernels
//! that must obey these rules) can introduce nondeterminism that those
//! pins only catch after the fact, and it turns each `unsafe`/atomic into
//! reviewed text instead of implicit folklore.

pub mod lexer;
pub mod rules;

pub use rules::{check_file, rules, Diagnostic, RuleInfo};

use crate::error::{err, Result};
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under `path` (or `path` itself when
/// it is a file), sorted so diagnostics are emitted in a stable order.
fn rs_files(path: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if path.is_file() {
        out.push(path.to_path_buf());
        return Ok(out);
    }
    if !path.is_dir() {
        return Err(err(format!("audit: {} is neither a file nor a directory", path.display())));
    }
    let mut stack = vec![path.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Audit one file or a whole source tree. Returns every diagnostic,
/// ordered by file then line; an empty vec means the tree is clean.
pub fn audit_path(path: impl AsRef<Path>) -> Result<Vec<Diagnostic>> {
    let mut diags = Vec::new();
    for f in rs_files(path.as_ref())? {
        let src = std::fs::read_to_string(&f)
            .map_err(|e| err(format!("audit: reading {}: {e}", f.display())))?;
        diags.extend(check_file(&f.to_string_lossy(), &src));
    }
    Ok(diags)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The repo's own sources must audit clean: every `unsafe` and atomic
    /// is annotated and every pragma carries a reason. This is the
    /// in-tree twin of the CI `lead audit src` step — it keeps the sweep
    /// honest without a shell.
    #[test]
    fn tree_audits_clean() {
        let src_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let diags = audit_path(&src_dir).expect("audit walk failed");
        assert!(
            diags.is_empty(),
            "rust/src must audit clean; {} violation(s):\n{}",
            diags.len(),
            diags.iter().map(|d| format!("  {d}\n")).collect::<String>()
        );
    }

    /// The auditor must actually *see* the tree it certifies: sanity-pin
    /// that the walk finds the known core modules.
    #[test]
    fn tree_walk_finds_core_modules() {
        let src_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let files = rs_files(&src_dir).unwrap();
        for needle in ["pool.rs", "engine.rs", "scenarios.rs", "neural.rs", "mod.rs"] {
            assert!(
                files.iter().any(|f| f.file_name().is_some_and(|n| n == needle)),
                "walk missed {needle}; found {} files",
                files.len()
            );
        }
        assert!(files.len() > 30, "suspiciously small tree: {} files", files.len());
    }

    #[test]
    fn audit_path_accepts_single_file() {
        let pool = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/pool.rs");
        let diags = audit_path(&pool).unwrap();
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn missing_path_errors() {
        assert!(audit_path("/definitely/not/a/path").is_err());
    }

    #[test]
    fn rule_listing_is_stable() {
        let ids: Vec<&str> = rules().iter().map(|r| r.id).collect();
        assert_eq!(
            ids,
            vec![
                "safety_comment",
                "nondeterminism",
                "rng_stream",
                "thread_spawn",
                "atomic_ordering",
                "arch_intrinsics",
                "wall_clock_choke_point",
                "pragma"
            ]
        );
    }
}
