//! Deterministic, trajectory-invisible structured tracing and metrics.
//!
//! # §Observability contract
//!
//! The engine's determinism story rests on bitwise-differential tests, so
//! an observability layer is only admissible if it can *never* perturb a
//! trajectory. This module holds that line with three rules:
//!
//! 1. **Trajectory-invisible.** The [`Recorder`] only *reads* run state
//!    (stamps, byte counts, fault transitions) and writes into its own
//!    buffers; no engine/pool/transport decision ever branches on trace
//!    state. `rust/tests/trace.rs` pins tracing-on vs tracing-off
//!    bitwise-identical (dist/consensus/comp_err/bits series) across
//!    algorithms × codecs × thread counts × transports.
//! 2. **Ring-buffer ownership, zero steady-state allocation.** Each
//!    execution lane (lane 0 = the coordinator thread, lane `w` = pool
//!    worker `w`) owns one pre-allocated fixed-capacity [`Event`] ring;
//!    once full it overwrites oldest-first and counts the loss instead of
//!    growing. Recording is push-within-capacity behind an uncontended
//!    per-lane mutex, so the engine's zero-alloc steady-state contract
//!    (`rust/tests/alloc_steady_state.rs`) holds with the recorder
//!    **enabled** — the rounds-proportional [`TraceCapture`] is only
//!    materialized on demand by `Engine::take_trace`, never inside the
//!    round loop. [`TraceSummary`] (counters + fixed-bucket histogram) is
//!    constant-size and built once per run.
//! 3. **Clock choke point.** All wall-clock stamps come from
//!    [`clock::now`] — the single pragma-certified `Instant::now` in the
//!    tree, enforced by audit rule R7 (`wall_clock_choke_point`, see
//!    `crate::audit`). Spans carry a **dual timeline**: wall microseconds
//!    since the recorder's epoch, plus the simnet virtual time
//!    ([`Event::vt_us`]) when a `NetModel` is active — so Chrome traces
//!    line up real compute cost against simulated network time.
//!
//! # Exporters
//!
//! [`chrome_json`] renders a [`TraceCapture`] as Chrome trace-event JSON
//! (the `chrome://tracing` / Perfetto format: one `"X"` complete event
//! per span, `"i"` instants, `"M"` metadata naming the lanes);
//! [`validate_chrome_json`] re-parses an emitted artifact and checks the
//! per-lane `ts` monotonicity CI relies on. `lead trace <grid.toml>`
//! drives both; `lead net-report` appends the per-phase/per-counter
//! breakdown from [`TraceSummary`].

pub mod clock;

use crate::error::{err, Result};
use crate::serialize::json;
use std::cell::Cell;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Per-lane ring capacity, in events. A 500-round 8-agent traced run
/// emits ~7 coordinator events per round plus per-frame transport
/// instants; overflow overwrites oldest-first (counted, never grows).
pub const EVENT_CAP: usize = 4096;

/// Log₂-nanosecond buckets for the pool wake-to-start latency histogram:
/// bucket `k` counts latencies in `[2^(k−1), 2^k)` ns (bucket 0: < 1 ns),
/// covering 1 ns up to ~2 s. Fixed buckets keep the artifact shape
/// deterministic even though the latencies themselves are wall-clock.
pub const WAKE_BUCKETS: usize = 32;

/// Typed trace event kinds, spanning every timing-sensitive layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Fused gradient→send→compress phase span (engine round loop).
    PhaseProduce,
    /// Mix phase span (shared-memory or transport receive+mix).
    PhaseMix,
    /// Apply phase span.
    PhaseApply,
    /// Metric observation span (round 0 and every recorded round).
    PhaseObserve,
    /// One pool fan-out: dispatch to barrier-return (`arg` = workers).
    PoolDispatch,
    /// One worker's wake-to-start latency span (`arg` = worker index).
    PoolWake,
    /// Transport frame enqueued (`arg` = frame bytes).
    FrameSend,
    /// Transport frame drained + decoded (`arg` = frame bytes).
    FrameRecv,
    /// Fault schedule took an agent down (`arg` = agent id).
    FaultDown,
    /// Fault schedule brought an agent back (`arg` = agent id).
    FaultUp,
    /// Simnet finished a round's event-queue replay (`arg` = round).
    NetRound,
    /// One agent's last simnet arrival this round (`arg` = agent id;
    /// `vt_us` is the arrival's virtual time).
    NetArrival,
}

impl EventKind {
    /// Chrome event name.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::PhaseProduce => "produce",
            EventKind::PhaseMix => "mix",
            EventKind::PhaseApply => "apply",
            EventKind::PhaseObserve => "observe",
            EventKind::PoolDispatch => "pool_dispatch",
            EventKind::PoolWake => "pool_wake",
            EventKind::FrameSend => "frame_send",
            EventKind::FrameRecv => "frame_recv",
            EventKind::FaultDown => "fault_down",
            EventKind::FaultUp => "fault_up",
            EventKind::NetRound => "net_round",
            EventKind::NetArrival => "net_arrival",
        }
    }

    /// Chrome category lane.
    pub fn cat(self) -> &'static str {
        match self {
            EventKind::PhaseProduce
            | EventKind::PhaseMix
            | EventKind::PhaseApply
            | EventKind::PhaseObserve => "phase",
            EventKind::PoolDispatch | EventKind::PoolWake => "pool",
            EventKind::FrameSend | EventKind::FrameRecv => "transport",
            EventKind::FaultDown | EventKind::FaultUp => "fault",
            EventKind::NetRound | EventKind::NetArrival => "net",
        }
    }

    /// Spans render as `"X"` complete events (with `dur`); the rest as
    /// `"i"` instants.
    pub fn is_span(self) -> bool {
        matches!(
            self,
            EventKind::PhaseProduce
                | EventKind::PhaseMix
                | EventKind::PhaseApply
                | EventKind::PhaseObserve
                | EventKind::PoolDispatch
                | EventKind::PoolWake
        )
    }
}

/// Sentinel for "no simnet virtual time attached".
pub const NO_VT: u64 = u64::MAX;

/// One recorded event: plain `Copy` data so ring pushes never allocate.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub kind: EventKind,
    /// Engine round the event belongs to (0 before the loop starts).
    pub round: u32,
    /// Wall-clock µs since the recorder's epoch.
    pub t_us: u64,
    /// Span duration in µs (0 for instants).
    pub dur_us: u64,
    /// Simnet virtual time in µs; [`NO_VT`] when no `NetModel` is active.
    pub vt_us: u64,
    /// Kind-specific payload (see [`EventKind`] variants).
    pub arg: u64,
}

/// Fixed-capacity oldest-first-overwrite event ring. Pre-allocated at
/// construction; `push` never allocates.
struct Ring {
    buf: Vec<Event>,
    /// Oldest retained event once the buffer is full (wraparound cursor).
    head: usize,
    overwritten: u64,
}

impl Ring {
    fn with_capacity(cap: usize) -> Ring {
        Ring { buf: Vec::with_capacity(cap.max(1)), head: 0, overwritten: 0 }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.buf.len();
            self.overwritten += 1;
        }
    }

    /// Retained events, oldest first (drains nothing).
    fn snapshot(&self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

thread_local! {
    /// This thread's trace lane. Lane 0 is the coordinator; pool worker
    /// `w` records into lane `w` (set by the traced dispatch wrapper in
    /// `crate::pool`). Out-of-range lanes clamp to the last ring, so a
    /// stale lane id from an earlier, wider dispatch can never index out
    /// of bounds.
    static LANE: Cell<usize> = const { Cell::new(0) };
}

/// Tag the calling thread with a trace lane (see [`LANE`]).
pub fn set_lane(lane: usize) {
    LANE.with(|c| c.set(lane));
}

/// The calling thread's trace lane.
pub fn lane() -> usize {
    LANE.with(|c| c.get())
}

/// Pre-allocated per-lane event rings plus fleet counters — the engine's
/// per-run trace sink (§Observability contract). `Sync`: lanes are
/// independent mutexes, counters are atomics, so pool workers record
/// concurrently without contending.
pub struct Recorder {
    epoch: Instant,
    lanes: Vec<Mutex<Ring>>,
    /// Current simnet virtual time in µs ([`NO_VT`] ⇒ no `NetModel`).
    vt_us: AtomicU64,
    round: AtomicU32,
    dispatches: AtomicU64,
    wake_hist: Vec<AtomicU64>,
}

impl Recorder {
    /// A recorder with `lanes` rings (clamped to ≥ 1): one per execution
    /// lane of the run's widest dispatch. All rings are allocated here,
    /// up front — recording is allocation-free.
    pub fn new(lanes: usize) -> Recorder {
        Recorder {
            epoch: clock::now(),
            lanes: (0..lanes.max(1)).map(|_| Mutex::new(Ring::with_capacity(EVENT_CAP))).collect(),
            vt_us: AtomicU64::new(NO_VT),
            round: AtomicU32::new(0),
            dispatches: AtomicU64::new(0),
            wake_hist: (0..WAKE_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// The recorder's epoch stamp (all `t_us` fields are relative to it).
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Tag subsequent events with the engine round.
    pub fn set_round(&self, round: usize) {
        // ORDERING: Relaxed — observability stamp written by the
        // coordinator between dispatches; worker reads are ordered by the
        // dispatch barrier, and no data synchronizes through it.
        self.round.store(round as u32, Ordering::Relaxed);
    }

    /// Tag subsequent events with the simnet virtual time (seconds).
    pub fn set_vt(&self, sim_secs: f64) {
        let us =
            if sim_secs.is_finite() && sim_secs >= 0.0 { (sim_secs * 1e6) as u64 } else { NO_VT };
        // ORDERING: Relaxed — observability stamp, same rationale as
        // `set_round`.
        self.vt_us.store(us, Ordering::Relaxed);
    }

    fn stamp(&self) -> (u32, u64) {
        // ORDERING: Relaxed (both) — observability reads of the stamps
        // above; any interleaving yields a valid round/vt tag.
        (self.round.load(Ordering::Relaxed), self.vt_us.load(Ordering::Relaxed))
    }

    fn push(&self, ev: Event) {
        let lane = lane().min(self.lanes.len() - 1);
        self.lanes[lane].lock().expect("trace ring poisoned").push(ev);
    }

    /// Record a completed span that began at stamp `t0` into the calling
    /// thread's lane.
    pub fn span(&self, kind: EventKind, t0: Instant, arg: u64) {
        let (round, vt_us) = self.stamp();
        self.push(Event {
            kind,
            round,
            t_us: clock::micros_between(self.epoch, t0),
            dur_us: clock::micros_since(t0),
            vt_us,
            arg,
        });
    }

    /// Record an instant event, stamped now, into the calling thread's
    /// lane.
    pub fn instant(&self, kind: EventKind, arg: u64) {
        let (round, vt_us) = self.stamp();
        self.push(Event {
            kind,
            round,
            t_us: clock::micros_since(self.epoch),
            dur_us: 0,
            vt_us,
            arg,
        });
    }

    /// Record an instant event carrying an explicit virtual timestamp
    /// (simnet arrivals, whose `vt` is per-agent rather than the round's).
    pub fn instant_vt(&self, kind: EventKind, vt_us: u64, arg: u64) {
        let (round, _) = self.stamp();
        self.push(Event {
            kind,
            round,
            t_us: clock::micros_since(self.epoch),
            dur_us: 0,
            vt_us,
            arg,
        });
    }

    /// Worker-side wake record: the latency from the dispatch stamp `t0`
    /// to "this worker started running" lands in the log₂-ns histogram
    /// and as a [`EventKind::PoolWake`] span in the worker's lane.
    pub fn wake(&self, t0: Instant, worker: usize) {
        let ns = clock::nanos_since(t0);
        let bucket = (64 - ns.leading_zeros() as usize).min(WAKE_BUCKETS - 1);
        // ORDERING: Relaxed — independent monotonic counter; totals are
        // read after the dispatch barrier.
        self.wake_hist[bucket].fetch_add(1, Ordering::Relaxed);
        let (round, vt_us) = self.stamp();
        self.push(Event {
            kind: EventKind::PoolWake,
            round,
            t_us: clock::micros_between(self.epoch, t0),
            dur_us: (ns / 1000).max(1),
            vt_us,
            arg: worker as u64,
        });
    }

    /// Coordinator-side record of one completed pool fan-out.
    pub fn dispatch_span(&self, t0: Instant, workers: u64) {
        // ORDERING: Relaxed — independent monotonic counter.
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        self.span(EventKind::PoolDispatch, t0, workers);
    }

    /// Events recorded over the run (retained + overwritten).
    pub fn events_recorded(&self) -> u64 {
        self.lanes
            .iter()
            .map(|m| {
                let r = m.lock().expect("trace ring poisoned");
                r.buf.len() as u64 + r.overwritten
            })
            .sum()
    }

    /// Constant-size end-of-run rollup: the recorder's own counters
    /// followed by `extra` (the engine appends transport/fault/simnet
    /// totals), plus the wake histogram. Built once per run — allocation
    /// here is per-run constant, outside the steady-state contract.
    pub fn summary(&self, extra: &[(&'static str, u64)]) -> TraceSummary {
        let overwritten: u64 =
            self.lanes.iter().map(|m| m.lock().expect("trace ring poisoned").overwritten).sum();
        let mut counters = Vec::with_capacity(3 + extra.len());
        counters.push(("events", self.events_recorded()));
        counters.push(("events_overwritten", overwritten));
        // ORDERING: Relaxed — end-of-run read; all increments happened
        // before the final dispatch barrier.
        counters.push(("pool_dispatches", self.dispatches.load(Ordering::Relaxed)));
        counters.extend_from_slice(extra);
        // ORDERING: Relaxed — end-of-run histogram read, same rationale.
        let mut hist: Vec<u64> = self.wake_hist.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        while hist.last() == Some(&0) {
            hist.pop();
        }
        TraceSummary { counters, wake_hist_ns: hist }
    }

    /// Drain the rings into a per-lane, chronologically sorted capture.
    /// Rounds-proportional allocation — call only *after* the run (the
    /// engine exposes this as `take_trace`, never inside the round loop).
    pub fn capture(&self) -> TraceCapture {
        let mut lanes = Vec::with_capacity(self.lanes.len());
        let mut overwritten = 0;
        for m in &self.lanes {
            let ring = m.lock().expect("trace ring poisoned");
            let mut evs = ring.snapshot();
            overwritten += ring.overwritten;
            // Stable sort: threads sharing a lane (the Spawn backend) may
            // interleave stamps; Chrome requires per-lane monotone `ts`.
            evs.sort_by_key(|e| e.t_us);
            lanes.push(evs);
        }
        TraceCapture { lanes, overwritten }
    }
}

/// Constant-size per-run trace rollup, surfaced as `RunRecord.trace` and
/// aggregated into `<grid>.json` seed bands. `counters` is ordered
/// (insertion order is the artifact order) so JSON output is
/// deterministic.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    /// Monotonic fleet counters: recorder totals (`events`,
    /// `events_overwritten`, `pool_dispatches`) then the engine's
    /// transport / fault / simnet totals.
    pub counters: Vec<(&'static str, u64)>,
    /// Pool wake-to-start latency histogram, log₂-ns buckets (trailing
    /// zero buckets trimmed; see [`WAKE_BUCKETS`]).
    pub wake_hist_ns: Vec<u64>,
}

impl TraceSummary {
    /// Counter by name (0 when absent — counters are totals, so absence
    /// means "none observed").
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(k, _)| *k == name).map_or(0, |(_, v)| *v)
    }

    /// Compact JSON object (hand-rolled, matching the other summaries).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            json::write_str(&mut out, k);
            out.push_str(&format!(":{v}"));
        }
        out.push_str("},\"wake_hist_ns\":[");
        for (i, v) in self.wake_hist_ns.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&v.to_string());
        }
        out.push_str("]}");
        out
    }
}

/// A drained trace: per-lane events, oldest first within each lane.
pub struct TraceCapture {
    /// `lanes[0]` is the coordinator; `lanes[w]` is pool worker `w`.
    pub lanes: Vec<Vec<Event>>,
    /// Events lost to ring wraparound across all lanes.
    pub overwritten: u64,
}

impl TraceCapture {
    pub fn total_events(&self) -> usize {
        self.lanes.iter().map(Vec::len).sum()
    }
}

/// Render a capture as Chrome trace-event JSON (`chrome://tracing` /
/// Perfetto). One process (`pid` 0) with one thread lane per ring;
/// `"M"` metadata names them, spans become `"X"` complete events,
/// instants `"i"`. Events are emitted lane-by-lane in chronological
/// order, so `ts` is monotone per `(pid, tid)` — the property
/// [`validate_chrome_json`] checks.
pub fn chrome_json(cap: &TraceCapture, label: &str) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    out.push_str("{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,\"args\":{\"name\":");
    json::write_str(&mut out, label);
    out.push_str("}}");
    for lane in 0..cap.lanes.len() {
        let name = if lane == 0 { "coordinator".to_string() } else { format!("lead-pool-{lane}") };
        out.push_str(&format!(
            ",{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{lane},\"args\":{{\"name\":"
        ));
        json::write_str(&mut out, &name);
        out.push_str("}}");
    }
    for (lane, evs) in cap.lanes.iter().enumerate() {
        for ev in evs {
            out.push_str(",{\"name\":\"");
            out.push_str(ev.kind.name());
            out.push_str("\",\"cat\":\"");
            out.push_str(ev.kind.cat());
            if ev.kind.is_span() {
                out.push_str(&format!(
                    "\",\"ph\":\"X\",\"pid\":0,\"tid\":{lane},\"ts\":{},\"dur\":{}",
                    ev.t_us, ev.dur_us
                ));
            } else {
                out.push_str(&format!(
                    "\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{lane},\"ts\":{}",
                    ev.t_us
                ));
            }
            out.push_str(&format!(",\"args\":{{\"round\":{},\"arg\":{}", ev.round, ev.arg));
            if ev.vt_us != NO_VT {
                out.push_str(&format!(",\"vt_us\":{}", ev.vt_us));
            }
            out.push_str("}}");
        }
    }
    out.push_str("]}\n");
    out
}

/// Validate an emitted Chrome-trace artifact: parses as JSON, has a
/// `traceEvents` array, every event carries `name`/`ph`, and `ts` is
/// monotone non-decreasing per `(pid, tid)` lane in array order (the
/// invariant `chrome_json` guarantees and the CI smoke step enforces).
pub fn validate_chrome_json(src: &str) -> Result<()> {
    let doc = json::parse(src).map_err(|e| err(format!("trace artifact: {e}")))?;
    let evs = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| err("trace artifact: missing traceEvents array"))?;
    let mut last: std::collections::BTreeMap<(i64, i64), f64> = std::collections::BTreeMap::new();
    for (i, e) in evs.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err(format!("trace event {i}: missing ph")))?;
        if e.get("name").and_then(|v| v.as_str()).is_none() {
            return Err(err(format!("trace event {i}: missing name")));
        }
        if ph == "M" {
            continue;
        }
        let num = |k: &str| -> Result<f64> {
            e.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| err(format!("trace event {i}: missing {k}")))
        };
        let (pid, tid, ts) = (num("pid")? as i64, num("tid")? as i64, num("ts")?);
        if let Some(&prev) = last.get(&(pid, tid)) {
            if ts < prev {
                return Err(err(format!(
                    "trace event {i}: ts {ts} < {prev} — not monotone in lane (pid {pid}, tid {tid})"
                )));
            }
        }
        last.insert((pid, tid), ts);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, t_us: u64) -> Event {
        Event { kind, round: 1, t_us, dur_us: 0, vt_us: NO_VT, arg: 0 }
    }

    #[test]
    fn ring_overwrites_oldest_first_and_counts() {
        let mut r = Ring::with_capacity(4);
        for t in 0..6 {
            r.push(ev(EventKind::FrameSend, t));
        }
        assert_eq!(r.overwritten, 2);
        let snap = r.snapshot();
        let ts: Vec<u64> = snap.iter().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![2, 3, 4, 5], "oldest events were overwritten");
        assert_eq!(r.buf.capacity(), 4, "ring never grows");
    }

    #[test]
    fn recorder_stamps_round_vt_and_clamps_lanes() {
        let r = Recorder::new(2);
        r.set_round(7);
        r.set_vt(0.25);
        r.instant(EventKind::FrameSend, 99);
        set_lane(50); // stale wide-dispatch lane: must clamp, not panic
        r.instant(EventKind::FrameRecv, 1);
        set_lane(0);
        let cap = r.capture();
        assert_eq!(cap.lanes.len(), 2);
        assert_eq!(cap.lanes[0].len(), 1);
        assert_eq!(cap.lanes[1].len(), 1, "out-of-range lane clamps to the last ring");
        let e = &cap.lanes[0][0];
        assert_eq!(e.round, 7);
        assert_eq!(e.vt_us, 250_000);
        assert_eq!(e.arg, 99);
        assert_eq!(r.events_recorded(), 2);
    }

    #[test]
    fn wake_histogram_buckets_log2_ns() {
        let r = Recorder::new(2);
        let t0 = clock::now();
        set_lane(1);
        r.wake(t0, 1);
        set_lane(0);
        let s = r.summary(&[]);
        assert_eq!(s.wake_hist_ns.iter().sum::<u64>(), 1);
        assert_eq!(s.counter("events"), 1);
        assert_eq!(s.counter("nonexistent"), 0);
    }

    #[test]
    fn summary_appends_extras_in_order_and_serializes() {
        let r = Recorder::new(1);
        r.instant(EventKind::NetRound, 3);
        let s = r.summary(&[("frames_sent", 16), ("bytes_on_wire", 1024)]);
        assert_eq!(s.counter("frames_sent"), 16);
        let js = s.to_json();
        let doc = json::parse(&js).unwrap();
        assert_eq!(
            doc.get("counters").unwrap().get("bytes_on_wire").unwrap().as_f64(),
            Some(1024.0)
        );
        assert!(doc.get("wake_hist_ns").unwrap().as_arr().is_some());
    }

    #[test]
    fn chrome_export_is_valid_and_lane_monotone() {
        let r = Recorder::new(2);
        r.set_round(1);
        let t0 = clock::now();
        r.instant(EventKind::FrameSend, 64);
        r.span(EventKind::PhaseProduce, t0, 0);
        r.set_vt(1.5);
        r.instant_vt(EventKind::NetArrival, 1_400_000, 3);
        let cap = r.capture();
        let js = chrome_json(&cap, "unit");
        validate_chrome_json(&js).unwrap();
        let doc = json::parse(&js).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 2 thread_name metadata + 3 events.
        assert_eq!(evs.len(), 6);
        let arrival = evs.iter().find(|e| {
            e.get("name").unwrap().as_str() == Some("net_arrival")
        });
        let a = arrival.expect("net_arrival emitted");
        assert_eq!(a.get("args").unwrap().get("vt_us").unwrap().as_f64(), Some(1_400_000.0));
        let send = evs.iter().find(|e| e.get("name").unwrap().as_str() == Some("frame_send")).unwrap();
        assert!(
            send.get("args").unwrap().get("vt_us").is_none(),
            "NO_VT events omit the virtual timestamp"
        );
    }

    #[test]
    fn validate_rejects_garbage_and_non_monotone() {
        assert!(validate_chrome_json("not json").is_err());
        assert!(validate_chrome_json("{\"other\":1}").is_err());
        let bad = r#"{"traceEvents":[
            {"name":"a","ph":"i","s":"t","pid":0,"tid":0,"ts":10},
            {"name":"b","ph":"i","s":"t","pid":0,"tid":0,"ts":5}
        ]}"#;
        assert!(validate_chrome_json(bad).is_err(), "ts must be monotone per lane");
        let ok = r#"{"traceEvents":[
            {"name":"a","ph":"i","s":"t","pid":0,"tid":0,"ts":10},
            {"name":"b","ph":"i","s":"t","pid":0,"tid":1,"ts":5}
        ]}"#;
        validate_chrome_json(ok).unwrap();
    }
}
