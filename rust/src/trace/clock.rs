//! §Clock choke point: the tree's ONE sanctioned wall-clock read.
//!
//! Every wall-clock consumer — `PhaseTimes` stamps, `RunRecord.wall_secs`,
//! trace spans, pool wake-latency histograms — takes opaque [`Instant`]
//! stamps from [`now`] and turns them into durations with the helpers
//! below. Nothing else in `src/` may call `Instant::now()` or touch
//! `SystemTime`: audit rule R7 (`wall_clock_choke_point`) flags any such
//! read outside this file, and rule R2 (`nondeterminism`) additionally
//! requires the single read here to carry its pragma. Concentrating the
//! read keeps the determinism story auditable — wall time is *recorded*
//! (metrics, spans) but can never feed back into a trajectory, because
//! every caller is funnelled through one reviewed, metrics-only source.
//!
//! Readings are monotonic (`Instant` semantics) but **not** deterministic:
//! two runs of the same seed produce different stamps. Consumers must
//! treat them as observability payload only — the tracing-on-vs-off
//! differential (`rust/tests/trace.rs`) pins that no trajectory bit
//! depends on anything derived from this module.

use std::time::Instant;

/// An opaque wall-clock stamp. Pass it back to [`secs_since`] /
/// [`micros_since`] / [`nanos_since`] (or [`micros_between`]) to obtain a
/// duration; the stamp itself carries no absolute meaning.
pub fn now() -> Instant {
    // audit:allow(nondeterminism): the tree's single wall-clock source (audit R7 choke point); readings feed metrics and trace spans only, never trajectories
    Instant::now()
}

/// Seconds elapsed since stamp `t0` (saturating at 0).
pub fn secs_since(t0: Instant) -> f64 {
    now().saturating_duration_since(t0).as_secs_f64()
}

/// Whole microseconds elapsed since stamp `t0` (saturating at 0).
pub fn micros_since(t0: Instant) -> u64 {
    now().saturating_duration_since(t0).as_micros() as u64
}

/// Whole nanoseconds elapsed since stamp `t0` (saturating at 0).
pub fn nanos_since(t0: Instant) -> u64 {
    now().saturating_duration_since(t0).as_nanos() as u64
}

/// Whole microseconds from stamp `a` to the later stamp `b` (saturating
/// at 0 when `b` precedes `a`).
pub fn micros_between(a: Instant, b: Instant) -> u64 {
    b.saturating_duration_since(a).as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations_are_nonnegative_and_consistent() {
        let a = now();
        let b = now();
        assert!(secs_since(a) >= 0.0);
        assert_eq!(micros_between(b, a), 0, "reversed stamps saturate at 0");
        assert!(micros_between(a, b) <= micros_since(a));
        // Measure the µs bound against the *earlier* stamp `b`, then the
        // ns reading afterwards — elapsed time only grows the left side.
        let us = micros_between(a, b);
        assert!(nanos_since(a) >= 1000 * us);
    }
}
