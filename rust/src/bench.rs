//! Bench-trajectory tooling: compare a freshly produced `BENCH_*.json`
//! (written by `benches/hotpath.rs` / `benches/grid.rs`) against a
//! committed baseline so perf regressions fail loudly in CI
//! (`lead bench-diff <new.json> <baseline.json> [--tol X]`).
//!
//! Comparison model: every bench artifact carries a `configs` array of
//! objects with a `name` and a `speedup` (a *ratio* — old vs new
//! scheduler, serial vs sharded driver — which is far more stable across
//! machines than absolute throughput). Configs are matched by name;
//! matched configs whose speedup dropped by more than `tol` (relative)
//! are **regressions**. Absolute-throughput drift (`new_rounds_per_s`)
//! is machine-dependent and therefore reported as a note, never a
//! failure. Unmatched configs are notes too, so renaming a config can't
//! silently disarm the gate without a visible trace.

use crate::error::{err, Result};
use crate::serialize::json::{self, Json};

/// Outcome of one baseline comparison.
#[derive(Debug, Default)]
pub struct DiffReport {
    /// Hard failures: matched configs whose speedup regressed beyond tol.
    pub regressions: Vec<String>,
    /// Informational: unmatched configs, throughput drift, missing fields.
    pub notes: Vec<String>,
    /// Number of configs matched by name and compared.
    pub compared: usize,
}

impl DiffReport {
    pub fn ok(&self) -> bool {
        self.regressions.is_empty()
    }
}

fn configs(doc: &Json, which: &str) -> Result<Vec<(String, Json)>> {
    let arr = doc
        .get("configs")
        .and_then(|c| c.as_arr())
        .ok_or_else(|| err(format!("{which}: no \"configs\" array — not a bench artifact")))?;
    Ok(arr
        .iter()
        .filter_map(|c| {
            c.get("name")
                .and_then(|n| n.as_str())
                .map(|n| (n.to_string(), c.clone()))
        })
        .collect())
}

/// Compare `new_src` against `baseline_src` with relative tolerance
/// `tol` (e.g. 0.25 ⇒ a matched config may lose up to 25% of its
/// baseline speedup before failing).
pub fn diff(new_src: &str, baseline_src: &str, tol: f64) -> Result<DiffReport> {
    let new_doc = json::parse(new_src).map_err(|e| err(format!("new artifact: {e}")))?;
    let base_doc = json::parse(baseline_src).map_err(|e| err(format!("baseline: {e}")))?;
    let new_cfgs = configs(&new_doc, "new artifact")?;
    let base_cfgs = configs(&base_doc, "baseline")?;
    let mut report = DiffReport::default();

    for (name, cfg) in &new_cfgs {
        let Some((_, base)) = base_cfgs.iter().find(|(b, _)| b == name) else {
            report.notes.push(format!("{name}: not in baseline — skipped"));
            continue;
        };
        let speed = cfg.get("speedup").and_then(|v| v.as_f64());
        let base_speed = base.get("speedup").and_then(|v| v.as_f64());
        match (speed, base_speed) {
            (Some(s), Some(b)) if b.is_finite() && b > 0.0 => {
                report.compared += 1;
                if s < b * (1.0 - tol) {
                    report.regressions.push(format!(
                        "{name}: speedup {s:.2}x vs baseline {b:.2}x (dropped {:.0}%, tol {:.0}%)",
                        (1.0 - s / b) * 100.0,
                        tol * 100.0
                    ));
                } else if s > b * (1.0 + tol) {
                    report
                        .notes
                        .push(format!("{name}: speedup improved {b:.2}x -> {s:.2}x"));
                }
            }
            _ => report
                .notes
                .push(format!("{name}: no finite speedup on both sides — skipped")),
        }
        // Absolute throughput: machine-dependent, note-only.
        if let (Some(s), Some(b)) = (
            cfg.get("new_rounds_per_s").and_then(|v| v.as_f64()),
            base.get("new_rounds_per_s").and_then(|v| v.as_f64()),
        ) {
            if b > 0.0 && (s / b - 1.0).abs() > tol {
                report.notes.push(format!(
                    "{name}: throughput {s:.1} r/s vs baseline {b:.1} r/s ({:+.0}%, note only)",
                    (s / b - 1.0) * 100.0
                ));
            }
        }
    }
    for (name, _) in &base_cfgs {
        if !new_cfgs.iter().any(|(n, _)| n == name) {
            report.notes.push(format!("baseline config {name} missing from new run"));
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(name: &str, speedup: f64, rps: f64) -> String {
        format!(
            "{{\"schema\":1,\"bench\":\"hotpath\",\"configs\":[{{\"name\":\"{name}\",\
             \"speedup\":{speedup},\"new_rounds_per_s\":{rps}}}]}}"
        )
    }

    #[test]
    fn within_tolerance_passes() {
        let r = diff(&artifact("a", 1.9, 100.0), &artifact("a", 2.0, 100.0), 0.25).unwrap();
        assert!(r.ok(), "{:?}", r.regressions);
        assert_eq!(r.compared, 1);
    }

    #[test]
    fn regression_fails() {
        let r = diff(&artifact("a", 1.0, 100.0), &artifact("a", 2.0, 100.0), 0.25).unwrap();
        assert!(!r.ok());
        assert!(r.regressions[0].contains("speedup 1.00x vs baseline 2.00x"));
    }

    #[test]
    fn throughput_drift_is_note_only() {
        let r = diff(&artifact("a", 2.0, 50.0), &artifact("a", 2.0, 100.0), 0.25).unwrap();
        assert!(r.ok());
        assert!(r.notes.iter().any(|n| n.contains("throughput")));
    }

    #[test]
    fn unmatched_configs_are_notes() {
        let r = diff(&artifact("a", 2.0, 1.0), &artifact("b", 2.0, 1.0), 0.25).unwrap();
        assert!(r.ok());
        assert_eq!(r.compared, 0);
        assert!(r.notes.iter().any(|n| n.contains("not in baseline")));
        assert!(r.notes.iter().any(|n| n.contains("missing from new run")));
    }

    #[test]
    fn null_speedup_skipped() {
        let new = "{\"configs\":[{\"name\":\"a\",\"speedup\":null}]}";
        let r = diff(new, &artifact("a", 2.0, 1.0), 0.25).unwrap();
        assert!(r.ok());
        assert_eq!(r.compared, 0);
    }

    #[test]
    fn malformed_artifacts_error() {
        assert!(diff("{}", &artifact("a", 1.0, 1.0), 0.25).is_err());
        assert!(diff("not json", &artifact("a", 1.0, 1.0), 0.25).is_err());
    }
}
