//! Serialization substrates (serde is not available in the offline vendor
//! set, so the repo carries its own JSON and TOML-subset codecs).

pub mod json;
pub mod toml_mini;
