//! A small TOML-subset parser for experiment config files.
//!
//! Supports what our configs use: `[section]` headers, `key = value` with
//! string / bool / integer / float / homogeneous-array values, `#` comments,
//! and dotted keys inside values being out of scope. This is a config
//! substrate, not a general TOML implementation — unknown syntax is a hard
//! error so config typos fail loudly.

use std::collections::BTreeMap;

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Numeric coercion: ints read as floats too.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: section name -> key -> value. Top-level keys live under
/// the empty section name "".
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse(src: &str) -> Result<Doc, String> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.rfind('"').ok_or("unterminated string")?;
        if end != rest.len() - 1 {
            return Err("trailing content after string".into());
        }
        return Ok(Value::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue; // allow trailing comma
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Arr(items));
    }
    // Number: int if it parses as i64 and has no float syntax.
    let cleaned = s.replace('_', "");
    if !cleaned.contains('.') && !cleaned.contains('e') && !cleaned.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    cleaned
        .parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config() {
        let src = r#"
# experiment config
name = "fig1"
seed = 42
eta = 0.1           # stepsize

[lead]
gamma = 1.0
alpha = 0.5
bits = 2
blocks = [512, 1024]
compress = "qinf"
stochastic = false
"#;
        let doc = parse(src).unwrap();
        assert_eq!(doc[""]["name"].as_str(), Some("fig1"));
        assert_eq!(doc[""]["seed"].as_i64(), Some(42));
        assert_eq!(doc[""]["eta"].as_f64(), Some(0.1));
        assert_eq!(doc["lead"]["gamma"].as_f64(), Some(1.0));
        assert_eq!(doc["lead"]["bits"].as_f64(), Some(2.0));
        assert_eq!(
            doc["lead"]["blocks"].as_arr().unwrap(),
            &[Value::Int(512), Value::Int(1024)]
        );
        assert_eq!(doc["lead"]["stochastic"].as_bool(), Some(false));
    }

    #[test]
    fn errors() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"oops").is_err());
    }

    #[test]
    fn comment_in_string() {
        let doc = parse("k = \"a # b\"").unwrap();
        assert_eq!(doc[""]["k"].as_str(), Some("a # b"));
    }
}
