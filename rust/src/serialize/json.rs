//! Minimal JSON parser and writer.
//!
//! serde is not in the offline vendor set, so the repo carries its own JSON
//! substrate: a recursive-descent parser producing a [`Json`] value tree
//! (enough for `artifacts/manifest.json`) and an escaping writer used by the
//! metrics recorder. Numbers are f64; integer-valued numbers round-trip
//! exactly up to 2^53, which covers shapes, counts, and bit totals.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub at: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}
impl std::error::Error for JsonError {}

/// Parse a complete JSON document.
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for our
                            // machine-generated manifests); map lone
                            // surrogates to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Consume one UTF-8 scalar; JSON strings are valid UTF-8
                    // because the input is a &str.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let s = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Escape and write a JSON string literal into `out`.
pub fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Write a finite f64 compactly (JSON has no NaN/Inf; we map them to null).
pub fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null");
    }
}

impl Json {
    /// Serialize back to compact JSON text.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{
            "artifacts": [
                {"name": "linreg_grad", "inputs": [{"shape": [200, 200], "dtype": "f32"}],
                 "outputs": 1, "flops": 1.6e7}
            ],
            "version": 1, "ok": true, "note": "q∞ \"quant\""
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("linreg_grad"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(200));
        assert_eq!(v.get("note").unwrap().as_str(), Some("q∞ \"quant\""));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":null,"d":false},"s":"x\ny"}"#;
        let v = parse(src).unwrap();
        let s = v.to_string_compact();
        let v2 = parse(&s).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a':1}").is_err());
    }

    #[test]
    fn escapes() {
        let mut s = String::new();
        write_str(&mut s, "a\"b\\c\nd\u{1}");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        let back = parse(&s).unwrap();
        assert_eq!(back.as_str(), Some("a\"b\\c\nd\u{1}"));
    }
}
