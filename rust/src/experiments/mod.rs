//! Experiment drivers — one per figure/table in the paper's evaluation
//! (see DESIGN.md §5 for the index). Every driver prints the paper-style
//! series/rows to stdout and, given an output directory, writes one CSV
//! per curve so the figures can be re-plotted.

pub mod ablations;

use crate::compress::quantize::{PNorm, QuantizeP};
use crate::compress::{randk::RandK, topk::TopK, Compressor};
use crate::config::{self, AlgoSetup};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::metrics::RunRecord;
use crate::problems::{linreg::LinReg, logreg::LogReg, DataSplit, Problem};
use crate::rng::Rng;
use crate::topology::{MixingRule, Topology};
use std::path::Path;

/// The paper's compressor: 2-bit q∞, block 512.
fn paper_compressor() -> Box<dyn Compressor> {
    Box::new(QuantizeP::paper_default())
}

fn run_table(
    problem_factory: &dyn Fn() -> Box<dyn Problem>,
    setups: &[AlgoSetup],
    rounds: usize,
    batch: Option<usize>,
    out: Option<&Path>,
    tag: &str,
) -> Vec<RunRecord> {
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    // Problem construction can be expensive (L-BFGS reference optimum);
    // build once and share it across the per-algorithm engine runs.
    let shared: std::sync::Arc<dyn Problem> = std::sync::Arc::from(problem_factory());
    println!("\n== {tag} ==");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>14} {:>10}",
        "algorithm", "dist(x*)", "consensus", "comp err", "bits/agent", "secs"
    );
    let mut records = Vec::new();
    for s in setups {
        let mut engine = Engine::new(
            EngineConfig {
                eta: s.eta,
                batch_size: batch,
                record_every: (rounds / 100).max(1),
                threads: 8, // leader/worker gradient pool
                ..Default::default()
            },
            mix.clone(),
            Box::new(shared.clone()),
        );
        let comp = if s.compressed { Some(paper_compressor()) } else { None };
        let rec = engine.run(s.build(), comp, rounds);
        let m = rec.last();
        let diverged = !m.dist_opt.is_finite() && !m.loss.is_finite();
        println!(
            "{:<22} {:>12} {:>12} {:>12} {:>14.3e} {:>10.2}{}",
            rec.algo,
            fmt(m.dist_opt),
            fmt(m.consensus),
            fmt(m.comp_err),
            m.bits_per_agent,
            rec.wall_secs,
            if diverged { "  *diverged*" } else { "" }
        );
        if let Some(dir) = out {
            let fname = format!("{tag}_{}", s.algo);
            rec.write_csv(dir, &fname).expect("write csv");
        }
        records.push(rec);
    }
    records
}

fn fmt(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3e}")
    } else {
        "nan/div".into()
    }
}

/// Fig. 1 (a–d): linear regression on the 8-ring, full gradient, 2-bit q∞.
pub fn fig1(out: Option<&Path>, rounds: usize) -> Vec<RunRecord> {
    let recs = run_table(
        &|| Box::new(LinReg::synthetic(8, 200, 0.1, 42)) as Box<dyn Problem>,
        &config::table1_linreg(),
        rounds,
        None,
        out,
        "fig1_linreg",
    );
    // Fig. 1b companion: bits to reach 1e-6.
    println!("-- bits/agent to reach dist 1e-6 (Fig. 1b) --");
    for r in &recs {
        match r.bits_to_tol(1e-6) {
            Some(b) => println!("{:<22} {b:.3e}", r.algo),
            None => println!("{:<22} not reached", r.algo),
        }
    }
    recs
}

/// Figs. 2/8 (full-batch) and 3/9 (mini-batch 512) — logistic regression.
pub fn fig_logreg(
    split: DataSplit,
    minibatch: bool,
    out: Option<&Path>,
    rounds: usize,
    n_total: usize,
) -> Vec<RunRecord> {
    let setups = if minibatch {
        config::table3_logreg_minibatch()
    } else {
        config::table2_logreg_full(split == DataSplit::Heterogeneous)
    };
    let tag = format!(
        "fig_logreg_{}_{}",
        if split == DataSplit::Heterogeneous { "hetero" } else { "homo" },
        if minibatch { "minibatch" } else { "full" }
    );
    run_table(
        &|| Box::new(LogReg::paper_shaped(n_total, split, 42)) as Box<dyn Problem>,
        &setups,
        rounds,
        if minibatch { Some(512) } else { None },
        out,
        &tag,
    )
}

/// Fig. 4: "deep net" (MLP on synthetic CIFAR-shaped data via PJRT).
/// Reports loss trajectories; divergence shows up as NaN (the paper's *).
pub fn fig4(split: DataSplit, out: Option<&Path>, rounds: usize) -> crate::error::Result<Vec<RunRecord>> {
    use crate::problems::neural::MlpProblem;
    let manifest = crate::runtime::Manifest::load("artifacts")?;
    let setups = config::table4_dnn(split == DataSplit::Heterogeneous);
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    let tag = format!(
        "fig4_dnn_{}",
        if split == DataSplit::Heterogeneous { "hetero" } else { "homo" }
    );
    println!("\n== {tag} ==");
    println!("{:<22} {:>12} {:>12} {:>14}", "algorithm", "loss", "consensus", "bits/agent");
    let mut records = Vec::new();
    for s in &setups {
        let p = MlpProblem::new(&manifest, 8, 256, split, 42)?;
        let mut engine = Engine::new(
            EngineConfig {
                eta: s.eta,
                batch_size: Some(64),
                record_every: (rounds / 20).max(1),
                ..Default::default()
            },
            mix.clone(),
            Box::new(p),
        );
        let comp = if s.compressed { Some(paper_compressor()) } else { None };
        let rec = engine.run(s.build(), comp, rounds);
        let m = rec.last();
        let diverged = !m.loss.is_finite() || m.loss > 50.0;
        println!(
            "{:<22} {:>12} {:>12} {:>14.3e}{}",
            rec.algo,
            fmt(m.loss),
            fmt(m.consensus),
            m.bits_per_agent,
            if diverged { "  *diverged*" } else { "" }
        );
        if let Some(dir) = out {
            rec.write_csv(dir, &format!("{tag}_{}", s.algo)).expect("write csv");
        }
        records.push(rec);
    }
    Ok(records)
}

/// Fig. 5: relative compression error of p-norm b-bit quantization,
/// p ∈ {1, 2, 3, …, 6, ∞}, averaged over 100 random vectors in R^10000.
pub fn fig5(out: Option<&Path>) -> Vec<(String, u32, f64)> {
    let d = 10_000;
    let trials = 100;
    let mut rng = Rng::new(7);
    let vectors: Vec<Vec<f64>> = (0..trials)
        .map(|_| {
            let mut v = vec![0.0f64; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    println!("\n== fig5: relative error ‖x−Q(x)‖/‖x‖, p-norm b-bit quantization ==");
    println!("{:<8} {:>6} {:>12}", "norm", "bits", "rel err");
    let mut rows = Vec::new();
    let mut csv = String::from("norm,bits,rel_err\n");
    for (label, norm) in [
        ("p=1", PNorm::P(1.0)),
        ("p=2", PNorm::P(2.0)),
        ("p=3", PNorm::P(3.0)),
        ("p=4", PNorm::P(4.0)),
        ("p=6", PNorm::P(6.0)),
        ("inf", PNorm::Inf),
    ] {
        for bits in [2u32, 4, 6, 8] {
            let q = QuantizeP::new(bits, norm, d); // whole-vector (paper C.2)
            let mut acc = 0.0;
            let mut qrng = Rng::new(17);
            for v in &vectors {
                acc += crate::compress::relative_error(&q, v, &mut qrng, 1);
            }
            let err = acc / trials as f64;
            println!("{label:<8} {bits:>6} {err:>12.4e}");
            csv.push_str(&format!("{label},{bits},{err:e}\n"));
            rows.push((label.to_string(), bits, err));
        }
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("fig5_pnorm_error.csv"), csv).ok();
    }
    rows
}

/// Fig. 6: error-per-bit across compression families (q∞ vs top-k vs
/// random-k), same random vectors as Fig. 5.
pub fn fig6(out: Option<&Path>) -> Vec<(String, f64, f64)> {
    let d = 10_000;
    let trials = 40;
    let mut rng = Rng::new(7);
    let vectors: Vec<Vec<f64>> = (0..trials)
        .map(|_| {
            let mut v = vec![0.0f64; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    println!("\n== fig6: rel err vs avg bits/element across methods ==");
    println!("{:<22} {:>12} {:>12}", "method", "bits/elem", "rel err");
    let mut rows = Vec::new();
    let mut csv = String::from("method,bits_per_elem,rel_err\n");
    let mut eval = |c: Box<dyn Compressor>| {
        let mut qrng = Rng::new(23);
        let mut acc_err = 0.0;
        let mut acc_bits = 0.0;
        let mut msg = crate::compress::CompressedMsg::with_dim(d);
        for v in &vectors {
            c.compress(v, &mut qrng, &mut msg);
            acc_bits += msg.wire_bits as f64 / d as f64;
            acc_err += crate::linalg::dist_sq(v, &msg.values).sqrt() / crate::linalg::norm2(v);
        }
        let (bits, err) = (acc_bits / trials as f64, acc_err / trials as f64);
        println!("{:<22} {:>12.3} {:>12.4e}", c.name(), bits, err);
        csv.push_str(&format!("{},{bits},{err:e}\n", c.name()));
        rows.push((c.name(), bits, err));
    };
    for bits in [1u32, 2, 4, 6, 8] {
        eval(Box::new(QuantizeP::new(bits, PNorm::Inf, 512)));
    }
    for k in [100usize, 400, 1000, 2500] {
        eval(Box::new(TopK::new(k)));
    }
    for k in [100usize, 400, 1000, 2500] {
        eval(Box::new(RandK::new(k, false)));
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("fig6_methods.csv"), csv).ok();
    }
    rows
}

/// Fig. 7: LEAD sensitivity over the (α, γ) grid on linear regression;
/// the paper's claim is that nearly every cell converges.
pub fn fig7(out: Option<&Path>, rounds: usize) -> Vec<(f64, f64, Option<usize>)> {
    let alphas = [0.1, 0.3, 0.5, 0.7, 0.9];
    let gammas = [0.2, 0.5, 1.0, 1.5, 2.0];
    println!("\n== fig7: LEAD (α, γ) sensitivity — rounds to dist 1e-6 ==");
    print!("{:>6}", "α\\γ");
    for g in gammas {
        print!("{g:>9}");
    }
    println!();
    let mut rows = Vec::new();
    let mut csv = String::from("alpha,gamma,rounds_to_1e6\n");
    for a in alphas {
        print!("{a:>6}");
        for g in gammas {
            let p = LinReg::synthetic(8, 200, 0.1, 42);
            let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
            let mut e = Engine::new(
                EngineConfig { eta: 0.1, record_every: 10, ..Default::default() },
                mix,
                Box::new(p),
            );
            let rec = e.run(
                Box::new(crate::algorithms::lead::Lead::new(
                    crate::algorithms::lead::LeadParams { gamma: g, alpha: a },
                )),
                Some(paper_compressor()),
                rounds,
            );
            let hit = rec.rounds_to_tol(1e-6);
            match hit {
                Some(r) => print!("{r:>9}"),
                None => print!("{:>9}", "-"),
            }
            csv.push_str(&format!("{a},{g},{}\n", hit.map_or(-1i64, |r| r as i64)));
            rows.push((a, g, hit));
        }
        println!();
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("fig7_sensitivity.csv"), csv).ok();
    }
    rows
}

/// Print the paper's parameter tables (Appendix D.3) as configured here.
pub fn tables() {
    let dump = |name: &str, t: &[AlgoSetup]| {
        println!("\n== {name} ==");
        println!("{:<16} {:>6} {:>7} {:>7}", "algorithm", "η", "γ", "α");
        for s in t {
            println!(
                "{:<16} {:>6} {:>7} {:>7}",
                s.algo,
                s.eta,
                if s.gamma.is_nan() { "-".into() } else { format!("{}", s.gamma) },
                if s.alpha.is_nan() { "-".into() } else { format!("{}", s.alpha) }
            );
        }
    };
    dump("Table 1 (linreg)", &config::table1_linreg());
    dump("Table 2 homo (logreg full)", &config::table2_logreg_full(false));
    dump("Table 2 hetero (logreg full)", &config::table2_logreg_full(true));
    dump("Table 3 (logreg minibatch)", &config::table3_logreg_minibatch());
    dump("Table 4 homo (dnn)", &config::table4_dnn(false));
    dump("Table 4 hetero (dnn)", &config::table4_dnn(true));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_ordering_matches_paper() {
        // Short version of the Fig. 5 claim: at every bit width, larger p
        // compresses better, ∞ best.
        let rows = fig5(None);
        for bits in [2u32, 4, 6, 8] {
            let get = |label: &str| {
                rows.iter().find(|(l, b, _)| l == label && *b == bits).unwrap().2
            };
            assert!(get("p=1") > get("p=2"));
            assert!(get("p=2") > get("p=6"));
            assert!(get("p=6") > get("inf"));
        }
    }

    #[test]
    fn fig7_paper_default_cell_converges() {
        let rows = fig7(None, 800);
        let cell = rows
            .iter()
            .find(|(a, g, _)| (*a - 0.5).abs() < 1e-9 && (*g - 1.0).abs() < 1e-9)
            .unwrap();
        assert!(cell.2.is_some(), "paper default (α=0.5, γ=1) must converge");
        // Robustness claim: a large majority of the grid converges.
        let ok = rows.iter().filter(|r| r.2.is_some()).count();
        assert!(ok * 10 >= rows.len() * 7, "only {ok}/{} cells converged", rows.len());
    }
}
