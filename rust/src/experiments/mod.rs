//! Experiment drivers — one per figure/table in the paper's evaluation
//! (see DESIGN.md §5 for the index). Every engine-driven figure is
//! expressed as a batch of [`RunSpec`]s (a *grid*) executed by the
//! sharded [`Driver`] under one shared thread budget
//! (`crate::scenarios`); drivers print the paper-style series/rows to
//! stdout and, given an output directory, the driver writes one CSV per
//! cell plus a unified `<grid>.json` artifact, and the figure adds its
//! aggregate CSV.
//!
//! # RunSpec / Grid in brief
//!
//! A [`RunSpec`] is one cell as plain data — problem, topology + mixing
//! rule + agent count, algorithm setup (name, η, γ, α), compressor spec
//! string, rounds, stepsize schedule, seed. Batches come from preset
//! tables ([`crate::scenarios::specs_from_setups`] — rows applied
//! jointly) or cartesian [`Grid`] axes; the same machinery backs
//! `lead grid <spec.toml>`:
//!
//! ```toml
//! [grid]
//! name = "sweep"
//! rounds = 800
//! compressor = "qinf:2:512"
//! tol = 1e-6                   # optional: per-run time_to_tol in <grid>.json
//!
//! [problem]
//! kind = "linreg"
//! dim = 200
//!
//! [axes]
//! alpha = [0.1, 0.3, 0.5, 0.7, 0.9]
//! gamma = [0.2, 0.5, 1.0, 1.5, 2.0]
//! # Network conditions are an axis too (`lead::simnet` specs; the
//! # timing overlay never changes trajectories, only the time axis):
//! # link = ["uniform:1e-4:1e9", "lognormal:1e-3:1e8:0.75",
//! #         "straggler:1e-4:1e9:0.25:10:drop=0.01"]
//! # Sweeping `seed` additionally emits mean ± std aggregate bands per
//! # cell into <grid>.json (scenarios §Seed-axis aggregation); see
//! # examples/time_to_accuracy.toml for the full time-to-accuracy grid.
//! # Fault plans are an axis too (`lead::faults` specs; unlike `link`
//! # these DO perturb trajectories — deterministically, from the
//! # dedicated fault RNG stream):
//! # faults = ["none", "loss:0.05", "crash:0.25:100:down=40",
//! #           "churn:0.01+loss:0.02:stale=2"]
//! # Degraded-inbox contract: a lost in-link folds its weight into the
//! # receiver's self weight (row stays stochastic); crashed agents skip
//! # their apply entirely (state frozen, including LEAD's h / CHOCO's
//! # x̂ reference points). `time_budget = <secs>` stops every cell once
//! # sim_time crosses it (record flags stopped_early); see
//! # examples/fault_tolerance.toml for the full graceful-degradation grid.
//! # Message-passing backends are an axis too (`lead::transport` specs;
//! # lossless transports never change trajectories — only the frame
//! # counters in each cell's record — so the axis A/Bs the runtime, not
//! # the math). Compressed cells need a wire-complete codec (topk, q*):
//! # transport = ["mem", "channel", "mux:8"]
//! ```
//!
//! Any grid TOML also drives `lead trace <spec.toml> [--out DIR]
//! [--rounds N]`: the same cells re-run with the deterministic trace
//! recorder on (`crate::trace` §Observability contract — tracing never
//! changes a trajectory bit) and each cell exports a Chrome trace-event
//! JSON (`<name>.trace.json`, openable in `chrome://tracing` /
//! Perfetto) showing per-phase spans, pool dispatch/wake latencies,
//! transport frames, and simnet/fault timeline marks. `lead net-report`
//! additionally appends a per-phase wall-time and frame-counter
//! breakdown table per cell.
//!
//! Determinism: grids are bitwise-identical at any thread count (every
//! run derives its randomness from its own seed), so these drivers
//! reproduce the exact trajectories of the historical serial loops.

pub mod ablations;

use crate::compress::quantize::{PNorm, QuantizeP};
use crate::compress::{randk::RandK, topk::TopK, Compressor};
use crate::config::{self, AlgoSetup};
use crate::coordinator::metrics::RunRecord;
use crate::error::Result;
use crate::problems::DataSplit;
use crate::rng::Rng;
use crate::scenarios::{specs_from_setups, Driver, Grid, ProblemSpec, RunSpec};
use crate::serialize::toml_mini::Value;
use std::path::Path;

/// Shared thread budget for the experiment drivers (historically the
/// per-engine gradient pool size; now the grid driver's outer+inner
/// budget).
const EXP_THREADS: usize = 8;

fn run_table(
    problem: ProblemSpec,
    setups: &[AlgoSetup],
    rounds: usize,
    batch: Option<usize>,
    out: Option<&Path>,
    tag: &str,
) -> Result<Vec<RunRecord>> {
    let base = RunSpec {
        problem,
        rounds,
        batch_size: batch,
        record_every: (rounds / 100).max(1),
        ..RunSpec::paper_default()
    };
    let specs = specs_from_setups(tag, &base, setups);
    let records = Driver::new(EXP_THREADS).with_out(out).run(tag, &specs)?;
    println!("\n== {tag} ==");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>14} {:>10}",
        "algorithm", "dist(x*)", "consensus", "comp err", "bits/agent", "secs"
    );
    for rec in &records {
        let m = rec.last();
        let diverged = !m.dist_opt.is_finite() && !m.loss.is_finite();
        println!(
            "{:<22} {:>12} {:>12} {:>12} {:>14.3e} {:>10.2}{}",
            rec.algo,
            fmt(m.dist_opt),
            fmt(m.consensus),
            fmt(m.comp_err),
            m.bits_per_agent,
            rec.wall_secs,
            if diverged { "  *diverged*" } else { "" }
        );
    }
    Ok(records)
}

fn fmt(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3e}")
    } else {
        "nan/div".into()
    }
}

/// Fig. 1 (a–d): linear regression on the 8-ring, full gradient, 2-bit q∞.
pub fn fig1(out: Option<&Path>, rounds: usize) -> Result<Vec<RunRecord>> {
    let recs = run_table(
        ProblemSpec::LinReg { dim: 200, reg: 0.1, seed: 42 },
        &config::table1_linreg(),
        rounds,
        None,
        out,
        "fig1_linreg",
    )?;
    // Fig. 1b companion: bits to reach 1e-6.
    println!("-- bits/agent to reach dist 1e-6 (Fig. 1b) --");
    for r in &recs {
        match r.bits_to_tol(1e-6) {
            Some(b) => println!("{:<22} {b:.3e}", r.algo),
            None => println!("{:<22} not reached", r.algo),
        }
    }
    Ok(recs)
}

/// Figs. 2/8 (full-batch) and 3/9 (mini-batch 512) — logistic regression.
pub fn fig_logreg(
    split: DataSplit,
    minibatch: bool,
    out: Option<&Path>,
    rounds: usize,
    n_total: usize,
) -> Result<Vec<RunRecord>> {
    let setups = if minibatch {
        config::table3_logreg_minibatch()
    } else {
        config::table2_logreg_full(split == DataSplit::Heterogeneous)
    };
    let tag = format!(
        "fig_logreg_{}_{}",
        if split == DataSplit::Heterogeneous { "hetero" } else { "homo" },
        if minibatch { "minibatch" } else { "full" }
    );
    run_table(
        ProblemSpec::LogReg { n_total, split, seed: 42 },
        &setups,
        rounds,
        if minibatch { Some(512) } else { None },
        out,
        &tag,
    )
}

/// Fig. 4: "deep net" (MLP on synthetic CIFAR-shaped data via PJRT).
/// Reports loss trajectories; divergence shows up as NaN (the paper's *).
/// The PJRT problem is not plain data, so it rides the grid as a
/// [`ProblemSpec::Shared`] instance (built once, shared across setups).
pub fn fig4(split: DataSplit, out: Option<&Path>, rounds: usize) -> Result<Vec<RunRecord>> {
    use crate::problems::neural::MlpProblem;
    let manifest = crate::runtime::Manifest::load("artifacts")?;
    let setups = config::table4_dnn(split == DataSplit::Heterogeneous);
    let tag = format!(
        "fig4_dnn_{}",
        if split == DataSplit::Heterogeneous { "hetero" } else { "homo" }
    );
    let problem = std::sync::Arc::new(MlpProblem::new(&manifest, 8, 256, split, 42)?);
    let base = RunSpec {
        problem: ProblemSpec::Shared(problem),
        rounds,
        batch_size: Some(64),
        record_every: (rounds / 20).max(1),
        ..RunSpec::paper_default()
    };
    let specs = specs_from_setups(&tag, &base, &setups);
    let records = Driver::new(EXP_THREADS).with_out(out).run(&tag, &specs)?;
    println!("\n== {tag} ==");
    println!("{:<22} {:>12} {:>12} {:>14}", "algorithm", "loss", "consensus", "bits/agent");
    for rec in &records {
        let m = rec.last();
        let diverged = !m.loss.is_finite() || m.loss > 50.0;
        println!(
            "{:<22} {:>12} {:>12} {:>14.3e}{}",
            rec.algo,
            fmt(m.loss),
            fmt(m.consensus),
            m.bits_per_agent,
            if diverged { "  *diverged*" } else { "" }
        );
    }
    Ok(records)
}

/// Fig. 5: relative compression error of p-norm b-bit quantization,
/// p ∈ {1, 2, 3, …, 6, ∞}, averaged over 100 random vectors in R^10000.
/// (Pure codec evaluation — no engine runs, so no grid.)
pub fn fig5(out: Option<&Path>) -> Result<Vec<(String, u32, f64)>> {
    let d = 10_000;
    let trials = 100;
    // audit:allow(rng_stream): fixed figure-synthesis seed for the fig5 random vectors (pure codec eval; the engine stream tree is not in play)
    let mut rng = Rng::new(7);
    let vectors: Vec<Vec<f64>> = (0..trials)
        .map(|_| {
            let mut v = vec![0.0f64; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    println!("\n== fig5: relative error ‖x−Q(x)‖/‖x‖, p-norm b-bit quantization ==");
    println!("{:<8} {:>6} {:>12}", "norm", "bits", "rel err");
    let mut rows = Vec::new();
    let mut csv = String::from("norm,bits,rel_err\n");
    for (label, norm) in [
        ("p=1", PNorm::P(1.0)),
        ("p=2", PNorm::P(2.0)),
        ("p=3", PNorm::P(3.0)),
        ("p=4", PNorm::P(4.0)),
        ("p=6", PNorm::P(6.0)),
        ("inf", PNorm::Inf),
    ] {
        for bits in [2u32, 4, 6, 8] {
            let q = QuantizeP::new(bits, norm, d); // whole-vector (paper C.2)
            let mut acc = 0.0;
            // audit:allow(rng_stream): fixed dither seed, reset per (norm, bits) cell so every quantizer sees identical draws
            let mut qrng = Rng::new(17);
            for v in &vectors {
                acc += crate::compress::relative_error(&q, v, &mut qrng, 1);
            }
            let err = acc / trials as f64;
            println!("{label:<8} {bits:>6} {err:>12.4e}");
            csv.push_str(&format!("{label},{bits},{err:e}\n"));
            rows.push((label.to_string(), bits, err));
        }
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("fig5_pnorm_error.csv"), csv)?;
    }
    Ok(rows)
}

/// Fig. 6: error-per-bit across compression families (q∞ vs top-k vs
/// random-k), same random vectors as Fig. 5. (Pure codec evaluation.)
pub fn fig6(out: Option<&Path>) -> Result<Vec<(String, f64, f64)>> {
    let d = 10_000;
    let trials = 40;
    // audit:allow(rng_stream): fixed figure-synthesis seed for the fig6 random vectors (pure codec eval; the engine stream tree is not in play)
    let mut rng = Rng::new(7);
    let vectors: Vec<Vec<f64>> = (0..trials)
        .map(|_| {
            let mut v = vec![0.0f64; d];
            rng.fill_normal(&mut v, 1.0);
            v
        })
        .collect();
    println!("\n== fig6: rel err vs avg bits/element across methods ==");
    println!("{:<22} {:>12} {:>12}", "method", "bits/elem", "rel err");
    let mut rows = Vec::new();
    let mut csv = String::from("method,bits_per_elem,rel_err\n");
    let mut eval = |c: Box<dyn Compressor>| {
        // audit:allow(rng_stream): fixed codec seed, reset per method so every compression family sees identical draws
        let mut qrng = Rng::new(23);
        let mut acc_err = 0.0;
        let mut acc_bits = 0.0;
        let mut msg = crate::compress::CompressedMsg::with_dim(d);
        for v in &vectors {
            c.compress(v, &mut qrng, &mut msg);
            acc_bits += msg.wire_bits as f64 / d as f64;
            acc_err += crate::linalg::dist_sq(v, &msg.values).sqrt() / crate::linalg::norm2(v);
        }
        let (bits, err) = (acc_bits / trials as f64, acc_err / trials as f64);
        println!("{:<22} {:>12.3} {:>12.4e}", c.name(), bits, err);
        csv.push_str(&format!("{},{bits},{err:e}\n", c.name()));
        rows.push((c.name(), bits, err));
    };
    for bits in [1u32, 2, 4, 6, 8] {
        eval(Box::new(QuantizeP::new(bits, PNorm::Inf, 512)));
    }
    for k in [100usize, 400, 1000, 2500] {
        eval(Box::new(TopK::new(k)));
    }
    for k in [100usize, 400, 1000, 2500] {
        eval(Box::new(RandK::new(k, false)));
    }
    drop(eval);
    if let Some(dir) = out {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("fig6_methods.csv"), csv)?;
    }
    Ok(rows)
}

/// The fig7 sensitivity sweep as a declarative grid: LEAD over the paper's
/// (α, γ) cartesian product on the Fig. 1 workload. Shared by the driver
/// below, the determinism pin (`scenarios::tests`), and
/// `benches/grid.rs`.
pub fn fig7_grid(rounds: usize) -> Grid {
    Grid {
        name: "fig7".into(),
        base: RunSpec {
            rounds,
            // Engine defaults of the historical driver: η=0.1, seed 42,
            // record every 10 rounds.
            ..RunSpec::paper_default()
        },
        axes: vec![
            (
                "alpha".into(),
                [0.1, 0.3, 0.5, 0.7, 0.9].iter().map(|&v| Value::Float(v)).collect(),
            ),
            (
                "gamma".into(),
                [0.2, 0.5, 1.0, 1.5, 2.0].iter().map(|&v| Value::Float(v)).collect(),
            ),
        ],
        tol: None,
    }
}

/// Fig. 7: LEAD sensitivity over the (α, γ) grid on linear regression;
/// the paper's claim is that nearly every cell converges.
pub fn fig7(out: Option<&Path>, rounds: usize) -> Result<Vec<(f64, f64, Option<usize>)>> {
    let grid = fig7_grid(rounds);
    let specs = grid.expand()?;
    let records = Driver::new(EXP_THREADS).with_out(out).run(&grid.name, &specs)?;
    // Table shape follows the grid: the innermost (gamma) axis is one
    // printed row, so header and row stride are derived rather than
    // duplicating fig7_grid's axis values here.
    let stride = grid.axes.last().map_or(1, |(_, v)| v.len()).max(1);
    println!("\n== fig7: LEAD (α, γ) sensitivity — rounds to dist 1e-6 ==");
    print!("{:>6}", "α\\γ");
    for s in &specs[..stride.min(specs.len())] {
        print!("{:>9}", s.gamma);
    }
    println!();
    let mut rows = Vec::new();
    let mut csv = String::from("alpha,gamma,rounds_to_1e6\n");
    for (s, rec) in specs.iter().zip(&records) {
        if rows.len() % stride == 0 {
            print!("{:>6}", s.alpha);
        }
        let hit = rec.rounds_to_tol(1e-6);
        match hit {
            Some(r) => print!("{r:>9}"),
            None => print!("{:>9}", "-"),
        }
        if rows.len() % stride == stride - 1 {
            println!();
        }
        csv.push_str(&format!("{},{},{}\n", s.alpha, s.gamma, hit.map_or(-1i64, |r| r as i64)));
        rows.push((s.alpha, s.gamma, hit));
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("fig7_sensitivity.csv"), csv)?;
    }
    Ok(rows)
}

/// Print the paper's parameter tables (Appendix D.3) as configured here.
pub fn tables() {
    let dump = |name: &str, t: &[AlgoSetup]| {
        println!("\n== {name} ==");
        println!("{:<16} {:>6} {:>7} {:>7}", "algorithm", "η", "γ", "α");
        for s in t {
            println!(
                "{:<16} {:>6} {:>7} {:>7}",
                s.algo,
                s.eta,
                if s.gamma.is_nan() { "-".into() } else { format!("{}", s.gamma) },
                if s.alpha.is_nan() { "-".into() } else { format!("{}", s.alpha) }
            );
        }
    };
    dump("Table 1 (linreg)", &config::table1_linreg());
    dump("Table 2 homo (logreg full)", &config::table2_logreg_full(false));
    dump("Table 2 hetero (logreg full)", &config::table2_logreg_full(true));
    dump("Table 3 (logreg minibatch)", &config::table3_logreg_minibatch());
    dump("Table 4 homo (dnn)", &config::table4_dnn(false));
    dump("Table 4 hetero (dnn)", &config::table4_dnn(true));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_ordering_matches_paper() {
        // Short version of the Fig. 5 claim: at every bit width, larger p
        // compresses better, ∞ best.
        let rows = fig5(None).unwrap();
        for bits in [2u32, 4, 6, 8] {
            let get = |label: &str| {
                rows.iter().find(|(l, b, _)| l == label && *b == bits).unwrap().2
            };
            assert!(get("p=1") > get("p=2"));
            assert!(get("p=2") > get("p=6"));
            assert!(get("p=6") > get("inf"));
        }
    }

    #[test]
    fn fig7_paper_default_cell_converges() {
        let rows = fig7(None, 800).unwrap();
        let cell = rows
            .iter()
            .find(|(a, g, _)| (*a - 0.5).abs() < 1e-9 && (*g - 1.0).abs() < 1e-9)
            .unwrap();
        assert!(cell.2.is_some(), "paper default (α=0.5, γ=1) must converge");
        // Robustness claim: a large majority of the grid converges.
        let ok = rows.iter().filter(|r| r.2.is_some()).count();
        assert!(ok * 10 >= rows.len() * 7, "only {ok}/{} cells converged", rows.len());
    }
}
