//! Ablation studies over the design choices DESIGN.md §5 calls out —
//! beyond the paper's own figures, these probe *why* LEAD behaves as it
//! does. Each ablation is a declarative [`RunSpec`] batch through the
//! sharded [`Driver`] (see `crate::scenarios`): one shared problem
//! instance, whole-run outer parallelism, bitwise-identical to the
//! historical serial loops.
//!
//! * **topology**: iteration complexity vs the graph condition number κ_g
//!   (Corollary 1 predicts O(κ_f + κ_g) scaling at C ≈ 0);
//! * **bit width**: the bits-per-round vs rounds-to-accuracy trade-off —
//!   where the total-communication optimum sits;
//! * **block size**: blockwise norms vs one global norm (the paper's
//!   block = 512 choice);
//! * **state momentum**: α-update (LEAD) vs raw integration (CHOCO-style
//!   h ← h + q, i.e. α = 1) under aggressive compression (Remark 1).

use crate::coordinator::metrics::RunRecord;
use crate::error::Result;
use crate::scenarios::{Driver, ProblemSpec, RunSpec};
use crate::topology::MixingRule;
use std::path::Path;

/// Shared thread budget (matches `experiments::EXP_THREADS`).
const ABL_THREADS: usize = 8;

/// Common base cell for every ablation: LEAD with paper defaults on the
/// synthetic d = 64 linear regression, Metropolis–Hastings mixing,
/// metrics every 5 rounds (the historical `lead_run` harness).
fn ablation_base(agents: usize, rounds: usize) -> RunSpec {
    RunSpec {
        problem: ProblemSpec::LinReg { dim: 64, reg: 0.1, seed: 42 },
        mixing: MixingRule::MetropolisHastings,
        agents,
        rounds,
        record_every: 5,
        ..RunSpec::paper_default()
    }
}

fn run_batch(tag: &str, specs: &[RunSpec], out: Option<&Path>) -> Result<Vec<RunRecord>> {
    Driver::new(ABL_THREADS).with_out(out).run(tag, specs)
}

fn write_csv(out: Option<&Path>, name: &str, csv: String) -> Result<()> {
    if let Some(dir) = out {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(name), csv)?;
    }
    Ok(())
}

/// Topology ablation: rounds-to-1e-8 vs κ_g across graph families.
pub fn topology(out: Option<&Path>) -> Result<Vec<(String, f64, Option<usize>)>> {
    // "er:0.4:3" pins the sampled graph to the historical seed 3
    // regardless of the spec's engine seed.
    let topos = ["full", "grid", "er:0.4:3", "star", "ring", "path"];
    let specs: Vec<RunSpec> = topos
        .iter()
        .map(|t| {
            let mut s = ablation_base(16, 4000);
            s.topology = (*t).into();
            s.name = format!("ablation_topology_{}", t.replace(':', "_"));
            s
        })
        .collect();
    let recs = run_batch("ablation_topology", &specs, out)?;
    println!("\n== ablation: topology (LEAD 2-bit, n=16) ==");
    println!("{:<12} {:>8} {:>8} {:>16}", "graph", "κ_g", "β", "rounds→1e-8");
    let mut rows = Vec::new();
    let mut csv = String::from("graph,kappa_g,beta,rounds\n");
    for (spec, rec) in specs.iter().zip(&recs) {
        let mix = spec.build_mix()?;
        let hit = rec.rounds_to_tol(1e-8);
        let name = &spec.topology;
        println!(
            "{name:<12} {:>8.2} {:>8.3} {:>16}",
            mix.kappa_g(),
            mix.beta(),
            hit.map_or("-".into(), |r| r.to_string())
        );
        csv.push_str(&format!(
            "{name},{},{},{}\n",
            mix.kappa_g(),
            mix.beta(),
            hit.map_or(-1, |r| r as i64)
        ));
        rows.push((name.clone(), mix.kappa_g(), hit));
    }
    write_csv(out, "ablation_topology.csv", csv)?;
    Ok(rows)
}

/// Bit-width ablation: total bits to reach 1e-8 as a function of b —
/// reveals the communication-optimal quantization level. γ moves jointly
/// with b (shrinks with compression error per Eq. (9)), so this is a
/// tuple batch rather than a cartesian axis.
pub fn bits(out: Option<&Path>) -> Result<Vec<(u32, Option<f64>)>> {
    let widths = [1u32, 2, 3, 4, 6, 8, 12];
    let specs: Vec<RunSpec> = widths
        .iter()
        .map(|&b| {
            let mut s = ablation_base(8, 6000);
            s.compressor = format!("qinf:{b}:512");
            s.gamma = if b == 1 { 0.6 } else { 1.0 };
            s.name = format!("ablation_bits_{b}");
            s
        })
        .collect();
    let recs = run_batch("ablation_bits", &specs, out)?;
    println!("\n== ablation: quantization bit width (LEAD, ring n=8) ==");
    println!("{:<6} {:>16} {:>18}", "bits", "rounds→1e-8", "bits/agent→1e-8");
    let mut rows = Vec::new();
    let mut csv = String::from("bits,rounds,bits_per_agent\n");
    for (&b, rec) in widths.iter().zip(&recs) {
        let r = rec.rounds_to_tol(1e-8);
        let bits = rec.bits_to_tol(1e-8);
        println!(
            "{b:<6} {:>16} {:>18}",
            r.map_or("-".into(), |x| x.to_string()),
            bits.map_or("-".into(), |x| format!("{x:.3e}"))
        );
        csv.push_str(&format!("{b},{},{}\n", r.map_or(-1, |x| x as i64), bits.unwrap_or(-1.0)));
        rows.push((b, bits));
    }
    write_csv(out, "ablation_bits.csv", csv)?;
    Ok(rows)
}

/// Block-size ablation for the blockwise norm (paper uses 512).
pub fn block_size(out: Option<&Path>) -> Result<Vec<(usize, Option<usize>)>> {
    let blocks = [8usize, 16, 32, 64, 512];
    let specs: Vec<RunSpec> = blocks
        .iter()
        .map(|&block| {
            let mut s = ablation_base(8, 4000);
            s.compressor = format!("qinf:2:{block}");
            s.name = format!("ablation_block_{block}");
            s
        })
        .collect();
    let recs = run_batch("ablation_block", &specs, out)?;
    println!("\n== ablation: quantization block size (LEAD 2-bit, ring n=8, d=64) ==");
    println!("{:<8} {:>16}", "block", "rounds→1e-8");
    let mut rows = Vec::new();
    let mut csv = String::from("block,rounds\n");
    for (&block, rec) in blocks.iter().zip(&recs) {
        let r = rec.rounds_to_tol(1e-8);
        println!("{block:<8} {:>16}", r.map_or("-".into(), |x| x.to_string()));
        csv.push_str(&format!("{block},{}\n", r.map_or(-1, |x| x as i64)));
        rows.push((block, r));
    }
    write_csv(out, "ablation_block.csv", csv)?;
    Ok(rows)
}

/// Momentum-state ablation (Remark 1): LEAD's α-damped state update vs
/// the CHOCO-style raw integration (α = 1) under aggressive 1-bit
/// compression — the damped update should stay stable further.
pub fn momentum(out: Option<&Path>) -> Result<Vec<(f64, f64)>> {
    let alphas = [0.25, 0.5, 0.75, 1.0];
    let specs: Vec<RunSpec> = alphas
        .iter()
        .map(|&alpha| {
            let mut s = ablation_base(8, 2000);
            s.compressor = "qinf:1:64".into();
            s.gamma = 0.6;
            s.alpha = alpha;
            s.name = format!("ablation_momentum_{alpha}");
            s
        })
        .collect();
    let recs = run_batch("ablation_momentum", &specs, out)?;
    println!("\n== ablation: H-update momentum α under 1-bit compression ==");
    println!("{:<8} {:>14}", "α", "final dist");
    let mut rows = Vec::new();
    let mut csv = String::from("alpha,final_dist\n");
    for (&alpha, rec) in alphas.iter().zip(&recs) {
        let dist = rec.last().dist_opt;
        println!("{alpha:<8} {:>14.3e}", dist);
        csv.push_str(&format!("{alpha},{dist:e}\n"));
        rows.push((alpha, dist));
    }
    write_csv(out, "ablation_momentum.csv", csv)?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_complexity_tracks_kappa_g() {
        let rows = topology(None).unwrap();
        // Corollary 1: better-conditioned graphs need no more rounds.
        let full = rows.iter().find(|r| r.0 == "full").unwrap();
        let path = rows.iter().find(|r| r.0 == "path").unwrap();
        let (Some(rf), Some(rp)) = (full.2, path.2) else {
            panic!("both must converge: {rows:?}");
        };
        assert!(full.1 < path.1, "κ_g(full) < κ_g(path)");
        assert!(rf < rp, "full graph should need fewer rounds ({rf} vs {rp})");
    }

    #[test]
    fn two_bits_nearly_optimal_total_communication() {
        // The paper's 2-bit choice: within the bit-width sweep, very low
        // bit widths minimize the total bits to accuracy.
        let rows = bits(None).unwrap();
        let best = rows
            .iter()
            .filter_map(|(b, bits)| bits.map(|x| (*b, x)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(best.0 <= 4, "total-bits optimum at {} bits — expected ≤ 4", best.0);
        // 12-bit must cost more total bits than the optimum.
        let twelve = rows.iter().find(|(b, _)| *b == 12).unwrap().1.unwrap();
        assert!(twelve > best.1);
    }
}
