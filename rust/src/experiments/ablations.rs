//! Ablation studies over the design choices DESIGN.md §5 calls out —
//! beyond the paper's own figures, these probe *why* LEAD behaves as it
//! does:
//!
//! * **topology**: iteration complexity vs the graph condition number κ_g
//!   (Corollary 1 predicts O(κ_f + κ_g) scaling at C ≈ 0);
//! * **bit width**: the bits-per-round vs rounds-to-accuracy trade-off —
//!   where the total-communication optimum sits;
//! * **block size**: blockwise norms vs one global norm (the paper's
//!   block = 512 choice);
//! * **state momentum**: α-update (LEAD) vs raw integration (CHOCO-style
//!   h ← h + q, i.e. α = 1) under aggressive compression (Remark 1).

use crate::algorithms::lead::{Lead, LeadParams};
use crate::compress::quantize::{PNorm, QuantizeP};
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::problems::linreg::LinReg;
use crate::topology::{MixingRule, Topology};
use std::path::Path;

fn lead_run(
    topo: &Topology,
    n: usize,
    comp: QuantizeP,
    params: LeadParams,
    rounds: usize,
) -> crate::coordinator::metrics::RunRecord {
    let p = LinReg::synthetic(n, 64, 0.1, 42);
    let mix = topo.build(n, MixingRule::MetropolisHastings);
    let mut e = Engine::new(
        EngineConfig { record_every: 5, ..Default::default() },
        mix,
        Box::new(p),
    );
    e.run(Box::new(Lead::new(params)), Some(Box::new(comp)), rounds)
}

/// Topology ablation: rounds-to-1e-8 vs κ_g across graph families.
pub fn topology(out: Option<&Path>) -> Vec<(String, f64, Option<usize>)> {
    println!("\n== ablation: topology (LEAD 2-bit, n=16) ==");
    println!("{:<12} {:>8} {:>8} {:>16}", "graph", "κ_g", "β", "rounds→1e-8");
    let mut rows = Vec::new();
    let mut csv = String::from("graph,kappa_g,beta,rounds\n");
    for (name, topo) in [
        ("full", Topology::FullyConnected),
        ("grid", Topology::Grid2D),
        ("er:0.4", Topology::ErdosRenyi { p: 0.4, seed: 3 }),
        ("star", Topology::Star),
        ("ring", Topology::Ring),
        ("path", Topology::Path),
    ] {
        let mix = topo.build(16, MixingRule::MetropolisHastings);
        let rec = lead_run(&topo, 16, QuantizeP::paper_default(), LeadParams::default(), 4000);
        let hit = rec.rounds_to_tol(1e-8);
        println!(
            "{name:<12} {:>8.2} {:>8.3} {:>16}",
            mix.kappa_g(),
            mix.beta(),
            hit.map_or("-".into(), |r| r.to_string())
        );
        csv.push_str(&format!("{name},{},{},{}\n", mix.kappa_g(), mix.beta(), hit.map_or(-1, |r| r as i64)));
        rows.push((name.to_string(), mix.kappa_g(), hit));
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("ablation_topology.csv"), csv).ok();
    }
    rows
}

/// Bit-width ablation: total bits to reach 1e-8 as a function of b —
/// reveals the communication-optimal quantization level.
pub fn bits(out: Option<&Path>) -> Vec<(u32, Option<f64>)> {
    println!("\n== ablation: quantization bit width (LEAD, ring n=8) ==");
    println!("{:<6} {:>16} {:>18}", "bits", "rounds→1e-8", "bits/agent→1e-8");
    let mut rows = Vec::new();
    let mut csv = String::from("bits,rounds,bits_per_agent\n");
    for b in [1u32, 2, 3, 4, 6, 8, 12] {
        // γ shrinks with compression error per Eq. (9).
        let gamma = if b == 1 { 0.6 } else { 1.0 };
        let rec = lead_run(
            &Topology::Ring,
            8,
            QuantizeP::new(b, PNorm::Inf, 512),
            LeadParams { gamma, alpha: 0.5 },
            6000,
        );
        let r = rec.rounds_to_tol(1e-8);
        let bits = rec.bits_to_tol(1e-8);
        println!(
            "{b:<6} {:>16} {:>18}",
            r.map_or("-".into(), |x| x.to_string()),
            bits.map_or("-".into(), |x| format!("{x:.3e}"))
        );
        csv.push_str(&format!("{b},{},{}\n", r.map_or(-1, |x| x as i64), bits.unwrap_or(-1.0)));
        rows.push((b, bits));
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("ablation_bits.csv"), csv).ok();
    }
    rows
}

/// Block-size ablation for the blockwise norm (paper uses 512).
pub fn block_size(out: Option<&Path>) -> Vec<(usize, Option<usize>)> {
    println!("\n== ablation: quantization block size (LEAD 2-bit, ring n=8, d=64) ==");
    println!("{:<8} {:>16}", "block", "rounds→1e-8");
    let mut rows = Vec::new();
    let mut csv = String::from("block,rounds\n");
    for block in [8usize, 16, 32, 64, 512] {
        let rec = lead_run(
            &Topology::Ring,
            8,
            QuantizeP::new(2, PNorm::Inf, block),
            LeadParams::default(),
            4000,
        );
        let r = rec.rounds_to_tol(1e-8);
        println!("{block:<8} {:>16}", r.map_or("-".into(), |x| x.to_string()));
        csv.push_str(&format!("{block},{}\n", r.map_or(-1, |x| x as i64)));
        rows.push((block, r));
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("ablation_block.csv"), csv).ok();
    }
    rows
}

/// Momentum-state ablation (Remark 1): LEAD's α-damped state update vs
/// the CHOCO-style raw integration (α = 1) under aggressive 1-bit
/// compression — the damped update should stay stable further.
pub fn momentum(out: Option<&Path>) -> Vec<(f64, f64)> {
    println!("\n== ablation: H-update momentum α under 1-bit compression ==");
    println!("{:<8} {:>14}", "α", "final dist");
    let mut rows = Vec::new();
    let mut csv = String::from("alpha,final_dist\n");
    for alpha in [0.25, 0.5, 0.75, 1.0] {
        let rec = lead_run(
            &Topology::Ring,
            8,
            QuantizeP::new(1, PNorm::Inf, 64),
            LeadParams { gamma: 0.6, alpha },
            2000,
        );
        let dist = rec.last().dist_opt;
        println!("{alpha:<8} {:>14.3e}", dist);
        csv.push_str(&format!("{alpha},{dist:e}\n"));
        rows.push((alpha, dist));
    }
    if let Some(dir) = out {
        std::fs::create_dir_all(dir).ok();
        std::fs::write(dir.join("ablation_momentum.csv"), csv).ok();
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_complexity_tracks_kappa_g() {
        let rows = topology(None);
        // Corollary 1: better-conditioned graphs need no more rounds.
        let full = rows.iter().find(|r| r.0 == "full").unwrap();
        let path = rows.iter().find(|r| r.0 == "path").unwrap();
        let (Some(rf), Some(rp)) = (full.2, path.2) else {
            panic!("both must converge: {rows:?}");
        };
        assert!(full.1 < path.1, "κ_g(full) < κ_g(path)");
        assert!(rf < rp, "full graph should need fewer rounds ({rf} vs {rp})");
    }

    #[test]
    fn two_bits_nearly_optimal_total_communication() {
        // The paper's 2-bit choice: within the bit-width sweep, very low
        // bit widths minimize the total bits to accuracy.
        let rows = bits(None);
        let best = rows
            .iter()
            .filter_map(|(b, bits)| bits.map(|x| (*b, x)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(best.0 <= 4, "total-bits optimum at {} bits — expected ≤ 4", best.0);
        // 12-bit must cost more total bits than the optimum.
        let twelve = rows.iter().find(|(b, _)| *b == 12).unwrap().1.unwrap();
        assert!(twelve > best.1);
    }
}
