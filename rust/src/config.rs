//! Experiment configuration: the paper's tuned parameter tables (Appendix
//! D.3, Tables 1–4) as presets, an algorithm factory, and a TOML-subset
//! config loader for custom runs.

use crate::algorithms::{
    choco::ChocoSgd, d2::D2, deepsqueeze::DeepSqueeze, dgd::Dgd, diging::DiGing,
    exact_diffusion::ExactDiffusion, lead::{Lead, LeadParams}, nids::Nids, qdgd::Qdgd, Algorithm,
};
use crate::serialize::toml_mini;

/// One algorithm row of a paper parameter table.
#[derive(Clone, Debug)]
pub struct AlgoSetup {
    pub algo: String,
    pub eta: f64,
    /// γ for QDGD/DeepSqueeze/CHOCO/LEAD ("-" in the paper tables ⇒ NaN).
    pub gamma: f64,
    /// α for LEAD only.
    pub alpha: f64,
    /// Whether this algorithm passes through the compressor.
    pub compressed: bool,
}

impl AlgoSetup {
    fn new(algo: &str, eta: f64, gamma: f64, alpha: f64, compressed: bool) -> Self {
        AlgoSetup { algo: algo.into(), eta, gamma, alpha, compressed }
    }

    /// Instantiate the algorithm object for this row.
    pub fn build(&self) -> Box<dyn Algorithm> {
        build_algo(&self.algo, self.gamma, self.alpha).expect("unknown algorithm in preset")
    }
}

/// Algorithm factory by name.
pub fn build_algo(name: &str, gamma: f64, alpha: f64) -> Option<Box<dyn Algorithm>> {
    Some(match name.to_ascii_lowercase().as_str() {
        "lead" => Box::new(Lead::new(LeadParams { gamma, alpha })),
        "dgd" => Box::new(Dgd::new()),
        "nids" => Box::new(Nids::new()),
        "d2" => Box::new(D2::new()),
        "exactdiffusion" | "exact-diffusion" => Box::new(ExactDiffusion::new()),
        "diging" => Box::new(DiGing::new()),
        "qdgd" => Box::new(Qdgd::new(gamma)),
        "deepsqueeze" => Box::new(DeepSqueeze::new(gamma)),
        "choco" | "choco-sgd" => Box::new(ChocoSgd::new(gamma)),
        _ => return None,
    })
}

/// Table 1 — linear regression.
pub fn table1_linreg() -> Vec<AlgoSetup> {
    vec![
        AlgoSetup::new("dgd", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("nids", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("qdgd", 0.1, 0.2, f64::NAN, true),
        AlgoSetup::new("deepsqueeze", 0.1, 0.2, f64::NAN, true),
        AlgoSetup::new("choco", 0.1, 0.8, f64::NAN, true),
        AlgoSetup::new("lead", 0.1, 1.0, 0.5, true),
    ]
}

/// Table 2 — logistic regression, full-batch (homo | hetero columns).
pub fn table2_logreg_full(heterogeneous: bool) -> Vec<AlgoSetup> {
    let (q, ds, ch) = if heterogeneous { (0.2, 0.6, 0.6) } else { (0.4, 0.4, 0.6) };
    vec![
        AlgoSetup::new("dgd", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("nids", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("qdgd", 0.1, q, f64::NAN, true),
        AlgoSetup::new("deepsqueeze", 0.1, ds, f64::NAN, true),
        AlgoSetup::new("choco", 0.1, ch, f64::NAN, true),
        AlgoSetup::new("lead", 0.1, 1.0, 0.5, true),
    ]
}

/// Table 3 — logistic regression, mini-batch 512 (both splits share rows).
pub fn table3_logreg_minibatch() -> Vec<AlgoSetup> {
    vec![
        AlgoSetup::new("dgd", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("nids", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("qdgd", 0.05, 0.2, f64::NAN, true),
        AlgoSetup::new("deepsqueeze", 0.1, 0.6, f64::NAN, true),
        AlgoSetup::new("choco", 0.1, 0.6, f64::NAN, true),
        AlgoSetup::new("lead", 0.1, 1.0, 0.5, true),
    ]
}

/// Table 4 — deep net. In the heterogeneous column the paper reports
/// divergence (*) for QDGD/DeepSqueeze/CHOCO across every option tried;
/// we keep their homogeneous settings and *measure* the divergence.
pub fn table4_dnn(heterogeneous: bool) -> Vec<AlgoSetup> {
    let dgd_eta = if heterogeneous { 0.05 } else { 0.1 };
    vec![
        AlgoSetup::new("dgd", dgd_eta, f64::NAN, f64::NAN, false),
        AlgoSetup::new("nids", 0.1, f64::NAN, f64::NAN, false),
        AlgoSetup::new("qdgd", 0.05, 0.1, f64::NAN, true),
        AlgoSetup::new("deepsqueeze", 0.1, 0.2, f64::NAN, true),
        AlgoSetup::new("choco", 0.1, 0.6, f64::NAN, true),
        AlgoSetup::new("lead", 0.1, 1.0, 0.5, true),
    ]
}

/// Custom run description loaded from a TOML-subset file:
///
/// ```toml
/// algo = "lead"
/// eta = 0.1
/// gamma = 1.0
/// alpha = 0.5
/// rounds = 500
/// compressor = "qinf:2:512"
/// topology = "ring"
/// agents = 8
/// seed = 42
/// # link = "straggler:1e-4:1e9:0.25:10"   # simnet timing overlay
/// # transport = "channel"                 # mem | channel | mux:<N>
/// ```
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub algo: String,
    pub eta: f64,
    pub gamma: f64,
    pub alpha: f64,
    pub rounds: usize,
    pub compressor: String,
    pub topology: String,
    pub agents: usize,
    pub seed: u64,
    pub batch_size: Option<usize>,
    /// Simnet link-model spec (`crate::simnet::NetModel::parse`); empty
    /// ⇒ the legacy uniform round-time formula.
    pub link: String,
    /// Transport-mode spec (`crate::transport::TransportMode::parse`):
    /// `mem` | `channel` | `mux:<N>`; empty ⇒ shared memory.
    pub transport: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algo: "lead".into(),
            eta: 0.1,
            gamma: 1.0,
            alpha: 0.5,
            rounds: 500,
            compressor: "qinf:2:512".into(),
            topology: "ring".into(),
            agents: 8,
            seed: 42,
            batch_size: None,
            link: String::new(),
            transport: String::new(),
        }
    }
}

impl RunConfig {
    /// Bridge to the scenario layer: a [`RunConfig`] is a one-cell grid.
    /// `lead run` routes through the same [`crate::scenarios::Driver`]
    /// path as `lead grid` / `lead exp`, so validation (topology,
    /// algorithm, compressor strings) fails loudly instead of silently
    /// degrading.
    pub fn to_spec(&self) -> crate::scenarios::RunSpec {
        crate::scenarios::RunSpec {
            name: "run".into(),
            // The historical `lead run` problem: the paper's synthetic
            // linreg workload at the config's agent count and seed.
            problem: crate::scenarios::ProblemSpec::LinReg { dim: 200, reg: 0.1, seed: self.seed },
            topology: self.topology.clone(),
            mixing: crate::topology::MixingRule::UniformNeighbors,
            agents: self.agents,
            algo: self.algo.clone(),
            eta: self.eta,
            gamma: self.gamma,
            alpha: self.alpha,
            compressor: self.compressor.clone(),
            rounds: self.rounds,
            batch_size: self.batch_size,
            seed: self.seed,
            record_every: (self.rounds / 100).max(1),
            t0: None,
            link: self.link.clone(),
            faults: String::new(),
            time_budget: None,
            transport: self.transport.clone(),
        }
    }

    pub fn from_toml(src: &str) -> Result<RunConfig, String> {
        let doc = toml_mini::parse(src)?;
        let top = doc.get("").ok_or("missing top-level section")?;
        let mut c = RunConfig::default();
        for (k, v) in top {
            match k.as_str() {
                "algo" => c.algo = v.as_str().ok_or("algo must be a string")?.into(),
                "eta" => c.eta = v.as_f64().ok_or("eta must be numeric")?,
                "gamma" => c.gamma = v.as_f64().ok_or("gamma must be numeric")?,
                "alpha" => c.alpha = v.as_f64().ok_or("alpha must be numeric")?,
                "rounds" => c.rounds = v.as_i64().ok_or("rounds must be int")? as usize,
                "compressor" => c.compressor = v.as_str().ok_or("compressor: string")?.into(),
                "topology" => c.topology = v.as_str().ok_or("topology: string")?.into(),
                "agents" => c.agents = v.as_i64().ok_or("agents must be int")? as usize,
                "seed" => c.seed = v.as_i64().ok_or("seed must be int")? as u64,
                "batch_size" => c.batch_size = Some(v.as_i64().ok_or("batch_size: int")? as usize),
                "link" => c.link = v.as_str().ok_or("link: string")?.into(),
                "transport" => c.transport = v.as_str().ok_or("transport: string")?.into(),
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build() {
        for setup in table1_linreg()
            .into_iter()
            .chain(table2_logreg_full(true))
            .chain(table3_logreg_minibatch())
            .chain(table4_dnn(true))
        {
            let algo = setup.build();
            assert!(!algo.name().is_empty());
            assert_eq!(algo.spec().compressed, setup.compressed, "{}", setup.algo);
        }
        assert!(build_algo("nope", 0.0, 0.0).is_none());
    }

    #[test]
    fn run_config_parses() {
        let c = RunConfig::from_toml(
            "algo = \"choco\"\neta = 0.05\ngamma = 0.6\nrounds = 100\nbatch_size = 64\nlink = \"uniform:1e-4:1e9\"\n",
        )
        .unwrap();
        assert_eq!(c.algo, "choco");
        assert_eq!(c.eta, 0.05);
        assert_eq!(c.batch_size, Some(64));
        assert_eq!(c.link, "uniform:1e-4:1e9");
        assert!(c.to_spec().build_net().unwrap().is_some(), "link flows into the spec");
        assert!(RunConfig::from_toml("bogus_key = 1").is_err());

        let t = RunConfig::from_toml("transport = \"mux:8\"\n").unwrap();
        assert_eq!(t.transport, "mux:8");
        assert_eq!(
            t.to_spec().build_transport().unwrap(),
            crate::transport::TransportMode::Mux { per_worker: 8 },
            "transport flows into the spec"
        );
        assert!(RunConfig::from_toml("transport = \"udp\"\n").unwrap().to_spec().build_transport().is_err());
    }

    #[test]
    fn run_config_bridges_to_run_spec() {
        let c = RunConfig::from_toml(
            "algo = \"choco\"\neta = 0.05\ngamma = 0.6\nrounds = 100\nseed = 9\n",
        )
        .unwrap();
        let spec = c.to_spec();
        assert_eq!(spec.algo, "choco");
        assert_eq!(spec.eta, 0.05);
        assert_eq!(spec.rounds, 100);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.record_every, 1);
        // The spec builds: algorithm, topology, and compressor all valid.
        assert!(spec.build_algo().is_ok());
        assert!(spec.build_mix().is_ok());
        assert!(spec.build_compressor().unwrap().is_some());
    }
}
