//! Minimal error plumbing (anyhow is not in the offline vendor set).
//!
//! Everything fallible in the crate returns [`Result`]; errors are boxed
//! `std::error::Error` trait objects built from plain strings via [`err`].
//! `?` converts any concrete error (io, parse, …) automatically.

/// Boxed dynamic error, `Send + Sync` so it crosses the worker pool.
pub type Error = Box<dyn std::error::Error + Send + Sync>;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Build an [`Error`] from a message: `return Err(err(format!("...")))`.
pub fn err(msg: impl Into<String>) -> Error {
    msg.into().into()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn string_and_io_errors_convert() {
        fn fails() -> Result<()> {
            Err(err("boom"))
        }
        assert_eq!(fails().unwrap_err().to_string(), "boom");

        fn io_err() -> Result<String> {
            let s = std::fs::read_to_string("/nonexistent/definitely/missing")?;
            Ok(s)
        }
        assert!(io_err().is_err());
    }
}
