//! DeepSqueeze (Tang et al. 2019a): error-compensated compression of the
//! local model, gossiped with damping γ:
//!
//! ```text
//! v_i = x_i − η ∇f_i(x_i; ξ)
//! c_i = Q(v_i + e_i)                    (compress with error memory)
//! e_i ← v_i + e_i − c_i                 (error feedback)
//! x_i^{k+1} = c_i + γ Σ_j w_ij (c_j − c_i)
//! ```
//!
//! Unlike LEAD's *implicit* compensation through the dual update
//! (Remark 2), DeepSqueeze stores the error in memory and re-injects it
//! before the next compression — and it still compresses a full-magnitude
//! model vector, so its compression error does not vanish (Fig. 1d).

use super::{zeros, AlgoSpec, Algorithm, Ctx};

pub struct DeepSqueeze {
    /// Gossip damping γ (paper Tables: 0.2–0.6).
    pub gamma: f64,
    x: Vec<Vec<f64>>,
    /// Error-feedback memory e_i.
    e: Vec<Vec<f64>>,
}

impl DeepSqueeze {
    pub fn new(gamma: f64) -> Self {
        DeepSqueeze { gamma, x: vec![], e: vec![] }
    }

    pub fn error_memory(&self, agent: usize) -> &[f64] {
        &self.e[agent]
    }
}

impl Algorithm for DeepSqueeze {
    fn name(&self) -> String {
        format!("DeepSqueeze(γ={})", self.gamma)
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: true }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        self.x = x0.to_vec();
        self.e = zeros(x0.len(), x0[0].len());
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        // Broadcast v + e; engine compresses it into c.
        let x = &self.x[agent];
        let e = &self.e[agent];
        let payload = &mut out[0];
        for t in 0..x.len() {
            payload[t] = x[t] - ctx.eta * g[t] + e[t];
        }
    }

    fn recv(&mut self, ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        let gamma = self.gamma;
        let eta = ctx.eta;
        let x = &mut self.x[agent];
        let e = &mut self.e[agent];
        let c_own = &self_dec[0];
        let c_mix = &mixed[0];
        for t in 0..x.len() {
            // Error feedback: e ← (v + e) − c (v + e is what we sent).
            let sent = x[t] - eta * g[t] + e[t];
            e[t] = sent - c_own[t];
            // Gossip on the compressed models.
            x[t] = c_own[t] + gamma * (c_mix[t] - c_own[t]);
        }
    }

    fn x(&self, agent: usize) -> &[f64] {
        &self.x[agent]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn stable_without_compression() {
        // Identity compression ⇒ e stays 0 and the update is damped gossip
        // + gradient: converges to a neighborhood.
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = DeepSqueeze::new(0.2);
        let xs = run_plain(&mut algo, &p, &mix, 0.05, 2000);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1.0, "DeepSqueeze diverged: {err}");
        for i in 0..8 {
            assert!(crate::linalg::norm2(algo.error_memory(i)) < 1e-6);
        }
    }
}
