//! DeepSqueeze (Tang et al. 2019a): error-compensated compression of the
//! local model, gossiped with damping γ:
//!
//! ```text
//! v_i = x_i − η ∇f_i(x_i; ξ)
//! c_i = Q(v_i + e_i)                    (compress with error memory)
//! e_i ← v_i + e_i − c_i                 (error feedback)
//! x_i^{k+1} = c_i + γ Σ_j w_ij (c_j − c_i)
//! ```
//!
//! Unlike LEAD's *implicit* compensation through the dual update
//! (Remark 2), DeepSqueeze stores the error in memory and re-injects it
//! before the next compression — and it still compresses a full-magnitude
//! model vector, so its compression error does not vanish (Fig. 1d).

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

pub struct DeepSqueeze {
    /// Gossip damping γ (paper Tables: 0.2–0.6).
    pub gamma: f64,
    x: Mat,
    /// Error-feedback memory e_i.
    e: Mat,
}

/// Per-agent DeepSqueeze send step: broadcast `v + e = x − ηg + e` (the
/// engine compresses it into c).
#[inline]
fn send_agent(eta: f64, x: &[f64], e: &[f64], g: &[f64], out0: &mut [f64]) {
    for t in 0..x.len() {
        out0[t] = x[t] - eta * g[t] + e[t];
    }
}

/// Per-agent DeepSqueeze apply step over disjoint state rows. `c_own` is
/// an [`OwnView`]: the error memory and gossip base both consume the own
/// compressed model, so sparse messages are applied from their published
/// entries (unpublished coordinates read exactly `+0.0` — ±0.0 rule).
#[inline]
fn apply_agent(
    gamma: f64,
    eta: f64,
    g: &[f64],
    c_own: OwnView<'_>,
    c_mix: &[f64],
    x: &mut [f64],
    e: &mut [f64],
) {
    c_own.for_each(x.len(), |t, c| {
        // Error feedback: e ← (v + e) − c (v + e is what we sent).
        let sent = x[t] - eta * g[t] + e[t];
        e[t] = sent - c;
        // Gossip on the compressed models.
        x[t] = c + gamma * (c_mix[t] - c);
    });
}

impl DeepSqueeze {
    pub fn new(gamma: f64) -> Self {
        DeepSqueeze { gamma, x: Mat::zeros(0, 0), e: Mat::zeros(0, 0) }
    }

    pub fn error_memory(&self, agent: usize) -> &[f64] {
        self.e.row(agent)
    }
}

impl Algorithm for DeepSqueeze {
    fn name(&self) -> String {
        format!("DeepSqueeze(γ={})", self.gamma)
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: true, own: OwnAccess::Sparse }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        self.x = Mat::from_rows(x0);
        self.e = Mat::zeros(x0.len(), x0[0].len());
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        send_agent(ctx.eta, self.x.row(agent), self.e.row(agent), g, &mut out[0]);
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let eta = ctx.eta;
        let (x, e) = (&self.x, &self.e);
        super::par_agents2(exec, &mut [], g, payload, |i, _rows, gi, pi| {
            grad(i, x.row(i), gi);
            send_agent(eta, x.row(i), e.row(i), gi, &mut pi[0]);
            sink(i, pi);
        });
    }

    fn recv(&mut self, ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        apply_agent(
            self.gamma,
            ctx.eta,
            g,
            OwnView::Dense(self_dec[0]),
            mixed[0],
            self.x.row_mut(agent),
            self.e.row_mut(agent),
        );
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let gamma = self.gamma;
        let eta = ctx.eta;
        super::par_agents(exec, &mut [&mut self.x, &mut self.e], |i, rows| match rows {
            _ if !inbox.live(i) => {}
            [x, e] => apply_agent(gamma, eta, &g[i], inbox.own_view(i, 0), inbox.mix(i, 0), x, e),
            _ => unreachable!(),
        });
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn stable_without_compression() {
        // Identity compression ⇒ e stays 0 and the update is damped gossip
        // + gradient: converges to a neighborhood.
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = DeepSqueeze::new(0.2);
        let xs = run_plain(&mut algo, &p, &mix, 0.05, 2000);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1.0, "DeepSqueeze diverged: {err}");
        for i in 0..8 {
            assert!(crate::linalg::norm2(algo.error_memory(i)) < 1e-6);
        }
    }
}
