//! D² (Tang et al. 2018b) in the closed form of the paper's Proposition 1,
//! Eq. (15):
//!
//! ```text
//! x^{k+1} = (I+W)/2 · (2x^k − x^{k−1} − η∇F(x^k;ξ) + η∇F(x^{k−1};ξ'))
//! ```
//!
//! Equivalent to LEAD without compression at γ = 1 and to NIDS with full
//! gradients — implemented independently in its history form so the
//! Prop. 1 equivalence can be *tested* rather than assumed.

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

pub struct D2 {
    x: Mat,
    x_prev: Mat,
    g_prev: Mat,
}

/// Per-agent D² send step: broadcast `z = 2x − x_prev − η(g − g_prev)`.
#[inline]
fn send_agent(eta: f64, x: &[f64], xp: &[f64], gp: &[f64], g: &[f64], out0: &mut [f64]) {
    for t in 0..x.len() {
        out0[t] = 2.0 * x[t] - xp[t] - eta * (g[t] - gp[t]);
    }
}

/// Per-agent D² apply step: x⁺ = (z + Wz)/2, history shifts. `z_own` is
/// an [`OwnView`] so the kernel has a sparse overload like the compressed
/// family (D² broadcasts uncompressed, so the engine always serves it the
/// dense arm — the sparse arm is pinned at the unit level by
/// `rust/tests/sparse_own.rs`).
#[inline]
fn apply_agent(
    g: &[f64],
    z_own: OwnView<'_>,
    z_mix: &[f64],
    x: &mut [f64],
    xp: &mut [f64],
    gp: &mut [f64],
) {
    z_own.for_each(x.len(), |t, z| {
        let xnew = 0.5 * (z + z_mix[t]);
        xp[t] = x[t];
        x[t] = xnew;
    });
    gp.copy_from_slice(g);
}

impl D2 {
    pub fn new() -> Self {
        D2 { x: Mat::zeros(0, 0), x_prev: Mat::zeros(0, 0), g_prev: Mat::zeros(0, 0) }
    }
}

impl Default for D2 {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for D2 {
    fn name(&self) -> String {
        "D2".into()
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: false, own: OwnAccess::Sparse }
    }

    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]) {
        // Matches LEAD's init (Prop. 1 derivation assumes D¹ = 0):
        // x⁰ stored as history, x¹ = x⁰ − ηg⁰.
        self.x_prev = Mat::from_rows(x0);
        self.g_prev = Mat::from_rows(g0);
        self.x = Mat::from_rows(x0);
        for (i, g) in g0.iter().enumerate() {
            crate::linalg::axpy(-ctx.eta, g, self.x.row_mut(i));
        }
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        send_agent(
            ctx.eta,
            self.x.row(agent),
            self.x_prev.row(agent),
            self.g_prev.row(agent),
            g,
            &mut out[0],
        );
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let eta = ctx.eta;
        let (x, xp, gp) = (&self.x, &self.x_prev, &self.g_prev);
        super::par_agents2(exec, &mut [], g, payload, |i, _rows, gi, pi| {
            grad(i, x.row(i), gi);
            send_agent(eta, x.row(i), xp.row(i), gp.row(i), gi, &mut pi[0]);
            sink(i, pi);
        });
    }

    fn recv(&mut self, _ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        apply_agent(
            g,
            OwnView::Dense(self_dec[0]),
            mixed[0],
            self.x.row_mut(agent),
            self.x_prev.row_mut(agent),
            self.g_prev.row_mut(agent),
        );
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let _ = ctx;
        super::par_agents(
            exec,
            &mut [&mut self.x, &mut self.x_prev, &mut self.g_prev],
            |i, rows| match rows {
                _ if !inbox.live(i) => {}
                [x, xp, gp] => apply_agent(&g[i], inbox.own_view(i, 0), inbox.mix(i, 0), x, xp, gp),
                _ => unreachable!(),
            },
        );
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = D2::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 400);
        assert!(max_dist_to_opt(&xs, &p) < 1e-4);
    }

    #[test]
    fn matches_nids_trajectory() {
        // Proposition 1: D² ≡ NIDS (full gradient). Same inputs, same
        // trajectory up to f64 roundoff.
        let p = LinReg::synthetic(5, 24, 0.1, 9);
        let mix = Topology::Ring.build(5, MixingRule::UniformNeighbors);
        let mut d2 = D2::new();
        let mut nids = crate::algorithms::nids::Nids::new();
        let xs_d2 = run_plain(&mut d2, &p, &mix, 0.1, 60);
        let xs_nids = run_plain(&mut nids, &p, &mix, 0.1, 60);
        for (a, b) in xs_d2.iter().zip(&xs_nids) {
            let diff = crate::linalg::dist_sq(a, b).sqrt();
            assert!(diff < 1e-3, "D² vs NIDS drift: {diff}");
        }
    }
}
