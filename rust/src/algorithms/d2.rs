//! D² (Tang et al. 2018b) in the closed form of the paper's Proposition 1,
//! Eq. (15):
//!
//! ```text
//! x^{k+1} = (I+W)/2 · (2x^k − x^{k−1} − η∇F(x^k;ξ) + η∇F(x^{k−1};ξ'))
//! ```
//!
//! Equivalent to LEAD without compression at γ = 1 and to NIDS with full
//! gradients — implemented independently in its history form so the
//! Prop. 1 equivalence can be *tested* rather than assumed.

use super::{AlgoSpec, Algorithm, Ctx};

pub struct D2 {
    x: Vec<Vec<f64>>,
    x_prev: Vec<Vec<f64>>,
    g_prev: Vec<Vec<f64>>,
}

impl D2 {
    pub fn new() -> Self {
        D2 { x: vec![], x_prev: vec![], g_prev: vec![] }
    }
}

impl Default for D2 {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for D2 {
    fn name(&self) -> String {
        "D2".into()
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: false }
    }

    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]) {
        // Matches LEAD's init (Prop. 1 derivation assumes D¹ = 0):
        // x⁰ stored as history, x¹ = x⁰ − ηg⁰.
        self.x_prev = x0.to_vec();
        self.g_prev = g0.to_vec();
        self.x = x0.to_vec();
        for (x, g) in self.x.iter_mut().zip(g0) {
            crate::linalg::axpy(-ctx.eta, g, x);
        }
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        // z = 2x − x_prev − ηg + ηg_prev
        let z = &mut out[0];
        let x = &self.x[agent];
        let xp = &self.x_prev[agent];
        let gp = &self.g_prev[agent];
        for t in 0..x.len() {
            z[t] = 2.0 * x[t] - xp[t] - ctx.eta * (g[t] - gp[t]);
        }
    }

    fn recv(&mut self, _ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        // x⁺ = (z + Wz)/2 per agent; history shifts.
        let x = &mut self.x[agent];
        let xp = &mut self.x_prev[agent];
        for t in 0..x.len() {
            let xnew = 0.5 * (self_dec[0][t] + mixed[0][t]);
            xp[t] = x[t];
            x[t] = xnew;
        }
        self.g_prev[agent].copy_from_slice(g);
    }

    fn x(&self, agent: usize) -> &[f64] {
        &self.x[agent]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = D2::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 400);
        assert!(max_dist_to_opt(&xs, &p) < 1e-4);
    }

    #[test]
    fn matches_nids_trajectory() {
        // Proposition 1: D² ≡ NIDS (full gradient). Same inputs, same
        // trajectory up to f64 roundoff.
        let p = LinReg::synthetic(5, 24, 0.1, 9);
        let mix = Topology::Ring.build(5, MixingRule::UniformNeighbors);
        let mut d2 = D2::new();
        let mut nids = crate::algorithms::nids::Nids::new();
        let xs_d2 = run_plain(&mut d2, &p, &mix, 0.1, 60);
        let xs_nids = run_plain(&mut nids, &p, &mix, 0.1, 60);
        for (a, b) in xs_d2.iter().zip(&xs_nids) {
            let diff = crate::linalg::dist_sq(a, b).sqrt();
            assert!(diff < 1e-3, "D² vs NIDS drift: {diff}");
        }
    }
}
