//! QDGD (Reisizadeh et al. 2019a): quantized decentralized gradient
//! descent. Each agent broadcasts a *quantized copy of its model* and
//! mixes toward the quantized neighborhood average with consensus rate γ:
//!
//! ```text
//! x_i^{k+1} = x_i^k + γ ( w_ii x_i^k + Σ_{j≠i} w_ij Q(x_j^k) − x_i^k )
//!             − γ η ∇f_i(x_i^k; ξ)
//! ```
//!
//! Because the model itself (not a difference) is quantized, the
//! compression error never vanishes (‖x‖ stays large at the optimum) —
//! this is the Fig. 1d contrast with LEAD, and why QDGD needs a small
//! effective stepsize to converge at all (§2).

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

pub struct Qdgd {
    /// Consensus/stepsize damping γ (paper Table 1–4: 0.1–0.4).
    pub gamma: f64,
    x: Mat,
}

/// Per-agent QDGD apply step. `wii` is the agent's self-weight: mixed
/// includes w_ii·Q(x_i) but QDGD uses the *exact* own model, so the own
/// term is swapped out: m = mixed + w_ii (x_i − Q(x_i)). `q_own` is an
/// [`OwnView`]; a sparse Q(x_i) is consumed from its published entries
/// (unpublished coordinates subtract exactly `+0.0` — ±0.0 rule).
#[inline]
fn apply_agent(
    gamma: f64,
    eta: f64,
    wii: f64,
    g: &[f64],
    q_own: OwnView<'_>,
    q_mix: &[f64],
    x: &mut [f64],
) {
    q_own.for_each(x.len(), |t, q| {
        let m = q_mix[t] + wii * (x[t] - q);
        x[t] += gamma * (m - x[t]) - gamma * eta * g[t];
    });
}

impl Qdgd {
    pub fn new(gamma: f64) -> Self {
        Qdgd { gamma, x: Mat::zeros(0, 0) }
    }
}

impl Algorithm for Qdgd {
    fn name(&self) -> String {
        format!("QDGD(γ={})", self.gamma)
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: true, own: OwnAccess::Sparse }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        self.x = Mat::from_rows(x0);
    }

    fn send(&mut self, _ctx: &Ctx, agent: usize, _g: &[f64], out: &mut [Vec<f64>]) {
        // Quantize the raw model (the defining design choice of QDGD).
        out[0].copy_from_slice(self.x.row(agent));
    }

    fn produce_all(
        &mut self,
        _ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let x = &self.x;
        super::par_agents2(exec, &mut [], g, payload, |i, _rows, gi, pi| {
            grad(i, x.row(i), gi);
            pi[0].copy_from_slice(x.row(i));
            sink(i, pi);
        });
    }

    fn recv(&mut self, ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        apply_agent(
            self.gamma,
            ctx.eta,
            ctx.mix.self_weight(agent),
            g,
            OwnView::Dense(self_dec[0]),
            mixed[0],
            self.x.row_mut(agent),
        );
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let gamma = self.gamma;
        let eta = ctx.eta;
        let mix = ctx.mix;
        super::par_agents(exec, &mut [&mut self.x], |i, rows| match rows {
            _ if !inbox.live(i) => {}
            [x] => apply_agent(
                gamma,
                eta,
                mix.self_weight(i),
                &g[i],
                inbox.own_view(i, 0),
                inbox.mix(i, 0),
                x,
            ),
            _ => unreachable!(),
        });
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn converges_without_compression_to_neighborhood() {
        // With identity compression QDGD ≈ damped DGD: biased but stable.
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = Qdgd::new(0.2);
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 3000);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1.0, "QDGD diverged: {err}");
        assert!(err > 1e-4, "QDGD should retain bias, got {err}");
    }
}
