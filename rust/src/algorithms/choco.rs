//! CHOCO-SGD (Koloskova et al. 2019/2020): quantized gossip with public
//! model copies.
//!
//! Each agent keeps a public copy x̂_i that all neighbors mirror; only the
//! *difference* to the public copy is compressed (like LEAD), but the
//! state update is the plain integration `x̂ += q` (vs LEAD's momentum
//! update, Remark 1) and the method remains primal-only, so under data
//! heterogeneity it converges sublinearly and needs a tuned γ:
//!
//! ```text
//! x_i^{k+½} = x_i^k − η ∇f_i(x_i^k; ξ)
//! q_i       = Q(x_i^{k+½} − x̂_i^k)
//! x̂_j      ← x̂_j + q_j             (all agents update all mirrors)
//! x_i^{k+1} = x_i^{k+½} + γ Σ_j w_ij (x̂_j^{k+1} − x̂_i^{k+1})
//! ```
//!
//! We maintain `s_i = Σ_j w_ij x̂_j` incrementally (`s_i += Σ_j w_ij q_j`,
//! which is exactly the engine's mixed channel), so per-neighbor mirrors
//! never need to be materialized.

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

pub struct ChocoSgd {
    /// Gossip stepsize γ (paper Tables: 0.6–0.8).
    pub gamma: f64,
    x: Mat,
    /// Own public copy x̂_i.
    xhat: Mat,
    /// s_i = Σ_j w_ij x̂_j, maintained incrementally.
    s: Mat,
    /// Scratch: x^{k+½} between send and recv.
    xhalf: Mat,
}

/// Per-agent CHOCO send step over disjoint rows: stash `x^{k+½} = x − ηg`
/// and broadcast the public-copy difference `x^{k+½} − x̂` (the engine
/// compresses it into q).
#[inline]
fn send_agent(eta: f64, x: &[f64], xh: &[f64], g: &[f64], half: &mut [f64], out0: &mut [f64]) {
    for t in 0..x.len() {
        half[t] = x[t] - eta * g[t];
        out0[t] = half[t] - xh[t];
    }
}

/// Per-agent CHOCO apply step over disjoint state rows. `q_own` is an
/// [`OwnView`]: the public copy integrates the own compressed difference
/// (`x̂ += q`), so sparse messages are applied from their k published
/// entries — unpublished coordinates add exactly `+0.0`, matching the
/// dense decode bit-for-bit (±0.0 rule on [`OwnView`]).
#[inline]
fn apply_agent(
    gamma: f64,
    q_own: OwnView<'_>,
    q_mix: &[f64],
    x: &mut [f64],
    xh: &mut [f64],
    s: &mut [f64],
    half: &mut [f64],
) {
    q_own.for_each(x.len(), |t, q| {
        xh[t] += q; // x̂_i ← x̂_i + q_i
        s[t] += q_mix[t]; // s_i ← s_i + Σ w_ij q_j
        x[t] = half[t] + gamma * (s[t] - xh[t]);
    });
}

impl ChocoSgd {
    pub fn new(gamma: f64) -> Self {
        let empty = Mat::zeros(0, 0);
        ChocoSgd { gamma, x: empty.clone(), xhat: empty.clone(), s: empty.clone(), xhalf: empty }
    }

    pub fn public_copy(&self, agent: usize) -> &[f64] {
        self.xhat.row(agent)
    }
}

impl Algorithm for ChocoSgd {
    fn name(&self) -> String {
        format!("CHOCO-SGD(γ={})", self.gamma)
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: true, own: OwnAccess::Sparse }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        let (n, d) = (x0.len(), x0[0].len());
        self.x = Mat::from_rows(x0);
        self.xhat = Mat::zeros(n, d);
        self.s = Mat::zeros(n, d);
        self.xhalf = Mat::zeros(n, d);
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        let ChocoSgd { x, xhat, xhalf, .. } = self;
        send_agent(ctx.eta, x.row(agent), xhat.row(agent), g, xhalf.row_mut(agent), &mut out[0]);
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let eta = ctx.eta;
        let ChocoSgd { x, xhat, xhalf, .. } = self;
        let (x, xhat) = (&*x, &*xhat);
        super::par_agents2(exec, &mut [xhalf], g, payload, |i, rows, gi, pi| match rows {
            [half] => {
                grad(i, x.row(i), gi);
                send_agent(eta, x.row(i), xhat.row(i), gi, half, &mut pi[0]);
                sink(i, pi);
            }
            _ => unreachable!(),
        });
    }

    fn recv(
        &mut self,
        _ctx: &Ctx,
        agent: usize,
        _g: &[f64],
        self_dec: &[&[f64]],
        mixed: &[&[f64]],
    ) {
        apply_agent(
            self.gamma,
            OwnView::Dense(self_dec[0]),
            mixed[0],
            self.x.row_mut(agent),
            self.xhat.row_mut(agent),
            self.s.row_mut(agent),
            self.xhalf.row_mut(agent),
        );
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let _ = (ctx, g);
        let gamma = self.gamma;
        super::par_agents(
            exec,
            &mut [&mut self.x, &mut self.xhat, &mut self.s, &mut self.xhalf],
            |i, rows| match rows {
                // Crashed agents freeze x and the x̂ difference-
                // compression reference alike (degraded-inbox contract).
                _ if !inbox.live(i) => {}
                [x, xh, s, half] => {
                    apply_agent(gamma, inbox.own_view(i, 0), inbox.mix(i, 0), x, xh, s, half)
                }
                _ => unreachable!(),
            },
        );
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn without_compression_behaves_like_dpsgd() {
        // Identity compression ⇒ x̂ tracks x^{k+½} exactly after one round
        // and the update is gossip-averaged SGD: biased but stable.
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = ChocoSgd::new(0.8);
        let xs = run_plain(&mut algo, &p, &mix, 0.05, 2000);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1.0, "CHOCO diverged: {err}");
        assert!(err > 1e-4, "CHOCO is primal-only; exact convergence unexpected ({err})");
    }

    #[test]
    fn mirrors_track_models() {
        let p = LinReg::synthetic(4, 16, 0.1, 5);
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut algo = ChocoSgd::new(0.8);
        let _ = run_plain(&mut algo, &p, &mix, 0.05, 400);
        for i in 0..4 {
            // At stationarity x̂ tracks x^{k+½} = x − ηg, so the x̂-to-x gap
            // is O(η‖∇f_i‖) — small but not zero (CHOCO's residual bias).
            let gap = crate::linalg::dist_sq(algo.public_copy(i), algo.x(i)).sqrt();
            assert!(gap < 0.2, "public copy drifted: {gap}");
        }
    }
}
