//! NIDS (Li, Shi & Yan 2019) in the two-step primal–dual form the paper
//! builds LEAD from (Eqs. 4–5):
//!
//! ```text
//! d_i^{k+1} = d_i^k + (1/2η) [(I−W)(x^k − η∇F(x^k) − η d^k)]_i
//! x_i^{k+1} = x_i^k − η ∇f_i(x_i^k) − η d_i^{k+1}
//! ```
//!
//! This is exactly LEAD with identity compression and γ = 1 (Prop. 1 /
//! Cor. 3) — an equality our integration tests verify trajectory-for-
//! trajectory against both [`super::lead::Lead`] and [`super::d2::D2`].

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

pub struct Nids {
    x: Mat,
    d: Mat,
}

/// Per-agent NIDS send step: broadcast `y = x − ηg − ηd` (uncompressed).
#[inline]
fn send_agent(eta: f64, x: &[f64], d: &[f64], g: &[f64], out0: &mut [f64]) {
    out0.copy_from_slice(x);
    crate::linalg::axpy(-eta, g, out0);
    crate::linalg::axpy(-eta, d, out0);
}

/// Per-agent NIDS apply step over disjoint state rows. `y_own` is an
/// [`OwnView`] so the kernel has a sparse overload like the compressed
/// family (NIDS itself broadcasts uncompressed, so the engine always
/// serves it the dense arm — the sparse arm is pinned at the unit level
/// by `rust/tests/sparse_own.rs`).
#[inline]
fn apply_agent(eta: f64, g: &[f64], y_own: OwnView<'_>, y_mix: &[f64], x: &mut [f64], d: &mut [f64]) {
    // (I−W) y = y_i − (Wy)_i = self − mixed.
    let c = 1.0 / (2.0 * eta);
    y_own.for_each(x.len(), |t, y| {
        d[t] += c * (y - y_mix[t]);
        x[t] -= eta * (g[t] + d[t]);
    });
}

impl Nids {
    pub fn new() -> Self {
        Nids { x: Mat::zeros(0, 0), d: Mat::zeros(0, 0) }
    }

    pub fn dual(&self, agent: usize) -> &[f64] {
        self.d.row(agent)
    }
}

impl Default for Nids {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Nids {
    fn name(&self) -> String {
        "NIDS".into()
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: false, own: OwnAccess::Sparse }
    }

    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]) {
        let n = x0.len();
        self.d = Mat::zeros(n, x0[0].len());
        self.x = Mat::from_rows(x0);
        // Same warm start as LEAD: x¹ = x⁰ − ηg⁰.
        for i in 0..n {
            crate::linalg::axpy(-ctx.eta, &g0[i], self.x.row_mut(i));
        }
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        send_agent(ctx.eta, self.x.row(agent), self.d.row(agent), g, &mut out[0]);
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let eta = ctx.eta;
        let (x, dv) = (&self.x, &self.d);
        super::par_agents2(exec, &mut [], g, payload, |i, _rows, gi, pi| {
            grad(i, x.row(i), gi);
            send_agent(eta, x.row(i), dv.row(i), gi, &mut pi[0]);
            sink(i, pi);
        });
    }

    fn recv(&mut self, ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        apply_agent(
            ctx.eta,
            g,
            OwnView::Dense(self_dec[0]),
            mixed[0],
            self.x.row_mut(agent),
            self.d.row_mut(agent),
        );
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let eta = ctx.eta;
        super::par_agents(exec, &mut [&mut self.x, &mut self.d], |i, rows| match rows {
            _ if !inbox.live(i) => {}
            [x, d] => apply_agent(eta, &g[i], inbox.own_view(i, 0), inbox.mix(i, 0), x, d),
            _ => unreachable!(),
        });
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence_heterogeneous() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = Nids::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 400);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1e-4, "NIDS should converge exactly, got {err}");
    }
}
