//! NIDS (Li, Shi & Yan 2019) in the two-step primal–dual form the paper
//! builds LEAD from (Eqs. 4–5):
//!
//! ```text
//! d_i^{k+1} = d_i^k + (1/2η) [(I−W)(x^k − η∇F(x^k) − η d^k)]_i
//! x_i^{k+1} = x_i^k − η ∇f_i(x_i^k) − η d_i^{k+1}
//! ```
//!
//! This is exactly LEAD with identity compression and γ = 1 (Prop. 1 /
//! Cor. 3) — an equality our integration tests verify trajectory-for-
//! trajectory against both [`super::lead::Lead`] and [`super::d2::D2`].

use super::{zeros, AlgoSpec, Algorithm, Ctx};

pub struct Nids {
    x: Vec<Vec<f64>>,
    d: Vec<Vec<f64>>,
}

impl Nids {
    pub fn new() -> Self {
        Nids { x: vec![], d: vec![] }
    }

    pub fn dual(&self, agent: usize) -> &[f64] {
        &self.d[agent]
    }
}

impl Default for Nids {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Nids {
    fn name(&self) -> String {
        "NIDS".into()
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: false }
    }

    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]) {
        let n = x0.len();
        self.d = zeros(n, x0[0].len());
        self.x = x0.to_vec();
        // Same warm start as LEAD: x¹ = x⁰ − ηg⁰.
        for i in 0..n {
            crate::linalg::axpy(-ctx.eta, &g0[i], &mut self.x[i]);
        }
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        // Broadcast y = x − ηg − ηd (uncompressed).
        let y = &mut out[0];
        y.copy_from_slice(&self.x[agent]);
        crate::linalg::axpy(-ctx.eta, g, y);
        crate::linalg::axpy(-ctx.eta, &self.d[agent], y);
    }

    fn recv(&mut self, ctx: &Ctx, agent: usize, g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        // (I−W) y = y_i − (Wy)_i = self − mixed.
        let eta = ctx.eta;
        let c = 1.0 / (2.0 * eta);
        let x = &mut self.x[agent];
        let d = &mut self.d[agent];
        for t in 0..x.len() {
            d[t] += c * (self_dec[0][t] - mixed[0][t]);
            x[t] -= eta * (g[t] + d[t]);
        }
    }

    fn x(&self, agent: usize) -> &[f64] {
        &self.x[agent]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence_heterogeneous() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = Nids::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 400);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1e-4, "NIDS should converge exactly, got {err}");
    }
}
