//! DGD (Nedić & Ozdaglar 2009; Yuan et al. 2016): the classical
//! decentralized (sub)gradient method,
//!
//! ```text
//! x_i^{k+1} = Σ_j w_ij x_j^k − η ∇f_i(x_i^k; ξ)
//! ```
//!
//! With a constant stepsize DGD converges only to an O(η)-neighborhood of
//! x* under data heterogeneity (paper §3.1) — our integration tests check
//! precisely that bias, which LEAD/NIDS eliminate.

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, SinkFn};
use crate::linalg::Mat;

pub struct Dgd {
    x: Mat,
}

/// Per-agent DGD apply step.
#[inline]
fn apply_agent(eta: f64, g: &[f64], x_mix: &[f64], x: &mut [f64]) {
    x.copy_from_slice(x_mix);
    crate::linalg::axpy(-eta, g, x);
}

impl Dgd {
    pub fn new() -> Self {
        Dgd { x: Mat::zeros(0, 0) }
    }
}

impl Default for Dgd {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for Dgd {
    fn name(&self) -> String {
        "DGD".into()
    }

    fn spec(&self) -> AlgoSpec {
        // recv uses only the mixed channel, never its own decoded payload.
        AlgoSpec { channels: 1, compressed: false, own: OwnAccess::None }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        self.x = Mat::from_rows(x0);
    }

    fn send(&mut self, _ctx: &Ctx, agent: usize, _g: &[f64], out: &mut [Vec<f64>]) {
        out[0].copy_from_slice(self.x.row(agent));
    }

    fn produce_all(
        &mut self,
        _ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let x = &self.x;
        super::par_agents2(exec, &mut [], g, payload, |i, _rows, gi, pi| {
            grad(i, x.row(i), gi);
            pi[0].copy_from_slice(x.row(i));
            sink(i, pi);
        });
    }

    fn recv(&mut self, ctx: &Ctx, agent: usize, g: &[f64], _self_dec: &[&[f64]], mixed: &[&[f64]]) {
        apply_agent(ctx.eta, g, mixed[0], self.x.row_mut(agent));
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let eta = ctx.eta;
        super::par_agents(exec, &mut [&mut self.x], |i, rows| match rows {
            _ if !inbox.live(i) => {}
            [x] => apply_agent(eta, &g[i], inbox.mix(i, 0), x),
            _ => unreachable!(),
        });
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn converges_to_neighborhood_with_bias() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut dgd = Dgd::new();
        let xs = run_plain(&mut dgd, &p, &mix, 0.05, 2000);
        let err = max_dist_to_opt(&xs, &p);
        // Converges to a neighborhood…
        assert!(err < 1.0, "DGD diverged: {err}");
        // …but NOT to the optimum (heterogeneous data ⇒ constant bias).
        assert!(err > 1e-3, "DGD should retain an O(η) bias, got {err}");
    }

    #[test]
    fn smaller_stepsize_smaller_bias() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let err_at = |eta: f64| {
            let mut dgd = Dgd::new();
            let xs = run_plain(&mut dgd, &p, &mix, eta, 4000);
            max_dist_to_opt(&xs, &p)
        };
        let e_small = err_at(0.01);
        let e_large = err_at(0.1);
        assert!(e_small < e_large, "bias should shrink with η: {e_small} vs {e_large}");
    }
}
