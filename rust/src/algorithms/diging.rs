//! DIGing (Nedić, Olshevsky & Shi 2017) — gradient tracking over two
//! broadcast channels:
//!
//! ```text
//! x^{k+1} = W x^k − η y^k
//! y^{k+1} = W y^k + ∇F(x^{k+1}) − ∇F(x^k)
//! ```
//!
//! Included as the gradient-tracking representative in the related-work
//! family (§2). It transmits 2 d-vectors per round, which the engine bills
//! accordingly — the communication-efficiency benches show this costs 2×
//! the bits of NIDS per iteration.
//!
//! The y-update needs ∇F(x^{k+1}), which only becomes available at the
//! start of the next round; we therefore *complete* y lazily in `send`
//! using the fresh gradient before broadcasting.

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, SinkFn};
use crate::linalg::Mat;

pub struct DiGing {
    x: Mat,
    /// Tracker; between rounds holds the mixed part (Wy)_i awaiting the
    /// `+ g^{k+1} − g^k` completion.
    y: Mat,
    g_prev: Mat,
}

/// Per-agent DIGing send step over disjoint rows: lazily complete the
/// tracker `y^k = (Wy^{k−1})_i + g^k − g^{k−1}` with the fresh gradient,
/// shift the gradient history, and broadcast (x, y) on two channels.
#[inline]
fn send_agent(round: usize, x: &[f64], g: &[f64], y: &mut [f64], gp: &mut [f64], out: &mut [Vec<f64>]) {
    if round > 1 {
        for t in 0..y.len() {
            y[t] += g[t] - gp[t];
        }
    }
    gp.copy_from_slice(g);
    out[0].copy_from_slice(x);
    out[1].copy_from_slice(y);
}

/// Per-agent DIGing apply step: x⁺ = (Wx)_i − η y_i (own completed
/// tracker), y ← (Wy)_i.
#[inline]
fn apply_agent(eta: f64, x_mix: &[f64], y_mix: &[f64], x: &mut [f64], y: &mut [f64]) {
    for t in 0..x.len() {
        x[t] = x_mix[t] - eta * y[t];
        y[t] = y_mix[t];
    }
}

impl DiGing {
    pub fn new() -> Self {
        DiGing { x: Mat::zeros(0, 0), y: Mat::zeros(0, 0), g_prev: Mat::zeros(0, 0) }
    }

    /// Gradient tracker (diagnostics: mean over agents equals the mean
    /// gradient — conservation property tested below).
    pub fn tracker(&self, agent: usize) -> &[f64] {
        self.y.row(agent)
    }
}

impl Default for DiGing {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for DiGing {
    fn name(&self) -> String {
        "DIGing".into()
    }

    fn spec(&self) -> AlgoSpec {
        // recv uses only the mixed channels, never its own payloads.
        AlgoSpec { channels: 2, compressed: false, own: OwnAccess::None }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]) {
        self.x = Mat::from_rows(x0);
        self.y = Mat::from_rows(g0); // y¹ = ∇F(x¹)
        self.g_prev = Mat::from_rows(g0);
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        let DiGing { x, y, g_prev } = self;
        send_agent(ctx.round, x.row(agent), g, y.row_mut(agent), g_prev.row_mut(agent), out);
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let round = ctx.round;
        let DiGing { x, y, g_prev } = self;
        let x = &*x;
        super::par_agents2(exec, &mut [y, g_prev], g, payload, |i, rows, gi, pi| match rows {
            [y, gp] => {
                grad(i, x.row(i), gi);
                send_agent(round, x.row(i), gi, y, gp, pi);
                sink(i, pi);
            }
            _ => unreachable!(),
        });
    }

    fn recv(
        &mut self,
        ctx: &Ctx,
        agent: usize,
        _g: &[f64],
        _self_dec: &[&[f64]],
        mixed: &[&[f64]],
    ) {
        apply_agent(ctx.eta, mixed[0], mixed[1], self.x.row_mut(agent), self.y.row_mut(agent));
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let _ = g;
        let eta = ctx.eta;
        super::par_agents(exec, &mut [&mut self.x, &mut self.y], |i, rows| match rows {
            _ if !inbox.live(i) => {}
            [x, y] => apply_agent(eta, inbox.mix(i, 0), inbox.mix(i, 1), x, y),
            _ => unreachable!(),
        });
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::{linreg::LinReg, Problem};
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = DiGing::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.02, 4000);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1e-4, "DIGing err {err}");
    }

    #[test]
    fn tracker_conserves_mean_gradient() {
        // Σ_i y_i^k = Σ_i ∇f_i(x_i^k) after completion — the defining
        // conservation law of gradient tracking (W doubly stochastic).
        let p = LinReg::synthetic(4, 12, 0.1, 5);
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut algo = DiGing::new();
        let _ = run_plain(&mut algo, &p, &mix, 0.05, 30);
        // After recv, y_i = (Wy)_i, so Σ_i y_i = Σ_i y_i (pre-mix) which
        // equals Σ_i g_i(x^k_i); compare against the *current* gradients
        // shifted by one completion: recompute after completing manually.
        let d = p.dim();
        let mut sum_y = vec![0.0f64; d];
        let mut sum_g = vec![0.0f64; d];
        let mut g = vec![0.0f64; d];
        for i in 0..4 {
            p.grad_full(i, algo.x(i), &mut g);
            // completion that the next send would apply:
            for t in 0..d {
                sum_y[t] += (algo.y.row(i)[t] + g[t] - algo.g_prev.row(i)[t]) as f64;
                sum_g[t] += g[t] as f64;
            }
        }
        for t in 0..d {
            assert!(
                (sum_y[t] - sum_g[t]).abs() < 1e-3,
                "tracking broken at coord {t}: {} vs {}",
                sum_y[t],
                sum_g[t]
            );
        }
    }
}
