//! **LEAD** — the paper's Algorithm 1/2 (agent-perspective form).
//!
//! Per agent i and round k (communication is the single broadcast of the
//! compressed difference `q_i`):
//!
//! ```text
//! y_i   = x_i − η ∇f_i(x_i; ξ) − η d_i              (aux. variable, line 8)
//! q_i   = COMPRESS(y_i − h_i)                       (line 9; engine-owned)
//! ŷ_i   = h_i + q_i                                 (line 10)
//! ŷw_i  = hw_i + Σ_j w_ij q_j                       (line 13)
//! h_i   ← (1−α) h_i + α ŷ_i                         (line 14, momentum state)
//! hw_i  ← (1−α) hw_i + α ŷw_i                       (line 15)
//! d_i   ← d_i + γ/(2η) (ŷ_i − ŷw_i)                 (line 16, inexact dual)
//! x_i   ← x_i − η ∇f_i(x_i; ξ) − η d_i              (line 17, same ξ!)
//! ```
//!
//! Key invariants (tested in `rust/tests/theory.rs`):
//! * `Σ_i d_i = 0` for all k (dual lives in Range(I−W)), *regardless of
//!   compression error* — this is what makes the global average view
//!   `x̄^{k+1} = x̄^k − η ḡ^k` exact (paper Eq. 3);
//! * with C = 0 and γ = 1, the trajectory equals NIDS / D² (Prop. 1).

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

/// LEAD hyper-parameters. The paper fixes `α = 0.5, γ = 1.0` for every
/// experiment (robustness is one of its claims; Fig. 7 sweeps this grid).
#[derive(Clone, Copy, Debug)]
pub struct LeadParams {
    /// Dual stepsize γ ∈ (0, min{…}) per Theorem 1; paper default 1.0.
    pub gamma: f64,
    /// State momentum α per Theorem 1; paper default 0.5.
    pub alpha: f64,
}

impl Default for LeadParams {
    fn default() -> Self {
        LeadParams { gamma: 1.0, alpha: 0.5 }
    }
}

pub struct Lead {
    pub params: LeadParams,
    x: Mat,
    d: Mat,
    h: Mat,
    hw: Mat,
    /// Scratch: y_i of the current round (written in send, read-only in
    /// the apply phase and by `compression_reference`).
    y: Mat,
}

/// Per-agent LEAD send step (Alg. 1 lines 8–9) over disjoint rows:
/// `y = x − ηg − ηd`, broadcast `y − h` (the engine compresses it). The
/// single definition shared by the sequential `send` and the fused
/// `produce_all` paths.
#[inline]
fn send_agent(eta: f64, x: &[f64], d: &[f64], h: &[f64], g: &[f64], y: &mut [f64], out0: &mut [f64]) {
    y.copy_from_slice(x);
    crate::linalg::axpy(-eta, g, y);
    crate::linalg::axpy(-eta, d, y);
    crate::linalg::sub(y, h, out0);
}

/// Per-agent LEAD apply step (Alg. 1 lines 14–17) over disjoint state
/// rows — the single definition shared by the sequential `recv` and the
/// parallel `recv_all` paths. The flat argument list mirrors the state
/// rows handed out by `par_agents`; bundling them would just move the
/// unpacking into both callers.
///
/// `q_own` is an [`OwnView`]: LEAD only ever consumes the own broadcast
/// as `ŷ = h + q` (line 10), so a sparse top-k/rand-k message is applied
/// straight from its k published entries — every unpublished coordinate
/// contributes exactly `h + 0.0`, which is what the dense decode would
/// feed too (±0.0 rule on [`OwnView`]) — and no O(d) own-decode pass is
/// needed.
#[allow(clippy::too_many_arguments)]
#[inline]
fn apply_agent(
    params: LeadParams,
    eta: f64,
    g: &[f64],
    q_own: OwnView<'_>,
    q_mix: &[f64],
    x: &mut [f64],
    dvar: &mut [f64],
    h: &mut [f64],
    hw: &mut [f64],
) {
    let LeadParams { gamma, alpha } = params;
    let c = gamma / (2.0 * eta);
    q_own.for_each(x.len(), |t, q| {
        let yhat = h[t] + q; // ŷ = h + q
        let yhat_w = hw[t] + q_mix[t]; // ŷw = hw + (Wq)
        // Inexact dual ascent (line 16).
        dvar[t] += c * (yhat - yhat_w);
        // Momentum state tracking (lines 14–15).
        h[t] += alpha * (yhat - h[t]);
        hw[t] += alpha * (yhat_w - hw[t]);
        // Primal update with the SAME stochastic gradient (line 17).
        x[t] -= eta * (g[t] + dvar[t]);
    });
}

impl Lead {
    pub fn new(params: LeadParams) -> Self {
        let empty = Mat::zeros(0, 0);
        Lead {
            params,
            x: empty.clone(),
            d: empty.clone(),
            h: empty.clone(),
            hw: empty.clone(),
            y: empty,
        }
    }

    /// Paper defaults (α = 0.5, γ = 1.0).
    pub fn paper_default() -> Self {
        Self::new(LeadParams::default())
    }

    /// Dual variable of an agent (diagnostics / invariant tests).
    pub fn dual(&self, agent: usize) -> &[f64] {
        self.d.row(agent)
    }

    /// State variable H of an agent (diagnostics).
    pub fn state_h(&self, agent: usize) -> &[f64] {
        self.h.row(agent)
    }
}

impl Algorithm for Lead {
    fn name(&self) -> String {
        format!("LEAD(γ={}, α={})", self.params.gamma, self.params.alpha)
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: true, own: OwnAccess::Sparse }
    }

    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]) {
        let n = x0.len();
        let d = x0[0].len();
        // D¹ = (I−W)Z with Z = 0 ⇒ D¹ = 0 (guarantees D ∈ Range(I−W)).
        self.d = Mat::zeros(n, d);
        // H¹ = X⁰ (any choice is admissible; X⁰ keeps the first compressed
        // difference small). Hw¹ = W H¹ — computed directly from the global
        // state we own; on a real deployment this is the one-time
        // uncompressed warm-up exchange of Alg. 2 line 3.
        self.h = Mat::from_rows(x0);
        self.hw = Mat::zeros(n, d);
        for i in 0..n {
            for j in std::iter::once(i).chain(ctx.mix.neighbors[i].iter().copied()) {
                crate::linalg::axpy(ctx.mix.weight(i, j), &x0[j], self.hw.row_mut(i));
            }
        }
        // X¹ = X⁰ − η ∇F(X⁰; ξ⁰)  (Alg. 2 line 5).
        self.x = Mat::from_rows(x0);
        for i in 0..n {
            crate::linalg::axpy(-ctx.eta, &g0[i], self.x.row_mut(i));
        }
        self.y = Mat::zeros(n, d);
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        let Lead { x, d, h, y, .. } = self;
        send_agent(ctx.eta, x.row(agent), d.row(agent), h.row(agent), g, y.row_mut(agent), &mut out[0]);
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let eta = ctx.eta;
        let Lead { x, d, h, y, .. } = self;
        let (x, d, h) = (&*x, &*d, &*h);
        super::par_agents2(exec, &mut [y], g, payload, |i, rows, gi, pi| match rows {
            [y] => {
                grad(i, x.row(i), gi);
                send_agent(eta, x.row(i), d.row(i), h.row(i), gi, y, &mut pi[0]);
                sink(i, pi);
            }
            _ => unreachable!(),
        });
    }

    fn recv(
        &mut self,
        ctx: &Ctx,
        agent: usize,
        g: &[f64],
        self_dec: &[&[f64]],
        mixed: &[&[f64]],
    ) {
        apply_agent(
            self.params,
            ctx.eta,
            g,
            OwnView::Dense(self_dec[0]),
            mixed[0],
            self.x.row_mut(agent),
            self.d.row_mut(agent),
            self.h.row_mut(agent),
            self.hw.row_mut(agent),
        );
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let params = self.params;
        let eta = ctx.eta;
        super::par_agents(
            exec,
            &mut [&mut self.x, &mut self.d, &mut self.h, &mut self.hw],
            |i, rows| match rows {
                // Crashed agents skip the update wholesale: x, d, and
                // the compression references h/hw all freeze (degraded-
                // inbox contract — no corrupted h on recovery).
                _ if !inbox.live(i) => {}
                [x, dvar, h, hw] => {
                    let (own, mixed) = (inbox.own_view(i, 0), inbox.mix(i, 0));
                    apply_agent(params, eta, &g[i], own, mixed, x, dvar, h, hw)
                }
                _ => unreachable!(),
            },
        );
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }

    fn compression_reference(&self, agent: usize) -> Option<&[f64]> {
        Some(self.y.row(agent))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::{linreg::LinReg, Problem};
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn converges_linearly_without_compression() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = Lead::paper_default();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 400);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1e-4, "LEAD did not converge: {err}");
    }

    #[test]
    fn dual_sums_to_zero() {
        // 1ᵀD^k = 0 — the engine-level proptest covers the compressed
        // case; this is the plain sanity check.
        let p = LinReg::synthetic(6, 20, 0.1, 5);
        let mix = Topology::Ring.build(6, MixingRule::UniformNeighbors);
        let mut algo = Lead::paper_default();
        let _ = run_plain(&mut algo, &p, &mix, 0.1, 50);
        for t in 0..p.dim() {
            let s: f64 = (0..6).map(|i| algo.dual(i)[t] as f64).sum();
            assert!(s.abs() < 1e-3, "Σ_i d_i[{t}] = {s}");
        }
    }

    #[test]
    fn dual_approaches_negative_gradient() {
        // D^k → −∇F(X*) (gradient-correction property, §3.1).
        let p = LinReg::synthetic(4, 16, 0.1, 11);
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut algo = Lead::paper_default();
        let _ = run_plain(&mut algo, &p, &mix, 0.1, 600);
        let xstar = p.optimum().unwrap();
        let mut g = vec![0.0f64; p.dim()];
        for i in 0..4 {
            p.grad_full(i, xstar, &mut g);
            let diff: f64 = algo
                .dual(i)
                .iter()
                .zip(&g)
                .map(|(d, gi)| ((*d + *gi) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(diff < 1e-2, "agent {i}: ‖d + ∇f_i(x*)‖ = {diff}");
        }
    }

    /// The parallel apply phase must equal the sequential one bitwise —
    /// algorithm-level check (the engine-level test covers the full loop).
    #[test]
    fn recv_all_parallel_equals_sequential() {
        use crate::algorithms::testutil::run_plain_threads;
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let run = |threads: usize| {
            let mut algo = Lead::paper_default();
            run_plain_threads(&mut algo, &p, &mix, 0.1, 20, threads)
        };
        let seq = run(1);
        let par = run(4);
        for (a, b) in seq.iter().zip(&par) {
            for (u, v) in a.iter().zip(b) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }
}
