//! Exact Diffusion (Yuan et al. 2018) — adapt-then-combine with a
//! correction step:
//!
//! ```text
//! ψ^{k+1} = x^k − η ∇f(x^k)          (adapt)
//! φ^{k+1} = ψ^{k+1} + x^k − ψ^k      (correct)
//! x^{k+1} = (I+W)/2 · φ^{k+1}        (combine)
//! ```
//!
//! Another member of the primal–dual family LEAD recovers (Remark 3 /
//! Prop. 1, via A = (I+W)/2, M = ηI in Yuan et al. Eq. 97).

use super::{AlgoSpec, Algorithm, Ctx};

pub struct ExactDiffusion {
    x: Vec<Vec<f64>>,
    psi: Vec<Vec<f64>>,
}

impl ExactDiffusion {
    pub fn new() -> Self {
        ExactDiffusion { x: vec![], psi: vec![] }
    }
}

impl Default for ExactDiffusion {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for ExactDiffusion {
    fn name(&self) -> String {
        "ExactDiffusion".into()
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: false }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        self.x = x0.to_vec();
        // ψ⁰ = x⁰ makes the first correction a no-op.
        self.psi = x0.to_vec();
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        let x = &self.x[agent];
        let psi_old = &mut self.psi[agent];
        let phi = &mut out[0];
        for t in 0..x.len() {
            let psi_new = x[t] - ctx.eta * g[t];
            phi[t] = psi_new + x[t] - psi_old[t];
            psi_old[t] = psi_new;
        }
    }

    fn recv(&mut self, _ctx: &Ctx, agent: usize, _g: &[f64], self_dec: &[&[f64]], mixed: &[&[f64]]) {
        let x = &mut self.x[agent];
        for t in 0..x.len() {
            x[t] = 0.5 * (self_dec[0][t] + mixed[0][t]);
        }
    }

    fn x(&self, agent: usize) -> &[f64] {
        &self.x[agent]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = ExactDiffusion::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 500);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1e-4, "ExactDiffusion err {err}");
    }
}
