//! Exact Diffusion (Yuan et al. 2018) — adapt-then-combine with a
//! correction step:
//!
//! ```text
//! ψ^{k+1} = x^k − η ∇f(x^k)          (adapt)
//! φ^{k+1} = ψ^{k+1} + x^k − ψ^k      (correct)
//! x^{k+1} = (I+W)/2 · φ^{k+1}        (combine)
//! ```
//!
//! Another member of the primal–dual family LEAD recovers (Remark 3 /
//! Prop. 1, via A = (I+W)/2, M = ηI in Yuan et al. Eq. 97).

use super::{AlgoSpec, Algorithm, Ctx, Exec, GradFn, Inbox, OwnAccess, OwnView, SinkFn};
use crate::linalg::Mat;

pub struct ExactDiffusion {
    x: Mat,
    psi: Mat,
}

/// Per-agent adapt+correct step over disjoint rows: `ψ⁺ = x − ηg`,
/// broadcast `φ = ψ⁺ + x − ψ`, then shift ψ.
#[inline]
fn send_agent(eta: f64, x: &[f64], g: &[f64], psi: &mut [f64], out0: &mut [f64]) {
    for t in 0..x.len() {
        let psi_new = x[t] - eta * g[t];
        out0[t] = psi_new + x[t] - psi[t];
        psi[t] = psi_new;
    }
}

/// Per-agent combine step: x = (φ + Wφ)/2. `phi_own` is an [`OwnView`]
/// so the kernel has a sparse overload like the compressed family
/// (Exact Diffusion broadcasts uncompressed, so the engine always serves
/// it the dense arm — the sparse arm is pinned at the unit level by
/// `rust/tests/sparse_own.rs`).
#[inline]
fn apply_agent(phi_own: OwnView<'_>, phi_mix: &[f64], x: &mut [f64]) {
    phi_own.for_each(x.len(), |t, phi| {
        x[t] = 0.5 * (phi + phi_mix[t]);
    });
}

impl ExactDiffusion {
    pub fn new() -> Self {
        ExactDiffusion { x: Mat::zeros(0, 0), psi: Mat::zeros(0, 0) }
    }
}

impl Default for ExactDiffusion {
    fn default() -> Self {
        Self::new()
    }
}

impl Algorithm for ExactDiffusion {
    fn name(&self) -> String {
        "ExactDiffusion".into()
    }

    fn spec(&self) -> AlgoSpec {
        AlgoSpec { channels: 1, compressed: false, own: OwnAccess::Sparse }
    }

    fn init(&mut self, _ctx: &Ctx, x0: &[Vec<f64>], _g0: &[Vec<f64>]) {
        self.x = Mat::from_rows(x0);
        // ψ⁰ = x⁰ makes the first correction a no-op.
        self.psi = Mat::from_rows(x0);
    }

    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]) {
        let ExactDiffusion { x, psi } = self;
        send_agent(ctx.eta, x.row(agent), g, psi.row_mut(agent), &mut out[0]);
    }

    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let eta = ctx.eta;
        let ExactDiffusion { x, psi } = self;
        let x = &*x;
        super::par_agents2(exec, &mut [psi], g, payload, |i, rows, gi, pi| match rows {
            [psi] => {
                grad(i, x.row(i), gi);
                send_agent(eta, x.row(i), gi, psi, &mut pi[0]);
                sink(i, pi);
            }
            _ => unreachable!(),
        });
    }

    fn recv(
        &mut self,
        _ctx: &Ctx,
        agent: usize,
        _g: &[f64],
        self_dec: &[&[f64]],
        mixed: &[&[f64]],
    ) {
        apply_agent(OwnView::Dense(self_dec[0]), mixed[0], self.x.row_mut(agent));
    }

    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let _ = (ctx, g);
        super::par_agents(exec, &mut [&mut self.x], |i, rows| match rows {
            _ if !inbox.live(i) => {}
            [x] => apply_agent(inbox.own_view(i, 0), inbox.mix(i, 0), x),
            _ => unreachable!(),
        });
    }

    fn x(&self, agent: usize) -> &[f64] {
        self.x.row(agent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::testutil::{max_dist_to_opt, run_plain};
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn exact_convergence() {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut algo = ExactDiffusion::new();
        let xs = run_plain(&mut algo, &p, &mix, 0.1, 500);
        let err = max_dist_to_opt(&xs, &p);
        assert!(err < 1e-4, "ExactDiffusion err {err}");
    }
}
