//! Decentralized optimization algorithms: LEAD (the paper's contribution)
//! and the baselines it is evaluated against.
//!
//! Every algorithm in the paper's experimental section fits one
//! communication pattern per round: each agent broadcasts one (or, for
//! gradient-tracking methods, two) d-vectors to its neighbors, possibly
//! compressed, and consumes (a) its *own* decoded broadcast and (b) the
//! W-weighted mix `Σ_j w_ij decode(msg_j)` over its closed neighborhood.
//! The [`Algorithm`] trait captures exactly that; the coordinator engine
//! owns gradient evaluation, compression, mixing, and wire-bit accounting,
//! so all algorithms are measured under identical rules.
//!
//! Round protocol driven by the engine:
//!
//! 1. engine computes `g_i = ∇f_i(x_i; ξ_i)` once per agent (LEAD reuses
//!    the same sample in its two updates — paper Alg. 1 lines 4 & 7);
//! 2. `send(i, g_i)` returns the per-channel payload vectors of agent i;
//! 3. the engine compresses channel 0 (if the algorithm opts in), counts
//!    wire bits, decodes, and forms the weighted mixes;
//! 4. `recv(i, g_i, self_decoded, mixed)` applies the local update.

pub mod choco;
pub mod d2;
pub mod deepsqueeze;
pub mod dgd;
pub mod diging;
pub mod exact_diffusion;
pub mod lead;
pub mod nids;
pub mod qdgd;

use crate::topology::MixingMatrix;

/// Static description the engine needs before the first round.
#[derive(Clone, Debug)]
pub struct AlgoSpec {
    /// Number of broadcast channels per round (1 for everything except
    /// gradient tracking, which sends the tracker too).
    pub channels: usize,
    /// Whether channel 0 should pass through the configured compressor.
    /// Non-compressed baselines (DGD, NIDS, …) set this to false and are
    /// billed 32 bits/element.
    pub compressed: bool,
}

/// Per-round immutable context handed to the algorithm.
pub struct Ctx<'a> {
    pub mix: &'a MixingMatrix,
    /// Round index, starting at 1 (round 0 is `init`).
    pub round: usize,
    /// Stepsize η for this round (engine applies any decay schedule).
    pub eta: f64,
}

/// A decentralized algorithm.
///
/// The struct owns all per-agent state (x_i, duals, error memories, ...).
/// `Sync` is required so the engine's worker pool can read iterates
/// (`x(i)`) concurrently during the gradient phase; all mutation happens in
/// the sequential leader phase.
pub trait Algorithm: Send + Sync {
    fn name(&self) -> String;

    fn spec(&self) -> AlgoSpec;

    /// Initialize state. `x0[i]` is agent i's initial iterate and `g0[i]`
    /// the gradient at it (LEAD's init performs `X¹ = X⁰ − η∇F(X⁰)`).
    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]);

    /// Produce the payload(s) agent i broadcasts this round, given the
    /// fresh gradient `g`. Returns `spec().channels` vectors via `out`.
    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]);

    /// Apply the received communication: `self_dec[c]` is agent i's own
    /// decoded channel-c payload (== the sent payload when uncompressed),
    /// `mixed[c] = Σ_{j∈N_i∪{i}} w_ij · decode(payload_j[c])`.
    fn recv(
        &mut self,
        ctx: &Ctx,
        agent: usize,
        g: &[f64],
        self_dec: &[&[f64]],
        mixed: &[&[f64]],
    );

    /// Current iterate of agent i.
    fn x(&self, agent: usize) -> &[f64];

    /// Auxiliary diagnostic: the compression *input* of the last round
    /// (`Y^k` for LEAD, the raw model for QDGD/DeepSqueeze, the gossip
    /// difference for CHOCO). Used for the paper's Fig. 1d compression
    /// error panel. Returns None for non-compressed algorithms.
    fn compression_reference(&self, agent: usize) -> Option<&[f64]> {
        let _ = agent;
        None
    }
}

/// Helper used by several algorithms: allocate n copies of a zero vector.
pub(crate) fn zeros(n: usize, d: usize) -> Vec<Vec<f64>> {
    vec![vec![0.0f64; d]; n]
}

pub mod testutil {
    //! A miniature reference engine used by per-algorithm unit tests
    //! (the real engines live in `coordinator` and get their own tests;
    //! this one is deliberately simple — full mixing, no compression).

    use super::*;
    use crate::problems::Problem;

    /// Run `algo` for `rounds` full-gradient rounds without compression.
    /// Returns per-agent final iterates.
    pub fn run_plain(
        algo: &mut dyn Algorithm,
        problem: &dyn Problem,
        mix: &MixingMatrix,
        eta: f64,
        rounds: usize,
    ) -> Vec<Vec<f64>> {
        let n = problem.n_agents();
        let d = problem.dim();
        let spec = algo.spec();
        let x0 = zeros(n, d);
        let mut g = zeros(n, d);
        for i in 0..n {
            problem.grad_full(i, &x0[i], &mut g[i]);
        }
        let ctx0 = Ctx { mix, round: 0, eta };
        algo.init(&ctx0, &x0, &g);
        let mut payload = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        for round in 1..=rounds {
            let ctx = Ctx { mix, round, eta };
            for i in 0..n {
                problem.grad_full(i, algo.x(i), &mut g[i]);
            }
            for i in 0..n {
                let gi = g[i].clone();
                algo.send(&ctx, i, &gi, &mut payload[i]);
            }
            for i in 0..n {
                let mut mixed = vec![vec![0.0f64; d]; spec.channels];
                for c in 0..spec.channels {
                    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                        crate::linalg::axpy(mix.weight(i, j), &payload[j][c], &mut mixed[c]);
                    }
                }
                let self_dec: Vec<&[f64]> = payload[i].iter().map(|v| v.as_slice()).collect();
                let mixed_refs: Vec<&[f64]> = mixed.iter().map(|v| v.as_slice()).collect();
                let gi = g[i].clone();
                algo.recv(&ctx, i, &gi, &self_dec, &mixed_refs);
            }
        }
        (0..n).map(|i| algo.x(i).to_vec()).collect()
    }

    /// Max distance of any agent's iterate to the problem optimum.
    pub fn max_dist_to_opt(xs: &[Vec<f64>], problem: &dyn Problem) -> f64 {
        let opt = problem.optimum().expect("problem must expose optimum");
        xs.iter()
            .map(|x| crate::linalg::dist_sq(x, opt).sqrt())
            .fold(0.0, f64::max)
    }
}
