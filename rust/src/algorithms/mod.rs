//! Decentralized optimization algorithms: LEAD (the paper's contribution)
//! and the baselines it is evaluated against.
//!
//! Every algorithm in the paper's experimental section fits one
//! communication pattern per round: each agent broadcasts one (or, for
//! gradient-tracking methods, two) d-vectors to its neighbors, possibly
//! compressed, and consumes (a) its *own* decoded broadcast and (b) the
//! W-weighted mix `Σ_j w_ij decode(msg_j)` over its closed neighborhood.
//! The [`Algorithm`] trait captures exactly that; the coordinator engine
//! owns gradient evaluation, compression, mixing, and wire-bit accounting,
//! so all algorithms are measured under identical rules.
//!
//! Round protocol driven by the engine:
//!
//! 1. engine computes `g_i = ∇f_i(x_i; ξ_i)` once per agent (LEAD reuses
//!    the same sample in its two updates — paper Alg. 1 lines 4 & 7);
//! 2. `send(i, g_i)` returns the per-channel payload vectors of agent i;
//! 3. the engine compresses channel 0 (if the algorithm opts in), counts
//!    wire bits, decodes, and forms the weighted mixes;
//! 4. `recv_all(g, inbox, threads)` applies the local updates — in
//!    parallel over agents when `threads > 1`, which is safe because
//!    per-agent state is disjoint (see [`par_agents`]).
//!
//! # State layout and the parallel apply phase
//!
//! Per-agent state lives in contiguous row-major [`Mat`] buffers (one row
//! per agent) rather than `Vec<Vec<f64>>`: the hot apply loops then stream
//! over cache-friendly, auto-vectorizable rows, and [`par_agents`] can
//! hand disjoint row bundles to a scoped worker pool without any
//! synchronization. Each algorithm expresses its per-agent update once as
//! a plain-function kernel over those rows; the sequential [`Algorithm::
//! recv`] path (used by invariant tests that probe state mid-round) and
//! the parallel [`Algorithm::recv_all`] path both call that kernel, so
//! they cannot drift apart.

pub mod choco;
pub mod d2;
pub mod deepsqueeze;
pub mod dgd;
pub mod diging;
pub mod exact_diffusion;
pub mod lead;
pub mod nids;
pub mod qdgd;

use crate::linalg::Mat;
use crate::topology::MixingMatrix;

/// Static description the engine needs before the first round.
#[derive(Clone, Debug)]
pub struct AlgoSpec {
    /// Number of broadcast channels per round (1 for everything except
    /// gradient tracking, which sends the tracker too).
    pub channels: usize,
    /// Whether channel 0 should pass through the configured compressor.
    /// Non-compressed baselines (DGD, NIDS, …) set this to false and are
    /// billed 32 bits/element.
    pub compressed: bool,
}

/// Per-round immutable context handed to the algorithm.
pub struct Ctx<'a> {
    pub mix: &'a MixingMatrix,
    /// Round index, starting at 1 (round 0 is `init`).
    pub round: usize,
    /// Stepsize η for this round (engine applies any decay schedule).
    pub eta: f64,
}

/// The per-round received communication, assembled once by the engine (or
/// a test harness) and consumed by [`Algorithm::recv_all`].
///
/// Both views are per-agent, per-channel borrowed slices, so the inbox is
/// `Sync` and can be read concurrently by the apply-phase worker pool.
pub struct Inbox<'a> {
    /// `self_dec[i][c]` — agent i's own decoded channel-c payload
    /// (== the sent payload when uncompressed).
    pub self_dec: Vec<Vec<&'a [f64]>>,
    /// `mixed[i][c] = Σ_{j∈N_i∪{i}} w_ij · decode(payload_j[c])`.
    pub mixed: Vec<Vec<&'a [f64]>>,
}

impl<'a> Inbox<'a> {
    /// Assemble an inbox from raw (uncompressed) payloads and per-agent
    /// mixes — the harness case where every agent's own decoded payload is
    /// just what it sent. The engine builds its view by hand instead, to
    /// splice decoded channel-0 messages in front of the raw payloads.
    pub fn from_payloads(payload: &'a [Vec<Vec<f64>>], mixed: &'a [Vec<Vec<f64>>]) -> Inbox<'a> {
        Inbox {
            self_dec: payload
                .iter()
                .map(|p| p.iter().map(|v| v.as_slice()).collect())
                .collect(),
            mixed: mixed.iter().map(|a| a.iter().map(|v| v.as_slice()).collect()).collect(),
        }
    }

    /// Agent i's own decoded channel-c payload.
    #[inline]
    pub fn own(&self, agent: usize, channel: usize) -> &'a [f64] {
        self.self_dec[agent][channel]
    }

    /// The W-weighted channel-c mix delivered to agent i.
    #[inline]
    pub fn mix(&self, agent: usize, channel: usize) -> &'a [f64] {
        self.mixed[agent][channel]
    }
}

/// A decentralized algorithm.
///
/// The struct owns all per-agent state (x_i, duals, error memories, ...)
/// as row-major [`Mat`]s — one row per agent. `Sync` is required so the
/// engine's worker pool can read iterates (`x(i)`) concurrently during the
/// gradient phase and apply per-agent updates concurrently in `recv_all`.
pub trait Algorithm: Send + Sync {
    fn name(&self) -> String;

    fn spec(&self) -> AlgoSpec;

    /// Initialize state. `x0[i]` is agent i's initial iterate and `g0[i]`
    /// the gradient at it (LEAD's init performs `X¹ = X⁰ − η∇F(X⁰)`).
    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]);

    /// Produce the payload(s) agent i broadcasts this round, given the
    /// fresh gradient `g`. Returns `spec().channels` vectors via `out`.
    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]);

    /// Apply the received communication for ONE agent: `self_dec[c]` is
    /// agent i's own decoded channel-c payload, `mixed[c]` the W-weighted
    /// mix. Sequential path — kept for harnesses that probe invariants
    /// between single-agent updates; the engine calls [`recv_all`].
    ///
    /// [`recv_all`]: Algorithm::recv_all
    fn recv(
        &mut self,
        ctx: &Ctx,
        agent: usize,
        g: &[f64],
        self_dec: &[&[f64]],
        mixed: &[&[f64]],
    );

    /// Apply the received communication for ALL agents. Implementations
    /// override this with a [`par_agents`]-based version that updates
    /// agents on `threads` workers; the default falls back to the
    /// sequential per-agent [`recv`].
    ///
    /// Contract: the result must be bitwise-identical to calling `recv`
    /// for agents `0..n` in order (per-agent updates touch disjoint state
    /// and no RNG, so scheduling cannot change the trajectory).
    ///
    /// [`recv`]: Algorithm::recv
    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, threads: usize) {
        let _ = threads;
        for (i, gi) in g.iter().enumerate() {
            self.recv(ctx, i, gi, &inbox.self_dec[i], &inbox.mixed[i]);
        }
    }

    /// Current iterate of agent i.
    fn x(&self, agent: usize) -> &[f64];

    /// Auxiliary diagnostic: the compression *input* of the last round
    /// (`Y^k` for LEAD, the raw model for QDGD/DeepSqueeze, the gossip
    /// difference for CHOCO). Used for the paper's Fig. 1d compression
    /// error panel. Returns None for non-compressed algorithms.
    fn compression_reference(&self, agent: usize) -> Option<&[f64]> {
        let _ = agent;
        None
    }
}

/// Run `f(i, rows)` for every agent i, where `rows[m]` is agent i's row of
/// `mats[m]` — sequentially when `threads == 1`, otherwise chunked across
/// a scoped worker pool.
///
/// Safety model: each `Mat` is split into disjoint per-thread row ranges
/// (`chunks_mut`), so no two workers ever alias state; `f` receives only
/// agent i's rows plus whatever `Sync` references it captured. Combined
/// with the no-RNG contract of [`Algorithm::recv_all`], the parallel
/// schedule is bitwise-equal to the sequential one.
pub fn par_agents<F>(threads: usize, mats: Vec<&mut Mat>, f: F)
where
    F: Fn(usize, &mut [&mut [f64]]) + Sync,
{
    let n = mats.first().map_or(0, |m| m.rows);
    debug_assert!(mats.iter().all(|m| m.rows == n), "par_agents: agent-count mismatch");
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || mats.iter().any(|m| m.cols == 0) {
        let mut mats = mats;
        for i in 0..n {
            let mut rows: Vec<&mut [f64]> = mats.iter_mut().map(|m| m.row_mut(i)).collect();
            f(i, &mut rows);
        }
        return;
    }
    let widths: Vec<usize> = mats.iter().map(|m| m.cols).collect();
    let chunk = n.div_ceil(threads);
    // bundles[t][m] = thread t's contiguous row range of mats[m].
    let mut bundles: Vec<Vec<&mut [f64]>> = Vec::new();
    for m in mats {
        let w = chunk * m.cols;
        for (t, ch) in m.data.chunks_mut(w).enumerate() {
            if bundles.len() <= t {
                bundles.push(Vec::new());
            }
            bundles[t].push(ch);
        }
    }
    std::thread::scope(|s| {
        for (t, mut bundle) in bundles.into_iter().enumerate() {
            let base = t * chunk;
            let f = &f;
            let widths = &widths;
            s.spawn(move || {
                let rows_here = bundle[0].len() / widths[0];
                for off in 0..rows_here {
                    let mut rows: Vec<&mut [f64]> = bundle
                        .iter_mut()
                        .zip(widths.iter())
                        .map(|(ch, &w)| &mut ch[off * w..(off + 1) * w])
                        .collect();
                    f(base + off, &mut rows);
                }
            });
        }
    });
}

/// Helper used by several algorithms: allocate n copies of a zero vector.
pub(crate) fn zeros(n: usize, d: usize) -> Vec<Vec<f64>> {
    vec![vec![0.0f64; d]; n]
}

pub mod testutil {
    //! A miniature reference engine used by per-algorithm unit tests
    //! (the real engines live in `coordinator` and get their own tests;
    //! this one is deliberately simple — full mixing, no compression —
    //! but drives the same `recv_all` apply phase the coordinator uses).

    use super::*;
    use crate::problems::Problem;

    /// Run `algo` for `rounds` full-gradient rounds without compression.
    /// Returns per-agent final iterates.
    pub fn run_plain(
        algo: &mut dyn Algorithm,
        problem: &dyn Problem,
        mix: &MixingMatrix,
        eta: f64,
        rounds: usize,
    ) -> Vec<Vec<f64>> {
        run_plain_threads(algo, problem, mix, eta, rounds, 1)
    }

    /// [`run_plain`] with an explicit apply-phase thread count — used by
    /// the parallel-equals-sequential tests to pin the `recv_all`
    /// contract without going through the full engine.
    pub fn run_plain_threads(
        algo: &mut dyn Algorithm,
        problem: &dyn Problem,
        mix: &MixingMatrix,
        eta: f64,
        rounds: usize,
        threads: usize,
    ) -> Vec<Vec<f64>> {
        let n = problem.n_agents();
        let d = problem.dim();
        let spec = algo.spec();
        let x0 = zeros(n, d);
        let mut g = zeros(n, d);
        for i in 0..n {
            problem.grad_full(i, &x0[i], &mut g[i]);
        }
        let ctx0 = Ctx { mix, round: 0, eta };
        algo.init(&ctx0, &x0, &g);
        let mut payload = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut mixed_all = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        for round in 1..=rounds {
            let ctx = Ctx { mix, round, eta };
            for i in 0..n {
                problem.grad_full(i, algo.x(i), &mut g[i]);
            }
            for i in 0..n {
                let gi = g[i].clone();
                algo.send(&ctx, i, &gi, &mut payload[i]);
            }
            for (i, mixed) in mixed_all.iter_mut().enumerate() {
                for (c, mx) in mixed.iter_mut().enumerate() {
                    mx.fill(0.0);
                    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                        crate::linalg::axpy(mix.weight(i, j), &payload[j][c], mx);
                    }
                }
            }
            let inbox = Inbox::from_payloads(&payload, &mixed_all);
            algo.recv_all(&ctx, &g, &inbox, threads);
        }
        (0..n).map(|i| algo.x(i).to_vec()).collect()
    }

    /// Max distance of any agent's iterate to the problem optimum.
    pub fn max_dist_to_opt(xs: &[Vec<f64>], problem: &dyn Problem) -> f64 {
        let opt = problem.optimum().expect("problem must expose optimum");
        xs.iter()
            .map(|x| crate::linalg::dist_sq(x, opt).sqrt())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every algorithm's recv_all closure must be schedule-invariant:
    /// threads > 1 (including counts that don't divide n and exceed n)
    /// reproduces the sequential trajectory bitwise. This is the
    /// per-algorithm wiring check (slice-pattern order, channel indices);
    /// the chunking mechanism itself is covered below.
    #[test]
    fn all_algorithms_recv_all_parallel_equals_sequential() {
        use crate::problems::linreg::LinReg;
        use crate::topology::{MixingRule, Topology};
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let builders: Vec<(&str, fn() -> Box<dyn Algorithm>)> = vec![
            ("lead", || Box::new(lead::Lead::paper_default())),
            ("nids", || Box::new(nids::Nids::new())),
            ("d2", || Box::new(d2::D2::new())),
            ("dgd", || Box::new(dgd::Dgd::new())),
            ("diging", || Box::new(diging::DiGing::new())),
            ("exact_diffusion", || Box::new(exact_diffusion::ExactDiffusion::new())),
            ("choco", || Box::new(choco::ChocoSgd::new(0.8))),
            ("deepsqueeze", || Box::new(deepsqueeze::DeepSqueeze::new(0.2))),
            ("qdgd", || Box::new(qdgd::Qdgd::new(0.2))),
        ];
        for (name, build) in builders {
            let run = |threads: usize| {
                let mut algo = build();
                testutil::run_plain_threads(&mut *algo, &p, &mix, 0.05, 15, threads)
            };
            let seq = run(1);
            for threads in [3usize, 4, 16] {
                let par = run(threads);
                for (a, b) in seq.iter().zip(&par) {
                    for (u, v) in a.iter().zip(b) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{name} threads={threads}");
                    }
                }
            }
        }
    }

    /// par_agents must visit every agent exactly once with its own rows,
    /// for any thread count (including thread counts above n).
    #[test]
    fn par_agents_covers_all_rows_disjointly() {
        for n in [1usize, 3, 7, 8] {
            for threads in [1usize, 2, 3, 8, 16] {
                let mut a = Mat::zeros(n, 4);
                let mut b = Mat::zeros(n, 2);
                par_agents(threads, vec![&mut a, &mut b], |i, rows| match rows {
                    [ra, rb] => {
                        for v in ra.iter_mut() {
                            *v += (i + 1) as f64;
                        }
                        for v in rb.iter_mut() {
                            *v += 10.0 * (i + 1) as f64;
                        }
                    }
                    _ => unreachable!(),
                });
                for i in 0..n {
                    assert!(a.row(i).iter().all(|&v| v == (i + 1) as f64), "n={n} t={threads}");
                    assert!(b.row(i).iter().all(|&v| v == 10.0 * (i + 1) as f64));
                }
            }
        }
    }

    /// Zero-width state (d = 0) must not panic (degenerate chunk size).
    #[test]
    fn par_agents_handles_zero_cols() {
        let mut a = Mat::zeros(4, 0);
        let visited = std::sync::atomic::AtomicUsize::new(0);
        let v = &visited;
        par_agents(4, vec![&mut a], |_, _| {
            v.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(visited.load(std::sync::atomic::Ordering::Relaxed), 4);
    }
}
