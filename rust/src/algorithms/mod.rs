//! Decentralized optimization algorithms: LEAD (the paper's contribution)
//! and the baselines it is evaluated against.
//!
//! Every algorithm in the paper's experimental section fits one
//! communication pattern per round: each agent broadcasts one (or, for
//! gradient-tracking methods, two) d-vectors to its neighbors, possibly
//! compressed, and consumes (a) its *own* decoded broadcast and (b) the
//! W-weighted mix `Σ_j w_ij decode(msg_j)` over its closed neighborhood.
//! The [`Algorithm`] trait captures exactly that; the coordinator engine
//! owns gradient evaluation, compression, mixing, and wire-bit accounting,
//! so all algorithms are measured under identical rules.
//!
//! Round protocol driven by the engine:
//!
//! 1. [`Algorithm::produce_all`] — the fused *produce* phase, one parallel
//!    task per agent: evaluate `g_i = ∇f_i(x_i; ξ_i)` through the
//!    engine-supplied gradient oracle (LEAD reuses the same sample in its
//!    two updates — paper Alg. 1 lines 4 & 7), assemble the broadcast
//!    payload(s), and hand them to the engine's `sink` (channel-0
//!    compression + wire-bit accounting) without an intervening barrier;
//! 2. the engine forms the W-weighted mixes (sparse-aware on channel 0);
//! 3. [`Algorithm::recv_all`] applies the local updates — in parallel over
//!    agents, which is safe because per-agent state is disjoint (see
//!    [`par_agents`]). Kernels consume the agent's own decoded broadcast
//!    through [`Inbox::own_view`], so sparse messages (top-k / rand-k)
//!    are applied straight from their k published entries and no dense
//!    own-decode pass runs in the steady state ([`OwnAccess`]).
//!
//! The sequential [`Algorithm::send`] / [`Algorithm::recv`] pair is kept
//! for harnesses that probe invariants between single-agent steps; each
//! algorithm expresses its per-agent send and apply updates once as
//! plain-function kernels, and both the sequential and fused/parallel
//! paths call those kernels, so they cannot drift apart.
//!
//! # State layout and the parallel phases
//!
//! Per-agent state lives in contiguous row-major [`crate::linalg::Mat`]
//! buffers (one row
//! per agent) rather than `Vec<Vec<f64>>`: the hot loops then stream over
//! cache-friendly, auto-vectorizable rows, and [`par_agents`] /
//! [`par_agents2`] can hand disjoint row bundles to the worker pool
//! (`crate::pool`) without any synchronization or per-round allocation.

pub mod choco;
pub mod d2;
pub mod deepsqueeze;
pub mod dgd;
pub mod diging;
pub mod exact_diffusion;
pub mod lead;
pub mod nids;
pub mod qdgd;

use crate::compress::CompressedMsg;
use crate::topology::MixingMatrix;

pub use crate::pool::{par_agents, par_agents2, Exec, WorkerPool};

/// Static description the engine needs before the first round.
#[derive(Clone, Debug)]
pub struct AlgoSpec {
    /// Number of broadcast channels per round (1 for everything except
    /// gradient tracking, which sends the tracker too).
    pub channels: usize,
    /// Whether channel 0 should pass through the configured compressor.
    /// Non-compressed baselines (DGD, NIDS, …) set this to false and are
    /// billed 32 bits/element.
    pub compressed: bool,
    /// How the apply phase consumes the agent's *own* decoded channel-0
    /// broadcast — see [`OwnAccess`]. Declaring [`OwnAccess::Sparse`] is
    /// what lets the engine skip the O(n·d) own-decode pass in the top-k
    /// steady state (§Perf in `coordinator::engine`).
    pub own: OwnAccess,
}

/// How an algorithm's apply phase consumes the agent's *own* decoded
/// channel-0 payload. This replaces the old boolean `reads_own`
/// dense-materialization contract: the engine uses it to decide whether
/// sparse messages (top-k / rand-k) must be decoded to a dense d-vector
/// before [`Algorithm::recv_all`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OwnAccess {
    /// `recv`/`recv_all` never read the own decoded payload (DGD,
    /// DIGing). The engine skips the dense decode of sparse messages
    /// entirely.
    None,
    /// The apply kernels accept [`OwnView::Sparse`]: sparse messages are
    /// consumed straight from their k published `(index, value)` entries
    /// and the engine never materializes the dense decoded vector on the
    /// hot path. Kernels must go through [`Inbox::own_view`] (not
    /// [`Inbox::own`], which hard-asserts on a stale dense view).
    Sparse,
    /// The apply path requires a fully materialized dense vector
    /// ([`Inbox::own`]); the engine eagerly decodes every sparse message
    /// inside the produce phase — an O(d)-per-agent pass. Only declare
    /// this when the kernels cannot be expressed over [`OwnView`]; the
    /// trait-default `recv_all` (which funnels dense slices into `recv`)
    /// also requires it.
    Dense,
}

/// A borrowed view of one agent's own decoded channel-0 message, handed
/// to the apply kernels by [`Inbox::own_view`].
///
/// # The ±0.0 bit-exactness rule
///
/// The `Sparse` arm carries the codec's published `(index, value)`
/// entries (ascending, unique indices) — **all** selected entries,
/// ±0.0-valued ones included (see `Compressor::compress_into`). The dense
/// decode of such a message is `fill(0.0)` + scatter, so coordinate `t`
/// decodes to the published value verbatim, or to exactly `+0.0` when
/// unpublished. [`OwnView::for_each`] feeds kernels precisely those
/// values, which makes a kernel driven through it bitwise-identical to
/// the same kernel reading the materialized dense vector — not merely
/// numerically close. The differential harness in
/// `rust/tests/sparse_own.rs` pins this end to end.
#[derive(Clone, Copy)]
pub enum OwnView<'a> {
    /// Fully materialized decoded vector (dense codecs, uncompressed
    /// payloads, eagerly decoded messages, and the sequential `recv`
    /// harness path).
    Dense(&'a [f64]),
    /// The k published `(index, value)` entries of a sparse message whose
    /// dense vector was never materialized; every unlisted coordinate
    /// decodes to exactly `+0.0`.
    Sparse(&'a [(u32, f64)]),
}

impl OwnView<'_> {
    /// Drive `body(t, q_t)` for every coordinate `t in 0..d`, where `q_t`
    /// is the decoded own value at `t` (±0.0 rule above). This is the
    /// single definition both arms share: per-agent apply kernels put
    /// their per-coordinate update in `body` once, and the sparse arm is
    /// then bitwise-equal to the dense arm by construction — the only
    /// difference is an O(k) cursor walk instead of an O(d) memory
    /// stream.
    #[inline]
    pub fn for_each(&self, d: usize, mut body: impl FnMut(usize, f64)) {
        match *self {
            OwnView::Dense(vals) => {
                debug_assert_eq!(vals.len(), d, "own view length mismatch");
                for (t, &q) in vals.iter().enumerate() {
                    body(t, q);
                }
            }
            OwnView::Sparse(entries) => {
                let mut it = entries.iter();
                let mut cur = it.next();
                for t in 0..d {
                    let q = match cur {
                        Some(&(i, v)) if i as usize == t => {
                            cur = it.next();
                            v
                        }
                        _ => 0.0,
                    };
                    body(t, q);
                }
            }
        }
    }
}

/// Per-round immutable context handed to the algorithm.
pub struct Ctx<'a> {
    pub mix: &'a MixingMatrix,
    /// Round index, starting at 1 (round 0 is `init`).
    pub round: usize,
    /// Stepsize η for this round (engine applies any decay schedule).
    pub eta: f64,
}

/// The per-round received communication, consumed by
/// [`Algorithm::recv_all`].
///
/// A zero-allocation *view* over the engine's reusable round buffers
/// (§Perf): constructing one copies three references, and the accessors
/// resolve per (agent, channel) on demand. When the engine compressed
/// channel 0, `decoded0` overrides the raw payload with the decoded
/// messages every receiver reconstructs.
pub struct Inbox<'a> {
    /// Raw per-agent, per-channel payloads as sent.
    payload: &'a [Vec<Vec<f64>>],
    /// `mixed[i][c] = Σ_{j∈N_i∪{i}} w_ij · decode(payload_j[c])`.
    mixed: &'a [Vec<Vec<f64>>],
    /// Decoded channel-0 messages (compressed runs only).
    decoded0: Option<&'a [CompressedMsg]>,
    /// Per-agent crash mask for this round (fault-injection runs only;
    /// see the degraded-inbox contract in `coordinator::engine` §Fault
    /// injection). A down agent's apply must be skipped wholesale —
    /// [`Inbox::live`] — freezing its state until recovery.
    down: Option<&'a [bool]>,
}

impl<'a> Inbox<'a> {
    /// Assemble an inbox from raw (uncompressed) payloads and per-agent
    /// mixes — every agent's own decoded payload is just what it sent.
    pub fn from_payloads(payload: &'a [Vec<Vec<f64>>], mixed: &'a [Vec<Vec<f64>>]) -> Inbox<'a> {
        Inbox { payload, mixed, decoded0: None, down: None }
    }

    /// Engine view: decoded channel-0 messages spliced in front of the
    /// raw payloads. Messages may carry only a sparse view
    /// (`dense_stale`); a valid dense vector is guaranteed only when the
    /// algorithm's spec declares [`OwnAccess::Dense`] (the engine then
    /// materializes it inside the produce phase).
    pub fn with_decoded0(
        payload: &'a [Vec<Vec<f64>>],
        mixed: &'a [Vec<Vec<f64>>],
        msgs: &'a [CompressedMsg],
    ) -> Inbox<'a> {
        Inbox { payload, mixed, decoded0: Some(msgs), down: None }
    }

    /// Attach the fault schedule's per-agent crash mask (builder-style,
    /// engine-only). Apply kernels — overrides and the trait default —
    /// must gate on [`Inbox::live`] so crashed agents' state freezes.
    pub fn with_faults(mut self, down: &'a [bool]) -> Inbox<'a> {
        self.down = Some(down);
        self
    }

    /// Whether `agent` participates in this round's apply phase (always
    /// true outside fault-injection runs).
    #[inline]
    pub fn live(&self, agent: usize) -> bool {
        self.down.is_none_or(|d| !d[agent])
    }

    /// Agent i's own decoded channel-c payload as a *dense* slice.
    ///
    /// Prefer [`Inbox::own_view`] in apply kernels — it is what licenses
    /// the engine to skip the O(d) own-decode of sparse messages. This
    /// accessor exists for harnesses and for algorithms that declared
    /// [`OwnAccess::Dense`].
    #[inline]
    pub fn own(&self, agent: usize, channel: usize) -> &'a [f64] {
        match self.decoded0 {
            Some(msgs) if channel == 0 => {
                let m = &msgs[agent];
                // Hard assert (one predictable branch per agent per round):
                // under the sparse-own contract a mis-declared spec —
                // `OwnAccess::None`, or `OwnAccess::Sparse` with a kernel
                // that still calls the dense accessor — would otherwise
                // return a stale previous-round vector and silently
                // corrupt the trajectory in release builds.
                assert!(
                    !m.dense_stale,
                    "Inbox::own on a stale dense view — either declare \
                     AlgoSpec::own = OwnAccess::Dense so the engine materializes it, \
                     or consume the message through Inbox::own_view"
                );
                &m.values
            }
            _ => &self.payload[agent][channel],
        }
    }

    /// Agent i's own decoded channel-c payload as an [`OwnView`] — the
    /// sparse-own hot path. Messages whose dense vector was never
    /// materialized (`dense_stale`, sparse codecs under
    /// [`OwnAccess::Sparse`]) are served straight from their published
    /// `(index, value)` entries; everything else (dense codecs,
    /// uncompressed channels, eagerly decoded messages) comes back as a
    /// dense slice. Consuming either arm through [`OwnView::for_each`]
    /// yields bitwise-identical kernels (±0.0 rule on [`OwnView`]).
    #[inline]
    pub fn own_view(&self, agent: usize, channel: usize) -> OwnView<'a> {
        match self.decoded0 {
            Some(msgs) if channel == 0 => {
                let m = &msgs[agent];
                if m.dense_stale {
                    // Contract on `Compressor::compress_into`: a codec
                    // that defers the dense fill MUST publish the sparse
                    // view — without it the message is unreadable.
                    OwnView::Sparse(
                        m.sparse
                            .as_deref()
                            .expect("stale dense view without a sparse view (codec bug)"),
                    )
                } else {
                    OwnView::Dense(&m.values)
                }
            }
            _ => OwnView::Dense(&self.payload[agent][channel]),
        }
    }

    /// The W-weighted channel-c mix delivered to agent i.
    #[inline]
    pub fn mix(&self, agent: usize, channel: usize) -> &'a [f64] {
        &self.mixed[agent][channel]
    }
}

/// Per-agent gradient oracle handed to [`Algorithm::produce_all`]:
/// `grad(agent, x_agent, out)` evaluates `∇f_agent` at `x_agent` into
/// `out` (full or mini-batch — the engine decides; batch indices are
/// pre-drawn in agent order so the RNG stream is schedule-independent).
pub type GradFn<'e> = &'e (dyn Fn(usize, &[f64], &mut [f64]) + Sync);

/// Per-agent payload sink handed to [`Algorithm::produce_all`]:
/// `sink(agent, payload_agent)` compresses/accounts the just-assembled
/// payload. The engine relies on it being invoked **exactly once per
/// agent**, each agent from a single worker (it writes per-agent engine
/// buffers through that index).
pub type SinkFn<'e> = &'e (dyn Fn(usize, &mut [Vec<f64>]) + Sync);

/// A decentralized algorithm.
///
/// The struct owns all per-agent state (x_i, duals, error memories, ...)
/// as row-major [`crate::linalg::Mat`]s — one row per agent. `Sync` is required so the
/// engine's worker pool can read iterates (`x(i)`) concurrently and apply
/// per-agent updates concurrently in `produce_all` / `recv_all`.
pub trait Algorithm: Send + Sync {
    fn name(&self) -> String;

    fn spec(&self) -> AlgoSpec;

    /// Initialize state. `x0[i]` is agent i's initial iterate and `g0[i]`
    /// the gradient at it (LEAD's init performs `X¹ = X⁰ − η∇F(X⁰)`).
    fn init(&mut self, ctx: &Ctx, x0: &[Vec<f64>], g0: &[Vec<f64>]);

    /// Produce the payload(s) agent i broadcasts this round, given the
    /// fresh gradient `g`. Returns `spec().channels` vectors via `out`.
    /// Sequential path — kept for harnesses; the engine drives
    /// [`produce_all`]. Implementations may only touch per-agent state
    /// rows (plus shared reads), so the fused parallel path stays
    /// equivalent.
    ///
    /// [`produce_all`]: Algorithm::produce_all
    fn send(&mut self, ctx: &Ctx, agent: usize, g: &[f64], out: &mut [Vec<f64>]);

    /// Fused produce phase: for every agent, evaluate the gradient via
    /// `grad`, assemble the payload(s), and hand them to `sink` — one
    /// task per agent, parallel over `exec`. Implementations override
    /// this with a [`par_agents2`]-based version; the default is the
    /// sequential loop.
    ///
    /// Contract: bitwise-equivalent to `grad(i, x(i), g[i]); send(i);
    /// sink(i)` for agents `0..n` in order (per-agent work touches
    /// disjoint state and no RNG), and `sink` is invoked exactly once per
    /// agent.
    fn produce_all(
        &mut self,
        ctx: &Ctx,
        grad: GradFn<'_>,
        g: &mut [Vec<f64>],
        payload: &mut [Vec<Vec<f64>>],
        sink: SinkFn<'_>,
        exec: Exec<'_>,
    ) {
        let _ = exec;
        for i in 0..g.len() {
            grad(i, self.x(i), &mut g[i]);
            self.send(ctx, i, &g[i], &mut payload[i]);
            sink(i, &mut payload[i]);
        }
    }

    /// Apply the received communication for ONE agent: `self_dec[c]` is
    /// agent i's own decoded channel-c payload, `mixed[c]` the W-weighted
    /// mix. Sequential path — kept for harnesses that probe invariants
    /// between single-agent updates; the engine calls [`recv_all`].
    ///
    /// [`recv_all`]: Algorithm::recv_all
    fn recv(
        &mut self,
        ctx: &Ctx,
        agent: usize,
        g: &[f64],
        self_dec: &[&[f64]],
        mixed: &[&[f64]],
    );

    /// Apply the received communication for ALL agents. Implementations
    /// override this with a [`par_agents`]-based version that updates
    /// agents across `exec`'s workers and reads the own payload through
    /// [`Inbox::own_view`]; the default falls back to the sequential
    /// per-agent [`recv`] over *dense* slices (and, unlike the overrides,
    /// is not allocation-free) — an algorithm relying on it must declare
    /// [`OwnAccess::Dense`] (or [`OwnAccess::None`]), never
    /// [`OwnAccess::Sparse`].
    ///
    /// Contract: the result must be bitwise-identical to calling `recv`
    /// for agents `0..n` in order (per-agent updates touch disjoint state
    /// and no RNG, so scheduling cannot change the trajectory).
    ///
    /// [`recv`]: Algorithm::recv
    fn recv_all(&mut self, ctx: &Ctx, g: &[Vec<f64>], inbox: &Inbox<'_>, exec: Exec<'_>) {
        let _ = exec;
        let ch = self.spec().channels;
        for (i, gi) in g.iter().enumerate() {
            if !inbox.live(i) {
                continue;
            }
            let own: Vec<&[f64]> = (0..ch).map(|c| inbox.own(i, c)).collect();
            let mixed: Vec<&[f64]> = (0..ch).map(|c| inbox.mix(i, c)).collect();
            self.recv(ctx, i, gi, &own, &mixed);
        }
    }

    /// Current iterate of agent i.
    fn x(&self, agent: usize) -> &[f64];

    /// Auxiliary diagnostic: the compression *input* of the last round
    /// (`Y^k` for LEAD, the raw model for QDGD/DeepSqueeze, the gossip
    /// difference for CHOCO). Used for the paper's Fig. 1d compression
    /// error panel. Returns None for non-compressed algorithms.
    fn compression_reference(&self, agent: usize) -> Option<&[f64]> {
        let _ = agent;
        None
    }
}

/// Helper used by several algorithms: allocate n copies of a zero vector.
pub(crate) fn zeros(n: usize, d: usize) -> Vec<Vec<f64>> {
    vec![vec![0.0f64; d]; n]
}

pub mod testutil {
    //! A miniature reference engine used by per-algorithm unit tests
    //! (the real engines live in `coordinator` and get their own tests;
    //! this one is deliberately simple — full mixing, no compression —
    //! but drives the same fused `produce_all` and parallel `recv_all`
    //! phases the coordinator uses).

    use super::*;
    use crate::problems::Problem;

    /// Run `algo` for `rounds` full-gradient rounds without compression.
    /// Returns per-agent final iterates.
    pub fn run_plain(
        algo: &mut dyn Algorithm,
        problem: &dyn Problem,
        mix: &MixingMatrix,
        eta: f64,
        rounds: usize,
    ) -> Vec<Vec<f64>> {
        run_plain_threads(algo, problem, mix, eta, rounds, 1)
    }

    /// [`run_plain`] with an explicit thread count (a private
    /// [`WorkerPool`] is stood up when > 1) — used by the
    /// parallel-equals-sequential tests to pin the `produce_all` and
    /// `recv_all` contracts without going through the full engine.
    pub fn run_plain_threads(
        algo: &mut dyn Algorithm,
        problem: &dyn Problem,
        mix: &MixingMatrix,
        eta: f64,
        rounds: usize,
        threads: usize,
    ) -> Vec<Vec<f64>> {
        let pool = (threads > 1).then(|| WorkerPool::new(threads));
        let exec = match &pool {
            Some(p) => Exec::pool(p),
            None => Exec::seq(),
        };
        let n = problem.n_agents();
        let d = problem.dim();
        let spec = algo.spec();
        let x0 = zeros(n, d);
        let mut g = zeros(n, d);
        for i in 0..n {
            problem.grad_full(i, &x0[i], &mut g[i]);
        }
        let ctx0 = Ctx { mix, round: 0, eta };
        algo.init(&ctx0, &x0, &g);
        let mut payload = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut mixed_all = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let grad = |i: usize, x: &[f64], out: &mut [f64]| problem.grad_full(i, x, out);
        let sink = |_i: usize, _p: &mut [Vec<f64>]| {};
        for round in 1..=rounds {
            let ctx = Ctx { mix, round, eta };
            algo.produce_all(&ctx, &grad, &mut g, &mut payload, &sink, exec);
            for (i, mixed) in mixed_all.iter_mut().enumerate() {
                for (c, mx) in mixed.iter_mut().enumerate() {
                    mx.fill(0.0);
                    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                        crate::linalg::axpy(mix.weight(i, j), &payload[j][c], mx);
                    }
                }
            }
            let inbox = Inbox::from_payloads(&payload, &mixed_all);
            algo.recv_all(&ctx, &g, &inbox, exec);
        }
        (0..n).map(|i| algo.x(i).to_vec()).collect()
    }

    /// Max distance of any agent's iterate to the problem optimum.
    pub fn max_dist_to_opt(xs: &[Vec<f64>], problem: &dyn Problem) -> f64 {
        let opt = problem.optimum().expect("problem must expose optimum");
        xs.iter()
            .map(|x| crate::linalg::dist_sq(x, opt).sqrt())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    /// Every algorithm's fused produce + recv_all closures must be
    /// schedule-invariant: threads > 1 (including counts that don't
    /// divide n and exceed n) reproduces the sequential trajectory
    /// bitwise. This is the per-algorithm wiring check (slice-pattern
    /// order, channel indices); the chunking mechanism itself is covered
    /// in `crate::pool`.
    #[test]
    fn all_algorithms_recv_all_parallel_equals_sequential() {
        use crate::problems::linreg::LinReg;
        use crate::topology::{MixingRule, Topology};
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let builders: Vec<(&str, fn() -> Box<dyn Algorithm>)> = vec![
            ("lead", || Box::new(lead::Lead::paper_default())),
            ("nids", || Box::new(nids::Nids::new())),
            ("d2", || Box::new(d2::D2::new())),
            ("dgd", || Box::new(dgd::Dgd::new())),
            ("diging", || Box::new(diging::DiGing::new())),
            ("exact_diffusion", || Box::new(exact_diffusion::ExactDiffusion::new())),
            ("choco", || Box::new(choco::ChocoSgd::new(0.8))),
            ("deepsqueeze", || Box::new(deepsqueeze::DeepSqueeze::new(0.2))),
            ("qdgd", || Box::new(qdgd::Qdgd::new(0.2))),
        ];
        for (name, build) in builders {
            let run = |threads: usize| {
                let mut algo = build();
                testutil::run_plain_threads(&mut *algo, &p, &mix, 0.05, 15, threads)
            };
            let seq = run(1);
            for threads in [3usize, 4, 16] {
                let par = run(threads);
                for (a, b) in seq.iter().zip(&par) {
                    for (u, v) in a.iter().zip(b) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{name} threads={threads}");
                    }
                }
            }
        }
    }

    /// The fused produce path must equal the split sequential path
    /// (grad → send per agent) for every algorithm — payloads, gradients,
    /// and post-send state all bitwise.
    #[test]
    fn produce_all_equals_sequential_grad_then_send() {
        use crate::problems::linreg::LinReg;
        use crate::problems::Problem;
        use crate::topology::{MixingRule, Topology};
        let p = LinReg::synthetic(8, 30, 0.1, 5);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let builders: Vec<(&str, fn() -> Box<dyn Algorithm>)> = vec![
            ("lead", || Box::new(lead::Lead::paper_default())),
            ("diging", || Box::new(diging::DiGing::new())),
            ("choco", || Box::new(choco::ChocoSgd::new(0.8))),
            ("exact_diffusion", || Box::new(exact_diffusion::ExactDiffusion::new())),
        ];
        let n = 8;
        let d = p.dim();
        for (name, build) in builders {
            let setup = |algo: &mut dyn Algorithm| {
                let x0 = zeros(n, d);
                let mut g = zeros(n, d);
                for i in 0..n {
                    p.grad_full(i, &x0[i], &mut g[i]);
                }
                algo.init(&Ctx { mix: &mix, round: 0, eta: 0.05 }, &x0, &g);
            };
            // Sequential reference.
            let mut a = build();
            setup(&mut *a);
            let ctx = Ctx { mix: &mix, round: 1, eta: 0.05 };
            let ch = a.spec().channels;
            let mut g_ref = zeros(n, d);
            let mut pay_ref = vec![vec![vec![0.0f64; d]; ch]; n];
            for i in 0..n {
                p.grad_full(i, a.x(i), &mut g_ref[i]);
                let gi = g_ref[i].clone();
                a.send(&ctx, i, &gi, &mut pay_ref[i]);
            }
            // Fused parallel path.
            let pool = WorkerPool::new(3);
            let mut b = build();
            setup(&mut *b);
            let mut g_fused = zeros(n, d);
            let mut pay_fused = vec![vec![vec![0.0f64; d]; ch]; n];
            let grad = |i: usize, x: &[f64], out: &mut [f64]| p.grad_full(i, x, out);
            let sink = |_i: usize, _p: &mut [Vec<f64>]| {};
            b.produce_all(&ctx, &grad, &mut g_fused, &mut pay_fused, &sink, Exec::pool(&pool));
            for i in 0..n {
                for (u, v) in g_ref[i].iter().zip(&g_fused[i]) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{name}: gradient drift agent {i}");
                }
                for c in 0..ch {
                    for (u, v) in pay_ref[i][c].iter().zip(&pay_fused[i][c]) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{name}: payload drift agent {i} ch {c}");
                    }
                }
            }
        }
    }

    /// par_agents must visit every agent exactly once with its own rows,
    /// for any thread count (including thread counts above n).
    #[test]
    fn par_agents_covers_all_rows_disjointly() {
        for n in [1usize, 3, 7, 8] {
            for threads in [1usize, 2, 3, 8, 16] {
                let pool = WorkerPool::new(threads);
                let mut a = Mat::zeros(n, 4);
                let mut b = Mat::zeros(n, 2);
                par_agents(Exec::pool(&pool), &mut [&mut a, &mut b], |i, rows| match rows {
                    [ra, rb] => {
                        for v in ra.iter_mut() {
                            *v += (i + 1) as f64;
                        }
                        for v in rb.iter_mut() {
                            *v += 10.0 * (i + 1) as f64;
                        }
                    }
                    _ => unreachable!(),
                });
                for i in 0..n {
                    assert!(a.row(i).iter().all(|&v| v == (i + 1) as f64), "n={n} t={threads}");
                    assert!(b.row(i).iter().all(|&v| v == 10.0 * (i + 1) as f64));
                }
            }
        }
    }

    /// Zero-width state (d = 0) must not panic (degenerate chunk size).
    #[test]
    fn par_agents_handles_zero_cols() {
        let pool = WorkerPool::new(4);
        let mut a = Mat::zeros(4, 0);
        let visited = std::sync::atomic::AtomicUsize::new(0);
        let v = &visited;
        par_agents(Exec::pool(&pool), &mut [&mut a], |_, _| {
            v.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(visited.load(std::sync::atomic::Ordering::Relaxed), 4);
    }
}
