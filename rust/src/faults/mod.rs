//! Deterministic fault injection: crash / churn / partition / loss
//! schedules that *deliberately* perturb trajectories, plus the
//! bookkeeping the engine's graceful-degradation path needs.
//!
//! # Plan → schedule
//!
//! A [`FaultPlan`] is plain data, parseable from a grid TOML axis and
//! round-trippable through [`FaultPlan::label`] exactly like
//! [`crate::simnet::NetModel`]. The engine compiles it into a
//! [`FaultSchedule`]: a per-round event source driven by a dedicated
//! `streams::FAULT` RNG root, so enabling faults cannot shift any stream
//! an algorithm consumes — and with the plan absent (or a no-op plan)
//! the round loop is bitwise-identical to the fault-free engine
//! (`rust/tests/faults.rs`).
//!
//! # Degraded-inbox contract
//!
//! Every directed in-link (receiver `i`, sender `j`) resolves each round
//! to one [`LinkState`]:
//!
//! * `Delivered` — mixed at weight `w_ij` as usual;
//! * `Stale` — the link's *last delivered* decode is replayed at `w_ij`,
//!   bounded by the plan's `stale=` age limit;
//! * `Lost` — the message is simply gone (no retransmit); the mix step
//!   folds `w_ij` into the receiver's self weight
//!   ([`folded_self_weight`]), so the effective mixing row stays
//!   row-stochastic (proptest below: sums to 1, entries nonnegative,
//!   symmetric losses keep W symmetric).
//!
//! A crashed agent transmits nothing (its out-links resolve Lost and the
//! engine zeroes its wire bits), consumes nothing (in-links Lost), and
//! skips its apply step entirely (`Inbox::live`) — its state, including
//! the LEAD/CHOCO difference-compression reference points `h`/`x̂`,
//! stays frozen until recovery, so a skipped update can never corrupt
//! the compression bookkeeping.
//!
//! # Determinism
//!
//! All schedule mutation happens sequentially on the coordinator thread
//! ([`FaultSchedule::begin_round`] → [`FaultSchedule::force_lose`] →
//! [`FaultSchedule::resolve_round`]); the parallel mix/apply phases only
//! *read* it. Draw counts per round are fixed by the plan alone — one
//! churn draw per agent when `churn > 0`, one loss draw per directed
//! in-link (receiver ascending, neighbor-list order) when `loss > 0` —
//! never by which faults actually fire, so trajectories are
//! bitwise-deterministic across thread counts and reruns.

use crate::rng::{streams, Rng};
use crate::serialize::json;
use crate::topology::MixingMatrix;

/// Default crash outage length (rounds) when `crash:…` carries no
/// `down=` modifier.
pub const DEFAULT_CRASH_DOWN: usize = 10;
/// Default churn outage length (rounds) when `churn:…` carries no
/// `down=` modifier.
pub const DEFAULT_CHURN_DOWN: usize = 5;

/// A declarative fault plan — plain `Copy` data so [`crate::coordinator::
/// engine::EngineConfig`] stays `Copy`.
///
/// Spec-string grammar (clauses joined by `+`, `key=value` modifiers
/// allowed after a clause's positional arguments):
///
/// ```text
/// loss:P                      P ∈ (0, 1): per-round i.i.d. directed-link loss
/// crash:FRAC:ROUND[:down=K]   ⌈FRAC·n⌉ agents crash at ROUND for K rounds
/// churn:RATE[:down=K]         per-round per-agent crash probability RATE ∈ (0, 1)
/// partition:CUT:FROM:TO       links across {0..CUT-1} | {CUT..n-1} cut for rounds [FROM, TO)
/// ```
///
/// Global modifiers, attachable to any clause: `stale=S` (replay a
/// neighbor's last delivered message on a lost link, up to age S) and
/// `seed=N` (pin the fault stream independently of the engine seed —
/// the `NetModel` `seed=` convention). Examples:
/// `loss:0.05`, `crash:0.25:40+loss:0.1:stale=2`, `partition:4:50:80`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Per-round, per-directed-link message loss probability.
    pub loss: f64,
    /// Fraction of agents crashing in the one-shot crash event (0 = off).
    pub crash_frac: f64,
    /// Round at which the one-shot crash fires.
    pub crash_round: usize,
    /// Outage length, in rounds, of the one-shot crash.
    pub crash_down: usize,
    /// Per-round, per-agent crash probability (0 = off).
    pub churn: f64,
    /// Outage length, in rounds, of each churn crash.
    pub churn_down: usize,
    /// Partition boundary: agents {0..cut-1} vs {cut..n-1} (0 = off).
    pub part_cut: usize,
    /// First round (inclusive) of the partition window.
    pub part_from: usize,
    /// End round (exclusive) of the partition window.
    pub part_to: usize,
    /// Staleness bound: a lost link replays the neighbor's last
    /// delivered message while its age ≤ this (0 = replay off).
    pub stale: usize,
    /// Fault-stream seed; 0 ⇒ derive from the engine seed.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            loss: 0.0,
            crash_frac: 0.0,
            crash_round: 0,
            crash_down: DEFAULT_CRASH_DOWN,
            churn: 0.0,
            churn_down: DEFAULT_CHURN_DOWN,
            part_cut: 0,
            part_from: 0,
            part_to: 0,
            stale: 0,
            seed: 0,
        }
    }
}

impl FaultPlan {
    /// Parse the spec-string grammar above. Returns None on anything
    /// malformed: unknown clause kinds or modifiers, duplicate clauses
    /// or modifiers, missing/stray positionals, out-of-range numbers.
    pub fn parse(s: &str) -> Option<FaultPlan> {
        if s.is_empty() {
            return None;
        }
        let mut p = FaultPlan::default();
        let (mut saw_loss, mut saw_crash, mut saw_churn, mut saw_part) =
            (false, false, false, false);
        let (mut saw_stale, mut saw_seed) = (false, false);
        for clause in s.split('+') {
            let mut parts = clause.split(':');
            let kind = parts.next()?;
            let mut pos: Vec<&str> = Vec::new();
            let mut down: Option<usize> = None;
            let mut mods = false;
            for part in parts {
                if let Some((k, v)) = part.split_once('=') {
                    mods = true;
                    match k {
                        "down" => {
                            if down.is_some() {
                                return None;
                            }
                            let d = v.parse::<usize>().ok()?;
                            if d == 0 {
                                return None;
                            }
                            down = Some(d);
                        }
                        "stale" => {
                            if saw_stale {
                                return None;
                            }
                            p.stale = v.parse::<usize>().ok()?;
                            saw_stale = true;
                        }
                        "seed" => {
                            if saw_seed {
                                return None;
                            }
                            p.seed = v.parse::<u64>().ok()?;
                            saw_seed = true;
                        }
                        _ => return None,
                    }
                } else {
                    if mods {
                        // Positional after a modifier is a typo.
                        return None;
                    }
                    pos.push(part);
                }
            }
            match (kind, pos.as_slice()) {
                ("loss", [prob]) => {
                    if saw_loss || down.is_some() {
                        return None;
                    }
                    let l = prob.parse::<f64>().ok()?;
                    if !l.is_finite() || l <= 0.0 || l >= 1.0 {
                        return None;
                    }
                    p.loss = l;
                    saw_loss = true;
                }
                ("crash", [frac, round]) => {
                    if saw_crash {
                        return None;
                    }
                    let f = frac.parse::<f64>().ok()?;
                    let r = round.parse::<usize>().ok()?;
                    if !f.is_finite() || f <= 0.0 || f > 1.0 || r == 0 {
                        return None;
                    }
                    p.crash_frac = f;
                    p.crash_round = r;
                    p.crash_down = down.unwrap_or(DEFAULT_CRASH_DOWN);
                    saw_crash = true;
                }
                ("churn", [rate]) => {
                    if saw_churn {
                        return None;
                    }
                    let c = rate.parse::<f64>().ok()?;
                    if !c.is_finite() || c <= 0.0 || c >= 1.0 {
                        return None;
                    }
                    p.churn = c;
                    p.churn_down = down.unwrap_or(DEFAULT_CHURN_DOWN);
                    saw_churn = true;
                }
                ("partition", [cut, from, to]) => {
                    if saw_part || down.is_some() {
                        return None;
                    }
                    let c = cut.parse::<usize>().ok()?;
                    let f = from.parse::<usize>().ok()?;
                    let t = to.parse::<usize>().ok()?;
                    if c == 0 || f >= t {
                        return None;
                    }
                    p.part_cut = c;
                    p.part_from = f;
                    p.part_to = t;
                    saw_part = true;
                }
                _ => return None,
            }
        }
        Some(p)
    }

    /// Canonical spec string; [`FaultPlan::parse`] round-trips it
    /// (`parse(label()) == Some(self)` for any parseable plan).
    pub fn label(&self) -> String {
        let mut clauses: Vec<String> = Vec::new();
        if self.loss > 0.0 {
            clauses.push(format!("loss:{:e}", self.loss));
        }
        if self.crash_frac > 0.0 {
            let mut c = format!("crash:{:e}:{}", self.crash_frac, self.crash_round);
            if self.crash_down != DEFAULT_CRASH_DOWN {
                c.push_str(&format!(":down={}", self.crash_down));
            }
            clauses.push(c);
        }
        if self.churn > 0.0 {
            let mut c = format!("churn:{:e}", self.churn);
            if self.churn_down != DEFAULT_CHURN_DOWN {
                c.push_str(&format!(":down={}", self.churn_down));
            }
            clauses.push(c);
        }
        if self.part_cut > 0 {
            clauses.push(format!("partition:{}:{}:{}", self.part_cut, self.part_from, self.part_to));
        }
        if clauses.is_empty() {
            return "none".into();
        }
        let mut out = clauses.join("+");
        if self.stale > 0 {
            out.push_str(&format!(":stale={}", self.stale));
        }
        if self.seed != 0 {
            out.push_str(&format!(":seed={}", self.seed));
        }
        out
    }

    /// A plan with no enabled fault source. The engine treats a no-op
    /// plan exactly like `faults: None` (bitwise-identical round loop).
    pub fn is_noop(&self) -> bool {
        self.loss == 0.0 && self.crash_frac == 0.0 && self.churn == 0.0 && self.part_cut == 0
    }
}

/// Per-round resolution of one directed in-link (receiver, sender).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkState {
    /// Message arrived; mixed at the nominal weight.
    Delivered,
    /// Message lost; weight folded into the receiver's self weight.
    Lost,
    /// Message lost but the link's last delivered decode is replayed at
    /// the nominal weight (age within the plan's `stale=` bound).
    Stale,
}

/// Cumulative fault counters, sampled into `RoundMetrics` on observed
/// rounds and totalled in [`FaultSummary`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultTotals {
    /// Σ over rounds of the number of crashed agents.
    pub crashed_agent_rounds: u64,
    /// Directed messages that resolved [`LinkState::Lost`].
    pub lost_messages: u64,
    /// Directed messages that resolved [`LinkState::Stale`].
    pub stale_deliveries: u64,
    /// Live receiver rows with ≥ 1 lost in-link (i.e. rows the mix step
    /// renormalized by folding lost mass into the self weight).
    pub renormalized_rows: u64,
    /// Losses injected by [`FaultSchedule::force_lose`] — simnet
    /// transfers that hit the retransmit cap and, under a fault plan,
    /// become real losses instead of fictions of delivery.
    pub capped_losses: u64,
}

/// Fold the weights of lost in-links into agent `i`'s self weight:
/// `w'_ii = w_ii + Σ_{j ∈ N_i, lost(j)} w_ij`, which together with
/// skipping the lost terms keeps the effective row sum at exactly 1 up
/// to f64 roundoff. Shared by the engine's degraded mix and the
/// row-stochasticity proptest.
pub fn folded_self_weight(mix: &MixingMatrix, i: usize, mut lost: impl FnMut(usize) -> bool) -> f64 {
    let mut w = mix.self_weight(i);
    for &j in &mix.neighbors[i] {
        if lost(j) {
            w += mix.weight(i, j);
        }
    }
    w
}

/// Compiled per-round fault event source (see module docs for the
/// begin/force/resolve protocol and the determinism contract).
pub struct FaultSchedule {
    plan: FaultPlan,
    n: usize,
    channels: usize,
    d: usize,
    neighbors: Vec<Vec<usize>>,
    rng: Rng,
    /// Agents hit by the one-shot crash event (drawn at construction).
    crash_set: Vec<usize>,
    /// Remaining outage rounds per agent (0 = live).
    down_left: Vec<u32>,
    /// Down mask for the current round (read by mix/apply workers).
    down_now: Vec<bool>,
    /// Total rounds each agent has spent crashed.
    down_rounds: Vec<u64>,
    /// Dense directed-link state, indexed `receiver * n + sender`; only
    /// entries on real edges are ever read.
    state: Vec<LinkState>,
    /// Rounds since the link last delivered (`u32::MAX` = never).
    age: Vec<u32>,
    /// Last delivered decode per (receiver, sender, channel); allocated
    /// only when the plan enables stale replay.
    stale_buf: Vec<f64>,
    totals: FaultTotals,
}

impl FaultSchedule {
    /// Compile `plan` against a topology. `engine_seed` feeds the
    /// dedicated fault stream unless the plan pins its own `seed=`.
    pub fn new(
        mix: &MixingMatrix,
        plan: FaultPlan,
        engine_seed: u64,
        channels: usize,
        d: usize,
    ) -> FaultSchedule {
        let n = mix.n;
        let base = if plan.seed == 0 { engine_seed } else { plan.seed };
        let mut rng = Rng::new(base).derive(streams::FAULT);
        let crash_set = if plan.crash_frac > 0.0 {
            let k = ((plan.crash_frac * n as f64).ceil() as usize).clamp(1, n);
            rng.sample_indices(n, k)
        } else {
            Vec::new()
        };
        let stale_buf = if plan.stale > 0 {
            vec![0.0f64; n * n * channels * d]
        } else {
            Vec::new()
        };
        FaultSchedule {
            plan,
            n,
            channels,
            d,
            neighbors: mix.neighbors.clone(),
            rng,
            crash_set,
            down_left: vec![0; n],
            down_now: vec![false; n],
            down_rounds: vec![0; n],
            state: vec![LinkState::Delivered; n * n],
            age: vec![u32::MAX; n * n],
            stale_buf,
            totals: FaultTotals::default(),
        }
    }

    /// The compiled plan.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Draw this round's fault events (coordinator thread only; rounds
    /// are 1-based and must be presented in order). After this call the
    /// down mask is final; link states are *preliminary* until
    /// [`FaultSchedule::resolve_round`].
    pub fn begin_round(&mut self, round: usize) {
        let n = self.n;
        // (a) recovery: tick down the outage counters.
        for left in self.down_left.iter_mut() {
            *left = left.saturating_sub(1);
        }
        // (b) the one-shot crash event.
        if self.plan.crash_frac > 0.0 && round == self.plan.crash_round {
            for &i in &self.crash_set {
                self.down_left[i] = self.plan.crash_down as u32;
            }
        }
        // (c) churn: one draw per agent per round whenever churn is
        // enabled — the draw count never depends on outcomes.
        if self.plan.churn > 0.0 {
            for i in 0..n {
                let hit = self.rng.uniform() < self.plan.churn;
                if hit && self.down_left[i] == 0 {
                    self.down_left[i] = self.plan.churn_down as u32;
                }
            }
        }
        for i in 0..n {
            self.down_now[i] = self.down_left[i] > 0;
            if self.down_now[i] {
                self.down_rounds[i] += 1;
                self.totals.crashed_agent_rounds += 1;
            }
        }
        // (d) preliminary link states: crashed endpoints and partitioned
        // or lossy links resolve Lost. The loss draw always happens when
        // loss is enabled (fixed draw count), even on links already dead.
        let cut = self.plan.part_cut;
        let partition_on =
            cut > 0 && round >= self.plan.part_from && round < self.plan.part_to;
        for i in 0..n {
            for nj in 0..self.neighbors[i].len() {
                let j = self.neighbors[i][nj];
                let dropped = self.plan.loss > 0.0 && self.rng.uniform() < self.plan.loss;
                let cut_off = partition_on && ((i < cut) != (j < cut));
                let lost = self.down_now[i] || self.down_now[j] || cut_off || dropped;
                self.state[i * n + j] =
                    if lost { LinkState::Lost } else { LinkState::Delivered };
            }
        }
    }

    /// Demote a preliminarily-Delivered link to Lost — used by the
    /// engine when the simnet timer reports a transfer that hit the
    /// retransmit cap (`sender` → `receiver`): under a fault plan a
    /// capped transfer is a real loss, not a fiction of delivery.
    pub fn force_lose(&mut self, receiver: usize, sender: usize) {
        let idx = receiver * self.n + sender;
        if self.state[idx] == LinkState::Delivered {
            self.state[idx] = LinkState::Lost;
            self.totals.capped_losses += 1;
        }
    }

    /// Finalize this round's link states: upgrade Lost links with a
    /// fresh-enough last delivery to Stale, update link ages, and
    /// accumulate the round's counters.
    pub fn resolve_round(&mut self) {
        let n = self.n;
        let stale = self.plan.stale as u32;
        for i in 0..n {
            let mut any_lost = false;
            for nj in 0..self.neighbors[i].len() {
                let j = self.neighbors[i][nj];
                let idx = i * n + j;
                match self.state[idx] {
                    LinkState::Delivered => {
                        self.age[idx] = 0;
                    }
                    LinkState::Lost => {
                        let a = self.age[idx];
                        if !self.down_now[i] && stale > 0 && a != u32::MAX && a + 1 <= stale {
                            self.state[idx] = LinkState::Stale;
                            self.age[idx] = a + 1;
                            self.totals.stale_deliveries += 1;
                        } else {
                            if a != u32::MAX {
                                // Too old to replay from now on (until a
                                // fresh delivery resets the age).
                                self.age[idx] = a.saturating_add(1);
                            }
                            self.totals.lost_messages += 1;
                            any_lost = true;
                        }
                    }
                    LinkState::Stale => unreachable!("begin_round never emits Stale"),
                }
            }
            if any_lost && !self.down_now[i] {
                self.totals.renormalized_rows += 1;
            }
        }
    }

    /// Whether agent `i` is crashed this round.
    #[inline]
    pub fn is_down(&self, i: usize) -> bool {
        self.down_now[i]
    }

    /// Final state of the directed in-link `sender → receiver` this
    /// round (valid after [`FaultSchedule::resolve_round`]).
    #[inline]
    pub fn link(&self, receiver: usize, sender: usize) -> LinkState {
        self.state[receiver * self.n + sender]
    }

    /// The replayed decode for a [`LinkState::Stale`] in-link.
    #[inline]
    pub fn stale_payload(&self, receiver: usize, sender: usize, channel: usize) -> &[f64] {
        let off = ((receiver * self.n + sender) * self.channels + channel) * self.d;
        &self.stale_buf[off..off + self.d]
    }

    /// Record this round's delivered decodes for future stale replay
    /// (no-op when the plan disables replay). `fill(sender, channel,
    /// buf)` writes the sender's decoded channel payload into `buf` —
    /// the engine supplies the sparse-aware decode.
    pub fn store_delivered(&mut self, mut fill: impl FnMut(usize, usize, &mut [f64])) {
        if self.plan.stale == 0 {
            return;
        }
        let (n, ch, d) = (self.n, self.channels, self.d);
        for i in 0..n {
            for nj in 0..self.neighbors[i].len() {
                let j = self.neighbors[i][nj];
                if self.state[i * n + j] == LinkState::Delivered {
                    for c in 0..ch {
                        let off = ((i * n + j) * ch + c) * d;
                        fill(j, c, &mut self.stale_buf[off..off + d]);
                    }
                }
            }
        }
    }

    /// Per-agent down mask for the current round (lifetime-borrowed by
    /// the degraded `Inbox`).
    pub fn down_mask(&self) -> &[bool] {
        &self.down_now
    }

    /// Cumulative counters so far.
    pub fn totals(&self) -> FaultTotals {
        self.totals
    }

    /// End-of-run summary for the `RunRecord`.
    pub fn summary(&self) -> FaultSummary {
        FaultSummary {
            plan: self.plan.label(),
            crashed_agent_rounds: self.totals.crashed_agent_rounds,
            lost: self.totals.lost_messages,
            stale: self.totals.stale_deliveries,
            renormalized_rows: self.totals.renormalized_rows,
            capped_losses: self.totals.capped_losses,
            down_rounds: self.down_rounds.clone(),
        }
    }
}

/// End-of-run fault summary, serialized into the `RunRecord` JSON the
/// way `NetSummary` is.
#[derive(Clone, Debug)]
pub struct FaultSummary {
    /// Canonical plan label ([`FaultPlan::label`]).
    pub plan: String,
    pub crashed_agent_rounds: u64,
    pub lost: u64,
    pub stale: u64,
    pub renormalized_rows: u64,
    pub capped_losses: u64,
    /// Rounds each agent spent crashed.
    pub down_rounds: Vec<u64>,
}

impl FaultSummary {
    /// Compact JSON object (hand-rolled, mirroring `NetSummary::to_json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        json::write_str(&mut out, "plan");
        out.push(':');
        json::write_str(&mut out, &self.plan);
        out.push_str(&format!(
            ",\"crashed_agent_rounds\":{},\"lost\":{},\"stale\":{},\"renormalized_rows\":{},\"capped_losses\":{},\"down_rounds\":[",
            self.crashed_agent_rounds,
            self.lost,
            self.stale,
            self.renormalized_rows,
            self.capped_losses
        ));
        for (i, r) in self.down_rounds.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{r}"));
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::prop_assert;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn parse_accepts_all_kinds() {
        let p = FaultPlan::parse("loss:0.05").unwrap();
        assert_eq!(p.loss, 0.05);
        assert!(p.is_noop() == false);

        let p = FaultPlan::parse("crash:0.25:40").unwrap();
        assert_eq!(p.crash_frac, 0.25);
        assert_eq!(p.crash_round, 40);
        assert_eq!(p.crash_down, DEFAULT_CRASH_DOWN);

        let p = FaultPlan::parse("crash:0.25:40:down=3").unwrap();
        assert_eq!(p.crash_down, 3);

        let p = FaultPlan::parse("churn:0.01:down=2").unwrap();
        assert_eq!(p.churn, 0.01);
        assert_eq!(p.churn_down, 2);

        let p = FaultPlan::parse("partition:4:50:80").unwrap();
        assert_eq!((p.part_cut, p.part_from, p.part_to), (4, 50, 80));

        let p = FaultPlan::parse("loss:0.1+crash:0.5:10:stale=2:seed=7").unwrap();
        assert_eq!(p.loss, 0.1);
        assert_eq!(p.crash_frac, 0.5);
        assert_eq!(p.stale, 2);
        assert_eq!(p.seed, 7);
    }

    #[test]
    fn parse_label_roundtrip() {
        for s in [
            "loss:5e-2",
            "crash:2.5e-1:40",
            "crash:2.5e-1:40:down=3",
            "churn:1e-2",
            "churn:1e-2:down=2",
            "partition:4:50:80",
            "loss:1e-1+crash:5e-1:10+churn:2e-3+partition:2:5:9:stale=2:seed=7",
            "loss:5e-2:stale=1",
            "loss:5e-2:seed=123",
        ] {
            let p = FaultPlan::parse(s).unwrap_or_else(|| panic!("parse failed: {s}"));
            assert_eq!(p.label(), s, "label not canonical for {s}");
            assert_eq!(FaultPlan::parse(&p.label()), Some(p), "roundtrip failed for {s}");
        }
        // Non-canonical but valid spellings still round-trip through the
        // canonical label.
        let p = FaultPlan::parse("loss:0.05").unwrap();
        assert_eq!(FaultPlan::parse(&p.label()), Some(p));
    }

    #[test]
    fn parse_rejects_garbage() {
        for s in [
            "",
            "loss",
            "loss:",
            "loss:0",
            "loss:1.0",
            "loss:-0.1",
            "loss:nan",
            "loss:0.1:0.2",
            "loss:0.1+loss:0.2",
            "loss:0.1:down=3",
            "crash:0.5",
            "crash:0:10",
            "crash:1.5:10",
            "crash:0.5:0",
            "crash:0.5:10:down=0",
            "churn:1.0",
            "churn:0",
            "partition:0:5:9",
            "partition:4:9:5",
            "partition:4:5:5",
            "partition:4:5",
            "partition:4:5:9:down=2",
            "blackout:0.5",
            "loss:0.1:wat=3",
            "loss:0.1:stale=2:7",
            "loss:0.1:stale=2+churn:0.1:stale=3",
            "loss:0.1:seed=1:seed=2",
        ] {
            assert_eq!(FaultPlan::parse(s), None, "accepted garbage: {s}");
        }
    }

    #[test]
    fn noop_detection() {
        assert!(FaultPlan::default().is_noop());
        let p = FaultPlan { stale: 3, seed: 9, ..FaultPlan::default() };
        assert!(p.is_noop(), "stale/seed alone enable nothing");
        assert!(!FaultPlan::parse("loss:0.5").unwrap().is_noop());
    }

    fn ring(n: usize) -> MixingMatrix {
        Topology::Ring.build(n, MixingRule::UniformNeighbors)
    }

    /// Two schedules from the same plan and seed emit identical events.
    #[test]
    fn schedule_deterministic() {
        let mix = ring(8);
        let plan = FaultPlan::parse("loss:0.2+churn:0.05:down=2").unwrap();
        let mut a = FaultSchedule::new(&mix, plan, 7, 1, 4);
        let mut b = FaultSchedule::new(&mix, plan, 7, 1, 4);
        for round in 1..=50 {
            a.begin_round(round);
            b.begin_round(round);
            a.resolve_round();
            b.resolve_round();
            assert_eq!(a.down_now, b.down_now, "round {round}");
            assert_eq!(a.state, b.state, "round {round}");
        }
        assert_eq!(a.totals(), b.totals());
    }

    /// `seed=` pins the fault stream across engine seeds (the NetModel
    /// convention); without it, the engine seed drives the stream.
    #[test]
    fn plan_seed_pins_events_across_engine_seeds() {
        let mix = ring(8);
        let pinned = FaultPlan::parse("loss:0.3:seed=99").unwrap();
        let free = FaultPlan::parse("loss:0.3").unwrap();
        let events = |plan: FaultPlan, engine_seed: u64| {
            let mut fs = FaultSchedule::new(&mix, plan, engine_seed, 1, 4);
            let mut log = Vec::new();
            for round in 1..=30 {
                fs.begin_round(round);
                fs.resolve_round();
                log.push(fs.state.clone());
            }
            log
        };
        assert_eq!(events(pinned, 1), events(pinned, 2));
        assert_ne!(events(free, 1), events(free, 2));
    }

    /// The one-shot crash takes the right agents down for exactly
    /// `down` rounds, and counters add up.
    #[test]
    fn crash_window_and_recovery() {
        let mix = ring(8);
        let plan = FaultPlan::parse("crash:0.25:5:down=3").unwrap();
        let mut fs = FaultSchedule::new(&mix, plan, 42, 1, 4);
        let mut down_per_round = Vec::new();
        for round in 1..=12 {
            fs.begin_round(round);
            fs.resolve_round();
            down_per_round.push((0..8).filter(|&i| fs.is_down(i)).count());
        }
        // ⌈0.25·8⌉ = 2 agents down for rounds 5..=7, nothing else.
        let want: Vec<usize> = (1..=12).map(|r| if (5..=7).contains(&r) { 2 } else { 0 }).collect();
        assert_eq!(down_per_round, want);
        assert_eq!(fs.totals().crashed_agent_rounds, 6);
        // Crashed agents lose every in- and out-link: 2 agents × 2
        // links × 2 directions per crash round, minus double counting of
        // any link between the two crashed agents.
        assert!(fs.totals().lost_messages >= 12, "{:?}", fs.totals());
        let s = fs.summary();
        assert_eq!(s.down_rounds.iter().sum::<u64>(), 6);
        assert_eq!(s.down_rounds.iter().filter(|&&r| r == 3).count(), 2);
    }

    /// Partition cuts exactly the cross-boundary links during the
    /// window and nothing outside it.
    #[test]
    fn partition_window() {
        let mix = ring(8);
        let plan = FaultPlan::parse("partition:4:3:6").unwrap();
        let mut fs = FaultSchedule::new(&mix, plan, 42, 1, 4);
        for round in 1..=8 {
            fs.begin_round(round);
            fs.resolve_round();
            let in_window = (3..6).contains(&round);
            for i in 0..8 {
                for &j in &mix.neighbors[i] {
                    let cross = (i < 4) != (j < 4);
                    let want = if in_window && cross { LinkState::Lost } else { LinkState::Delivered };
                    assert_eq!(fs.link(i, j), want, "round {round} link {j}->{i}");
                }
            }
        }
        // Ring of 8 cut at 4: links 3↔4 and 7↔0 are cross-boundary — 4
        // directed messages per round × 3 rounds.
        assert_eq!(fs.totals().lost_messages, 12);
        assert_eq!(fs.totals().renormalized_rows, 12);
    }

    /// Stale replay: a lost link with a prior delivery resolves Stale up
    /// to the age bound, then Lost.
    #[test]
    fn stale_ages_out() {
        let mix = ring(8);
        // Partition rounds 2..6 with stale=2: rounds 2 and 3 replay the
        // round-1 delivery, rounds 4 and 5 are real losses.
        let plan = FaultPlan::parse("partition:4:2:6:stale=2").unwrap();
        let mut fs = FaultSchedule::new(&mix, plan, 42, 1, 4);
        let mut states = Vec::new();
        for round in 1..=7 {
            fs.begin_round(round);
            fs.resolve_round();
            states.push(fs.link(4, 3));
            fs.store_delivered(|_, _, buf| buf.fill(round as f64));
        }
        assert_eq!(
            states,
            vec![
                LinkState::Delivered,
                LinkState::Stale,
                LinkState::Stale,
                LinkState::Lost,
                LinkState::Lost,
                LinkState::Delivered,
                LinkState::Delivered,
            ]
        );
        // The replayed payload during the stale rounds is round 1's.
        // (Checked via the last store before the partition window.)
        let mut fs = FaultSchedule::new(&mix, plan, 42, 1, 4);
        fs.begin_round(1);
        fs.resolve_round();
        fs.store_delivered(|_, _, buf| buf.fill(1.0));
        fs.begin_round(2);
        fs.resolve_round();
        assert_eq!(fs.link(4, 3), LinkState::Stale);
        assert_eq!(fs.stale_payload(4, 3, 0), &[1.0, 1.0, 1.0, 1.0]);
    }

    /// A link that never delivered has nothing to replay: Lost even
    /// with stale enabled.
    #[test]
    fn stale_needs_a_prior_delivery() {
        let mix = ring(8);
        let plan = FaultPlan::parse("partition:4:1:3:stale=5").unwrap();
        let mut fs = FaultSchedule::new(&mix, plan, 42, 1, 4);
        fs.begin_round(1);
        fs.resolve_round();
        assert_eq!(fs.link(4, 3), LinkState::Lost);
        assert_eq!(fs.totals().stale_deliveries, 0);
    }

    /// force_lose demotes a delivered link and counts it.
    #[test]
    fn force_lose_counts_capped() {
        let mix = ring(8);
        let plan = FaultPlan::parse("loss:0.5").unwrap();
        let mut fs = FaultSchedule::new(&mix, plan, 42, 1, 4);
        fs.begin_round(1);
        let (mut i, mut j) = (usize::MAX, usize::MAX);
        'outer: for r in 0..8 {
            for &s in &mix.neighbors[r] {
                if fs.link(r, s) == LinkState::Delivered {
                    (i, j) = (r, s);
                    break 'outer;
                }
            }
        }
        assert!(i != usize::MAX, "all 16 links lost at p=0.5?");
        fs.force_lose(i, j);
        assert_eq!(fs.link(i, j), LinkState::Lost);
        assert_eq!(fs.totals().capped_losses, 1);
        // Idempotent on an already-lost link.
        fs.force_lose(i, j);
        assert_eq!(fs.totals().capped_losses, 1);
        fs.resolve_round();
    }

    /// Satellite: fault-renormalized mixing rows stay row-stochastic
    /// (and W stays symmetric when the loss pattern is symmetric) across
    /// random topologies × crash sets.
    #[test]
    fn proptest_renormalized_rows_stay_stochastic() {
        forall(128, 0xFA017, |g| {
            let n = g.usize_in(4..=12);
            let mix = match g.usize_in(0..=2) {
                0 => Topology::Ring.build(n, MixingRule::UniformNeighbors),
                1 => Topology::Path.build(n, MixingRule::MetropolisHastings),
                _ => Topology::ErdosRenyi { p: 0.5, seed: g.rng.next_u64() }
                    .build(n, MixingRule::MetropolisHastings),
            };
            // Random crash set (agents whose links all die — symmetric).
            let down: Vec<bool> = (0..n).map(|_| g.bool_with(0.3)).collect();
            let lost = |i: usize, j: usize| down[i] || down[j];
            for i in 0..n {
                if down[i] {
                    continue;
                }
                let w_self = folded_self_weight(&mix, i, |j| lost(i, j));
                prop_assert!(w_self >= mix.self_weight(i) - 1e-15, "self weight shrank");
                let mut row = w_self;
                for &j in &mix.neighbors[i] {
                    if !lost(i, j) {
                        let w = mix.weight(i, j);
                        prop_assert!(w >= 0.0, "negative surviving weight");
                        row += w;
                    }
                }
                prop_assert!((row - 1.0).abs() <= 1e-12, "row {i} sums to {row} (n={n})");
            }
            // Symmetric loss pattern ⇒ surviving off-diagonal weights
            // stay symmetric (w_ij == w_ji and both live or both dead).
            for i in 0..n {
                for &j in &mix.neighbors[i] {
                    prop_assert!(lost(i, j) == lost(j, i), "asymmetric loss from symmetric crashes");
                    if !lost(i, j) {
                        let diff = (mix.weight(i, j) - mix.weight(j, i)).abs();
                        prop_assert!(diff == 0.0, "weight asymmetry {diff}");
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn summary_json_parses() {
        let s = FaultSummary {
            plan: "loss:5e-2".into(),
            crashed_agent_rounds: 3,
            lost: 17,
            stale: 4,
            renormalized_rows: 11,
            capped_losses: 1,
            down_rounds: vec![0, 3, 0],
        };
        let js = crate::serialize::json::parse(&s.to_json()).unwrap();
        assert_eq!(js.get("plan").unwrap().as_str(), Some("loss:5e-2"));
        assert_eq!(js.get("lost").unwrap().as_f64(), Some(17.0));
        assert_eq!(js.get("down_rounds").unwrap().as_arr().unwrap().len(), 3);
    }
}
