//! Agents ↔ receive-slots geometry for multiplexed transports.
//!
//! A *slot* is one receive queue plus the decode/mix scratch for the
//! agents it hosts. [`TransportMode::Channel`] is the degenerate layout
//! (one agent per slot); [`TransportMode::Mux`] packs `per_worker`
//! contiguous agents per slot so a run with tens of thousands of agents
//! stands up only `⌈n / per_worker⌉` queues and fans the receive phase
//! out over at most that many pool tasks — no thread is ever spawned
//! here (audit R4: parallelism rides the caller's `Exec`).
//!
//! Contiguity is the invariant the engine's receive phase relies on:
//! slot `s` owns exactly agents `first_agent(s) .. first_agent(s) +
//! agents_in(s)`, the ranges partition `0..n`, so per-slot workers write
//! disjoint mix rows (the `SendPtr` SAFETY argument in
//! [`super::channel`]).
//!
//! [`TransportMode::Channel`]: super::TransportMode::Channel
//! [`TransportMode::Mux`]: super::TransportMode::Mux

use super::TransportMode;

/// Contiguous block layout of `n` agents over `⌈n / per_slot⌉` slots.
#[derive(Clone, Debug)]
pub struct SlotMap {
    n: usize,
    per_slot: usize,
}

impl SlotMap {
    /// Layout for a transport mode; `None` for [`TransportMode::Mem`]
    /// (no queues exist in shared memory).
    pub fn for_mode(mode: TransportMode, n: usize) -> Option<SlotMap> {
        let per_slot = match mode {
            TransportMode::Mem => return None,
            TransportMode::Channel => 1,
            TransportMode::Mux { per_worker } => per_worker.max(1),
        };
        Some(SlotMap { n, per_slot })
    }

    pub fn n_agents(&self) -> usize {
        self.n
    }

    /// Number of receive slots.
    pub fn n_slots(&self) -> usize {
        self.n.div_ceil(self.per_slot)
    }

    /// Slot hosting agent `i`.
    pub fn slot_of(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        i / self.per_slot
    }

    /// First agent hosted by slot `s`.
    pub fn first_agent(&self, s: usize) -> usize {
        s * self.per_slot
    }

    /// Number of agents hosted by slot `s` (the last slot may be short).
    pub fn agents_in(&self, s: usize) -> usize {
        self.n.min((s + 1) * self.per_slot) - self.first_agent(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_is_one_agent_per_slot() {
        let m = SlotMap::for_mode(TransportMode::Channel, 5).unwrap();
        assert_eq!(m.n_slots(), 5);
        for i in 0..5 {
            assert_eq!(m.slot_of(i), i);
            assert_eq!(m.first_agent(i), i);
            assert_eq!(m.agents_in(i), 1);
        }
    }

    #[test]
    fn mem_has_no_slots() {
        assert!(SlotMap::for_mode(TransportMode::Mem, 8).is_none());
    }

    #[test]
    fn mux_partitions_contiguously() {
        // 10 agents, 3 per slot: [0..3), [3..6), [6..9), [9..10).
        let m = SlotMap::for_mode(TransportMode::Mux { per_worker: 3 }, 10).unwrap();
        assert_eq!(m.n_slots(), 4);
        let mut covered = vec![false; 10];
        for s in 0..m.n_slots() {
            let (a0, len) = (m.first_agent(s), m.agents_in(s));
            assert!(len >= 1);
            for a in a0..a0 + len {
                assert_eq!(m.slot_of(a), s, "agent {a}");
                assert!(!covered[a], "agent {a} double-covered");
                covered[a] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "partition must cover all agents");
        assert_eq!(m.agents_in(3), 1, "last slot is short");
    }

    #[test]
    fn oversubscribed_mux_collapses_to_one_slot() {
        let m = SlotMap::for_mode(TransportMode::Mux { per_worker: 64 }, 8).unwrap();
        assert_eq!(m.n_slots(), 1);
        assert_eq!(m.agents_in(0), 8);
        assert!((0..8).all(|i| m.slot_of(i) == 0));
    }
}
