//! In-process channel transport: framed wire bytes over `std::sync::mpsc`.
//!
//! [`ChannelTransport`] is the engine-facing round exchange for every
//! non-`Mem` [`TransportMode`]: a sequential **send phase** on the
//! coordinator thread (one [`frame`] per delivered directed edge,
//! enqueued through the [`Delivery`] backend) followed by a parallel
//! **receive phase** (each slot drains its queue, decodes frames into
//! per-(receiver, neighbor-position) buffers, and mixes in exactly the
//! shared-memory accumulation order). The bitwise rules live in the
//! module docs of [`super`] (§Transport contract); the differential
//! harness is `rust/tests/transport.rs`.

use super::frame;
use super::multiplex::SlotMap;
use super::{Delivery, TransportMode, TransportStats, TransportSummary};
use crate::compress::wire::{index_bits, BitReader};
use crate::compress::{quantize, CompressedMsg, WireFormat};
use crate::faults::{FaultSchedule, LinkState};
use crate::pool::{par_chunks, Exec, SendPtr};
use crate::topology::MixingMatrix;
use crate::trace::{EventKind, Recorder};
use std::sync::mpsc;
use std::sync::Mutex;

/// [`Delivery`] over per-slot `std::sync::mpsc` queues. No threads are
/// spawned here (audit R4); senders live on the coordinator thread and
/// each receiver is drained by whichever pool worker processes its slot
/// (the `Mutex` makes the `!Sync` `Receiver` shareable — uncontended,
/// since distinct slots are drained by distinct workers).
pub struct MpscDelivery {
    senders: Vec<mpsc::Sender<Vec<u8>>>,
    receivers: Vec<Mutex<mpsc::Receiver<Vec<u8>>>>,
}

impl MpscDelivery {
    pub fn new(n_slots: usize) -> Self {
        let mut senders = Vec::with_capacity(n_slots);
        let mut receivers = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        MpscDelivery { senders, receivers }
    }
}

impl Delivery for MpscDelivery {
    fn send(&mut self, slot: usize, frame: Vec<u8>) {
        // The paired Receiver lives in `self.receivers`, so the endpoint
        // cannot have hung up.
        self.senders[slot].send(frame).expect("slot receiver alive");
    }

    fn drain(&self, slot: usize, sink: &mut dyn FnMut(Vec<u8>)) {
        let rx = self.receivers[slot].lock().expect("transport receiver mutex poisoned");
        while let Ok(buf) = rx.try_recv() {
            sink(buf);
        }
    }
}

/// One neighbor's decoded message for one receiver: reused across rounds
/// so the receive phase allocates only the frame buffers in flight.
#[derive(Default)]
struct DecodedNeighbor {
    /// Whether a frame arrived this round (false ⇒ the link was not
    /// `Delivered`, and the mix must not read the buffers).
    present: bool,
    /// Channel-0 sparse view (top-k wire format): every wire entry,
    /// ±0.0 values included — exactly the sender's `compress_into` list.
    sparse: Vec<(u32, f64)>,
    /// Channel-0 dense decode (quantize wire format).
    dense: Vec<f64>,
    /// Raw f64 channels, flattened (`raw_channels × d`).
    raw: Vec<f64>,
}

/// Per-slot receive-phase scratch (the `par_chunks` work item).
struct SlotLane {
    /// `decoded[agent_within_slot][neighbor_position]`.
    decoded: Vec<Vec<DecodedNeighbor>>,
}

/// Engine-facing round exchange over a [`Delivery`] backend (see module
/// docs). Constructed once per run; internal buffers are reused across
/// rounds.
pub struct ChannelTransport {
    mode: TransportMode,
    slots: SlotMap,
    delivery: Box<dyn Delivery>,
    lanes: Vec<SlotLane>,
    /// Channel-0 wire format; `Some` iff the run compresses channel 0.
    wire: Option<WireFormat>,
    use_comp: bool,
    channels: usize,
    d: usize,
    /// Per-agent published bits implied by a frame's metadata:
    /// `ch0_bits + (channels−1)·d·32` compressed, `channels·d·32` raw —
    /// asserted equal to the produce-phase `round_bits` on every send
    /// (§Transport rule 3).
    extra_channel_bits: u64,
    raw_bits_all: u64,
    stats: TransportStats,
    /// Reused frame-encode scratch (the queue takes an owned copy).
    frame_buf: Vec<u8>,
}

impl ChannelTransport {
    /// Stand up the transport for `mode`, or `None` for the shared-memory
    /// reference mode. Panics if the run compresses channel 0 with a
    /// codec that has no complete wire format (`Compressor::wire_format`
    /// returned `None`) — the scenario driver rejects such cells up
    /// front with a proper error; this is the engine-API backstop.
    pub fn for_mode(
        mode: TransportMode,
        mix: &MixingMatrix,
        d: usize,
        channels: usize,
        use_comp: bool,
        wire: Option<WireFormat>,
        codec_name: &str,
    ) -> Option<ChannelTransport> {
        let slots = SlotMap::for_mode(mode, mix.n)?;
        assert!(
            !use_comp || wire.is_some(),
            "transport '{}' requires a wire-complete codec (topk, q*); '{codec_name}' does not decode from its payload alone",
            mode.label()
        );
        let lanes = (0..slots.n_slots())
            .map(|s| SlotLane {
                decoded: (0..slots.agents_in(s))
                    .map(|k| {
                        let a = slots.first_agent(s) + k;
                        (0..mix.neighbors[a].len()).map(|_| DecodedNeighbor::default()).collect()
                    })
                    .collect(),
            })
            .collect();
        let delivery: Box<dyn Delivery> = Box::new(MpscDelivery::new(slots.n_slots()));
        Some(ChannelTransport {
            mode,
            slots,
            delivery,
            lanes,
            wire: if use_comp { wire } else { None },
            use_comp,
            channels,
            d,
            extra_channel_bits: (channels as u64 - 1) * (d as u64) * 32,
            raw_bits_all: (channels as u64) * (d as u64) * 32,
            stats: TransportStats::default(),
            frame_buf: Vec::new(),
        })
    }

    pub fn mode(&self) -> TransportMode {
        self.mode
    }

    pub fn stats(&self) -> TransportStats {
        self.stats
    }

    pub fn summary(&self) -> TransportSummary {
        TransportSummary {
            mode: self.mode.label(),
            frames_sent: self.stats.frames_sent,
            frames_dropped: self.stats.frames_dropped,
            bytes_on_wire: self.stats.bytes_on_wire,
        }
    }

    /// Send phase: enqueue one frame per deliverable directed edge, in
    /// (receiver, neighbor-order) sequence on the coordinator thread.
    /// Under a fault schedule a non-`Delivered` link (or a crashed
    /// receiver) is the drop path: no frame leaves the sender
    /// (`frames_dropped`). Call after the schedule's `resolve_round` so
    /// link states are final.
    ///
    /// `round_bits` is the produce-phase accounting; every sent frame's
    /// metadata must reproduce its sender's entry exactly (asserted).
    ///
    /// With a trace [`Recorder`] attached each enqueued frame records a
    /// `frame_send` instant (coordinator lane, arg = frame bytes) —
    /// observation only, never a behavior change (`crate::trace`
    /// §Observability contract).
    #[allow(clippy::too_many_arguments)]
    pub fn send_round(
        &mut self,
        round: usize,
        mix: &MixingMatrix,
        faults: Option<&FaultSchedule>,
        msgs: &[CompressedMsg],
        payload: &[Vec<Vec<f64>>],
        round_bits: &[u64],
        trace: Option<&Recorder>,
    ) {
        let n = mix.n;
        for i in 0..n {
            for &j in &mix.neighbors[i] {
                let deliverable = match faults {
                    None => true,
                    Some(fs) => !fs.is_down(i) && fs.link(i, j) == LinkState::Delivered,
                };
                if !deliverable {
                    self.stats.frames_dropped += 1;
                    continue;
                }
                let (ch0_bits, comp): (u64, &[u8]) = if self.use_comp {
                    (msgs[j].wire_bits, &msgs[j].payload)
                } else {
                    (0, &[])
                };
                // Raw section: channels 1.. when channel 0 is compressed,
                // every channel otherwise.
                let raw_from = usize::from(self.use_comp);
                let raw: Vec<&[f64]> =
                    payload[j][raw_from..].iter().map(|c| c.as_slice()).collect();
                let published = if self.use_comp {
                    ch0_bits + self.extra_channel_bits
                } else {
                    self.raw_bits_all
                };
                assert_eq!(
                    published, round_bits[j],
                    "frame-derived bits for sender {j} drifted from produce accounting"
                );
                frame::encode(
                    &mut self.frame_buf,
                    round as u64,
                    j as u32,
                    i as u32,
                    ch0_bits,
                    comp,
                    &raw,
                );
                self.stats.frames_sent += 1;
                self.stats.bytes_on_wire += self.frame_buf.len() as u64;
                if let Some(r) = trace {
                    r.instant(EventKind::FrameSend, self.frame_buf.len() as u64);
                }
                self.delivery.send(self.slots.slot_of(i), self.frame_buf.clone());
            }
        }
    }

    /// Receive phase: each slot drains its queue, decodes every frame
    /// into its per-(receiver, neighbor-position) buffer, then mixes its
    /// agents' rows into `mixed_all` — in exactly the shared-memory
    /// accumulation order (self first, then `mix.neighbors[i]` order;
    /// see `crate::coordinator::engine::mix_msgs` / `mix_degraded`,
    /// whose trajectories this reproduces bit-for-bit). Fans out over
    /// slots on `exec`; no per-agent state is shared across slots.
    #[allow(clippy::too_many_arguments)]
    pub fn recv_and_mix(
        &mut self,
        exec: Exec<'_>,
        round: usize,
        mix: &MixingMatrix,
        faults: Option<&FaultSchedule>,
        msgs: &[CompressedMsg],
        payload: &[Vec<Vec<f64>>],
        mixed_all: &mut [Vec<Vec<f64>>],
    ) {
        assert_eq!(mixed_all.len(), mix.n);
        let slots = &self.slots;
        let delivery = &*self.delivery;
        let wire = self.wire.as_ref();
        let (use_comp, channels, d) = (self.use_comp, self.channels, self.d);
        // §Observability: each drained frame records a `frame_recv`
        // instant in the draining worker's lane (arg = frame bytes).
        let trace = exec.trace();
        let mixed_p = SendPtr(mixed_all.as_mut_ptr());
        par_chunks(exec, &mut self.lanes, |s, lane| {
            let a0 = slots.first_agent(s);
            for agent in lane.decoded.iter_mut() {
                for dn in agent.iter_mut() {
                    dn.present = false;
                }
            }
            delivery.drain(s, &mut |buf: Vec<u8>| {
                if let Some(r) = trace {
                    r.instant(EventKind::FrameRecv, buf.len() as u64);
                }
                let fv = frame::decode(&buf).expect("in-process frame failed validation");
                assert_eq!(fv.round, round as u64, "stale frame crossed a round barrier");
                let dst = fv.dst as usize;
                let local = dst.checked_sub(a0).filter(|&l| l < lane.decoded.len())
                    .expect("frame routed to the wrong slot");
                let pos = mix.neighbors[dst]
                    .iter()
                    .position(|&j| j == fv.sender as usize)
                    .expect("frame from a non-neighbor");
                decode_into(&mut lane.decoded[local][pos], &fv, wire, use_comp, channels, d);
            });
            for (local, dec) in lane.decoded.iter().enumerate() {
                let a = a0 + local;
                // SAFETY: slot lanes own disjoint contiguous agent ranges
                // (SlotMap partition invariant) and par_chunks hands each
                // lane to exactly one worker, so mixed_all[a] is written
                // through this pointer by exactly one thread.
                let out: &mut Vec<Vec<f64>> = unsafe { &mut *mixed_p.0.add(a) };
                mix_decoded(mix, a, faults, use_comp, wire, msgs, payload, dec, d, out);
            }
        });
    }
}

/// Decode one validated frame into a receiver's neighbor buffer.
fn decode_into(
    dn: &mut DecodedNeighbor,
    fv: &frame::FrameView<'_>,
    wire: Option<&WireFormat>,
    use_comp: bool,
    channels: usize,
    d: usize,
) {
    if use_comp {
        match wire.expect("wire format validated at construction") {
            WireFormat::Quantize(q) => {
                // Pinned bitwise to the sender's `values` by
                // `quantize::decode_matches_values_exactly`.
                quantize::decode(q, fv.comp, d, &mut dn.dense);
                assert_eq!(dn.dense.len(), d, "quantize decode length");
            }
            WireFormat::TopK { .. } => {
                // k entries of (index, f32 value), ascending index — the
                // exact list `TopK::select_and_emit` published (±0.0
                // entries included), so scatter-mixing it is bitwise-equal
                // to the shared-memory sparse mix.
                dn.sparse.clear();
                if d > 0 {
                    let ib = index_bits(d);
                    let entry = (ib + 32) as u64;
                    assert_eq!(fv.ch0_bits % entry, 0, "top-k payload not entry-aligned");
                    let count = (fv.ch0_bits / entry) as usize;
                    let mut r = BitReader::new(fv.comp);
                    for _ in 0..count {
                        let idx = r.read(ib);
                        let v = r.read_f32() as f64;
                        assert!((idx as usize) < d, "top-k index out of range");
                        dn.sparse.push((idx as u32, v));
                    }
                }
            }
        }
    }
    let raw_channels = if use_comp { channels - 1 } else { channels };
    dn.raw.resize(raw_channels * d, 0.0);
    dn.raw.truncate(raw_channels * d);
    fv.copy_raw_into(&mut dn.raw);
    dn.present = true;
}

/// The receiving-side mix for agent `a` over its decoded frames —
/// accumulation-order-identical to the engine's shared-memory
/// `mix_msgs` (fault-free) / `mix_degraded` (under a schedule), with
/// each neighbor term read from the frame decode instead of the
/// coordinator's buffers. Self terms always come from the agent's own
/// local message (it never crosses the transport).
#[allow(clippy::too_many_arguments)]
fn mix_decoded(
    mix: &MixingMatrix,
    a: usize,
    faults: Option<&FaultSchedule>,
    use_comp: bool,
    wire: Option<&WireFormat>,
    msgs: &[CompressedMsg],
    payload: &[Vec<Vec<f64>>],
    dec: &[DecodedNeighbor],
    d: usize,
    out: &mut [Vec<f64>],
) {
    if let Some(fs) = faults {
        if fs.is_down(a) {
            for mx in out.iter_mut() {
                mx.fill(0.0);
            }
            return;
        }
    }
    let w_self = match faults {
        Some(fs) => {
            crate::faults::folded_self_weight(mix, a, |j| fs.link(a, j) == LinkState::Lost)
        }
        None => mix.weight(a, a),
    };
    let neighbor_term = |p: usize, j: usize, c: usize, mx: &mut [f64]| {
        let dn = &dec[p];
        assert!(dn.present, "no frame from {j} on a delivered link to {a}");
        if c == 0 && use_comp {
            match wire.expect("wire format validated at construction") {
                WireFormat::TopK { .. } => {
                    crate::linalg::scatter_axpy(mix.weight(a, j), &dn.sparse, mx)
                }
                WireFormat::Quantize(_) => crate::linalg::axpy(mix.weight(a, j), &dn.dense, mx),
            }
        } else {
            let rc = if use_comp { c - 1 } else { c };
            crate::linalg::axpy(mix.weight(a, j), &dn.raw[rc * d..(rc + 1) * d], mx);
        }
    };
    for (c, mx) in out.iter_mut().enumerate() {
        mx.fill(0.0);
        // Self term first — identical arms to mix_msgs / mix_degraded.
        if c == 0 && use_comp {
            match &msgs[a].sparse {
                Some(entries) => crate::linalg::scatter_axpy(w_self, entries, mx),
                None => {
                    debug_assert!(!msgs[a].dense_stale, "dense mix over a stale message");
                    crate::linalg::axpy(w_self, &msgs[a].values, mx)
                }
            }
        } else {
            crate::linalg::axpy(w_self, &payload[a][c], mx);
        }
        for (p, &j) in mix.neighbors[a].iter().enumerate() {
            match faults {
                None => neighbor_term(p, j, c, mx),
                Some(fs) => match fs.link(a, j) {
                    LinkState::Lost => {}
                    LinkState::Delivered => neighbor_term(p, j, c, mx),
                    LinkState::Stale => {
                        crate::linalg::axpy(mix.weight(a, j), fs.stale_payload(a, j, c), mx)
                    }
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quantize::{PNorm, QuantizeP};
    use crate::compress::topk::TopK;
    use crate::compress::{CodecScratch, Compressor};
    use crate::coordinator::engine::mix_msgs;
    use crate::rng::Rng;
    use crate::topology::{MixingRule, Topology};

    fn random_round(
        n: usize,
        d: usize,
        channels: usize,
        comp: Option<&dyn Compressor>,
        seed: u64,
    ) -> (Vec<Vec<Vec<f64>>>, Vec<CompressedMsg>) {
        let mut rng = Rng::new(seed);
        let payload: Vec<Vec<Vec<f64>>> = (0..n)
            .map(|_| {
                (0..channels)
                    .map(|_| {
                        let mut v = vec![0.0f64; d];
                        rng.fill_normal(&mut v, 1.5);
                        v
                    })
                    .collect()
            })
            .collect();
        let mut msgs: Vec<CompressedMsg> =
            (0..n).map(|_| CompressedMsg::with_dim(d)).collect();
        if let Some(c) = comp {
            let mut scratch = CodecScratch::default();
            for i in 0..n {
                c.compress_into(&payload[i][0], &mut rng, &mut msgs[i], &mut scratch);
            }
        }
        (payload, msgs)
    }

    /// Shared-memory reference mix for all channels (the engine's
    /// fault-free closure, verbatim semantics).
    fn reference_mix(
        mix: &MixingMatrix,
        use_comp: bool,
        msgs: &[CompressedMsg],
        payload: &[Vec<Vec<f64>>],
        channels: usize,
        d: usize,
    ) -> Vec<Vec<Vec<f64>>> {
        let n = mix.n;
        let mut want = vec![vec![vec![0.0f64; d]; channels]; n];
        for (i, out) in want.iter_mut().enumerate() {
            for (c, mx) in out.iter_mut().enumerate() {
                if c == 0 && use_comp {
                    mix_msgs(mix, i, msgs, mx);
                } else {
                    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                        crate::linalg::axpy(mix.weight(i, j), &payload[j][c], mx);
                    }
                }
            }
        }
        want
    }

    /// One exchanged round over every layout must reproduce the
    /// shared-memory mix bit-for-bit, for both wire-complete codec
    /// families and for the raw (uncompressed) path.
    #[test]
    fn exchange_matches_shared_memory_mix_bitwise() {
        let (n, d, channels) = (6, 41, 2);
        let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
        let codecs: [Option<Box<dyn Compressor>>; 3] = [
            Some(Box::new(TopK::new(7))),
            Some(Box::new(QuantizeP::new(2, PNorm::Inf, 16))),
            None,
        ];
        for (case, comp) in codecs.iter().enumerate() {
            let use_comp = comp.is_some();
            let (payload, msgs) =
                random_round(n, d, channels, comp.as_deref(), 11 + case as u64);
            let want = reference_mix(&mix, use_comp, &msgs, &payload, channels, d);
            let round_bits: Vec<u64> = (0..n)
                .map(|i| {
                    if use_comp {
                        msgs[i].wire_bits + (channels as u64 - 1) * (d as u64) * 32
                    } else {
                        (channels as u64) * (d as u64) * 32
                    }
                })
                .collect();
            for mode in [
                TransportMode::Channel,
                TransportMode::Mux { per_worker: 4 },
                TransportMode::Mux { per_worker: 64 },
            ] {
                let mut tr = ChannelTransport::for_mode(
                    mode,
                    &mix,
                    d,
                    channels,
                    use_comp,
                    comp.as_deref().and_then(|c| c.wire_format()),
                    "test",
                )
                .unwrap();
                tr.send_round(1, &mix, None, &msgs, &payload, &round_bits, None);
                let mut got = vec![vec![vec![0.0f64; d]; channels]; n];
                tr.recv_and_mix(Exec::seq(), 1, &mix, None, &msgs, &payload, &mut got);
                for i in 0..n {
                    for c in 0..channels {
                        for (u, v) in want[i][c].iter().zip(&got[i][c]) {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "case {case} mode {} agent {i} channel {c}",
                                mode.label()
                            );
                        }
                    }
                }
                let s = tr.summary();
                let edges: u64 = (0..n).map(|i| mix.neighbors[i].len() as u64).sum();
                assert_eq!(s.frames_sent, edges, "one frame per directed edge");
                assert_eq!(s.frames_dropped, 0);
                assert!(s.bytes_on_wire >= edges * frame::HEADER_LEN as u64);
            }
        }
    }

    #[test]
    fn mem_mode_has_no_transport() {
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        assert!(ChannelTransport::for_mode(TransportMode::Mem, &mix, 8, 1, false, None, "x")
            .is_none());
    }

    #[test]
    #[should_panic(expected = "wire-complete")]
    fn non_wire_complete_codec_is_rejected() {
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let _ = ChannelTransport::for_mode(
            TransportMode::Channel,
            &mix,
            8,
            1,
            true, // compressed run...
            None, // ...but the codec decodes only receiver-side (e.g. rand-k)
            "rand-10",
        );
    }

    #[test]
    fn mpsc_delivery_preserves_send_order() {
        let mut del = MpscDelivery::new(2);
        del.send(0, vec![1]);
        del.send(1, vec![9]);
        del.send(0, vec![2]);
        let mut got = Vec::new();
        del.drain(0, &mut |b| got.push(b));
        assert_eq!(got, vec![vec![1], vec![2]]);
        got.clear();
        del.drain(0, &mut |b| got.push(b));
        assert!(got.is_empty(), "drain empties the queue");
        del.drain(1, &mut |b| got.push(b));
        assert_eq!(got, vec![vec![9]]);
    }
}
