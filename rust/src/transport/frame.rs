//! Framed envelope for one directed-edge message (§Transport contract).
//!
//! Layout (all integers little-endian, header then payload, nothing
//! else — total length must match the header exactly):
//!
//! ```text
//! [ magic  "LEAD" : 4 bytes ]
//! [ round         : u64     ]
//! [ sender        : u32     ]
//! [ dst           : u32     ]
//! [ ch0_bits      : u64     ]   exact bit count of the compressed
//!                               channel-0 payload (0 on raw frames)
//! [ comp_len      : u32     ]   bytes of compressed channel-0 payload
//! [ raw_len       : u32     ]   count of raw f64 values that follow
//! [ comp payload  : comp_len bytes ]
//! [ raw payload   : raw_len × 8 bytes, f64 LE each ]
//! ```
//!
//! `comp_len` must equal `ceil(ch0_bits / 8)` — the codecs' `BitWriter`
//! invariant — so a frame cannot smuggle bits the accounting did not
//! bill. [`decode`] validates everything and **never panics**: truncated,
//! oversized, or inconsistent frames come back as [`FrameError`]s
//! (fuzz-style corpus in the tests below and in `rust/tests/transport.rs`).

/// Frame magic: identifies in-process LEAD transport frames.
pub const MAGIC: [u8; 4] = *b"LEAD";

/// Fixed envelope size in bytes (before the two payload sections).
pub const HEADER_LEN: usize = 4 + 8 + 4 + 4 + 8 + 4 + 4;

/// Upper bound on either payload section, in bytes. Generously above any
/// in-tree problem (d ≤ millions) while keeping a mutated length field
/// from driving a multi-gigabyte allocation on the receive path.
pub const MAX_SECTION_BYTES: u64 = 1 << 30;

/// Why a byte buffer failed to decode as a frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Shorter than the fixed header, or payload sections cut off.
    Truncated,
    /// First four bytes are not [`MAGIC`].
    BadMagic,
    /// A length field exceeds [`MAX_SECTION_BYTES`].
    Oversized,
    /// Total buffer length disagrees with the header's section lengths.
    LengthMismatch,
    /// `comp_len != ceil(ch0_bits / 8)` — bit count and byte count
    /// cannot describe the same payload.
    BitCount,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            FrameError::Truncated => "frame truncated",
            FrameError::BadMagic => "bad frame magic",
            FrameError::Oversized => "frame section oversized",
            FrameError::LengthMismatch => "frame length mismatch",
            FrameError::BitCount => "frame bit/byte count mismatch",
        };
        f.write_str(s)
    }
}

/// Borrowed view of a validated frame.
#[derive(Debug)]
pub struct FrameView<'a> {
    pub round: u64,
    pub sender: u32,
    pub dst: u32,
    /// Exact wire bits of `comp` (0 on raw-only frames).
    pub ch0_bits: u64,
    /// Compressed channel-0 payload bytes (codec wire format).
    pub comp: &'a [u8],
    /// Raw f64 section, still as little-endian bytes (`raw_len × 8`).
    raw: &'a [u8],
}

impl<'a> FrameView<'a> {
    /// Number of f64 values in the raw section.
    pub fn raw_len(&self) -> usize {
        self.raw.len() / 8
    }

    /// Decode the raw f64 section into `out` (must be `raw_len()` long).
    /// Exact: f64 → LE bytes → f64 is the identity on every bit pattern.
    pub fn copy_raw_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.raw_len(), "raw section length mismatch");
        for (chunk, v) in self.raw.chunks_exact(8).zip(out.iter_mut()) {
            *v = f64::from_le_bytes(chunk.try_into().expect("chunks_exact(8)"));
        }
    }
}

/// Encode one frame into `out` (cleared first; reuse the buffer across
/// calls to keep the send loop allocation-light). `raw` is the ordered
/// list of raw f64 channel slices to concatenate into the raw section.
pub fn encode(
    out: &mut Vec<u8>,
    round: u64,
    sender: u32,
    dst: u32,
    ch0_bits: u64,
    comp: &[u8],
    raw: &[&[f64]],
) {
    debug_assert_eq!(
        comp.len() as u64,
        ch0_bits.div_ceil(8),
        "codec payload byte length must be ceil(wire_bits/8)"
    );
    out.clear();
    let raw_len: usize = raw.iter().map(|r| r.len()).sum();
    out.reserve(HEADER_LEN + comp.len() + raw_len * 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&sender.to_le_bytes());
    out.extend_from_slice(&dst.to_le_bytes());
    out.extend_from_slice(&ch0_bits.to_le_bytes());
    out.extend_from_slice(&(comp.len() as u32).to_le_bytes());
    out.extend_from_slice(&(raw_len as u32).to_le_bytes());
    out.extend_from_slice(comp);
    for ch in raw {
        for v in ch.iter() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Validate and decode a frame. Total length must match the header
/// exactly; never panics on arbitrary input.
pub fn decode(buf: &[u8]) -> Result<FrameView<'_>, FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::Truncated);
    }
    if buf[..4] != MAGIC {
        return Err(FrameError::BadMagic);
    }
    let u64_at = |o: usize| u64::from_le_bytes(buf[o..o + 8].try_into().expect("header slice"));
    let u32_at = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().expect("header slice"));
    let round = u64_at(4);
    let sender = u32_at(12);
    let dst = u32_at(16);
    let ch0_bits = u64_at(20);
    let comp_len = u32_at(28) as u64;
    let raw_len = u32_at(32) as u64;
    if comp_len > MAX_SECTION_BYTES || raw_len * 8 > MAX_SECTION_BYTES {
        return Err(FrameError::Oversized);
    }
    if ch0_bits.div_ceil(8) != comp_len {
        return Err(FrameError::BitCount);
    }
    let want = HEADER_LEN as u64 + comp_len + raw_len * 8;
    if (buf.len() as u64) < want {
        return Err(FrameError::Truncated);
    }
    if buf.len() as u64 != want {
        return Err(FrameError::LengthMismatch);
    }
    let comp_end = HEADER_LEN + comp_len as usize;
    Ok(FrameView {
        round,
        sender,
        dst,
        ch0_bits,
        comp: &buf[HEADER_LEN..comp_end],
        raw: &buf[comp_end..],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::prop_assert;

    fn sample(round: u64, sender: u32, dst: u32, comp: &[u8], raw: &[f64]) -> Vec<u8> {
        let mut out = Vec::new();
        encode(&mut out, round, sender, dst, comp.len() as u64 * 8, comp, &[raw]);
        out
    }

    #[test]
    fn roundtrip_basic() {
        let comp = [0xAAu8, 0xBB, 0xCC];
        let raw = [1.5f64, -0.0, f64::MAX];
        let buf = sample(7, 3, 5, &comp, &raw);
        let f = decode(&buf).unwrap();
        assert_eq!((f.round, f.sender, f.dst), (7, 3, 5));
        assert_eq!(f.ch0_bits, 24);
        assert_eq!(f.comp, &comp);
        assert_eq!(f.raw_len(), 3);
        let mut out = vec![0.0f64; 3];
        f.copy_raw_into(&mut out);
        assert_eq!(out[0].to_bits(), raw[0].to_bits());
        assert_eq!(out[1].to_bits(), raw[1].to_bits(), "-0.0 survives the wire");
        assert_eq!(out[2].to_bits(), raw[2].to_bits());
    }

    #[test]
    fn roundtrip_empty_sections() {
        let mut out = Vec::new();
        encode(&mut out, 0, 0, 0, 0, &[], &[]);
        assert_eq!(out.len(), HEADER_LEN);
        let f = decode(&out).unwrap();
        assert_eq!(f.comp.len(), 0);
        assert_eq!(f.raw_len(), 0);
    }

    /// Proptest: random payload lengths / rounds / ids round-trip, and a
    /// partial ch0_bits (not a byte multiple) is carried exactly.
    #[test]
    fn roundtrip_random() {
        forall(120, 0xF4A3, |g| {
            let round = g.case_seed;
            let sender = g.usize_in(0..=100_000) as u32;
            let dst = g.usize_in(0..=100_000) as u32;
            let nbytes = g.usize_in(0..=64);
            let comp: Vec<u8> = (0..nbytes).map(|i| (i as u8).wrapping_mul(31) ^ round as u8).collect();
            // A bit count inside the last byte (codec streams rarely end
            // byte-aligned).
            let slack = if nbytes == 0 { 0 } else { g.usize_in(0..=7) as u64 };
            let ch0_bits = (nbytes as u64 * 8).saturating_sub(slack);
            let raw: Vec<f64> = (0..g.usize_in(0..=9)).map(|i| (i as f64 - 2.5) * 1e3).collect();
            let mut buf = Vec::new();
            encode(&mut buf, round, sender, dst, ch0_bits, &comp, &[&raw]);
            let f = decode(&buf).map_err(|e| format!("decode failed: {e}"))?;
            prop_assert!(f.round == round && f.sender == sender && f.dst == dst, "ids drifted");
            prop_assert!(f.ch0_bits == ch0_bits, "bit count drifted");
            prop_assert!(f.comp == comp, "comp payload drifted");
            let mut out = vec![0.0f64; f.raw_len()];
            f.copy_raw_into(&mut out);
            prop_assert!(
                out.len() == raw.len() && out.iter().zip(&raw).all(|(a, b)| a.to_bits() == b.to_bits()),
                "raw payload drifted"
            );
            Ok(())
        });
    }

    /// Every strict prefix of a valid frame must be rejected (Truncated
    /// or, once the length fields are in, LengthMismatch) — never panic,
    /// never accept.
    #[test]
    fn rejects_every_truncation() {
        let buf = sample(9, 1, 2, &[1, 2, 3, 4, 5], &[1.0, 2.0]);
        for cut in 0..buf.len() {
            let r = decode(&buf[..cut]);
            assert!(r.is_err(), "accepted a {cut}-byte prefix of a {}-byte frame", buf.len());
        }
        assert!(decode(&buf).is_ok());
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_magic() {
        let mut buf = sample(9, 1, 2, &[7; 4], &[]);
        buf.push(0);
        assert_eq!(decode(&buf), Err(FrameError::LengthMismatch));
        buf.pop();
        buf[0] ^= 0xFF;
        assert_eq!(decode(&buf), Err(FrameError::BadMagic));
    }

    #[test]
    fn rejects_oversized_and_inconsistent_lengths() {
        let mut buf = sample(1, 0, 0, &[1, 2], &[3.0]);
        // comp_len field beyond MAX_SECTION_BYTES.
        buf[28..32].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(decode(&buf), Err(FrameError::Oversized));
        // raw_len field beyond MAX_SECTION_BYTES.
        let mut buf = sample(1, 0, 0, &[1, 2], &[3.0]);
        buf[32..36].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(decode(&buf), Err(FrameError::Oversized));
        // ch0_bits disagreeing with comp_len.
        let mut buf = sample(1, 0, 0, &[1, 2], &[3.0]);
        buf[20..28].copy_from_slice(&999u64.to_le_bytes());
        assert_eq!(decode(&buf), Err(FrameError::BitCount));
    }

    /// Fuzz-style: single-byte mutations of a valid frame either decode
    /// (mutation hit an id/payload byte) or error — no panic, and a
    /// mutation in the magic or length fields is always caught.
    #[test]
    fn mutated_bytes_never_panic() {
        let buf = sample(33, 4, 6, &[9, 8, 7], &[0.25, -4.0]);
        for pos in 0..buf.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut m = buf.clone();
                m[pos] ^= flip;
                let r = decode(&m);
                if pos < 4 {
                    assert_eq!(r, Err(FrameError::BadMagic), "magic byte {pos}");
                }
                if (20..36).contains(&pos) {
                    // Length/bit-count fields: any change breaks a
                    // cross-check (total length, bit/byte consistency, or
                    // the oversize bound).
                    assert!(r.is_err(), "length-field mutation at {pos} accepted");
                }
                // Everywhere else (ids, payload): either verdict is fine —
                // the call simply must not panic, which reaching this line
                // proves.
            }
        }
    }
}
