//! Transport-backed message delivery: how [`CompressedMsg`] wire payloads
//! move between agents.
//!
//! The engine's historical execution model — and the **reference** this
//! layer is differentially tested against — is shared memory
//! ([`TransportMode::Mem`]): the mix phase reads every neighbor's
//! `CompressedMsg` straight out of the coordinator's `msgs` buffer, no
//! bytes move anywhere. This module adds the message-passing modes:
//!
//! * [`TransportMode::Channel`] — agents exchange the **existing
//!   wire-codec bytes** over in-process `mpsc` channels. Every directed
//!   edge's payload is packed into a framed envelope
//!   ([`frame`]: `{round, sender, dst, ch0_bits, lengths}` + payload),
//!   sent to the receiver's queue, and decoded back on the receiving
//!   side before mixing. One queue (slot) per agent.
//! * [`TransportMode::Mux`] — the same machinery with N contiguous
//!   agents multiplexed per slot ([`multiplex::SlotMap`]), so one
//!   machine hosts tens of thousands of agents on the existing
//!   `WorkerPool` without any new thread spawns (audit rule R4 holds:
//!   the receive/decode/mix fan-out rides the caller's `Exec`, and
//!   `mpsc` endpoints spawn nothing).
//!
//! # §Transport contract — delivery, ordering, bitwise rules
//!
//! 1. **Lossless transport is bitwise-invisible.** With no fault plan,
//!    a `Channel`/`Mux` run reproduces the `Mem` trajectory series
//!    (dist/consensus/comp_err/bits) bit-for-bit. This holds because
//!    (a) every in-tree *wire-complete* codec decodes its payload back
//!    to exactly the values/sparse view the sender published
//!    ([`WireFormat`]; quantize is pinned by
//!    `decode_matches_values_exactly`, top-k entries are `(index,
//!    f32-value)` pairs in ascending order), (b) raw channels are
//!    framed as exact little-endian f64 bytes (lossless round-trip),
//!    and (c) the receiving-side mix accumulates in exactly
//!    [`mix_msgs`]-order: self first, then `mix.neighbors[i]` order —
//!    frame *arrival* order is irrelevant because frames are demuxed
//!    into per-(receiver, neighbor-position) buffers before mixing.
//! 2. **Send is sequential, receive is parallel.** The coordinator
//!    thread enqueues all frames for a round (deterministic send
//!    order), then slots drain/decode/mix in parallel via `par_chunks`
//!    — each slot owns a disjoint contiguous agent range, so no two
//!    workers touch the same mix row.
//! 3. **Accounting.** `round_bits` stays bitwise-equal to the `Mem`
//!    path: each frame carries the channel-0 payload's exact bit count,
//!    and the sender asserts `ch0_bits + (channels−1)·d·32` (raw
//!    channels billed at 32 bits/element, matching the engine's
//!    historical convention) equals the produce-phase accounting for
//!    every frame it emits. The *actual* framed bytes — envelope
//!    included — are tracked separately in [`TransportStats`] /
//!    [`TransportSummary`] (`bytes_on_wire`), which is the honest
//!    measured cost of the message-passing run.
//! 4. **Faults route through the drop path.** Under a fault plan a
//!    non-`Delivered` link is literally an unsent frame
//!    (`frames_dropped`); `Stale` links replay the schedule's buffer
//!    and `Lost` links fold into the self weight exactly as the `Mem`
//!    degraded mix does — so `loss:P` plans are bitwise transport-
//!    independent (`rust/tests/faults.rs`).
//! 5. **Codec gate.** `Channel`/`Mux` with a compressed algorithm
//!    require a codec that implements
//!    [`Compressor::wire_format`](crate::compress::Compressor::wire_format)
//!    (today: `topk:*`, `q*:*`). Rand-k reconstructs indices from a
//!    receiver-side RNG the wire does not carry, and identity has no
//!    packed payload — both are rejected up front by the scenario
//!    validator rather than silently diverging.
//! 6. **Allocation.** The zero-alloc steady-state contract is
//!    `Mem`-only: channel modes allocate one `Vec<u8>` per frame per
//!    round (the queue owns the bytes in flight). Decode scratch and
//!    frame-encode buffers are still hoisted and reused.
//! 7. **Observability.** When tracing is on (`crate::trace`
//!    §Observability contract) the send path records a `frame_send`
//!    instant per enqueued frame and each receive slot records a
//!    `frame_recv` instant per drained frame (arg = framed byte length);
//!    the fleet totals (`frames_sent`/`frames_dropped`/`bytes_on_wire`)
//!    surface in the run's `TraceSummary` and must equal this module's
//!    own [`TransportSummary`] (`rust/tests/trace.rs`). The recorder is
//!    trajectory-invisible — rules 1–6 are unchanged with tracing on.
//!
//! [`CompressedMsg`]: crate::compress::CompressedMsg
//! [`WireFormat`]: crate::compress::WireFormat
//! [`mix_msgs`]: crate::coordinator::engine::mix_msgs

pub mod channel;
pub mod frame;
pub mod multiplex;

pub use channel::ChannelTransport;

/// Which transport backend moves messages between agents (see module
/// docs). Grid axis value / `EngineConfig` field; `Mem` is the default
/// and the bitwise reference.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportMode {
    /// Shared memory — the engine mixes straight from the coordinator's
    /// message buffers. No frames, no queues (the reference backend).
    #[default]
    Mem,
    /// Framed wire bytes over in-process `mpsc`, one slot per agent.
    Channel,
    /// Framed wire bytes over in-process `mpsc`, `per_worker` contiguous
    /// agents multiplexed per slot.
    Mux {
        /// Agents hosted per receive slot (≥ 1).
        per_worker: usize,
    },
}

impl TransportMode {
    /// Parse a spec string: `""`/`"mem"`, `"channel"`, `"mux:<N>"`.
    pub fn parse(s: &str) -> Option<TransportMode> {
        match s {
            "" | "mem" => Some(TransportMode::Mem),
            "channel" => Some(TransportMode::Channel),
            _ => {
                let n = s.strip_prefix("mux:")?.parse::<usize>().ok()?;
                (n >= 1).then_some(TransportMode::Mux { per_worker: n })
            }
        }
    }

    /// Canonical spec label (round-trips through [`TransportMode::parse`]).
    pub fn label(&self) -> String {
        match self {
            TransportMode::Mem => "mem".into(),
            TransportMode::Channel => "channel".into(),
            TransportMode::Mux { per_worker } => format!("mux:{per_worker}"),
        }
    }

    pub fn is_mem(&self) -> bool {
        matches!(self, TransportMode::Mem)
    }
}

/// Running counters for one transport-backed run (actual framed traffic,
/// envelope bytes included — distinct from the trajectory-facing
/// `round_bits` accounting, which stays bitwise-equal to `Mem`; see
/// §Transport rule 3).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Frames enqueued (one per delivered directed edge per round).
    pub frames_sent: u64,
    /// Frames withheld by the fault drop path (non-`Delivered` links).
    pub frames_dropped: u64,
    /// Total bytes of all sent frames, envelope included.
    pub bytes_on_wire: u64,
}

/// End-of-run transport summary attached to
/// [`RunRecord`](crate::coordinator::metrics::RunRecord) — `Some` iff the
/// run used a non-`Mem` transport.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportSummary {
    /// Mode label (`"channel"`, `"mux:8"`).
    pub mode: String,
    pub frames_sent: u64,
    pub frames_dropped: u64,
    pub bytes_on_wire: u64,
}

impl TransportSummary {
    /// Compact JSON object (embedded in `RunRecord::to_json`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        crate::serialize::json::write_str(&mut out, "mode");
        out.push(':');
        crate::serialize::json::write_str(&mut out, &self.mode);
        out.push_str(&format!(
            ",\"frames_sent\":{},\"frames_dropped\":{},\"bytes_on_wire\":{}}}",
            self.frames_sent, self.frames_dropped, self.bytes_on_wire
        ));
        out
    }
}

/// How encoded frames move from the coordinator's send phase to per-slot
/// receive queues. The engine talks to exactly this surface, so swapping
/// the in-process `mpsc` backend for a cross-process one (UDP sockets —
/// the ROADMAP follow-on) is a new impl, not an engine change.
///
/// Contract: `send` is called only from the coordinator thread, between
/// rounds' receive phases; `drain` yields the frames queued for `slot`
/// **in send order** and may be called concurrently for *distinct* slots
/// (hence `Sync`). All frames sent before a drain begins are visible to
/// it (the in-process impl gets this from `mpsc`'s own synchronization;
/// the engine additionally orders the phases with its dispatch barrier).
pub trait Delivery: Send + Sync {
    /// Enqueue one encoded frame for `slot`.
    fn send(&mut self, slot: usize, frame: Vec<u8>);
    /// Drain every frame currently queued for `slot`, in send order.
    fn drain(&self, slot: usize, sink: &mut dyn FnMut(Vec<u8>));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_and_label_roundtrip() {
        assert_eq!(TransportMode::parse(""), Some(TransportMode::Mem));
        assert_eq!(TransportMode::parse("mem"), Some(TransportMode::Mem));
        assert_eq!(TransportMode::parse("channel"), Some(TransportMode::Channel));
        assert_eq!(
            TransportMode::parse("mux:8"),
            Some(TransportMode::Mux { per_worker: 8 })
        );
        assert_eq!(TransportMode::parse("mux:0"), None);
        assert_eq!(TransportMode::parse("mux:"), None);
        assert_eq!(TransportMode::parse("udp"), None);
        for m in [
            TransportMode::Mem,
            TransportMode::Channel,
            TransportMode::Mux { per_worker: 3 },
        ] {
            assert_eq!(TransportMode::parse(&m.label()), Some(m));
        }
        assert!(TransportMode::Mem.is_mem());
        assert!(!TransportMode::Channel.is_mem());
    }

    #[test]
    fn summary_json_shape() {
        let s = TransportSummary {
            mode: "mux:4".into(),
            frames_sent: 10,
            frames_dropped: 2,
            bytes_on_wire: 1234,
        };
        let js = crate::serialize::json::parse(&s.to_json()).unwrap();
        assert_eq!(js.get("mode").unwrap().as_str(), Some("mux:4"));
        assert_eq!(js.get("frames_sent").unwrap().as_f64(), Some(10.0));
        assert_eq!(js.get("bytes_on_wire").unwrap().as_f64(), Some(1234.0));
    }
}
