//! Random-k sparsification with shared-seed index selection.
//!
//! Keeps k uniformly random coordinates. Because sender and receivers can
//! derive the index set from a shared per-round seed, *no index bits are
//! transmitted* — only k values and a 64-bit seed. This is the trick noted
//! in Appendix C.2 that makes random-k surprisingly competitive with top-k
//! per bit. With `unbiased = true` values are scaled by d/k so that
//! `E[Q(x)] = x` with variance constant `C = d/k − 1` (Assumption 2 holds).

use super::wire::BitWriter;
use super::{CompressedMsg, Compressor};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandK {
    pub k: usize,
    /// Scale kept values by d/k to make the operator unbiased.
    pub unbiased: bool,
}

impl RandK {
    pub fn new(k: usize, unbiased: bool) -> Self {
        assert!(k >= 1);
        RandK { k, unbiased }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand-{}{}", self.k, if self.unbiased { " (unbiased)" } else { "" })
    }

    fn compress(&self, x: &[f64], rng: &mut Rng, out: &mut CompressedMsg) {
        let d = x.len();
        let k = if d == 0 { 0 } else { self.k.min(d) };
        let idx = rng.sample_indices(d, k);
        let scale = if self.unbiased && k > 0 { d as f64 / k as f64 } else { 1.0 };

        out.values.clear();
        out.values.resize(d, 0.0);
        out.dense_stale = false;
        let sp = out.sparse.get_or_insert_with(Vec::new);
        sp.clear();
        let mut w = BitWriter::new();
        std::mem::swap(&mut w.bytes, &mut out.payload);
        w.clear();
        // Shared seed (64 bits) lets receivers regenerate `idx` locally.
        w.push(rng.next_u64(), 64);
        for &i in &idx {
            let wire = x[i] as f32; // f32 on the wire
            w.push_f32(wire);
            let v = wire as f64 * scale;
            out.values[i] = v;
            if v != 0.0 {
                sp.push((i as u32, v));
            }
        }
        sp.sort_unstable_by_key(|&(i, _)| i); // canonical ascending order
        out.wire_bits = w.bits;
        out.payload = w.bytes;
    }

    fn is_unbiased(&self) -> bool {
        self.unbiased
    }

    fn variance_constant(&self, d: usize) -> Option<f64> {
        if self.unbiased {
            Some((d as f64 / self.k.min(d) as f64) - 1.0)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_sq, norm2_sq};

    #[test]
    fn wire_is_values_plus_seed() {
        let r = RandK::new(10, true);
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let msg = r.compress_alloc(&x, &mut rng);
        assert_eq!(msg.wire_bits, 64 + 10 * 32);
        assert_eq!(msg.values.iter().filter(|&&v| v != 0.0).count(), 10);
    }

    #[test]
    fn unbiased_mean_and_variance() {
        let d = 50;
        let k = 10;
        let r = RandK::new(k, true);
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..d).map(|_| rng.normal_f64()).collect();
        let trials = 30_000;
        let mut mean = vec![0.0f64; d];
        let mut var_acc = 0.0;
        let mut msg = CompressedMsg::with_dim(d);
        for _ in 0..trials {
            r.compress(&x, &mut rng, &mut msg);
            for (m, v) in mean.iter_mut().zip(&msg.values) {
                *m += *v as f64;
            }
            var_acc += dist_sq(&x, &msg.values);
        }
        for (m, xi) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            assert!((avg - *xi as f64).abs() < 0.06, "bias {}", avg - *xi as f64);
        }
        // E‖x−Q(x)‖² = (d/k − 1)‖x‖² exactly for rand-k.
        let c = r.variance_constant(d).unwrap();
        let expected = c * norm2_sq(&x);
        let measured = var_acc / trials as f64;
        assert!(
            (measured - expected).abs() / expected < 0.05,
            "measured {measured} vs expected {expected}"
        );
    }

    #[test]
    fn biased_mode_keeps_raw_values() {
        let r = RandK::new(5, false);
        let mut rng = Rng::new(1);
        let x = vec![2.0f64; 20];
        let msg = r.compress_alloc(&x, &mut rng);
        for &v in &msg.values {
            assert!(v == 0.0 || v == 2.0);
        }
        assert!(r.variance_constant(20).is_none());
    }
}
