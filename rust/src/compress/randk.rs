//! Random-k sparsification with shared-seed index selection.
//!
//! Keeps k uniformly random coordinates. Because sender and receivers can
//! derive the index set from a shared per-round seed, *no index bits are
//! transmitted* — only k values and a 64-bit seed. This is the trick noted
//! in Appendix C.2 that makes random-k surprisingly competitive with top-k
//! per bit. With `unbiased = true` values are scaled by d/k so that
//! `E[Q(x)] = x` with variance constant `C = d/k − 1` (Assumption 2 holds).

use super::wire::BitWriter;
use super::{CodecScratch, CompressedMsg, Compressor};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandK {
    pub k: usize,
    /// Scale kept values by d/k to make the operator unbiased.
    pub unbiased: bool,
}

impl RandK {
    pub fn new(k: usize, unbiased: bool) -> Self {
        assert!(k >= 1);
        RandK { k, unbiased }
    }

    /// The single selection + wire-emission path behind both
    /// [`Compressor::compress`] and [`Compressor::compress_into`], so the
    /// two can never drift. Index draws and the shared seed consume the
    /// RNG identically on both paths (`sample_indices_into` is
    /// draw-for-draw the `sample_indices` stream), and the wire payload is
    /// emitted in the same shuffled draw order — so eager and fast path
    /// produce byte-identical messages and leave the dither stream in the
    /// same state (scheduler A/B equivalence).
    ///
    /// * `eager_dense = true` (compress): materialize `values` and the
    ///   canonical nonzero-only sparse list;
    /// * `eager_dense = false` (compress_into): defer the O(d) dense fill
    ///   (`dense_stale`) and record ALL selected entries — ±0.0 included —
    ///   in ascending index order (the reused `idx` buffer is sorted in
    ///   place; no `(index, value)` pair sort) so the lazy decode is
    ///   bit-exact (see the `Compressor` docs).
    fn sample_and_emit(
        &self,
        x: &[f64],
        rng: &mut Rng,
        out: &mut CompressedMsg,
        idx: &mut Vec<usize>,
        eager_dense: bool,
    ) {
        let d = x.len();
        let k = if d == 0 { 0 } else { self.k.min(d) };
        rng.sample_indices_into(d, k, idx);
        let scale = if self.unbiased && k > 0 { d as f64 / k as f64 } else { 1.0 };

        if eager_dense {
            out.values.clear();
        }
        out.values.resize(d, 0.0); // lazy case: contents stale until ensure_dense
        out.dense_stale = !eager_dense && d != 0;
        let sp = out.sparse.get_or_insert_with(Vec::new);
        sp.clear();
        let mut w = BitWriter::new();
        std::mem::swap(&mut w.bytes, &mut out.payload);
        w.clear();
        // Shared seed (64 bits) lets receivers regenerate `idx` locally.
        w.push(rng.next_u64(), 64);
        for &i in idx.iter() {
            let wire = x[i] as f32; // f32 on the wire
            w.push_f32(wire);
            if eager_dense {
                let v = wire as f64 * scale;
                out.values[i] = v;
                if v != 0.0 {
                    sp.push((i as u32, v));
                }
            }
        }
        if eager_dense {
            sp.sort_unstable_by_key(|&(i, _)| i); // canonical ascending order
        } else {
            // Ascending order comes from sorting the reused index buffer
            // (in place, allocation-free) before emitting the pairs.
            idx.sort_unstable();
            for &i in idx.iter() {
                sp.push((i as u32, (x[i] as f32) as f64 * scale));
            }
        }
        out.wire_bits = w.bits;
        out.payload = w.bytes;
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand-{}{}", self.k, if self.unbiased { " (unbiased)" } else { "" })
    }

    fn compress(&self, x: &[f64], rng: &mut Rng, out: &mut CompressedMsg) {
        let mut idx = Vec::new();
        self.sample_and_emit(x, rng, out, &mut idx, true);
    }

    /// Hot-path variant (§Perf): reuses `scratch.idx` for the Floyd
    /// index sample (the eager path allocates it per call) and skips the
    /// O(d) dense fill — the sparse view carries **every** selected entry,
    /// ±0.0 values included, so [`CompressedMsg::ensure_dense`] rebuilds
    /// `values` bit-identically to the eager path on demand. Wire payload,
    /// wire bits, selected set, and RNG consumption are identical to
    /// [`RandK::compress`] by construction: both call the same
    /// [`RandK::sample_and_emit`].
    fn compress_into(
        &self,
        x: &[f64],
        rng: &mut Rng,
        out: &mut CompressedMsg,
        scratch: &mut CodecScratch,
    ) {
        self.sample_and_emit(x, rng, out, &mut scratch.idx, false);
    }

    fn is_unbiased(&self) -> bool {
        self.unbiased
    }

    fn variance_constant(&self, d: usize) -> Option<f64> {
        if self.unbiased {
            Some((d as f64 / self.k.min(d) as f64) - 1.0)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_sq, norm2_sq};

    #[test]
    fn wire_is_values_plus_seed() {
        let r = RandK::new(10, true);
        let mut rng = Rng::new(4);
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let msg = r.compress_alloc(&x, &mut rng);
        assert_eq!(msg.wire_bits, 64 + 10 * 32);
        assert_eq!(msg.values.iter().filter(|&&v| v != 0.0).count(), 10);
    }

    #[test]
    fn unbiased_mean_and_variance() {
        let d = 50;
        let k = 10;
        let r = RandK::new(k, true);
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..d).map(|_| rng.normal_f64()).collect();
        let trials = 30_000;
        let mut mean = vec![0.0f64; d];
        let mut var_acc = 0.0;
        let mut msg = CompressedMsg::with_dim(d);
        for _ in 0..trials {
            r.compress(&x, &mut rng, &mut msg);
            for (m, v) in mean.iter_mut().zip(&msg.values) {
                *m += *v as f64;
            }
            var_acc += dist_sq(&x, &msg.values);
        }
        for (m, xi) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            assert!((avg - *xi as f64).abs() < 0.06, "bias {}", avg - *xi as f64);
        }
        // E‖x−Q(x)‖² = (d/k − 1)‖x‖² exactly for rand-k.
        let c = r.variance_constant(d).unwrap();
        let expected = c * norm2_sq(&x);
        let measured = var_acc / trials as f64;
        assert!(
            (measured - expected).abs() / expected < 0.05,
            "measured {measured} vs expected {expected}"
        );
    }

    /// The scratch fast path must match the eager path exactly: same wire
    /// payload/bits, same RNG consumption (so a mixed eager/lazy schedule
    /// keeps the dither stream bitwise-reproducible), and a lazily-rebuilt
    /// dense vector that is bit-identical — including ±0.0 selected
    /// entries, which is why `compress_into` records zero-valued
    /// selections explicitly.
    #[test]
    fn compress_into_matches_compress_bitwise() {
        use crate::prop::forall;
        use crate::prop_assert;
        forall(60, 0x7A2D, |g| {
            let mut x = g.vec_f64(1..=300, 4.0);
            // Plant exact and negative zeros so the zero-valued-selection
            // path is exercised.
            if x.len() >= 3 {
                x[0] = 0.0;
                x[1] = -0.0;
            }
            let k = g.usize_in(1..=x.len());
            let r = RandK::new(k, g.bool_with(0.5));
            let mut rng_a = Rng::new(g.case_seed);
            let mut rng_b = rng_a.clone();
            let eager = r.compress_alloc(&x, &mut rng_a);
            let mut scratch = CodecScratch::default();
            let mut lazy = CompressedMsg::default();
            r.compress_into(&x, &mut rng_b, &mut lazy, &mut scratch);
            prop_assert!(lazy.payload == eager.payload, "wire payload drifted");
            prop_assert!(lazy.wire_bits == eager.wire_bits, "wire bits drifted");
            prop_assert!(rng_a.next_u64() == rng_b.next_u64(), "RNG stream drifted");
            prop_assert!(x.is_empty() || lazy.dense_stale, "fast path should defer the dense fill");
            lazy.ensure_dense();
            prop_assert!(
                lazy.values.len() == eager.values.len()
                    && lazy
                        .values
                        .iter()
                        .zip(&eager.values)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "lazy dense decode != eager values"
            );
            // The fast-path sparse view holds every selected entry (zeros
            // included) in ascending index order.
            let sp = lazy.sparse.as_ref().unwrap();
            prop_assert!(sp.len() == k.min(x.len()), "must record every selected entry");
            prop_assert!(sp.windows(2).all(|w| w[0].0 < w[1].0), "ascending index order");
            for &(i, v) in sp {
                prop_assert!(
                    v.to_bits() == eager.values[i as usize].to_bits(),
                    "entry {i} mismatch"
                );
            }
            // Scratch reuse across calls must not change results.
            let mut rng_c = Rng::new(g.case_seed);
            let mut again = CompressedMsg::default();
            r.compress_into(&x, &mut rng_c, &mut again, &mut scratch);
            prop_assert!(again.payload == eager.payload, "scratch reuse drifted");
            Ok(())
        });
    }

    #[test]
    fn biased_mode_keeps_raw_values() {
        let r = RandK::new(5, false);
        let mut rng = Rng::new(1);
        let x = vec![2.0f64; 20];
        let msg = r.compress_alloc(&x, &mut rng);
        for &v in &msg.values {
            assert!(v == 0.0 || v == 2.0);
        }
        assert!(r.variance_constant(20).is_none());
    }
}
