//! p-norm b-bit stochastic quantization — the paper's compression operator
//! (Eq. 14 for p = ∞, Theorem 3 / Eq. 20 in general):
//!
//! ```text
//! Q_p(x) = (‖x‖_p · sign(x) · 2^{-(b-1)}) ⊙ ⌊ 2^{b-1} |x| / ‖x‖_p + u ⌋,
//! u ~ U[0,1)^d
//! ```
//!
//! The stochastic dither `u` makes the operator *unbiased* (Theorem 3), and
//! the variance is bounded by `(1/4)‖sign(x)2^{-(b-1)}‖² ‖x‖_p²` — minimized
//! by p = ∞, which is the paper's headline observation in Appendix C.
//!
//! Quantization is applied blockwise (paper §5 uses block = 512): each block
//! gets its own norm so one outlier cannot destroy the precision of the
//! whole vector. The wire format per block is
//!
//! ```text
//! [ norm: f64 | per element: sign (1 bit) + level (b bits) ]
//! ```
//!
//! `level ∈ {0, …, 2^{b-1}}` — note the inclusive upper end, which is why
//! levels need `b` bits rather than `b−1`. Total wire size:
//! `32·⌈d/block⌉ + d·(b+1)` bits. With b = 2 and block = 512 that is
//! ≈ 3.06 bits/element vs 32 for raw f64 — a 10.4× reduction.

use super::wire::{BitReader, BitWriter};
use super::{CompressedMsg, Compressor};
use crate::linalg::simd::LANES;
use crate::rng::Rng;

/// Which norm scales the quantization grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PNorm {
    /// Finite p ≥ 1.
    P(f64),
    /// ∞-norm (the paper's choice; smallest variance bound).
    Inf,
}

impl PNorm {
    fn eval(&self, x: &[f64]) -> f64 {
        match self {
            PNorm::Inf => crate::linalg::norm_inf(x) as f64,
            PNorm::P(p) => crate::linalg::norm_p(x, *p),
        }
    }
}

/// Blockwise p-norm b-bit stochastic quantizer.
#[derive(Clone, Debug)]
pub struct QuantizeP {
    /// Bits per magnitude level (b ≥ 1). Levels occupy b bits on the wire
    /// plus one sign bit.
    pub bits: u32,
    pub norm: PNorm,
    /// Block size for blockwise quantization (paper: 512).
    pub block: usize,
}

impl QuantizeP {
    pub fn new(bits: u32, norm: PNorm, block: usize) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        assert!(block >= 1);
        QuantizeP { bits, norm, block }
    }

    /// The paper's default: 2-bit ∞-norm quantization, block 512.
    pub fn paper_default() -> Self {
        QuantizeP::new(2, PNorm::Inf, 512)
    }

    /// Encode one block into the bit stream, returning the dequantized
    /// values in `vals`.
    fn encode_block(&self, x: &[f64], rng: &mut Rng, w: &mut BitWriter, vals: &mut [f64]) {
        // The wire carries the norm as f32 (32 bits); BOTH sides must use
        // the f32-rounded value so sender-side decode == receiver decode.
        let norm_f32 = self.norm.eval(x) as f32;
        w.push_f32(norm_f32);
        let norm = norm_f32 as f64;
        if norm <= 0.0 || !norm.is_finite() {
            // All-zero (or degenerate) block: levels are zero.
            for (v, out) in x.iter().zip(vals.iter_mut()) {
                let _ = v;
                *out = 0.0;
                w.push(0, 1 + self.bits);
            }
            return;
        }
        let scale = (1u64 << (self.bits - 1)) as f64; // 2^{b-1}
        let unit = norm / scale; // ‖x‖_p · 2^{-(b-1)}
        // Hot loop (§Perf): precompute 1/norm (divide → multiply), fuse
        // sign+level into a single field (`sign | level<<1` — LSB-first,
        // bit-identical to the two separate pushes), and emit fields in
        // 4-lane bursts via `push4` (byte-identical to sequential pushes).
        // `quantize_one` draws the dither in element-index order, so the
        // RNG stream, the wire bytes, and the dequantized values are all
        // unchanged from the per-element loop.
        let inv = scale / norm;
        let field_width = 1 + self.bits;
        let mut xit = x.chunks_exact(LANES);
        let mut vit = vals.chunks_exact_mut(LANES);
        for (cx, cv) in (&mut xit).zip(&mut vit) {
            let mut fields = [0u64; LANES];
            for l in 0..LANES {
                let (f, v) = quantize_one(cx[l], inv, unit, scale, rng);
                fields[l] = f;
                cv[l] = v;
            }
            w.push4(fields, field_width);
        }
        for (xi, out) in xit.remainder().iter().zip(vit.into_remainder()) {
            let (f, v) = quantize_one(*xi, inv, unit, scale, rng);
            w.push(f, field_width);
            *out = v;
        }
    }
}

/// One element of the quantize hot loop — exactly the pre-chunking
/// per-element expressions, factored out so the 4-lane burst loop and its
/// remainder tail stay bitwise- and RNG-stream-identical. Returns the
/// fused wire field (`sign | level<<1`) and the dequantized value.
#[inline]
fn quantize_one(xi: f64, inv: f64, unit: f64, scale: f64, rng: &mut Rng) -> (u64, f64) {
    let sign_bit = u64::from(xi.is_sign_negative());
    let scaled = xi.abs() * inv;
    let level = (scaled + rng.uniform_f64()).floor() as u64;
    debug_assert!(level <= scale as u64 + 1, "level {level} > {scale}");
    let level = level.min(scale as u64); // guard fp edge (|x| == norm, u→1)
    let mag = unit * level as f64;
    (sign_bit | (level << 1), if sign_bit == 1 { -mag } else { mag })
}

impl Compressor for QuantizeP {
    fn name(&self) -> String {
        let p = match self.norm {
            PNorm::Inf => "∞".to_string(),
            PNorm::P(p) => format!("p={p}"),
        };
        format!("q{}-{}bit/{}", p, self.bits, self.block)
    }

    fn compress(&self, x: &[f64], rng: &mut Rng, out: &mut CompressedMsg) {
        out.values.resize(x.len(), 0.0);
        out.sparse = None; // dense message — every coordinate carries a level
        out.dense_stale = false;
        let mut w = BitWriter::new();
        std::mem::swap(&mut w.bytes, &mut out.payload); // reuse buffer
        w.clear();
        for (xb, vb) in x.chunks(self.block).zip(out.values.chunks_mut(self.block)) {
            self.encode_block(xb, rng, &mut w, vb);
        }
        out.wire_bits = w.bits;
        out.payload = w.bytes;
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn wire_format(&self) -> Option<crate::compress::WireFormat> {
        // Wire-complete: `decode` reconstructs the sender's `values`
        // bit-for-bit from the payload and these params
        // (`decode_matches_values_exactly`).
        Some(crate::compress::WireFormat::Quantize(self.clone()))
    }

    /// Worst-case C (Remark 7). For p = ∞ the supremum of
    /// `‖x‖_∞²/‖x‖²` is 1 (a single spike), giving `C = B · 4^{-b}` with
    /// B the effective block length. For finite p ≥ 2 the same bound holds
    /// (‖x‖_p ≤ ‖x‖_2 ⇒ ratio ≤ 1 is false for p<2); for p < 2 the ratio
    /// can reach `B^{2/p − 1}`.
    fn variance_constant(&self, d: usize) -> Option<f64> {
        let b_eff = self.block.min(d).max(1) as f64;
        let base = b_eff / 4f64.powi(self.bits as i32);
        Some(match self.norm {
            PNorm::Inf => base,
            PNorm::P(p) if p >= 2.0 => base,
            PNorm::P(p) => base * b_eff.powf(2.0 / p - 1.0),
        })
    }
}

/// Decode a packed payload produced by [`QuantizeP::compress`] back into
/// values. Used by tests to prove the wire format is complete (the decoded
/// stream must reproduce `CompressedMsg::values` exactly) and by the
/// network-simulation layer when byte-level transport is exercised.
pub fn decode(q: &QuantizeP, payload: &[u8], d: usize, out: &mut Vec<f64>) {
    out.clear();
    out.reserve(d);
    let mut r = BitReader::new(payload);
    let scale = (1u64 << (q.bits - 1)) as f64;
    let mut remaining = d;
    while remaining > 0 {
        let blk = remaining.min(q.block);
        let norm = r.read_f32() as f64;
        // Mirror encode_block's degenerate-norm guard exactly: a zero,
        // negative (impossible for a norm, but defensive), infinite, or NaN
        // block norm encodes all-zero levels, so it must decode to 0.0 —
        // `inf · 0` would otherwise produce NaN here.
        let unit = if norm > 0.0 && norm.is_finite() { norm / scale } else { 0.0 };
        // 4-lane bursts mirroring encode_block: one fused field per
        // element (`sign | level<<1`, LSB-first — reading it whole is
        // bit-identical to the old read(1) + read(bits) pair).
        let fw = 1 + q.bits;
        let mut done = 0usize;
        while done + LANES <= blk {
            for f in r.read4(fw) {
                out.push(field_val(f, unit));
            }
            done += LANES;
        }
        for _ in done..blk {
            out.push(field_val(r.read(fw), unit));
        }
        remaining -= blk;
    }
}

/// Dequantize one fused wire field (see [`quantize_one`]).
#[inline]
fn field_val(f: u64, unit: f64) -> f64 {
    let mag = unit * (f >> 1) as f64;
    if f & 1 == 1 {
        -mag
    } else {
        mag
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dist_sq, norm2_sq};
    use crate::prop::forall;
    use crate::prop_assert;

    #[test]
    fn wire_size_formula() {
        let q = QuantizeP::new(2, PNorm::Inf, 512);
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..1000).map(|i| (i as f64).sin()).collect();
        let msg = q.compress_alloc(&x, &mut rng);
        let blocks = 1000usize.div_ceil(512) as u64;
        assert_eq!(msg.wire_bits, blocks * 32 + 1000 * 3);
        // ~3.06 bits/element => >10x compression.
        assert!(msg.wire_bits * 10 < 32 * 1000);
    }

    #[test]
    fn decode_matches_values_exactly() {
        forall(60, 0xBEEF, |g| {
            let bits = g.usize_in(1..=8) as u32;
            let block = *g.choose(&[3usize, 64, 512]);
            let q = QuantizeP::new(bits, PNorm::Inf, block);
            let x = g.vec_f64(1..=700, 5.0);
            let mut rng = Rng::new(g.case_seed);
            let msg = q.compress_alloc(&x, &mut rng);
            let mut dec = Vec::new();
            decode(&q, &msg.payload, x.len(), &mut dec);
            prop_assert!(dec == msg.values, "wire decode mismatch (bits={bits} block={block})");
            Ok(())
        });
    }

    #[test]
    fn widest_fields_take_the_burst_fallback() {
        // bits=16 ⇒ field width 17 ⇒ 4·17 > 64, exercising push4/read4's
        // sequential fallback path; the wire must still round-trip exactly.
        let q = QuantizeP::new(16, PNorm::Inf, 64);
        let mut rng = Rng::new(23);
        let x: Vec<f64> = (0..150).map(|i| ((i * 37) as f64).sin() * 4.0).collect();
        let msg = q.compress_alloc(&x, &mut rng);
        assert_eq!(msg.wire_bits, 3 * 32 + 150 * 17);
        let mut dec = Vec::new();
        decode(&q, &msg.payload, x.len(), &mut dec);
        assert!(dec.iter().zip(&msg.values).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn decode_matches_values_on_nonfinite_norm() {
        // Regression: encode_block zeroes every level when the block norm
        // is not finite, but decode only guarded `norm > 0.0`, turning an
        // inf norm into `inf · 0 = NaN`. Both an explicit inf entry and an
        // f64 too large for the f32 wire norm must round-trip to zeros.
        let q = QuantizeP::new(2, PNorm::Inf, 8);
        let mut rng = Rng::new(17);
        let roundtrip = |x: &[f64], rng: &mut Rng| {
            let msg = q.compress_alloc(x, rng);
            let mut dec = Vec::new();
            decode(&q, &msg.payload, x.len(), &mut dec);
            assert!(
                dec.iter().zip(&msg.values).all(|(a, b)| a.to_bits() == b.to_bits()),
                "decode diverged from encoder values: {dec:?} vs {:?}",
                msg.values
            );
            msg
        };
        for spike in [f64::INFINITY, 1e39] {
            let mut x = vec![0.5f64; 16];
            x[2] = spike; // first block norm becomes inf on the f32 wire
            let msg = roundtrip(&x, &mut rng);
            assert!(
                msg.values[..8].iter().all(|&v| v == 0.0),
                "degenerate block must encode zeros (spike {spike})"
            );
        }
        // A NaN entry leaves the ∞-norm finite (f64::max ignores NaN) but
        // must still round-trip without panicking or diverging.
        let mut x = vec![0.5f64; 16];
        x[2] = f64::NAN;
        let _ = roundtrip(&x, &mut rng);
    }

    #[test]
    fn unbiased_statistically() {
        // E[Q(x)] = x (Theorem 3): average many independent quantizations.
        let q = QuantizeP::new(2, PNorm::Inf, 64);
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..64).map(|_| rng.normal_f64()).collect();
        let trials = 20_000;
        let mut mean = vec![0.0f64; 64];
        let mut msg = CompressedMsg::with_dim(64);
        for _ in 0..trials {
            q.compress(&x, &mut rng, &mut msg);
            for (m, v) in mean.iter_mut().zip(&msg.values) {
                *m += *v as f64;
            }
        }
        for (m, xi) in mean.iter().zip(&x) {
            let avg = m / trials as f64;
            // std error of the mean ≈ unit/sqrt(12·trials); allow 6 sigma.
            let unit = crate::linalg::norm_inf(&x) / 2.0;
            let tol = 6.0 * (unit as f64) / (12.0 * trials as f64).sqrt();
            assert!((avg - *xi as f64).abs() < tol, "bias {} vs tol {tol}", avg - *xi as f64);
        }
    }

    #[test]
    fn variance_bound_holds() {
        // E‖x−Q(x)‖² ≤ C‖x‖² with the Remark 7 constant.
        forall(25, 0xFEED, |g| {
            let bits = g.usize_in(1..=6) as u32;
            let q = QuantizeP::new(bits, PNorm::Inf, 128);
            let x = g.vec_f64(16..=256, 3.0);
            let c = q.variance_constant(x.len()).unwrap();
            let mut rng = Rng::new(g.case_seed ^ 1);
            let mut msg = CompressedMsg::with_dim(x.len());
            let trials = 300;
            let mut err = 0.0;
            for _ in 0..trials {
                q.compress(&x, &mut rng, &mut msg);
                err += dist_sq(&x, &msg.values);
            }
            err /= trials as f64;
            let bound = c * norm2_sq(&x);
            prop_assert!(
                err <= bound * 1.15 + 1e-12,
                "E err {err} exceeds C‖x‖² = {bound} (bits={bits})"
            );
            Ok(())
        });
    }

    #[test]
    fn inf_norm_beats_smaller_p() {
        // Appendix C / Fig. 5: error decreases as p grows; ∞ is best.
        let mut rng = Rng::new(42);
        let x: Vec<f64> = (0..4096).map(|_| rng.normal_f64()).collect();
        let err_for = |norm: PNorm| {
            let q = QuantizeP::new(2, norm, 4096);
            super::super::relative_error(&q, &x, &mut Rng::new(7), 20)
        };
        let e1 = err_for(PNorm::P(1.0));
        let e2 = err_for(PNorm::P(2.0));
        let e6 = err_for(PNorm::P(6.0));
        let einf = err_for(PNorm::Inf);
        assert!(e1 > e2 && e2 > e6 && e6 > einf, "e1={e1} e2={e2} e6={e6} einf={einf}");
    }

    #[test]
    fn zero_and_spike_blocks() {
        let q = QuantizeP::new(2, PNorm::Inf, 8);
        let mut rng = Rng::new(3);
        // Zero vector quantizes to zero with finite wire size.
        let z = vec![0.0f64; 16];
        let msg = q.compress_alloc(&z, &mut rng);
        assert!(msg.values.iter().all(|&v| v == 0.0));
        assert_eq!(msg.wire_bits, 2 * 32 + 16 * 3);
        // A single spike: the spike itself is reproduced exactly
        // (|x| == norm ⇒ level = 2^{b-1} regardless of dither).
        let mut s = vec![0.0f64; 8];
        s[3] = -2.5;
        let msg = q.compress_alloc(&s, &mut rng);
        assert_eq!(msg.values[3], -2.5);
        for (i, v) in msg.values.iter().enumerate() {
            if i != 3 {
                assert_eq!(*v, 0.0);
            }
        }
    }

    #[test]
    fn higher_bits_lower_error() {
        let mut rng = Rng::new(9);
        let x: Vec<f64> = (0..1024).map(|_| rng.normal_f64()).collect();
        let mut prev = f64::INFINITY;
        for bits in [1u32, 2, 4, 6, 8] {
            let q = QuantizeP::new(bits, PNorm::Inf, 512);
            let e = super::super::relative_error(&q, &x, &mut Rng::new(11), 10);
            assert!(e < prev, "bits={bits}: {e} !< {prev}");
            prev = e;
        }
        assert!(prev < 0.01); // 8-bit is near-lossless at this scale
    }
}
