//! Communication compression operators (paper §5 / Appendix C).
//!
//! Each [`Compressor`] turns a vector into (a) a packed wire payload whose
//! exact bit count feeds the communication plots and (b) the decoded values
//! every receiver reconstructs. LEAD's theory (Assumption 2) requires the
//! operator to be *unbiased* with variance `E‖x − Q(x)‖² ≤ C‖x‖²`; the
//! p-norm b-bit quantizer ([`quantize::QuantizeP`], Eq. 20) satisfies this,
//! while top-k is biased and included only for the Fig. 6 comparison.
//!
//! # Sparse message representation
//!
//! Sparsifying codecs (top-k, rand-k) decode to a vector with k ≪ d
//! nonzeros. Alongside the dense `values`, they publish the nonzeros as a
//! [`CompressedMsg::sparse`] list of `(index, value)` pairs so the engine's
//! mix step can scatter-add in O(deg·k) instead of O(deg·d) per agent
//! (CHOCO-SGD-style sparse gossip). Through [`Compressor::compress`] the
//! sparse view is *exactly* the nonzero entries of `values` in ascending
//! index order; the scratch-carrying hot path
//! ([`Compressor::compress_into`]) may additionally include explicitly
//! selected ±0.0-valued entries so the dense vector can be reconstructed
//! lazily and bit-exactly ([`CompressedMsg::ensure_dense`]). Either way,
//! mixing through the sparse view is bitwise-identical to dense
//! accumulation: an accumulator that starts at +0.0 is never changed by
//! adding ±0.0 terms — whether omitted or explicit — because IEEE 754
//! round-to-nearest never produces −0.0 from a sum unless both addends
//! are −0.0, which a +0.0 start rules out. Dense codecs (quantizers,
//! identity) leave `sparse` as `None` and mixing falls back to `axpy` over
//! `values`.
//!
//! The sparse view has a second consumer besides mixing: the engine's
//! apply phase serves each agent's *own* message to the algorithm as a
//! `crate::algorithms::OwnView`, which for a stale sparse message is the
//! `(index, value)` list itself — so in the top-k/rand-k steady state the
//! dense vector is never rebuilt at all ([`CompressedMsg::ensure_dense`]
//! only runs on observed rounds, for the compression-error metric).

pub mod identity;
pub mod quantize;
pub mod randk;
pub mod topk;
pub mod wire;

use crate::rng::Rng;

/// A compressed message: decoded values + exact wire size.
///
/// The decoded values are what every receiver reconstructs (codecs are
/// deterministic given the payload, so decoding once is equivalent to each
/// receiver decoding its own copy). `payload` holds the actual packed
/// bytes; `wire_bits` is its exact size in bits, including per-block norms
/// and any index/seed overhead.
#[derive(Clone, Debug, Default)]
pub struct CompressedMsg {
    pub values: Vec<f64>,
    /// Sparse view of `values` for sparsifying codecs: the selected
    /// `(index, value)` pairs, ascending by index. After
    /// [`Compressor::compress`] this is exactly the nonzeros of `values`;
    /// after [`Compressor::compress_into`] it may also carry selected
    /// entries whose value is ±0.0 (see the module docs — mixing through
    /// either form is bitwise-equal to dense accumulation). `None` ⇒
    /// dense message.
    pub sparse: Option<Vec<(u32, f64)>>,
    /// §Perf: sparse fast paths ([`Compressor::compress_into`]) may skip
    /// the O(d) dense fill of `values` and mark it stale; call
    /// [`CompressedMsg::ensure_dense`] before reading `values`.
    /// [`Compressor::compress`] always leaves `values` valid (`false`).
    pub dense_stale: bool,
    pub payload: Vec<u8>,
    pub wire_bits: u64,
}

impl CompressedMsg {
    pub fn with_dim(d: usize) -> Self {
        CompressedMsg {
            values: vec![0.0; d],
            sparse: None,
            dense_stale: false,
            payload: Vec::new(),
            wire_bits: 0,
        }
    }

    /// Rebuild `values` from the sparse view if a sparse fast path left it
    /// stale; no-op otherwise. The scatter reproduces the eager dense
    /// encoding bit-for-bit because `compress_into` records *every*
    /// selected entry (including ±0.0 values): `fill(0.0)` + scatter is
    /// exactly the eager clear + per-entry write.
    ///
    /// Under the sparse-own contract the engine's steady-state round loop
    /// never triggers this rebuild (apply kernels consume
    /// `Inbox::own_view` directly); the only remaining caller is the
    /// observed-round compression-error pass. Debug builds count actual
    /// rebuilds in [`CompressedMsg::dense_decode_count`] so tests can pin
    /// that.
    pub fn ensure_dense(&mut self) {
        if !self.dense_stale {
            return;
        }
        #[cfg(debug_assertions)]
        // ORDERING: monotonic debug counter; tests read it only after the
        // run's dispatch barriers have joined (which is what provides the
        // happens-before), so Relaxed suffices.
        DENSE_DECODES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.values.fill(0.0);
        if let Some(sp) = &self.sparse {
            for &(i, v) in sp {
                self.values[i as usize] = v;
            }
        }
        self.dense_stale = false;
    }

    /// Debug-only instrumentation: process-wide count of
    /// [`CompressedMsg::ensure_dense`] calls that actually rebuilt a stale
    /// dense vector (no-op calls are not counted). Used by
    /// `rust/tests/alloc_steady_state.rs` to prove the sparse-own steady
    /// state performs no O(n·d) own-decode pass. Compiled out in release
    /// builds.
    #[cfg(debug_assertions)]
    pub fn dense_decode_count() -> u64 {
        // ORDERING: see the fetch_add in `ensure_dense` — the reader
        // synchronizes via the pool's dispatch barrier, not this load.
        DENSE_DECODES.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// See [`CompressedMsg::dense_decode_count`].
#[cfg(debug_assertions)]
static DENSE_DECODES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Reusable per-agent codec scratch (§Perf): buffers
/// [`Compressor::compress_into`] implementations use to keep the engine's
/// steady-state round loop allocation-free (top-k reuses its selection
/// index buffer here instead of collecting `0..d` every call).
#[derive(Default)]
pub struct CodecScratch {
    /// Selection working set for sparsifiers (top-k partial select).
    pub idx: Vec<usize>,
}

/// How a codec's packed channel-0 payload decodes on a receiving agent
/// that has **only the wire bytes** — the contract the transport layer
/// ([`crate::transport`]) needs to reconstruct a sender's published
/// message bit-for-bit on the far side of a channel.
///
/// A codec is *wire-complete* when `(payload, d)` alone determines the
/// exact decoded message:
///
/// * [`WireFormat::Quantize`] — decode via [`quantize::decode`], which is
///   pinned bitwise to the sender's `values` (test
///   `decode_matches_values_exactly`).
/// * [`WireFormat::TopK`] — the payload is `entries` records of
///   `(index_bits(d)`-wide index, f32 value)` in ascending index order;
///   each decodes to the sparse entry `(index, value as f64)`, which is
///   exactly the list `compress_into` published (±0.0 entries included).
///
/// Rand-k is **not** wire-complete (receivers re-derive the index set
/// from RNG state the wire does not carry) and identity packs no payload
/// — both return `None` from [`Compressor::wire_format`] and are
/// rejected by non-`Mem` transports up front.
#[derive(Clone, Debug)]
pub enum WireFormat {
    /// Block p-norm quantizer payload; decode with the carried params.
    Quantize(quantize::QuantizeP),
    /// Top-k sparse payload: k `(index, f32)` records, ascending.
    TopK {
        /// Entries per message (every message carries exactly k).
        k: usize,
    },
}

/// A communication compression operator.
pub trait Compressor: Send + Sync {
    /// Human-readable identifier, e.g. `q∞-2bit/512`.
    fn name(&self) -> String;

    /// Compress `x` into `out`. `values`, `payload`, `sparse`, **and
    /// `dense_stale`** must all be overwritten (buffers are reused across
    /// rounds, so a codec that leaves `sparse` or `dense_stale` untouched
    /// can expose a stale view from a previous compressor and silently
    /// corrupt the engine's sparse mix path): sparsifiers publish the
    /// canonical nonzero list, dense codecs must set `sparse = None`, and
    /// `compress` always materializes `values` (`dense_stale = false` —
    /// only [`Compressor::compress_into`] may defer the dense fill). `rng`
    /// supplies the dither / index randomness — each agent passes its own
    /// stream so the parallel engine stays deterministic.
    fn compress(&self, x: &[f64], rng: &mut Rng, out: &mut CompressedMsg);

    /// Scratch-carrying compression for the engine's hot loop. Semantics
    /// match [`Compressor::compress`] with two §Perf relaxations:
    ///
    /// 1. `scratch` may be used to avoid per-call allocations;
    /// 2. sparsifying codecs may skip the O(d) dense fill of
    ///    `out.values`, publish **all** selected `(index, value)` entries
    ///    — including ±0.0 values — in `out.sparse`, and set
    ///    `out.dense_stale = true`. Consumers that need the dense vector
    ///    call [`CompressedMsg::ensure_dense`], which reconstructs it
    ///    bit-exactly; mixing through the sparse view is bitwise-equal to
    ///    the dense path either way (module docs).
    ///
    /// A codec that leaves `dense_stale` set MUST publish a sparse view
    /// (otherwise the message is unreadable). Codecs without a fast path
    /// inherit this default, which falls back to `compress` (dense valid).
    fn compress_into(
        &self,
        x: &[f64],
        rng: &mut Rng,
        out: &mut CompressedMsg,
        scratch: &mut CodecScratch,
    ) {
        let _ = scratch;
        self.compress(x, rng, out);
    }

    /// Whether `E[Q(x)] = x` (Assumption 2). LEAD's guarantees require it.
    fn is_unbiased(&self) -> bool;

    /// The worst-case variance constant C with `E‖x−Q(x)‖² ≤ C‖x‖²`, if
    /// the operator is unbiased (None for biased operators).
    fn variance_constant(&self, d: usize) -> Option<f64>;

    /// The receiver-side decode recipe for this codec's packed payload,
    /// or `None` if the payload alone does not determine the decoded
    /// message (see [`WireFormat`]). `None` (the default) makes the
    /// codec `Mem`-only: the scenario validator rejects it for
    /// channel-backed transports instead of letting trajectories
    /// silently diverge. Wrappers ([`StripSparse`], [`EagerDense`])
    /// deliberately inherit `None` — they alter coordinator-side
    /// representation, which the wire does not carry.
    fn wire_format(&self) -> Option<WireFormat> {
        None
    }

    /// Convenience: allocate-and-compress.
    fn compress_alloc(&self, x: &[f64], rng: &mut Rng) -> CompressedMsg {
        let mut out = CompressedMsg::with_dim(x.len());
        self.compress(x, rng, &mut out);
        out
    }
}

/// Wrapper that delegates to the inner codec but withholds the sparse
/// view, forcing receivers onto the dense mixing path. Numerically a
/// no-op (the sparse view is a pure representation change) — used by the
/// engine's sparse-vs-dense trajectory-equality test and the hotpath
/// benchmark's dense-vs-sparse A/B.
pub struct StripSparse<C: Compressor>(pub C);

impl<C: Compressor> Compressor for StripSparse<C> {
    fn name(&self) -> String {
        format!("dense-{}", self.0.name())
    }

    fn compress(&self, x: &[f64], rng: &mut Rng, out: &mut CompressedMsg) {
        self.0.compress(x, rng, out);
        out.sparse = None;
    }

    fn is_unbiased(&self) -> bool {
        self.0.is_unbiased()
    }

    fn variance_constant(&self, d: usize) -> Option<f64> {
        self.0.variance_constant(d)
    }
}

/// Wrapper that delegates to the inner codec but eagerly materializes the
/// dense decoded vector on the scratch-carrying hot path, while keeping
/// the sparse view (so mixing stays sparse). This reproduces the
/// pre-sparse-own engine behavior — one O(d) own-decode pass per agent
/// per round — and is numerically a no-op: `ensure_dense` rebuilds the
/// exact dense vector the eager path writes. Used by the sparse-own
/// differential harness (`rust/tests/sparse_own.rs`) and the hotpath
/// benchmark's own-decode A/B.
pub struct EagerDense<C: Compressor>(pub C);

impl<C: Compressor> Compressor for EagerDense<C> {
    fn name(&self) -> String {
        format!("eager-{}", self.0.name())
    }

    fn compress(&self, x: &[f64], rng: &mut Rng, out: &mut CompressedMsg) {
        self.0.compress(x, rng, out);
    }

    fn compress_into(
        &self,
        x: &[f64],
        rng: &mut Rng,
        out: &mut CompressedMsg,
        scratch: &mut CodecScratch,
    ) {
        self.0.compress_into(x, rng, out, scratch);
        out.ensure_dense();
    }

    fn is_unbiased(&self) -> bool {
        self.0.is_unbiased()
    }

    fn variance_constant(&self, d: usize) -> Option<f64> {
        self.0.variance_constant(d)
    }
}

/// Parse a compressor spec string: `none`, `qinf:<bits>[:<block>]`,
/// `q2:<bits>`, `q1:<bits>`, `topk:<k>`, `randk:<k>`.
pub fn parse(spec: &str) -> Option<Box<dyn Compressor>> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts[0] {
        "none" | "identity" => Some(Box::new(identity::Identity)),
        "topk" => {
            let k = parts.get(1)?.parse().ok()?;
            Some(Box::new(topk::TopK::new(k)))
        }
        "randk" => {
            let k = parts.get(1)?.parse().ok()?;
            Some(Box::new(randk::RandK::new(k, true)))
        }
        p if p.starts_with('q') => {
            let norm = match &p[1..] {
                "inf" | "" => quantize::PNorm::Inf,
                s => quantize::PNorm::P(s.parse().ok()?),
            };
            let bits = parts.get(1)?.parse().ok()?;
            let block = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(512);
            Some(Box::new(quantize::QuantizeP::new(bits, norm, block)))
        }
        _ => None,
    }
}

/// Measured relative compression error `‖x − Q(x)‖₂ / ‖x‖₂`, averaged over
/// `trials` fresh random draws of the dither (Figs. 5–6 metric).
pub fn relative_error(c: &dyn Compressor, x: &[f64], rng: &mut Rng, trials: usize) -> f64 {
    let norm = crate::linalg::norm2(x).max(1e-30);
    let mut msg = CompressedMsg::with_dim(x.len());
    let mut acc = 0.0;
    for _ in 0..trials {
        c.compress(x, rng, &mut msg);
        acc += crate::linalg::dist_sq(x, &msg.values).sqrt() / norm;
    }
    acc / trials as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(parse("none").unwrap().name(), "identity");
        assert!(parse("qinf:2").unwrap().name().contains("2bit"));
        assert!(parse("q2:4:256").unwrap().name().contains("p=2"));
        assert!(parse("topk:10").unwrap().name().contains("top"));
        assert!(parse("randk:10").unwrap().name().contains("rand"));
        assert!(parse("wat").is_none());
        assert!(parse("topk").is_none());
    }
}
