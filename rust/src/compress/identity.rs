//! Identity "compression": transmits raw f64 values (32 bits/element).
//!
//! This is C = 0 in the paper's notation — LEAD with [`Identity`] recovers
//! NIDS exactly (Proposition 1 / Corollary 3), which the integration tests
//! verify trajectory-for-trajectory.

use super::{CompressedMsg, Compressor};
use crate::rng::Rng;

#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".to_string()
    }

    fn compress(&self, x: &[f64], _rng: &mut Rng, out: &mut CompressedMsg) {
        out.values.clear();
        out.values.extend_from_slice(x);
        out.sparse = None; // dense message — engine mixes over `values`
        out.dense_stale = false;

        // Raw IEEE-754 payload.
        out.payload.clear();
        out.payload.reserve(x.len() * 4);
        for v in x {
            out.payload.extend_from_slice(&(*v as f32).to_le_bytes());
        }
        out.wire_bits = (x.len() as u64) * 32;
    }

    fn is_unbiased(&self) -> bool {
        true
    }

    fn variance_constant(&self, _d: usize) -> Option<f64> {
        Some(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_passthrough() {
        let mut rng = Rng::new(0);
        let x = vec![1.5f64, -2.25, 0.0];
        let msg = Identity.compress_alloc(&x, &mut rng);
        assert_eq!(msg.values, x);
        assert_eq!(msg.wire_bits, 96);
        assert_eq!(msg.payload.len(), 12);
        assert_eq!(Identity.variance_constant(3), Some(0.0));
    }
}
