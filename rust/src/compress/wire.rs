//! Bit-level wire format helpers.
//!
//! The paper's communication plots are measured in *bits transmitted*, so
//! the codecs in this crate produce real packed bitstreams rather than
//! estimating sizes. [`BitWriter`] / [`BitReader`] implement an LSB-first
//! bit stream over a byte buffer; codecs append arbitrary-width fields.

/// LSB-first bit writer over a growable byte buffer.
#[derive(Default)]
pub struct BitWriter {
    pub bytes: Vec<u8>,
    /// Number of valid bits in the stream.
    pub bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset without deallocating (hot-loop reuse).
    pub fn clear(&mut self) {
        self.bytes.clear();
        self.bits = 0;
    }

    /// Append the low `width` bits of `value` (width ≤ 64).
    ///
    /// Word-level fast path: instead of feeding ≤ 8 bits per iteration,
    /// the field is written as one little-endian byte-slice append (plus a
    /// single OR into the current partial byte when unaligned) — up to 8
    /// bytes at a time. The stream layout is identical to the old
    /// per-chunk loop (LSB-first), pinned by the round-trip tests below.
    #[inline]
    pub fn push(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value < (1u64 << width), "value {value} overflows {width} bits");
        if width == 0 {
            return;
        }
        // Mask to `width` so stray high bits cannot leak into the stream
        // in release builds (the debug_assert catches misuse in debug).
        let value = if width == 64 { value } else { value & ((1u64 << width) - 1) };
        let bit = (self.bits % 8) as u32;
        self.bits += width as u64;
        if bit == 0 {
            let nbytes = width.div_ceil(8) as usize;
            self.bytes.extend_from_slice(&value.to_le_bytes()[..nbytes]);
        } else {
            // Merge the low bits into the partially-filled last byte, then
            // append whatever is left as whole little-endian bytes.
            *self.bytes.last_mut().unwrap() |= (value << bit) as u8;
            let consumed = 8 - bit;
            if width > consumed {
                let rest = value >> consumed;
                let nbytes = (width - consumed).div_ceil(8) as usize;
                self.bytes.extend_from_slice(&rest.to_le_bytes()[..nbytes]);
            }
        }
    }

    /// Append an f32 (32 bits, IEEE-754 little-endian bit order).
    #[inline]
    pub fn push_f32(&mut self, x: f32) {
        self.push(x.to_bits() as u64, 32);
    }

    /// Append four equal-width fields in order — the quantizer's 4-lane
    /// burst. When `4 · width ≤ 64` the lanes are pre-packed into one
    /// u64 (`v0 | v1≪w | v2≪2w | v3≪3w`) and written with a single
    /// [`push`](Self::push); because the stream is LSB-first, that packed
    /// word's byte layout is identical to four sequential pushes, so this
    /// is a pure speed path (pinned by `push4_matches_sequential`).
    /// Wider fields fall back to four pushes.
    #[inline]
    pub fn push4(&mut self, values: [u64; 4], width: u32) {
        if width != 0 && 4 * width <= 64 {
            let mut packed = 0u64;
            for (l, &v) in values.iter().enumerate() {
                // A lane overflowing `width` would bleed into the next
                // lane's bits (plain `push` merely writes a wrong value),
                // so overflow must be a hard error here.
                debug_assert!(v < (1u64 << width), "push4: lane {l} value {v} overflows {width} bits");
                packed |= (v & ((1u64 << width) - 1)) << (l as u32 * width);
            }
            self.push(packed, 4 * width);
        } else {
            for v in values {
                self.push(v, width);
            }
        }
    }
}

/// LSB-first bit reader.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    /// Read `width` bits (width ≤ 64). Panics past end of stream.
    ///
    /// Word-level fast path: the ≤ 9 bytes covering the field are gathered
    /// with one 8-byte little-endian load (plus one extra byte when the
    /// field straddles a 9th), instead of the old ≤ 8-bits-per-iteration
    /// loop. Bit order is unchanged (LSB-first).
    #[inline]
    pub fn read(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        if width == 0 {
            return 0;
        }
        let byte_pos = (self.pos / 8) as usize;
        let bit = (self.pos % 8) as u32;
        self.pos += width as u64;
        let needed = ((bit + width) as usize).div_ceil(8);
        let mut buf = [0u8; 8];
        let m = needed.min(8);
        // Slice indexing preserves the old panic-past-end behavior.
        buf[..m].copy_from_slice(&self.bytes[byte_pos..byte_pos + m]);
        let mut out = u64::from_le_bytes(buf) >> bit;
        if needed > 8 {
            // bit + width > 64 ⇒ bit ≥ 1, so the shift below is < 64.
            out |= (self.bytes[byte_pos + 8] as u64) << (64 - bit);
        }
        if width < 64 {
            out &= (1u64 << width) - 1;
        }
        out
    }

    #[inline]
    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32) as u32)
    }

    /// Read four equal-width fields in order ([`BitWriter::push4`]'s
    /// mirror — but it decodes ANY four sequential fields, packed or
    /// not, since the layouts are identical). One
    /// [`read`](Self::read) when `4 · width ≤ 64`, else four.
    #[inline]
    pub fn read4(&mut self, width: u32) -> [u64; 4] {
        if width != 0 && 4 * width <= 64 {
            let packed = self.read(4 * width);
            // width ≤ 16 here, so the mask shift cannot overflow.
            let mask = (1u64 << width) - 1;
            [
                packed & mask,
                (packed >> width) & mask,
                (packed >> (2 * width)) & mask,
                (packed >> (3 * width)) & mask,
            ]
        } else {
            [self.read(width), self.read(width), self.read(width), self.read(width)]
        }
    }

    /// Bits consumed so far.
    pub fn position(&self) -> u64 {
        self.pos
    }
}

/// ceil(log2(n)) for n >= 1 — index field width for sparsifiers.
pub fn index_bits(n: usize) -> u32 {
    debug_assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push_f32(-1.5);
        w.push(0xDEADBEEF, 37);
        w.push(1, 1);
        w.push(u64::MAX, 64);
        let mut r = BitReader::new(&w.bytes);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read_f32(), -1.5);
        assert_eq!(r.read(37), 0xDEADBEEF);
        assert_eq!(r.read(1), 1);
        assert_eq!(r.read(64), u64::MAX);
        assert_eq!(r.position(), w.bits);
    }

    #[test]
    fn random_fields_roundtrip() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let n = 1 + rng.below(64);
            let fields: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let width = 1 + rng.below(64) as u32;
                    let value = if width == 64 { rng.next_u64() } else { rng.next_u64() & ((1u64 << width) - 1) };
                    (value, width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &fields {
                w.push(v, width);
            }
            let mut r = BitReader::new(&w.bytes);
            for &(v, width) in &fields {
                assert_eq!(r.read(width), v, "width={width}");
            }
        }
    }

    #[test]
    fn bit_count_exact() {
        let mut w = BitWriter::new();
        w.push(1, 1);
        w.push(2, 2);
        assert_eq!(w.bits, 3);
        assert_eq!(w.bytes.len(), 1);
        w.push(0, 6);
        assert_eq!(w.bits, 9);
        assert_eq!(w.bytes.len(), 2);
    }

    /// Bit-by-bit reference writer matching the pre-fast-path layout
    /// exactly: the word-level `push` must produce identical streams.
    fn push_reference(bytes: &mut Vec<u8>, bits: &mut u64, value: u64, width: u32) {
        let mut remaining = width;
        let mut v = value;
        while remaining > 0 {
            let bit_in_byte = (*bits % 8) as u32;
            if bit_in_byte == 0 {
                bytes.push(0);
            }
            let take = remaining.min(8 - bit_in_byte);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            *bytes.last_mut().unwrap() |= ((v & mask) as u8) << bit_in_byte;
            v >>= take;
            *bits += take as u64;
            remaining -= take;
        }
    }

    /// All widths 1..=64 at every unaligned start position 0..8: the
    /// word-level writer matches the bit-by-bit reference stream and the
    /// word-level reader round-trips every field.
    #[test]
    fn word_fast_path_all_widths_all_offsets() {
        for width in 1u32..=64 {
            for offset in 0u32..8 {
                let value = if width == 64 {
                    0x9E37_79B9_7F4A_7C15
                } else {
                    0x9E37_79B9_7F4A_7C15u64 & ((1u64 << width) - 1)
                };
                let mut w = BitWriter::new();
                if offset > 0 {
                    w.push(0b1010_1010 & ((1u64 << offset) - 1), offset);
                }
                w.push(value, width);
                w.push(0b101, 3); // trailing field so reads cross the end
                let (mut ref_bytes, mut ref_bits) = (Vec::new(), 0u64);
                if offset > 0 {
                    push_reference(&mut ref_bytes, &mut ref_bits, 0b1010_1010 & ((1u64 << offset) - 1), offset);
                }
                push_reference(&mut ref_bytes, &mut ref_bits, value, width);
                push_reference(&mut ref_bytes, &mut ref_bits, 0b101, 3);
                assert_eq!(w.bytes, ref_bytes, "stream layout drifted (width={width} offset={offset})");
                assert_eq!(w.bits, ref_bits);
                let mut r = BitReader::new(&w.bytes);
                if offset > 0 {
                    assert_eq!(r.read(offset), 0b1010_1010 & ((1u64 << offset) - 1));
                }
                assert_eq!(r.read(width), value, "width={width} offset={offset}");
                assert_eq!(r.read(3), 0b101);
                assert_eq!(r.position(), w.bits);
            }
        }
    }

    /// `push4` must be a pure speed path: for every width (packed branch
    /// ≤ 16 and fallback > 16) at every start offset, the stream is
    /// byte-identical to four sequential `push`es, and `read4` recovers
    /// the lanes whichever writer produced them.
    #[test]
    fn push4_matches_sequential() {
        let mut rng = Rng::new(41);
        for width in 1u32..=20 {
            for offset in 0u32..8 {
                let lanes: [u64; 4] = std::array::from_fn(|_| rng.next_u64() & ((1u64 << width) - 1));
                let mut burst = BitWriter::new();
                let mut seq = BitWriter::new();
                for w in [&mut burst, &mut seq] {
                    if offset > 0 {
                        w.push(0b0110_1001 & ((1u64 << offset) - 1), offset);
                    }
                }
                burst.push4(lanes, width);
                for v in lanes {
                    seq.push(v, width);
                }
                // Trailing field so the final partial byte is compared too.
                burst.push(0b10, 2);
                seq.push(0b10, 2);
                assert_eq!(burst.bytes, seq.bytes, "layout drifted (width={width} offset={offset})");
                assert_eq!(burst.bits, seq.bits);
                let mut r = BitReader::new(&seq.bytes);
                if offset > 0 {
                    let _ = r.read(offset);
                }
                assert_eq!(r.read4(width), lanes, "width={width} offset={offset}");
                assert_eq!(r.read(2), 0b10);
            }
        }
    }

    #[test]
    fn read4_matches_sequential_reads() {
        let mut w = BitWriter::new();
        let vals = [5u64, 0, 31, 17];
        for v in vals {
            w.push(v, 5);
        }
        let mut r4 = BitReader::new(&w.bytes);
        assert_eq!(r4.read4(5), vals);
        assert_eq!(r4.position(), 20);
    }

    #[test]
    fn zero_width_fields_are_noops() {
        let mut w = BitWriter::new();
        w.push(0, 0);
        assert_eq!(w.bits, 0);
        assert!(w.bytes.is_empty());
        w.push(0b11, 2);
        w.push(0, 0);
        assert_eq!(w.bits, 2);
        let mut r = BitReader::new(&w.bytes);
        assert_eq!(r.read(0), 0);
        assert_eq!(r.read(2), 0b11);
        assert_eq!(r.read(0), 0);
        assert_eq!(r.position(), 2);
    }

    #[test]
    #[should_panic]
    fn read_past_end_panics() {
        let mut w = BitWriter::new();
        w.push(0x7, 3);
        let mut r = BitReader::new(&w.bytes);
        let _ = r.read(3);
        let _ = r.read(64); // only padding bits remain in the last byte
    }

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
        assert_eq!(index_bits(10_000), 14);
    }

    // ---- Fuzz-style corpus: mutated byte prefixes of real codec payloads.
    //
    // The transport layer feeds received wire bytes back through the
    // bit-level readers, so these tests pin the robustness contract the
    // framed envelope relies on: (a) a `BitReader` over a *same-length*
    // corrupted buffer never panics — reads are schedule-driven, not
    // content-driven — and (b) every strict byte-prefix truncation is
    // detectable from the declared bit count alone (`bits.div_ceil(8)`),
    // which is exactly the check `transport::frame::decode` performs
    // before any reader touches the bytes.

    /// Build a real top-k-shaped payload: `k` (index, f32) records.
    fn topk_style_payload(d: usize, k: usize, rng: &mut Rng) -> (Vec<u8>, u64) {
        let ib = index_bits(d);
        let mut w = BitWriter::new();
        for i in 0..k {
            w.push(i as u64, ib);
            w.push_f32(rng.next_u64() as f32 / 1e6);
        }
        (w.bytes, w.bits)
    }

    /// (a): bit-flips anywhere in a payload never panic the readers; the
    /// field schedule consumes exactly the declared bit count regardless
    /// of content. Corpus: top-k-shaped records and quantize-shaped
    /// `f32 norm + fused fields` blocks, mutated with single-bit, high-bit
    /// and whole-byte flips at every position.
    #[test]
    fn mutated_payloads_never_panic_schedule_driven_reads() {
        let mut rng = Rng::new(99);
        let (d, k) = (300, 9);
        let ib = index_bits(d);
        let (payload, bits) = topk_style_payload(d, k, &mut rng);
        for pos in 0..payload.len() {
            for mask in [0x01u8, 0x80, 0xFF] {
                let mut buf = payload.clone();
                buf[pos] ^= mask;
                let mut r = BitReader::new(&buf);
                for _ in 0..k {
                    let idx = r.read(ib);
                    let _v = r.read_f32(); // may be NaN/inf — must not panic
                    assert!(idx < 1 << ib, "field width bounds the value");
                }
                assert_eq!(r.position(), bits, "schedule consumes exact bits");
            }
        }
        // Quantize-shaped block: norm then 4-lane fused fields. Flipping
        // the norm bytes (first 32 bits) can produce NaN/inf norms — the
        // reader must still walk the full schedule.
        let mut w = BitWriter::new();
        w.push_f32(3.5);
        for i in 0..8u64 {
            w.push(i % 8, 3);
        }
        for pos in 0..w.bytes.len() {
            for mask in [0x01u8, 0x80, 0xFF] {
                let mut buf = w.bytes.clone();
                buf[pos] ^= mask;
                let mut r = BitReader::new(&buf);
                let _norm = r.read_f32();
                let lanes = r.read4(3);
                assert!(lanes.iter().all(|&l| l < 8));
                for _ in 0..4 {
                    assert!(r.read(3) < 8);
                }
                assert_eq!(r.position(), w.bits);
            }
        }
    }

    /// (b): every strict byte prefix of a payload is shorter than the
    /// length its bit count declares, so a length check rejects all
    /// truncations before a reader is constructed. Randomized over field
    /// schedules so byte-aligned totals are covered too.
    #[test]
    fn every_truncated_prefix_is_detectable_from_bit_count() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let n = 1 + rng.below(40);
            let mut w = BitWriter::new();
            for _ in 0..n {
                let width = 1 + rng.below(64) as u32;
                let v = if width == 64 {
                    rng.next_u64()
                } else {
                    rng.next_u64() & ((1u64 << width) - 1)
                };
                w.push(v, width);
            }
            let want = (w.bits as usize).div_ceil(8);
            assert_eq!(w.bytes.len(), want, "writer never over-allocates");
            for cut in 0..w.bytes.len() {
                assert!(cut < want, "prefix of {cut} bytes must fail the {want}-byte length check");
            }
        }
    }
}
