//! Bit-level wire format helpers.
//!
//! The paper's communication plots are measured in *bits transmitted*, so
//! the codecs in this crate produce real packed bitstreams rather than
//! estimating sizes. [`BitWriter`] / [`BitReader`] implement an LSB-first
//! bit stream over a byte buffer; codecs append arbitrary-width fields.

/// LSB-first bit writer over a growable byte buffer.
#[derive(Default)]
pub struct BitWriter {
    pub bytes: Vec<u8>,
    /// Number of valid bits in the stream.
    pub bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reset without deallocating (hot-loop reuse).
    pub fn clear(&mut self) {
        self.bytes.clear();
        self.bits = 0;
    }

    /// Append the low `width` bits of `value` (width ≤ 64).
    #[inline]
    pub fn push(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value < (1u64 << width), "value {value} overflows {width} bits");
        let mut remaining = width;
        let mut v = value;
        while remaining > 0 {
            let bit_in_byte = (self.bits % 8) as u32;
            if bit_in_byte == 0 {
                self.bytes.push(0);
            }
            let take = remaining.min(8 - bit_in_byte);
            let mask = if take == 64 { u64::MAX } else { (1u64 << take) - 1 };
            *self.bytes.last_mut().unwrap() |= ((v & mask) as u8) << bit_in_byte;
            v >>= take;
            self.bits += take as u64;
            remaining -= take;
        }
    }

    /// Append an f32 (32 bits, IEEE-754 little-endian bit order).
    #[inline]
    pub fn push_f32(&mut self, x: f32) {
        self.push(x.to_bits() as u64, 32);
    }
}

/// LSB-first bit reader.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    /// Read `width` bits (width ≤ 64). Panics past end of stream.
    #[inline]
    pub fn read(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        let mut out = 0u64;
        let mut got = 0u32;
        while got < width {
            let byte = self.bytes[(self.pos / 8) as usize];
            let bit_in_byte = (self.pos % 8) as u32;
            let take = (width - got).min(8 - bit_in_byte);
            let mask = ((1u16 << take) - 1) as u8;
            let chunk = (byte >> bit_in_byte) & mask;
            out |= (chunk as u64) << got;
            got += take;
            self.pos += take as u64;
        }
        out
    }

    #[inline]
    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read(32) as u32)
    }

    /// Bits consumed so far.
    pub fn position(&self) -> u64 {
        self.pos
    }
}

/// ceil(log2(n)) for n >= 1 — index field width for sparsifiers.
pub fn index_bits(n: usize) -> u32 {
    debug_assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push_f32(-1.5);
        w.push(0xDEADBEEF, 37);
        w.push(1, 1);
        w.push(u64::MAX, 64);
        let mut r = BitReader::new(&w.bytes);
        assert_eq!(r.read(3), 0b101);
        assert_eq!(r.read_f32(), -1.5);
        assert_eq!(r.read(37), 0xDEADBEEF);
        assert_eq!(r.read(1), 1);
        assert_eq!(r.read(64), u64::MAX);
        assert_eq!(r.position(), w.bits);
    }

    #[test]
    fn random_fields_roundtrip() {
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let n = 1 + rng.below(64);
            let fields: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let width = 1 + rng.below(64) as u32;
                    let value = if width == 64 { rng.next_u64() } else { rng.next_u64() & ((1u64 << width) - 1) };
                    (value, width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &fields {
                w.push(v, width);
            }
            let mut r = BitReader::new(&w.bytes);
            for &(v, width) in &fields {
                assert_eq!(r.read(width), v, "width={width}");
            }
        }
    }

    #[test]
    fn bit_count_exact() {
        let mut w = BitWriter::new();
        w.push(1, 1);
        w.push(2, 2);
        assert_eq!(w.bits, 3);
        assert_eq!(w.bytes.len(), 1);
        w.push(0, 6);
        assert_eq!(w.bits, 9);
        assert_eq!(w.bytes.len(), 2);
    }

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
        assert_eq!(index_bits(10_000), 14);
    }
}
