//! Top-k sparsification: keep the k largest-magnitude entries.
//!
//! Biased (violates Assumption 2), so LEAD's theory does not cover it — it
//! is included for the Appendix C.2 / Fig. 6 comparison, which shows that
//! per transmitted bit, ∞-norm quantization dominates top-k because top-k
//! pays ⌈log₂ d⌉ index bits per surviving value.

use super::wire::{index_bits, BitWriter};
use super::{CodecScratch, CompressedMsg, Compressor};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        TopK { k }
    }

    /// The single selection + wire-emission path behind both
    /// [`Compressor::compress`] and [`Compressor::compress_into`], so the
    /// two can never drift. Picks the k largest-|x_i| coordinates
    /// (`total_cmp` keeps the comparator total under NaN — NaN sorts
    /// largest, surfacing downstream rather than panicking), emits them in
    /// ascending-index wire order, and publishes the views:
    ///
    /// * `eager_dense = true` (compress): materialize `values` and the
    ///   canonical nonzero-only sparse list;
    /// * `eager_dense = false` (compress_into): defer the O(d) dense fill
    ///   (`dense_stale`) and record ALL selected entries — ±0.0 included —
    ///   so the lazy decode is bit-exact (see the `Compressor` docs).
    fn select_and_emit(&self, x: &[f64], out: &mut CompressedMsg, idx: &mut Vec<usize>, eager_dense: bool) {
        let d = x.len();
        if eager_dense {
            out.values.clear();
        }
        out.values.resize(d, 0.0); // lazy case: contents stale until ensure_dense
        out.dense_stale = false;
        let sp = out.sparse.get_or_insert_with(Vec::new);
        sp.clear();
        if d == 0 {
            // Empty input: nothing on the wire (the selection below would
            // underflow at d − 1).
            out.payload.clear();
            out.wire_bits = 0;
            return;
        }
        out.dense_stale = !eager_dense;
        let k = self.k.min(d);
        idx.clear();
        idx.extend(0..d);
        idx.select_nth_unstable_by(k - 1, |&a, &b| x[b].abs().total_cmp(&x[a].abs()));
        let sel = &mut idx[..k];
        sel.sort_unstable(); // canonical wire order

        let mut w = BitWriter::new();
        std::mem::swap(&mut w.bytes, &mut out.payload);
        w.clear();
        let ib = index_bits(d);
        for &i in sel.iter() {
            w.push(i as u64, ib);
            let wire = x[i] as f32; // f32 on the wire
            w.push_f32(wire);
            let v = wire as f64;
            if eager_dense {
                out.values[i] = v;
                if v != 0.0 {
                    sp.push((i as u32, v));
                }
            } else {
                sp.push((i as u32, v));
            }
        }
        out.wire_bits = w.bits;
        out.payload = w.bytes;
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top-{}", self.k)
    }

    fn compress(&self, x: &[f64], _rng: &mut Rng, out: &mut CompressedMsg) {
        let mut idx = Vec::new();
        self.select_and_emit(x, out, &mut idx, true);
    }

    /// Hot-path variant (§Perf): reuses `scratch.idx` for the partial
    /// selection (the eager path allocates it per call) and skips the
    /// O(d) dense fill — the sparse view carries **every** selected entry,
    /// ±0.0 values included, so [`CompressedMsg::ensure_dense`] rebuilds
    /// `values` bit-identically to the eager path on demand. Wire payload,
    /// wire bits, and the selected set are identical to [`TopK::compress`]
    /// by construction: both call the same [`TopK::select_and_emit`].
    fn compress_into(
        &self,
        x: &[f64],
        _rng: &mut Rng,
        out: &mut CompressedMsg,
        scratch: &mut CodecScratch,
    ) {
        self.select_and_emit(x, out, &mut scratch.idx, false);
    }

    fn is_unbiased(&self) -> bool {
        false
    }

    fn variance_constant(&self, _d: usize) -> Option<f64> {
        None
    }

    fn wire_format(&self) -> Option<crate::compress::WireFormat> {
        // Wire-complete: the payload is exactly k (index, f32) records in
        // ascending index order — see `select_and_emit`.
        Some(crate::compress::WireFormat::TopK { k: self.k })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::prop_assert;

    #[test]
    fn keeps_largest_magnitudes() {
        let t = TopK::new(2);
        let mut rng = Rng::new(1);
        let x = vec![0.1f64, -5.0, 0.3, 4.0, -0.2];
        let msg = t.compress_alloc(&x, &mut rng);
        assert_eq!(msg.values, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
        // 2 entries × (3 index bits + 32 value bits)
        assert_eq!(msg.wire_bits, 2 * (3 + 32));
    }

    #[test]
    fn empty_input_is_empty_message() {
        // Regression: `d − 1` underflowed in the selection when x was empty.
        let t = TopK::new(3);
        let mut rng = Rng::new(7);
        let msg = t.compress_alloc(&[], &mut rng);
        assert!(msg.values.is_empty());
        assert_eq!(msg.wire_bits, 0);
        assert!(msg.payload.is_empty());
        assert_eq!(msg.sparse.as_deref(), Some(&[][..]));
    }

    #[test]
    fn nan_entries_do_not_panic_and_rank_largest() {
        // Regression: partial_cmp(..).unwrap() panicked on NaN input.
        let t = TopK::new(1);
        let mut rng = Rng::new(8);
        let x = vec![1.0f64, f64::NAN, 2.0];
        let msg = t.compress_alloc(&x, &mut rng);
        // total_cmp ranks NaN above every finite magnitude.
        assert!(msg.values[1].is_nan());
        assert_eq!(msg.values[0], 0.0);
        assert_eq!(msg.values[2], 0.0);
        // Deterministic: a second compression gives the same selection.
        let msg2 = t.compress_alloc(&x, &mut rng);
        assert_eq!(msg.wire_bits, msg2.wire_bits);
        assert!(msg2.values[1].is_nan());
    }

    #[test]
    fn sparse_view_matches_dense_nonzeros() {
        let t = TopK::new(2);
        let mut rng = Rng::new(9);
        let x = vec![0.1f64, -5.0, 0.3, 4.0, -0.2];
        let msg = t.compress_alloc(&x, &mut rng);
        assert_eq!(msg.sparse, Some(vec![(1u32, -5.0), (3u32, 4.0)]));
        // Indices ascend and mirror the nonzeros of `values` exactly.
        let nz: Vec<(u32, f64)> = msg
            .values
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        assert_eq!(msg.sparse, Some(nz));
    }

    /// The scratch fast path must match the eager path exactly: same wire
    /// payload/bits, same selected set, and a lazily-rebuilt dense vector
    /// that is bit-identical — including ±0.0 selected entries, which is
    /// why `compress_into` records zero-valued selections explicitly.
    #[test]
    fn compress_into_matches_compress_bitwise() {
        use crate::compress::CodecScratch;
        forall(60, 0x70C1, |g| {
            let mut x = g.vec_f64(1..=300, 4.0);
            // Plant exact and negative zeros so the zero-valued-selection
            // path is exercised (k ≥ d selects them).
            if x.len() >= 3 {
                x[0] = 0.0;
                x[1] = -0.0;
            }
            let k = g.usize_in(1..=x.len());
            let t = TopK::new(k);
            let mut rng = Rng::new(g.case_seed);
            let eager = t.compress_alloc(&x, &mut rng);
            let mut scratch = CodecScratch::default();
            let mut lazy = crate::compress::CompressedMsg::default();
            // Two calls through the same scratch: reuse must not change
            // results.
            t.compress_into(&x, &mut rng, &mut lazy, &mut scratch);
            t.compress_into(&x, &mut rng, &mut lazy, &mut scratch);
            prop_assert!(lazy.payload == eager.payload, "wire payload drifted");
            prop_assert!(lazy.wire_bits == eager.wire_bits, "wire bits drifted");
            prop_assert!(x.is_empty() || lazy.dense_stale, "fast path should defer the dense fill");
            lazy.ensure_dense();
            prop_assert!(
                lazy.values.len() == eager.values.len()
                    && lazy.values.iter().zip(&eager.values).all(|(a, b)| a.to_bits() == b.to_bits()),
                "lazy dense decode != eager values"
            );
            // The fast-path sparse view is a superset of the canonical
            // nonzeros: all selected entries, zeros included.
            let sp = lazy.sparse.as_ref().unwrap();
            prop_assert!(sp.len() == k.min(x.len()), "must record every selected entry");
            prop_assert!(sp.windows(2).all(|w| w[0].0 < w[1].0), "ascending index order");
            for &(i, v) in sp {
                prop_assert!(
                    v.to_bits() == eager.values[i as usize].to_bits(),
                    "entry {i} mismatch"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn exact_when_k_geq_d() {
        let t = TopK::new(100);
        let mut rng = Rng::new(2);
        let x = vec![1.0f64, -2.0, 3.0];
        let msg = t.compress_alloc(&x, &mut rng);
        assert_eq!(msg.values, x);
    }

    #[test]
    fn error_never_worse_than_dropping_all() {
        forall(50, 0x70C0, |g| {
            let x = g.vec_f64(1..=300, 4.0);
            let k = g.usize_in(1..=x.len());
            let t = TopK::new(k);
            let mut rng = Rng::new(g.case_seed);
            let msg = t.compress_alloc(&x, &mut rng);
            let err = crate::linalg::dist_sq(&x, &msg.values);
            let total = crate::linalg::norm2_sq(&x);
            prop_assert!(err <= total + 1e-9, "err {err} > ‖x‖² {total}");
            // Contraction property of top-k: err ≤ (1 − k/d)‖x‖².
            let bound = (1.0 - k as f64 / x.len() as f64) * total;
            prop_assert!(err <= bound + 1e-6, "err {err} > (1−k/d)‖x‖² {bound}");
            Ok(())
        });
    }
}
