//! Fixed-shape chunked (4-lane) hot kernels — the crate's one SIMD layer.
//!
//! Profiling (ROADMAP "raw-speed pass", PR 2–5 phase breakdowns) shows the
//! zero-alloc round loop is memory/ALU-bound in exactly these BLAS-1
//! kernels plus the quantize bit-packing. This module rewrites the BLAS-1
//! loops in a fixed 4-lane chunk shape (`[f64; 4]`, one AVX2 register)
//! that the *stable* autovectorizer reliably turns into SIMD, and adds
//! optional hand-written AVX2 paths behind the off-by-default `simd`
//! cargo feature.
//!
//! # §Determinism — the fixed-reduction-shape contract
//!
//! Every trajectory claim in this repo is a bitwise differential pin, so
//! a kernel may change *speed* but never a single output bit across
//! builds, feature flags, thread counts, or CPUs. Two cases:
//!
//! **Elementwise kernels** ([`axpy`], [`scatter_axpy`], [`sub`],
//! [`scale`]) are bitwise-identical to the plain scalar loops *by
//! construction*: each output element is produced by exactly the same
//! IEEE-754 expression and there is no cross-element data flow, so
//! chunking (and any vectorization the compiler or the AVX2 path applies)
//! cannot change any bit. `scatter_axpy` additionally applies its entries
//! in list order, so even duplicate indices accumulate identically.
//!
//! **Reduction kernels** ([`dot`], [`norm2_sq`], [`dist_sq`]) DO fix an
//! accumulation order, and that order is part of this module's public
//! contract:
//!
//! ```text
//! element j's term accumulates into lane (j mod 4)
//! result = (lane0 + lane1) + (lane2 + lane3)
//! ```
//!
//! The tree shape is pinned IN SOURCE — it is never chosen by runtime CPU
//! detection, feature flags, or thread count. [`reference::dot_tree`] (and
//! friends) are scalar emulations of the same tree and serve as the
//! bitwise reference; the chunked portable code and the AVX2 path both
//! realize it with identical per-lane IEEE op sequences: one multiply,
//! one add, never FMA (a fused multiply-add rounds once instead of twice
//! and would fork trajectories between builds; rustc never contracts
//! float ops on its own, and the intrinsic paths use `_mm256_mul_pd` +
//! `_mm256_add_pd` explicitly). [`norm_inf`] is chunked too but needs no
//! shape contract: `f64::max` is exact (no rounding) and NaN-ignoring, so
//! every accumulation order yields the same bits on any input; it gets no
//! intrinsic path because `_mm256_max_pd` has *different* NaN semantics.
//!
//! The rule for future kernels: a float reordering is allowed only when
//! it is exact (elementwise work, max/min-reductions); anything that
//! changes a rounding sequence must change it for every build and arch at
//! once, in source, with the scalar tree emulation updated in lockstep.
//!
//! # The `simd` feature
//!
//! `--features simd` compiles `#[target_feature(enable = "avx2")]` x86_64
//! intrinsic paths and selects them at runtime via
//! `is_x86_feature_detected!`. Because both implementations compute the
//! identical pinned tree, detection is a pure performance knob — pinned by
//! the proptests below and by running the whole differential suite under
//! `--features simd` in CI. This module is the only place `core::arch`/
//! `std::arch` may appear (audit rule R6 `arch_intrinsics`).

/// Chunk width: 4 f64 lanes (one AVX2 register).
pub const LANES: usize = 4;

/// Largest multiple of [`LANES`] ≤ `len` (main-chunk/tail split point).
#[inline]
fn split4(len: usize) -> usize {
    len - len % LANES
}

/// Fold the tail elements into the fixed tree's lanes and reduce in the
/// pinned shape (see §Determinism): tail element `4m + t` lands in lane
/// `t` — i.e. lane `(4m + t) mod 4` — then `(l0 + l1) + (l2 + l3)`.
#[inline]
fn finish_tree(
    mut acc: [f64; LANES],
    ta: &[f64],
    tb: &[f64],
    term: impl Fn(f64, f64) -> f64,
) -> f64 {
    for (t, (x, y)) in ta.iter().zip(tb).enumerate() {
        acc[t] += term(*x, *y);
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// y += alpha * x (elementwise; bitwise-identical to the scalar loop).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2::usable() {
            // SAFETY: guarded by the runtime AVX2 detection above.
            unsafe { avx2::axpy(alpha, x, y) };
            return;
        }
    }
    let n = x.len().min(y.len());
    let m = split4(n);
    for (cx, cy) in x[..m].chunks_exact(LANES).zip(y[..m].chunks_exact_mut(LANES)) {
        for l in 0..LANES {
            cy[l] += alpha * cx[l];
        }
    }
    for (xi, yi) in x[m..n].iter().zip(&mut y[m..n]) {
        *yi += alpha * xi;
    }
}

/// Sparse counterpart of [`axpy`]: `y[i] += alpha * v` for each `(i, v)`
/// entry, applied in list order. When `entries` holds exactly the
/// nonzeros of a dense vector and `y` is accumulated from +0.0, the
/// result is bitwise-identical to the dense `axpy` over that vector (the
/// omitted terms are ±0.0 additions, which cannot change any partial sum
/// reachable from a +0.0 start under IEEE 754 round-to-nearest). This is
/// what lets the engine mix top-k / rand-k messages in O(deg·k) without
/// perturbing trajectories.
///
/// A scatter cannot vectorize (indexed stores), but the fixed 4-entry
/// chunks let the compiler interleave index loads with the FP ops; list
/// order is preserved, so duplicate indices accumulate identically to the
/// plain loop.
#[inline]
pub fn scatter_axpy(alpha: f64, entries: &[(u32, f64)], y: &mut [f64]) {
    let mut it = entries.chunks_exact(LANES);
    for c in &mut it {
        for &(i, v) in c {
            y[i as usize] += alpha * v;
        }
    }
    for &(i, v) in it.remainder() {
        y[i as usize] += alpha * v;
    }
}

/// out = a - b (elementwise; bitwise-identical to the scalar loop).
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2::usable() {
            // SAFETY: guarded by the runtime AVX2 detection above.
            unsafe { avx2::sub(a, b, out) };
            return;
        }
    }
    let n = a.len().min(b.len()).min(out.len());
    let m = split4(n);
    for ((ca, cb), co) in a[..m]
        .chunks_exact(LANES)
        .zip(b[..m].chunks_exact(LANES))
        .zip(out[..m].chunks_exact_mut(LANES))
    {
        for l in 0..LANES {
            co[l] = ca[l] - cb[l];
        }
    }
    for i in m..n {
        out[i] = a[i] - b[i];
    }
}

/// x *= alpha (elementwise; bitwise-identical to the scalar loop).
#[inline]
pub fn scale(x: &mut [f64], alpha: f64) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2::usable() {
            // SAFETY: guarded by the runtime AVX2 detection above.
            unsafe { avx2::scale(x, alpha) };
            return;
        }
    }
    let m = split4(x.len());
    for c in x[..m].chunks_exact_mut(LANES) {
        for l in 0..LANES {
            c[l] *= alpha;
        }
    }
    for v in &mut x[m..] {
        *v *= alpha;
    }
}

/// Dot product in the pinned 4-lane tree (see §Determinism; bitwise
/// reference: [`reference::dot_tree`]).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2::usable() {
            // SAFETY: guarded by the runtime AVX2 detection above.
            return unsafe { avx2::dot(a, b) };
        }
    }
    let n = a.len().min(b.len());
    let m = split4(n);
    let mut acc = [0.0f64; LANES];
    for (ca, cb) in a[..m].chunks_exact(LANES).zip(b[..m].chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += ca[l] * cb[l];
        }
    }
    finish_tree(acc, &a[m..n], &b[m..n], |x, y| x * y)
}

/// Squared L2 norm in the pinned 4-lane tree (= `dot(x, x)`).
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Squared distance ||a - b||² in the pinned 4-lane tree (per-element
/// term `(a[j] - b[j])²`; bitwise reference: [`reference::dist_sq_tree`]).
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx2::usable() {
            // SAFETY: guarded by the runtime AVX2 detection above.
            return unsafe { avx2::dist_sq(a, b) };
        }
    }
    let n = a.len().min(b.len());
    let m = split4(n);
    let mut acc = [0.0f64; LANES];
    for (ca, cb) in a[..m].chunks_exact(LANES).zip(b[..m].chunks_exact(LANES)) {
        for l in 0..LANES {
            let d = ca[l] - cb[l];
            acc[l] += d * d;
        }
    }
    finish_tree(acc, &a[m..n], &b[m..n], |x, y| {
        let d = x - y;
        d * d
    })
}

/// L-infinity norm, chunked. `f64::max` is exact and NaN-ignoring, so the
/// 4-lane accumulation is bitwise-identical to the sequential scalar loop
/// on every input (including NaN entries, which both simply skip). No
/// intrinsic path: `_mm256_max_pd` propagates NaN differently.
#[inline]
pub fn norm_inf(x: &[f64]) -> f64 {
    let m = split4(x.len());
    let mut acc = [0.0f64; LANES];
    for c in x[..m].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] = acc[l].max(c[l].abs());
        }
    }
    for (t, v) in x[m..].iter().enumerate() {
        acc[t] = acc[t].max(v.abs());
    }
    (acc[0].max(acc[1])).max(acc[2].max(acc[3]))
}

/// x86_64 AVX2 intrinsic paths (`--features simd` only). Every function
/// implements EXACTLY the portable kernel's elementwise expressions or
/// pinned reduction tree — lanewise `_mm256_mul_pd` + `_mm256_add_pd`,
/// never FMA — so the results are bitwise-identical and runtime dispatch
/// is a pure performance knob (see the module docs, §Determinism).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::{finish_tree, split4, LANES};
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_setzero_pd,
        _mm256_storeu_pd, _mm256_sub_pd,
    };

    /// Whether the AVX2 paths may be entered on this CPU. Dispatch only —
    /// both branches compute the identical pinned tree, so this runtime
    /// check can never affect a trajectory.
    #[inline]
    pub fn usable() -> bool {
        is_x86_feature_detected!("avx2")
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must ensure the CPU supports AVX2 (see `usable`).
    pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len().min(y.len());
        let m = split4(n);
        // SAFETY: every offset below is < m ≤ both slice lengths; loads
        // and stores are unaligned-tolerant (`loadu`/`storeu`).
        unsafe {
            let a = _mm256_set1_pd(alpha);
            let xp = x.as_ptr();
            let yp = y.as_mut_ptr();
            let mut i = 0;
            while i < m {
                let xv = _mm256_loadu_pd(xp.add(i));
                let yv = _mm256_loadu_pd(yp.add(i));
                _mm256_storeu_pd(yp.add(i), _mm256_add_pd(yv, _mm256_mul_pd(a, xv)));
                i += LANES;
            }
        }
        for i in m..n {
            y[i] += alpha * x[i];
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must ensure the CPU supports AVX2 (see `usable`).
    pub unsafe fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
        let n = a.len().min(b.len()).min(out.len());
        let m = split4(n);
        // SAFETY: every offset below is < m ≤ all three slice lengths.
        unsafe {
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let op = out.as_mut_ptr();
            let mut i = 0;
            while i < m {
                let av = _mm256_loadu_pd(ap.add(i));
                let bv = _mm256_loadu_pd(bp.add(i));
                _mm256_storeu_pd(op.add(i), _mm256_sub_pd(av, bv));
                i += LANES;
            }
        }
        for i in m..n {
            out[i] = a[i] - b[i];
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must ensure the CPU supports AVX2 (see `usable`).
    pub unsafe fn scale(x: &mut [f64], alpha: f64) {
        let m = split4(x.len());
        // SAFETY: every offset below is < m ≤ the slice length.
        unsafe {
            let a = _mm256_set1_pd(alpha);
            let xp = x.as_mut_ptr();
            let mut i = 0;
            while i < m {
                let xv = _mm256_loadu_pd(xp.add(i));
                _mm256_storeu_pd(xp.add(i), _mm256_mul_pd(xv, a));
                i += LANES;
            }
        }
        for v in &mut x[m..] {
            *v *= alpha;
        }
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must ensure the CPU supports AVX2 (see `usable`).
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let m = split4(n);
        let mut acc = [0.0f64; LANES];
        // SAFETY: every offset below is < m ≤ both slice lengths; the
        // accumulator store writes a full 4-lane array. Vector lane l
        // holds exactly the portable loop's acc[l] op sequence (lanewise
        // IEEE mul then add — no FMA).
        unsafe {
            let mut acc_v = _mm256_setzero_pd();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < m {
                let av = _mm256_loadu_pd(ap.add(i));
                let bv = _mm256_loadu_pd(bp.add(i));
                acc_v = _mm256_add_pd(acc_v, _mm256_mul_pd(av, bv));
                i += LANES;
            }
            _mm256_storeu_pd(acc.as_mut_ptr(), acc_v);
        }
        finish_tree(acc, &a[m..n], &b[m..n], |x, y| x * y)
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must ensure the CPU supports AVX2 (see `usable`).
    pub unsafe fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let m = split4(n);
        let mut acc = [0.0f64; LANES];
        // SAFETY: same bounds argument as `dot`; per-lane op sequence is
        // sub, mul, add — identical to the portable chunk body.
        unsafe {
            let mut acc_v = _mm256_setzero_pd();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < m {
                let av = _mm256_loadu_pd(ap.add(i));
                let bv = _mm256_loadu_pd(bp.add(i));
                let dv = _mm256_sub_pd(av, bv);
                acc_v = _mm256_add_pd(acc_v, _mm256_mul_pd(dv, dv));
                i += LANES;
            }
            _mm256_storeu_pd(acc.as_mut_ptr(), acc_v);
        }
        finish_tree(acc, &a[m..n], &b[m..n], |x, y| {
            let d = x - y;
            d * d
        })
    }
}

/// Bitwise reference implementations, public so tests and
/// `benches/hotpath.rs` can pin/compare against them:
///
/// * the pre-SIMD plain scalar loops for the elementwise kernels and
///   `norm_inf` (chunking must reproduce them exactly);
/// * `*_tree` — scalar emulations of the pinned 4-lane reduction tree
///   (THE bitwise reference for `dot`/`norm2_sq`/`dist_sq`);
/// * `*_seq` — the pre-PR sequential reductions, kept as the "old" arm
///   of the kernel microbenches (numerically different shape; never used
///   by library code).
pub mod reference {
    use super::LANES;

    /// Plain scalar `y += alpha * x` (the pre-SIMD loop).
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// Plain scalar scatter-add (the pre-SIMD loop).
    pub fn scatter_axpy(alpha: f64, entries: &[(u32, f64)], y: &mut [f64]) {
        for &(i, v) in entries {
            y[i as usize] += alpha * v;
        }
    }

    /// Plain scalar `out = a - b` (the pre-SIMD loop).
    pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
        for i in 0..a.len().min(b.len()).min(out.len()) {
            out[i] = a[i] - b[i];
        }
    }

    /// Plain scalar `x *= alpha` (the pre-SIMD loop).
    pub fn scale(x: &mut [f64], alpha: f64) {
        for v in x.iter_mut() {
            *v *= alpha;
        }
    }

    /// Plain sequential max-abs (the pre-SIMD loop).
    pub fn norm_inf(x: &[f64]) -> f64 {
        let mut m = 0.0f64;
        for v in x {
            m = m.max(v.abs());
        }
        m
    }

    /// Scalar emulation of the pinned 4-lane tree for `dot` — the
    /// bitwise reference: element j accumulates into lane `j mod 4`,
    /// reduced as `(l0 + l1) + (l2 + l3)`.
    pub fn dot_tree(a: &[f64], b: &[f64]) -> f64 {
        let mut acc = [0.0f64; LANES];
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            acc[j % LANES] += x * y;
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// Scalar emulation of the pinned tree for `norm2_sq`.
    pub fn norm2_sq_tree(x: &[f64]) -> f64 {
        dot_tree(x, x)
    }

    /// Scalar emulation of the pinned tree for `dist_sq`.
    pub fn dist_sq_tree(a: &[f64], b: &[f64]) -> f64 {
        let mut acc = [0.0f64; LANES];
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            let d = x - y;
            acc[j % LANES] += d * d;
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3])
    }

    /// Pre-PR sequential dot (bench "old" arm only — different shape).
    pub fn dot_seq(a: &[f64], b: &[f64]) -> f64 {
        let mut s = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            s += x * y;
        }
        s
    }

    /// Pre-PR sequential squared norm (bench "old" arm only).
    pub fn norm2_sq_seq(x: &[f64]) -> f64 {
        dot_seq(x, x)
    }

    /// Pre-PR sequential squared distance (bench "old" arm only).
    pub fn dist_sq_seq(a: &[f64], b: &[f64]) -> f64 {
        let mut s = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            let d = x - y;
            s += d * d;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::forall;
    use crate::prop_assert;
    use crate::rng::Rng;

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Random NaN-free vector with signed zeros sprinkled in.
    fn vec_with_zeros(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut v = vec![0.0f64; n];
        rng.fill_normal(&mut v, 3.0);
        for (j, x) in v.iter_mut().enumerate() {
            if j % 7 == 3 {
                *x = if j % 2 == 0 { 0.0 } else { -0.0 };
            }
        }
        v
    }

    /// Every length 0..=64 (all chunk/tail splits) plus signed zeros:
    /// chunked elementwise kernels == old scalar loops, bit for bit.
    #[test]
    fn elementwise_bitwise_equals_scalar_loops() {
        let mut rng = Rng::new(0x51AD);
        for n in 0..=64usize {
            let x = vec_with_zeros(&mut rng, n);
            let y0 = vec_with_zeros(&mut rng, n);
            for alpha in [0.37, -1.5, 0.0, -0.0] {
                let mut ya = y0.clone();
                let mut yb = y0.clone();
                axpy(alpha, &x, &mut ya);
                reference::axpy(alpha, &x, &mut yb);
                assert_eq!(bits(&ya), bits(&yb), "axpy n={n} alpha={alpha}");

                let mut sa = x.clone();
                let mut sb = x.clone();
                scale(&mut sa, alpha);
                reference::scale(&mut sb, alpha);
                assert_eq!(bits(&sa), bits(&sb), "scale n={n} alpha={alpha}");
            }
            let mut oa = vec![0.0f64; n];
            let mut ob = vec![0.0f64; n];
            sub(&x, &y0, &mut oa);
            reference::sub(&x, &y0, &mut ob);
            assert_eq!(bits(&oa), bits(&ob), "sub n={n}");
        }
    }

    /// Chunked scatter (4-unrolled, list order) == plain loop, including
    /// duplicate indices, every entry count 0..=64.
    #[test]
    fn scatter_axpy_bitwise_equals_scalar_loop() {
        let mut rng = Rng::new(0x5CA7);
        let d = 40usize;
        for k in 0..=64usize {
            let entries: Vec<(u32, f64)> = (0..k)
                .map(|_| {
                    let i = rng.below(d) as u32; // duplicates likely for k > d
                    let v = if rng.below(9) == 0 { -0.0 } else { rng.normal_f64() };
                    (i, v)
                })
                .collect();
            let mut ya = vec![0.0f64; d];
            let mut yb = vec![0.0f64; d];
            for alpha in [1.0, -0.5] {
                scatter_axpy(alpha, &entries, &mut ya);
                reference::scatter_axpy(alpha, &entries, &mut yb);
            }
            assert_eq!(bits(&ya), bits(&yb), "scatter_axpy k={k}");
        }
    }

    /// Every length 0..=64: the chunked (and, under `--features simd`,
    /// AVX2) reductions == the scalar emulation of the pinned tree, bit
    /// for bit; norm_inf == the old sequential loop.
    #[test]
    fn reductions_bitwise_equal_scalar_tree_emulation() {
        let mut rng = Rng::new(0x7EE5);
        for n in 0..=64usize {
            let a = vec_with_zeros(&mut rng, n);
            let b = vec_with_zeros(&mut rng, n);
            assert_eq!(dot(&a, &b).to_bits(), reference::dot_tree(&a, &b).to_bits(), "dot n={n}");
            assert_eq!(
                norm2_sq(&a).to_bits(),
                reference::norm2_sq_tree(&a).to_bits(),
                "norm2_sq n={n}"
            );
            assert_eq!(
                dist_sq(&a, &b).to_bits(),
                reference::dist_sq_tree(&a, &b).to_bits(),
                "dist_sq n={n}"
            );
            assert_eq!(
                norm_inf(&a).to_bits(),
                reference::norm_inf(&a).to_bits(),
                "norm_inf n={n}"
            );
        }
    }

    /// The tree SHAPE itself, pinned against the documented formula on a
    /// length with a tail (n = 7: lanes get {0,4}, {1,5}, {2,6}, {3}).
    #[test]
    fn reduction_tree_shape_is_the_documented_one() {
        let x = [1e16, 1.0, 2.0, 3.0, 5.0, -1e16, 7.0];
        let y = [2.0, 3.0, -1.0, 0.5, 4.0, 1.0, 0.25];
        let want = (((x[0] * y[0] + x[4] * y[4]) + (x[1] * y[1] + x[5] * y[5]))
            + ((x[2] * y[2] + x[6] * y[6]) + x[3] * y[3]))
            .to_bits();
        assert_eq!(dot(&x, &y).to_bits(), want);
        assert_eq!(reference::dot_tree(&x, &y).to_bits(), want);
    }

    /// Property sweep over random lengths (tails included): kernels match
    /// their bitwise references on NaN-free ±0.0-bearing inputs.
    #[test]
    fn kernels_match_references_prop() {
        forall(80, 0x51D5, |g| {
            let x = g.vec_f64(0..=257, 7.0);
            let n = x.len();
            let mut y = g.vec_f64(n..=n, 7.0);
            let alpha = g.f64_in(-2.0, 2.0);

            let mut ya = y.clone();
            axpy(alpha, &x, &mut ya);
            reference::axpy(alpha, &x, &mut y);
            prop_assert!(bits(&ya) == bits(&y), "axpy diverged at n={n}");

            prop_assert!(
                dot(&x, &ya).to_bits() == reference::dot_tree(&x, &ya).to_bits(),
                "dot diverged at n={n}"
            );
            prop_assert!(
                dist_sq(&x, &ya).to_bits() == reference::dist_sq_tree(&x, &ya).to_bits(),
                "dist_sq diverged at n={n}"
            );
            prop_assert!(
                norm2_sq(&x).to_bits() == reference::norm2_sq_tree(&x).to_bits(),
                "norm2_sq diverged at n={n}"
            );
            prop_assert!(
                norm_inf(&x).to_bits() == reference::norm_inf(&x).to_bits(),
                "norm_inf diverged at n={n}"
            );
            Ok(())
        });
    }

    /// Degenerate lengths run (and agree) without panicking.
    #[test]
    fn empty_inputs() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm2_sq(&[]), 0.0);
        assert_eq!(dist_sq(&[], &[]), 0.0);
        assert_eq!(norm_inf(&[]), 0.0);
        let mut y: Vec<f64> = vec![];
        axpy(1.0, &[], &mut y);
        scale(&mut y, 2.0);
        scatter_axpy(1.0, &[], &mut y);
        assert!(y.is_empty());
    }
}
