//! Minimal dense linear algebra over `f64` slices.
//!
//! Everything the reproduction needs and nothing more: BLAS-1 vector ops on
//! the hot path, small dense matrix routines for problem setup (Gram
//! matrices, Cholesky solve for the closed-form linear-regression optimum),
//! and symmetric eigensolvers for the mixing-matrix spectral constants
//! β = λmax(I−W) and κ_g = λmax(I−W)/λmin⁺(I−W) used throughout the
//! paper's theory.
//!
//! The hot BLAS-1 kernels live in [`simd`] as fixed-shape 4-lane chunked
//! loops (optionally AVX2 behind `--features simd`) and are re-exported
//! here unchanged — callers keep writing `linalg::axpy`. Read
//! `simd`'s §Determinism docs before touching any of them: the reduction
//! kernels pin an accumulation-tree shape in source, and a kernel may only
//! reorder float ops when the reordering is IEEE-exact or the pinned shape
//! (and its scalar emulation in [`simd::reference`]) changes for every
//! build and arch at once.
//!
//! Matrices are row-major `Vec<f64>` with explicit dimensions; at the sizes
//! we need (n ≤ 64 agents, d ≤ a few hundred for setup-time solves) cache
//! blocking is irrelevant and clarity wins.

pub mod simd;

pub use simd::{axpy, dist_sq, dot, norm2_sq, norm_inf, scale, scatter_axpy, sub};

/// L2 norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    norm2_sq(x).sqrt()
}

/// p-norm for finite p >= 1 (f64 accumulator).
pub fn norm_p(x: &[f64], p: f64) -> f64 {
    debug_assert!(p >= 1.0);
    let mut s = 0.0f64;
    for v in x {
        s += v.abs().powf(p);
    }
    s.powf(1.0 / p)
}

/// Mean of rows: `rows` yields equal-length vectors; `out` = average.
///
/// Generic over any exact-size iterator of slices so callers can feed
/// contiguous [`Mat`] rows ([`Mat::rows_iter`]) or borrowed per-agent
/// state views without materializing a `Vec<Vec<f64>>`. An empty
/// iterator fills `out` with NaN (0/0), matching the historical
/// behavior.
pub fn mean_rows<'a, I>(rows: I, out: &mut [f64])
where
    I: ExactSizeIterator<Item = &'a [f64]>,
{
    let n = rows.len();
    out.fill(0.0);
    for x in rows {
        axpy(1.0, x, out);
    }
    scale(out, 1.0 / n as f64);
}

// ---------------------------------------------------------------------------
// Dense matrices (f64, setup path)
// ---------------------------------------------------------------------------

/// Row-major dense f64 matrix.
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Iterate the rows as contiguous slices (exact-size, so it plugs
    /// straight into [`mean_rows`]).
    #[inline]
    pub fn rows_iter(&self) -> std::slice::ChunksExact<'_, f64> {
        self.data.chunks_exact(self.cols.max(1))
    }

    /// Pack equal-length row vectors into a contiguous row-major matrix
    /// (the algorithms' per-agent state layout: one row per agent).
    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "from_rows: ragged input");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// out = self * x (gemv).
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            let r = self.row(i);
            let mut s = 0.0;
            for j in 0..self.cols {
                s += r[j] * x[j];
            }
            out[i] = s;
        }
    }

    /// C = A * B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for j in 0..b.cols {
                    crow[j] += aik * brow[j];
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Maximum absolute asymmetry |A - A^T|_inf — used by topology checks.
    pub fn asymmetry(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        let mut m = 0.0f64;
        for i in 0..self.rows {
            for j in 0..i {
                m = m.max((self[(i, j)] - self[(j, i)]).abs());
            }
        }
        m
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

// ---------------------------------------------------------------------------
// Cholesky solve (SPD systems; linreg closed-form optimum)
// ---------------------------------------------------------------------------

/// Cholesky factorization A = L L^T of a symmetric positive-definite matrix.
/// Returns the lower factor, or None if A is not (numerically) SPD.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve A x = b for SPD A via Cholesky. Panics if A is not SPD.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Vec<f64> {
    let n = a.rows;
    assert_eq!(b.len(), n);
    let l = cholesky(a).expect("solve_spd: matrix not SPD");
    // Forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    // Backward: L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

// ---------------------------------------------------------------------------
// Symmetric eigensolver (Jacobi) — mixing-matrix spectra
// ---------------------------------------------------------------------------

/// All eigenvalues of a symmetric matrix via the cyclic Jacobi method,
/// returned in ascending order. O(n^3) per sweep; fine for n ≤ a few hundred
/// (we use it on n×n mixing matrices with n = #agents).
pub fn eigvals_sym(a: &Mat) -> Vec<f64> {
    assert_eq!(a.rows, a.cols, "eigvals_sym: square matrix required");
    assert!(a.asymmetry() < 1e-9, "eigvals_sym: matrix not symmetric");
    let n = a.rows;
    let mut m = a.clone();
    for _sweep in 0..100 {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in 0..i {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                // tan of rotation angle (stable formula).
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply Givens rotation J(p,q) on both sides.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
            }
        }
    }
    let mut ev: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    ev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ev
}

/// Largest eigenvalue of a symmetric PSD matrix by power iteration.
/// Cheaper than Jacobi when only λmax is needed.
pub fn lambda_max_sym(a: &Mat, iters: usize) -> f64 {
    let n = a.rows;
    let mut v = vec![1.0 / (n as f64).sqrt(); n];
    let mut av = vec![0.0; n];
    let mut lambda = 0.0;
    for _ in 0..iters {
        a.matvec(&v, &mut av);
        let norm = av.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm < 1e-300 {
            return 0.0;
        }
        for i in 0..n {
            v[i] = av[i] / norm;
        }
        lambda = norm;
    }
    // One Rayleigh quotient for sign/accuracy.
    a.matvec(&v, &mut av);
    let rq: f64 = v.iter().zip(&av).map(|(x, y)| x * y).sum();
    let _ = lambda;
    rq
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blas1_basics() {
        let mut y = vec![1.0f64, 2.0, 3.0];
        axpy(2.0, &[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(norm_inf(&[-7.0, 3.0]), 7.0);
        assert!((norm_p(&[3.0, 4.0], 2.0) - 5.0).abs() < 1e-9);
        // p -> inf approaches the inf-norm; p=1 is the sum.
        assert!((norm_p(&[1.0, -2.0, 3.0], 1.0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn scatter_axpy_matches_dense() {
        let dense = vec![0.0f64, -2.5, 0.0, 4.0, 0.0, 1.25];
        let entries: Vec<(u32, f64)> = dense
            .iter()
            .enumerate()
            .filter(|(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        let mut y_dense = vec![0.0f64; 6];
        let mut y_sparse = vec![0.0f64; 6];
        for w in [1.0 / 3.0, -0.7, 0.123456789] {
            axpy(w, &dense, &mut y_dense);
            scatter_axpy(w, &entries, &mut y_sparse);
        }
        for (a, b) in y_dense.iter().zip(&y_sparse) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn mean_rows_over_mat_rows_matches_vecs() {
        let rows = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0], vec![-2.0, 0.5, 7.0]];
        let m = Mat::from_rows(&rows);
        let mut from_mat = vec![0.0; 3];
        let mut from_vecs = vec![0.0; 3];
        mean_rows(m.rows_iter(), &mut from_mat);
        mean_rows(rows.iter().map(Vec::as_slice), &mut from_vecs);
        for (a, b) in from_mat.iter().zip(&from_vecs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!((from_mat[0] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn mat_rows_roundtrip() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut m = Mat::from_rows(&rows);
        assert_eq!((m.rows, m.cols), (3, 2));
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.rows_iter().count(), 3);
        assert_eq!(m.rows_iter().nth(2).unwrap(), &[5.0, 6.0]);
        m.row_mut(2)[0] = 9.0;
        assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0, 9.0, 6.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                a[(i, j)] = (i * 3 + j) as f64;
            }
        }
        let i3 = Mat::eye(3);
        let c = a.matmul(&i3);
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn cholesky_solve_roundtrip() {
        // A = B^T B + I is SPD.
        let mut b = Mat::zeros(4, 4);
        let mut seed = 1u64;
        for v in b.data.iter_mut() {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            *v = ((seed >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
        }
        let mut a = b.transpose().matmul(&b);
        for i in 0..4 {
            a[(i, i)] += 1.0;
        }
        let x_true = vec![1.0, -2.0, 3.0, 0.5];
        let mut rhs = vec![0.0; 4];
        a.matvec(&x_true, &mut rhs);
        let x = solve_spd(&a, &rhs);
        for i in 0..4 {
            assert!((x[i] - x_true[i]).abs() < 1e-10, "x={x:?}");
        }
    }

    #[test]
    fn jacobi_known_eigs() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let mut a = Mat::zeros(2, 2);
        a[(0, 0)] = 2.0;
        a[(0, 1)] = 1.0;
        a[(1, 0)] = 1.0;
        a[(1, 1)] = 2.0;
        let ev = eigvals_sym(&a);
        assert!((ev[0] - 1.0).abs() < 1e-10 && (ev[1] - 3.0).abs() < 1e-10, "{ev:?}");
    }

    #[test]
    fn jacobi_vs_trace_det() {
        // Random symmetric 6x6: eigenvalue sum == trace, within tolerance.
        let n = 6;
        let mut a = Mat::zeros(n, n);
        let mut seed = 99u64;
        for i in 0..n {
            for j in 0..=i {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let v = ((seed >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let trace: f64 = (0..n).map(|i| a[(i, i)]).sum();
        let ev = eigvals_sym(&a);
        let sum: f64 = ev.iter().sum();
        assert!((sum - trace).abs() < 1e-9, "sum={sum} trace={trace}");
    }

    #[test]
    fn power_iteration_matches_jacobi() {
        let n = 5;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = 1.0 / (1.0 + (i as f64 - j as f64).abs());
            }
        }
        let ev = eigvals_sym(&a);
        let lmax = lambda_max_sym(&a, 500);
        assert!((lmax - ev[n - 1]).abs() < 1e-6, "power={lmax} jacobi={:?}", ev);
    }
}
