//! Communication topologies and gossip mixing matrices (paper Assumption 1).
//!
//! A topology is an undirected connected graph over `n` agents; the mixing
//! matrix `W` is symmetric, doubly stochastic, and primitive, with
//! `w_ij = 0` whenever agents i and j are not connected. The paper's
//! experiments use a ring of 8 agents with uniform weight 1/3; the theory
//! depends on two spectral constants exposed by [`MixingMatrix`]:
//! `β = λmax(I − W)` and the graph condition number
//! `κ_g = λmax(I − W) / λmin⁺(I − W)`.

pub mod spectral;

use crate::linalg::Mat;
use crate::rng::Rng;

/// Graph families used in the paper and in our ablations.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Cycle over n agents (the paper's experimental setup; each agent has
    /// exactly two 1-hop neighbors).
    Ring,
    /// Complete graph — recovers centralized averaging, κ_g = 1.
    FullyConnected,
    /// Star: agent 0 connected to everyone else.
    Star,
    /// Path (line) graph — worst-case κ_g among the deterministic families.
    Path,
    /// √n × √n torus grid (n must be a perfect square).
    Grid2D,
    /// Erdős–Rényi G(n, p), resampled until connected.
    ErdosRenyi { p: f64, seed: u64 },
}

/// How to derive edge weights from the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixingRule {
    /// w_ij = 1/(deg_max + 1) for every edge, self-weight = remainder.
    /// On the 8-ring this gives exactly the paper's uniform weight 1/3.
    UniformNeighbors,
    /// Metropolis–Hastings: w_ij = 1/(1 + max(deg_i, deg_j)), self-weight =
    /// remainder. Symmetric and doubly stochastic for any graph.
    MetropolisHastings,
    /// Lazy Metropolis: (I + W_mh)/2 — guarantees λmin(W) > 0.
    LazyMetropolis,
}

/// A validated mixing matrix plus adjacency structure.
#[derive(Clone, Debug)]
pub struct MixingMatrix {
    pub n: usize,
    /// Dense row-major weights; w\[i\]\[j\] = 0 iff no edge (and i != j).
    pub w: Mat,
    /// Neighbor lists excluding self (communication partners).
    pub neighbors: Vec<Vec<usize>>,
    /// Cached spectral constants (computed on build).
    pub eigenvalues: Vec<f64>,
}

impl Topology {
    /// Build the mixing matrix for `n` agents.
    ///
    /// Panics if the parameters are invalid (e.g. Grid2D with non-square n)
    /// — topology construction happens at setup time where loud failure is
    /// correct.
    pub fn build(&self, n: usize, rule: MixingRule) -> MixingMatrix {
        assert!(n >= 2, "need at least two agents");
        let adj = self.adjacency(n);
        MixingMatrix::from_adjacency(&adj, rule)
    }

    /// Adjacency sets (undirected, no self-loops).
    pub fn adjacency(&self, n: usize) -> Vec<Vec<usize>> {
        let mut adj = vec![Vec::new(); n];
        let connect = |a: usize, b: usize, adj: &mut Vec<Vec<usize>>| {
            if a != b && !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        };
        match self {
            Topology::Ring => {
                for i in 0..n {
                    connect(i, (i + 1) % n, &mut adj);
                }
            }
            Topology::FullyConnected => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        connect(i, j, &mut adj);
                    }
                }
            }
            Topology::Star => {
                for i in 1..n {
                    connect(0, i, &mut adj);
                }
            }
            Topology::Path => {
                for i in 0..n - 1 {
                    connect(i, i + 1, &mut adj);
                }
            }
            Topology::Grid2D => {
                let side = (n as f64).sqrt().round() as usize;
                assert_eq!(side * side, n, "Grid2D requires a perfect square number of agents");
                for r in 0..side {
                    for c in 0..side {
                        let id = r * side + c;
                        connect(id, r * side + (c + 1) % side, &mut adj);
                        connect(id, ((r + 1) % side) * side + c, &mut adj);
                    }
                }
            }
            Topology::ErdosRenyi { p, seed } => {
                assert!((0.0..=1.0).contains(p), "ER probability out of range");
                let mut rng = Rng::new(*seed).derive(crate::rng::streams::TOPOLOGY);
                for attempt in 0..1000 {
                    for a in adj.iter_mut() {
                        a.clear();
                    }
                    for i in 0..n {
                        for j in (i + 1)..n {
                            if rng.uniform() < *p {
                                connect(i, j, &mut adj);
                            }
                        }
                    }
                    if is_connected(&adj) {
                        break;
                    }
                    assert!(attempt < 999, "could not sample a connected G(n,p); raise p");
                }
            }
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        assert!(is_connected(&adj), "topology must be connected (Assumption 1)");
        adj
    }

    /// Parse from a CLI/config string, e.g. "ring", "full", "er:0.3", or
    /// "er:0.3:7" (explicit graph seed; otherwise `seed` is used).
    ///
    /// Invalid Erdős–Rényi probabilities are rejected *here* rather than
    /// panicking later in [`Topology::build`]: `p` must be a finite
    /// number in (0, 1] (p = 0 can never be connected; p > 1 or NaN is a
    /// config typo).
    pub fn parse(s: &str, seed: u64) -> Option<Topology> {
        match s {
            "ring" => Some(Topology::Ring),
            "full" | "complete" => Some(Topology::FullyConnected),
            "star" => Some(Topology::Star),
            "path" | "line" => Some(Topology::Path),
            "grid" => Some(Topology::Grid2D),
            _ => {
                let rest = s.strip_prefix("er:")?;
                let (p_str, seed) = match rest.split_once(':') {
                    Some((p, s)) => (p, s.parse::<u64>().ok()?),
                    None => (rest, seed),
                };
                let p = p_str.parse::<f64>().ok()?;
                if !p.is_finite() || p <= 0.0 || p > 1.0 {
                    return None;
                }
                Some(Topology::ErdosRenyi { p, seed })
            }
        }
    }
}

impl MixingRule {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<MixingRule> {
        match s {
            "uniform" | "uniform-neighbors" => Some(MixingRule::UniformNeighbors),
            "metropolis" | "mh" | "metropolis-hastings" => Some(MixingRule::MetropolisHastings),
            "lazy" | "lazy-metropolis" => Some(MixingRule::LazyMetropolis),
            _ => None,
        }
    }
}

/// BFS connectivity check.
pub fn is_connected(adj: &[Vec<usize>]) -> bool {
    let n = adj.len();
    if n == 0 {
        return false;
    }
    let mut seen = vec![false; n];
    let mut queue = vec![0usize];
    seen[0] = true;
    while let Some(u) = queue.pop() {
        for &v in &adj[u] {
            if !seen[v] {
                seen[v] = true;
                queue.push(v);
            }
        }
    }
    seen.iter().all(|&s| s)
}

impl MixingMatrix {
    /// Build and validate W from adjacency sets.
    pub fn from_adjacency(adj: &[Vec<usize>], rule: MixingRule) -> MixingMatrix {
        let n = adj.len();
        let deg: Vec<usize> = adj.iter().map(|a| a.len()).collect();
        let mut w = Mat::zeros(n, n);
        match rule {
            MixingRule::UniformNeighbors => {
                let dmax = *deg.iter().max().unwrap();
                let wij = 1.0 / (dmax as f64 + 1.0);
                for i in 0..n {
                    for &j in &adj[i] {
                        w[(i, j)] = wij;
                    }
                    w[(i, i)] = 1.0 - deg[i] as f64 * wij;
                }
            }
            MixingRule::MetropolisHastings | MixingRule::LazyMetropolis => {
                for i in 0..n {
                    let mut row_sum = 0.0;
                    for &j in &adj[i] {
                        let wij = 1.0 / (1.0 + deg[i].max(deg[j]) as f64);
                        w[(i, j)] = wij;
                        row_sum += wij;
                    }
                    w[(i, i)] = 1.0 - row_sum;
                }
                if rule == MixingRule::LazyMetropolis {
                    for i in 0..n {
                        for j in 0..n {
                            w[(i, j)] *= 0.5;
                        }
                        w[(i, i)] += 0.5;
                    }
                }
            }
        }
        let m = MixingMatrix {
            n,
            eigenvalues: crate::linalg::eigvals_sym(&w),
            neighbors: adj.to_vec(),
            w,
        };
        m.validate();
        m
    }

    /// Build directly from an explicit weight matrix (tests, custom W).
    pub fn from_weights(w: Mat) -> MixingMatrix {
        let n = w.rows;
        let mut neighbors = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i != j && w[(i, j)] != 0.0 {
                    neighbors[i].push(j);
                }
            }
        }
        let m = MixingMatrix { n, eigenvalues: crate::linalg::eigvals_sym(&w), neighbors, w };
        m.validate();
        m
    }

    /// Assert Assumption 1: symmetric, doubly stochastic, eigenvalues in
    /// (-1, 1] with λ1 = 1 simple (primitive on a connected graph).
    pub fn validate(&self) {
        let n = self.n;
        assert!(self.w.asymmetry() < 1e-9, "W not symmetric");
        for i in 0..n {
            let row: f64 = (0..n).map(|j| self.w[(i, j)]).sum();
            assert!((row - 1.0).abs() < 1e-9, "row {i} sums to {row}");
            for j in 0..n {
                assert!(self.w[(i, j)] > -1e-12, "negative weight at ({i},{j})");
            }
        }
        let ev = &self.eigenvalues;
        assert!((ev[n - 1] - 1.0).abs() < 1e-8, "λ1 != 1: {ev:?}");
        assert!(ev[0] > -1.0 + 1e-9, "λn <= -1: {ev:?}");
        assert!(
            ev[n - 2] < 1.0 - 1e-9,
            "λ2 == 1 (disconnected or non-primitive): {ev:?}"
        );
    }

    /// β = λmax(I − W) = 1 − λn(W) (used by Theorem 1 parameter ranges).
    pub fn beta(&self) -> f64 {
        1.0 - self.eigenvalues[0]
    }

    /// λmin⁺(I − W) = 1 − λ2(W), the smallest nonzero eigenvalue of I − W.
    pub fn lambda_min_plus(&self) -> f64 {
        1.0 - self.eigenvalues[self.n - 2]
    }

    /// Graph condition number κ_g = λmax(I−W)/λmin⁺(I−W) (Corollary 1).
    pub fn kappa_g(&self) -> f64 {
        self.beta() / self.lambda_min_plus()
    }

    /// Spectral gap 1 − max(|λ2|, |λn|) — classic gossip mixing rate.
    pub fn spectral_gap(&self) -> f64 {
        1.0 - self.eigenvalues[self.n - 2]
            .abs()
            .max(self.eigenvalues[0].abs())
    }

    /// Self weight w_ii.
    #[inline]
    pub fn self_weight(&self, i: usize) -> f64 {
        self.w[(i, i)]
    }

    /// Edge weight w_ij.
    #[inline]
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        self.w[(i, j)]
    }

    /// Number of directed messages per gossip round (each agent sends its
    /// payload to every neighbor).
    pub fn directed_edges(&self) -> usize {
        self.neighbors.iter().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring8_matches_paper() {
        // Paper §5: 8 machines in a ring, mixing weight exactly 1/3.
        let m = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        for i in 0..8 {
            assert!((m.w[(i, i)] - 1.0 / 3.0).abs() < 1e-12);
            assert!((m.w[(i, (i + 1) % 8)] - 1.0 / 3.0).abs() < 1e-12);
            assert_eq!(m.neighbors[i].len(), 2);
        }
        // Ring eigenvalues: 1/3 + 2/3 cos(2πk/8).
        for (k, want) in (0..8)
            .map(|k| 1.0 / 3.0 + 2.0 / 3.0 * (2.0 * std::f64::consts::PI * k as f64 / 8.0).cos())
            .enumerate()
        {
            assert!(
                m.eigenvalues.iter().any(|e| (e - want).abs() < 1e-9),
                "missing eigenvalue {want} (k={k}): {:?}",
                m.eigenvalues
            );
        }
        let beta_want = 1.0 - (1.0 / 3.0 + 2.0 / 3.0 * (std::f64::consts::PI).cos());
        assert!((m.beta() - beta_want).abs() < 1e-9);
    }

    #[test]
    fn fully_connected_kappa_is_one() {
        let m = Topology::FullyConnected.build(8, MixingRule::UniformNeighbors);
        assert!((m.kappa_g() - 1.0).abs() < 1e-8, "κ_g = {}", m.kappa_g());
        // W = (1/n) 11^T exactly for uniform weights on K_n.
        for i in 0..8 {
            for j in 0..8 {
                assert!((m.w[(i, j)] - 0.125).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn metropolis_star_valid() {
        let m = Topology::Star.build(9, MixingRule::MetropolisHastings);
        m.validate();
        assert_eq!(m.neighbors[0].len(), 8);
        assert_eq!(m.neighbors[3], vec![0]);
    }

    #[test]
    fn lazy_metropolis_positive_spectrum() {
        let m = Topology::Path.build(10, MixingRule::LazyMetropolis);
        assert!(m.eigenvalues[0] > 0.0, "{:?}", m.eigenvalues);
    }

    #[test]
    fn grid_requires_square() {
        let m = Topology::Grid2D.build(9, MixingRule::MetropolisHastings);
        assert_eq!(m.n, 9);
        for i in 0..9 {
            assert!(m.neighbors[i].len() >= 2);
        }
    }

    #[test]
    #[should_panic]
    fn grid_non_square_panics() {
        let _ = Topology::Grid2D.build(8, MixingRule::MetropolisHastings);
    }

    #[test]
    fn erdos_renyi_connected() {
        for seed in 0..5 {
            let m = Topology::ErdosRenyi { p: 0.3, seed }.build(16, MixingRule::MetropolisHastings);
            m.validate();
            assert!(is_connected(&m.neighbors));
        }
    }

    #[test]
    fn path_worst_conditioning() {
        let ring = Topology::Ring.build(16, MixingRule::MetropolisHastings);
        let path = Topology::Path.build(16, MixingRule::MetropolisHastings);
        let full = Topology::FullyConnected.build(16, MixingRule::MetropolisHastings);
        assert!(path.kappa_g() > ring.kappa_g());
        assert!(ring.kappa_g() > full.kappa_g() - 1e-9);
    }

    #[test]
    fn parse_strings() {
        assert_eq!(Topology::parse("ring", 0), Some(Topology::Ring));
        assert_eq!(Topology::parse("full", 0), Some(Topology::FullyConnected));
        assert!(matches!(Topology::parse("er:0.4", 7), Some(Topology::ErdosRenyi { .. })));
        assert_eq!(Topology::parse("bogus", 0), None);
    }

    /// Erdős–Rényi parsing rejects what `build` would otherwise panic on
    /// (or sample forever): malformed, out-of-range, and degenerate p.
    #[test]
    fn parse_rejects_bad_erdos_renyi() {
        assert_eq!(Topology::parse("", 0), None);
        assert_eq!(Topology::parse("er:", 0), None);
        assert_eq!(Topology::parse("er:1.5", 0), None, "p > 1 is a typo, not a graph");
        assert_eq!(Topology::parse("er:0", 0), None, "p = 0 can never be connected");
        assert_eq!(Topology::parse("er:-0.2", 0), None);
        assert_eq!(Topology::parse("er:nan", 0), None);
        assert_eq!(Topology::parse("er:abc", 0), None);
        assert_eq!(Topology::parse("er:0.4:xyz", 0), None, "bad explicit seed");
        // p = 1 is the complete graph — valid.
        assert!(matches!(Topology::parse("er:1.0", 0), Some(Topology::ErdosRenyi { .. })));
    }

    /// The explicit-seed form pins the sampled graph regardless of the
    /// fallback seed argument.
    #[test]
    fn parse_explicit_er_seed_overrides() {
        let a = Topology::parse("er:0.4:3", 42).unwrap();
        assert_eq!(a, Topology::ErdosRenyi { p: 0.4, seed: 3 });
        let b = Topology::parse("er:0.4", 42).unwrap();
        assert_eq!(b, Topology::ErdosRenyi { p: 0.4, seed: 42 });
    }

    #[test]
    fn mixing_rule_parse() {
        assert_eq!(MixingRule::parse("uniform"), Some(MixingRule::UniformNeighbors));
        assert_eq!(MixingRule::parse("mh"), Some(MixingRule::MetropolisHastings));
        assert_eq!(MixingRule::parse("metropolis"), Some(MixingRule::MetropolisHastings));
        assert_eq!(MixingRule::parse("lazy"), Some(MixingRule::LazyMetropolis));
        assert_eq!(MixingRule::parse("wat"), None);
    }
}
