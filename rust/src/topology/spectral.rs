//! Spectral utilities over mixing matrices: the quantities the paper's
//! Theorem 1 / Corollary 1 need beyond what [`MixingMatrix`] caches, plus
//! helpers used by the theory-validation tests.

use super::MixingMatrix;
use crate::linalg::Mat;

/// λmax((I − W)†) = 1 / λmin⁺(I − W): appears in the Lyapunov weight of
/// Theorem 1 and the second branch of ρ.
pub fn lambda_max_pinv_i_minus_w(m: &MixingMatrix) -> f64 {
    1.0 / m.lambda_min_plus()
}

/// The second branch of the paper's contraction factor ρ (Theorem 1):
/// `1 − γ / (2 λmax((I−W)†))`.
pub fn rho_dual_branch(m: &MixingMatrix, gamma: f64) -> f64 {
    1.0 - gamma / (2.0 * lambda_max_pinv_i_minus_w(m))
}

/// Theorem 1 admissible γ upper bound, Eq. (9):
/// `min{ 2/((3C+1)β), 2μη(2−μη)/([2−μη(2−μη)] C β) }` (second branch only
/// for C > 0).
pub fn gamma_upper_bound(m: &MixingMatrix, c: f64, mu: f64, eta: f64) -> f64 {
    let beta = m.beta();
    let first = 2.0 / ((3.0 * c + 1.0) * beta);
    if c <= 0.0 {
        return first;
    }
    let t = mu * eta * (2.0 - mu * eta);
    let second = 2.0 * t / ((2.0 - t) * c * beta);
    first.min(second)
}

/// Theorem 1 admissible α interval, Eq. (10), given γ. Returns (lo, hi);
/// empty (lo > hi) means the (γ, η) pair is outside the theory's region.
pub fn alpha_interval(m: &MixingMatrix, c: f64, mu: f64, eta: f64, gamma: f64) -> (f64, f64) {
    let beta = m.beta();
    let a1 = 4.0 * (1.0 + c) / (c * beta * gamma + 2.0);
    let lo = c * beta * gamma / (2.0 * (1.0 + c));
    let t = mu * eta * (2.0 - mu * eta);
    let hi = (1.0 / a1) * ((2.0 - beta * gamma) / (4.0 - beta * gamma)).min(t);
    (lo, hi)
}

/// The full contraction factor ρ from Theorem 1 for a given parameter
/// choice (used to check measured rates against theory).
pub fn rho_theorem1(
    m: &MixingMatrix,
    c: f64,
    mu: f64,
    eta: f64,
    gamma: f64,
    alpha: f64,
) -> f64 {
    let beta = m.beta();
    let a1 = 4.0 * (1.0 + c) / (c * beta * gamma + 2.0);
    let t = mu * eta * (2.0 - mu * eta);
    let r1 = (1.0 - t) / (1.0 - a1 * alpha);
    let r2 = rho_dual_branch(m, gamma);
    let r3 = 1.0 - alpha;
    r1.max(r2).max(r3)
}

/// I − W as a dense matrix (for tests that need the explicit operator).
pub fn i_minus_w(m: &MixingMatrix) -> Mat {
    let n = m.n;
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            out[(i, j)] = if i == j { 1.0 - m.w[(i, j)] } else { -m.w[(i, j)] };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{MixingRule, Topology};

    fn ring8() -> MixingMatrix {
        Topology::Ring.build(8, MixingRule::UniformNeighbors)
    }

    #[test]
    fn pinv_eigen_consistency() {
        let m = ring8();
        let lam = lambda_max_pinv_i_minus_w(&m);
        assert!((lam - 1.0 / m.lambda_min_plus()).abs() < 1e-12);
        assert!(lam > 1.0); // ring is not fully connected
    }

    #[test]
    fn rho_below_one_for_valid_params() {
        // Check that the Theorem 1 recipe yields ρ < 1 across compression
        // levels on the paper's ring.
        let m = ring8();
        let (mu, l) = (0.5, 5.0);
        let eta = 2.0 / (mu + l);
        for &c in &[0.0, 0.1, 0.5, 1.0, 4.0] {
            let gamma = 0.999 * gamma_upper_bound(&m, c, mu, eta);
            assert!(gamma > 0.0);
            let (lo, hi) = alpha_interval(&m, c, mu, eta, gamma);
            if c > 0.0 {
                assert!(lo <= hi, "empty α interval at C={c}: ({lo}, {hi})");
            }
            let alpha = 0.5 * (lo + hi);
            let rho = rho_theorem1(&m, c, mu, eta, gamma, alpha.max(lo));
            assert!(rho < 1.0, "ρ={rho} at C={c}");
            assert!(rho > 0.0);
        }
    }

    #[test]
    fn rho_degrades_with_compression() {
        // More compression error (larger C) ⇒ no faster contraction.
        let m = ring8();
        let (mu, l) = (1.0, 10.0);
        let eta = 2.0 / (mu + l);
        let rho_at = |c: f64| {
            let gamma = 0.999 * gamma_upper_bound(&m, c, mu, eta);
            let (lo, hi) = alpha_interval(&m, c, mu, eta, gamma);
            rho_theorem1(&m, c, mu, eta, gamma, 0.5 * (lo + hi).max(lo))
        };
        assert!(rho_at(0.01) <= rho_at(1.0) + 1e-12);
        assert!(rho_at(1.0) <= rho_at(8.0) + 1e-12);
    }

    #[test]
    fn i_minus_w_psd() {
        let m = ring8();
        let ev = crate::linalg::eigvals_sym(&i_minus_w(&m));
        assert!(ev[0] > -1e-10, "{ev:?}");
        assert!((ev[ev.len() - 1] - m.beta()).abs() < 1e-9);
    }
}
