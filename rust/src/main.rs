//! `lead` — CLI for the LEAD reproduction.
//!
//! ```text
//! lead exp <fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|tables|all> [--out DIR] [--rounds N]
//! lead grid <spec.toml> [--out DIR] [--threads N] [--tol X]  # declarative scenario grid
//! lead net-report <spec.toml> [--out DIR] [--threads N] [--tol X]  # network/time view of a grid
//! lead trace <spec.toml> [--out DIR] [--threads N] [--rounds N]  # Chrome trace export per cell
//! lead run <config.toml> [--out DIR]                # custom single run
//! lead bench-diff <new.json> <baseline.json> [--tol X]  # perf gate
//! lead audit [--list-rules] [path]                  # determinism/unsafe auditor
//! lead info                                         # topology/spectral summary
//! ```
//! (clap is not in the offline vendor set; flags are parsed by hand.)
//!
//! `exp`, `grid`, and `run` all execute through the same scenario layer
//! (`lead::scenarios`): specs expand to a batch, the sharded driver runs
//! the batch on one shared worker pool, and artifacts (per-cell CSVs +
//! the unified `<grid>.json`) land in `--out`.
//!
//! Grid and run TOMLs accept a `transport` key/axis (`mem` | `channel`
//! | `mux:<N>`, see `lead::transport`): non-`mem` backends exchange the
//! framed wire bytes over in-process channels, bitwise-identically to
//! shared memory, and report frame counters in each record.

use lead::error::err;
use lead::experiments;
use lead::problems::DataSplit;
use lead::scenarios::{Driver, Grid};
use lead::topology::{MixingRule, Topology};
use std::path::PathBuf;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Shared preamble of the `grid` and `net-report` arms: load + expand the
/// grid TOML named by the first positional arg and resolve the common
/// flags (`--threads`, `--tol` overriding the grid's own `tol`).
fn load_grid_args(
    args: &[String],
    usage: &str,
) -> lead::error::Result<(Grid, Vec<lead::scenarios::RunSpec>, usize, Option<f64>)> {
    let path = args.get(1).ok_or_else(|| err(usage))?;
    let src = std::fs::read_to_string(path)?;
    let grid = Grid::from_toml(&src)?;
    let specs = grid.expand()?;
    let threads = flag(args, "--threads").and_then(|t| t.parse().ok()).unwrap_or(8);
    let tol = flag(args, "--tol").and_then(|t| t.parse().ok()).or(grid.tol);
    Ok((grid, specs, threads, tol))
}

fn main() -> lead::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let out = flag(&args, "--out").map(PathBuf::from);
    let out_ref = out.as_deref();
    let rounds = flag(&args, "--rounds").and_then(|r| r.parse().ok());

    match args.first().map(String::as_str) {
        Some("exp") => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            let r = |default| rounds.unwrap_or(default);
            match which {
                "fig1" => drop(experiments::fig1(out_ref, r(1500))?),
                "fig2" => drop(experiments::fig_logreg(DataSplit::Heterogeneous, false, out_ref, r(600), 8000)?),
                "fig3" => drop(experiments::fig_logreg(DataSplit::Heterogeneous, true, out_ref, r(600), 8000)?),
                "fig4" => {
                    experiments::fig4(DataSplit::Homogeneous, out_ref, r(150))?;
                    experiments::fig4(DataSplit::Heterogeneous, out_ref, r(150))?;
                }
                "fig5" => drop(experiments::fig5(out_ref)?),
                "fig6" => drop(experiments::fig6(out_ref)?),
                "fig7" => drop(experiments::fig7(out_ref, r(1200))?),
                "fig8" => drop(experiments::fig_logreg(DataSplit::Homogeneous, false, out_ref, r(600), 8000)?),
                "fig9" => drop(experiments::fig_logreg(DataSplit::Homogeneous, true, out_ref, r(600), 8000)?),
                "tables" => experiments::tables(),
                "ablations" => {
                    experiments::ablations::topology(out_ref)?;
                    experiments::ablations::bits(out_ref)?;
                    experiments::ablations::block_size(out_ref)?;
                    experiments::ablations::momentum(out_ref)?;
                }
                "all" => {
                    experiments::tables();
                    experiments::fig1(out_ref, rounds.unwrap_or(1500))?;
                    experiments::fig_logreg(DataSplit::Heterogeneous, false, out_ref, rounds.unwrap_or(600), 8000)?;
                    experiments::fig_logreg(DataSplit::Heterogeneous, true, out_ref, rounds.unwrap_or(600), 8000)?;
                    experiments::fig_logreg(DataSplit::Homogeneous, false, out_ref, rounds.unwrap_or(600), 8000)?;
                    experiments::fig_logreg(DataSplit::Homogeneous, true, out_ref, rounds.unwrap_or(600), 8000)?;
                    experiments::fig5(out_ref)?;
                    experiments::fig6(out_ref)?;
                    experiments::fig7(out_ref, rounds.unwrap_or(1200))?;
                    if let Err(e) = experiments::fig4(DataSplit::Homogeneous, out_ref, rounds.unwrap_or(150))
                        .and_then(|_| experiments::fig4(DataSplit::Heterogeneous, out_ref, rounds.unwrap_or(150)))
                    {
                        eprintln!("fig4 skipped (artifacts missing?): {e}");
                    }
                }
                other => return Err(err(format!("unknown experiment {other:?}"))),
            }
        }
        Some("grid") => {
            let (grid, specs, threads, tol) = load_grid_args(
                &args,
                "usage: lead grid <spec.toml> [--out DIR] [--threads N] [--tol X]",
            )?;
            eprintln!(
                "grid {:?}: {} cells, {} threads{}",
                grid.name,
                specs.len(),
                threads,
                out_ref.map_or(String::new(), |d| format!(", artifacts -> {}", d.display()))
            );
            let records =
                Driver::new(threads).with_out(out_ref).with_tol(tol).run(&grid.name, &specs)?;
            println!(
                "{:<40} {:<16} {:>12} {:>12} {:>14} {:>8}",
                "cell", "algorithm", "dist(x*)", "consensus", "bits/agent", "secs"
            );
            for (s, rec) in specs.iter().zip(&records) {
                let m = rec.last();
                let show = |x: f64| {
                    if x.is_finite() { format!("{x:.3e}") } else { "nan/div".into() }
                };
                println!(
                    "{:<40} {:<16} {:>12} {:>12} {:>14.3e} {:>8.2}",
                    s.name,
                    rec.algo,
                    show(m.dist_opt),
                    show(m.consensus),
                    m.bits_per_agent,
                    rec.wall_secs
                );
            }
        }
        Some("net-report") => {
            // The same grid execution as `lead grid`, reported on the
            // network/time axis: per-cell simulated time, time-to-tol,
            // idle (barrier-wait) stats, utilization, retransmits (plus
            // retransmit-cap force-deliveries), and fault totals when a
            // fault plan is active.
            let (grid, specs, threads, tol) = load_grid_args(
                &args,
                "usage: lead net-report <spec.toml> [--out DIR] [--threads N] [--tol X]",
            )?;
            eprintln!("net-report {:?}: {} cells, {} threads", grid.name, specs.len(), threads);
            let records =
                Driver::new(threads).with_out(out_ref).with_tol(tol).run(&grid.name, &specs)?;
            println!(
                "{:<44} {:>11} {:>11} {:>9} {:>9} {:>6} {:>7} {:>7} {:>8} {:>8} {:>7}",
                "cell", "sim_time", "t_to_tol", "idle_max", "idle_avg", "util", "retx",
                "capped", "crashed", "lost", "stale"
            );
            for (s, rec) in specs.iter().zip(&records) {
                let m = rec.last();
                let ttt = tol
                    .and_then(|t| rec.time_to_tol(t))
                    .map_or("-".into(), |v| format!("{v:.3e}"));
                let (idle_max, idle_avg, util, retx, capped) = match &rec.net {
                    Some(n) => {
                        let avg = n.idle_s.iter().sum::<f64>() / n.idle_s.len().max(1) as f64;
                        (
                            format!("{:.2e}", m.idle_max),
                            format!("{avg:.2e}"),
                            format!("{:.2}", n.utilization),
                            n.retransmits.to_string(),
                            n.capped.to_string(),
                        )
                    }
                    None => ("-".into(), "-".into(), "-".into(), "-".into(), "-".into()),
                };
                let (crashed, lost, stale) = match &rec.faults {
                    Some(f) => (
                        f.crashed_agent_rounds.to_string(),
                        f.lost.to_string(),
                        f.stale.to_string(),
                    ),
                    None => ("-".into(), "-".into(), "-".into()),
                };
                let early = if rec.stopped_early { "*" } else { "" };
                println!(
                    "{:<44} {:>10.3e}{:1} {:>11} {:>9} {:>9} {:>6} {:>7} {:>7} {:>8} {:>8} {:>7}",
                    s.name, m.sim_time, early, ttt, idle_max, idle_avg, util, retx, capped,
                    crashed, lost, stale
                );
            }
            if records.iter().any(|r| r.stopped_early) {
                println!("(* = stopped early at the time budget)");
            }
            // §Observability breakdown: per-phase wall times plus the
            // transport fleet counters, one row per cell (counters show
            // "-" for subsystems the cell never ran).
            println!();
            println!(
                "{:<44} {:>9} {:>9} {:>9} {:>9} {:>8} {:>8} {:>12}",
                "cell", "produce", "mix", "apply", "observe", "frames", "dropped", "bytes"
            );
            for (s, rec) in specs.iter().zip(&records) {
                let p = &rec.phases;
                let (frames, dropped, bytes) = match &rec.transport {
                    Some(t) => (
                        t.frames_sent.to_string(),
                        t.frames_dropped.to_string(),
                        t.bytes_on_wire.to_string(),
                    ),
                    None => ("-".into(), "-".into(), "-".into()),
                };
                println!(
                    "{:<44} {:>9.2e} {:>9.2e} {:>9.2e} {:>9.2e} {:>8} {:>8} {:>12}",
                    s.name, p.produce, p.mix, p.apply, p.observe, frames, dropped, bytes
                );
            }
        }
        Some("trace") => {
            // Execute the grid with the deterministic trace recorder on
            // and export one Chrome trace-event JSON file per cell
            // (lead::trace §Observability). `--rounds` shortens every
            // cell — traces are about phase structure, not convergence.
            let (grid, mut specs, threads, _tol) = load_grid_args(
                &args,
                "usage: lead trace <spec.toml> [--out DIR] [--threads N] [--rounds N]",
            )?;
            if let Some(r) = rounds {
                for s in &mut specs {
                    s.rounds = r;
                }
            }
            let dir =
                out.clone().unwrap_or_else(|| PathBuf::from(format!("{}_traces", grid.name)));
            eprintln!(
                "trace {:?}: {} cells, {} threads, artifacts -> {}",
                grid.name,
                specs.len(),
                threads,
                dir.display()
            );
            let paths = lead::scenarios::trace_runs(&specs, threads, &dir)?;
            for p in &paths {
                println!("{}", p.display());
            }
            eprintln!(
                "trace: {} file(s) written (open in chrome://tracing or ui.perfetto.dev)",
                paths.len()
            );
        }
        Some("run") => {
            let path = args.get(1).ok_or_else(|| err("usage: lead run <config.toml>"))?;
            let src = std::fs::read_to_string(path)?;
            let cfg = lead::config::RunConfig::from_toml(&src).map_err(err)?;
            let spec = cfg.to_spec();
            let records = Driver::new(1).with_out(out_ref).run("run", &[spec])?;
            let rec = &records[0];
            println!("{}", rec.to_csv());
            eprintln!(
                "final: dist={:.3e} consensus={:.3e} bits/agent={:.3e} ({:.2}s)",
                rec.last().dist_opt,
                rec.last().consensus,
                rec.last().bits_per_agent,
                rec.wall_secs
            );
        }
        Some("bench-diff") => {
            let (Some(new_p), Some(base_p)) = (args.get(1), args.get(2)) else {
                return Err(err("usage: lead bench-diff <new.json> <baseline.json> [--tol X]"));
            };
            let tol = flag(&args, "--tol").and_then(|t| t.parse().ok()).unwrap_or(0.25);
            if !std::path::Path::new(base_p).exists() {
                eprintln!(
                    "bench-diff: baseline {base_p} not found — nothing to compare \
                     (commit one to arm the perf gate)"
                );
                return Ok(());
            }
            let report = lead::bench::diff(
                &std::fs::read_to_string(new_p)?,
                &std::fs::read_to_string(base_p)?,
                tol,
            )?;
            for n in &report.notes {
                println!("note: {n}");
            }
            if report.ok() {
                println!(
                    "bench-diff: OK — {} config(s) within tolerance {tol}",
                    report.compared
                );
            } else {
                for r in &report.regressions {
                    eprintln!("REGRESSION: {r}");
                }
                return Err(err(format!(
                    "bench-diff: {} perf regression(s) beyond tolerance {tol}",
                    report.regressions.len()
                )));
            }
        }
        Some("audit") => {
            if args.iter().any(|a| a == "--list-rules") {
                for r in lead::audit::rules() {
                    println!("{:<16} {}", r.id, r.summary);
                }
                return Ok(());
            }
            // Default target: the crate sources, whether invoked from the
            // repo root or from rust/.
            let path = match args.get(1).filter(|a| !a.starts_with("--")) {
                Some(p) => p.clone(),
                None if std::path::Path::new("rust/src").is_dir() => "rust/src".into(),
                None => "src".into(),
            };
            let diags = lead::audit::audit_path(&path)?;
            for d in &diags {
                eprintln!("{d}");
            }
            if !diags.is_empty() {
                return Err(err(format!(
                    "audit: {} violation(s) in {path} (escape hatch: `audit:allow(rule): reason`; \
                     see `lead audit --list-rules`)",
                    diags.len()
                )));
            }
            println!("audit: {path} clean");
        }
        Some("info") => {
            for name in ["ring", "full", "star", "path"] {
                let t = Topology::parse(name, 0).unwrap();
                let m = t.build(8, MixingRule::UniformNeighbors);
                println!(
                    "{name:<6} n=8  β={:.4}  λmin⁺={:.4}  κ_g={:.3}  gap={:.4}",
                    m.beta(),
                    m.lambda_min_plus(),
                    m.kappa_g(),
                    m.spectral_gap()
                );
            }
        }
        _ => {
            eprintln!(
                "usage: lead <exp|grid|net-report|trace|run|bench-diff|audit|info> ... (see README)"
            );
        }
    }
    Ok(())
}
