//! `lead` — CLI for the LEAD reproduction.
//!
//! ```text
//! lead exp <fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|tables|all> [--out DIR] [--rounds N]
//! lead run <config.toml> [--out DIR]        # custom single run
//! lead info                                 # topology/spectral summary
//! ```
//! (clap is not in the offline vendor set; flags are parsed by hand.)

use lead::coordinator::engine::{Engine, EngineConfig};
use lead::error::err;
use lead::experiments;
use lead::problems::DataSplit;
use lead::topology::{MixingRule, Topology};
use std::path::PathBuf;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> lead::error::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let out = flag(&args, "--out").map(PathBuf::from);
    let out_ref = out.as_deref();
    let rounds = flag(&args, "--rounds").and_then(|r| r.parse().ok());

    match args.first().map(String::as_str) {
        Some("exp") => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            let r = |default| rounds.unwrap_or(default);
            match which {
                "fig1" => drop(experiments::fig1(out_ref, r(1500))),
                "fig2" => drop(experiments::fig_logreg(DataSplit::Heterogeneous, false, out_ref, r(600), 8000)),
                "fig3" => drop(experiments::fig_logreg(DataSplit::Heterogeneous, true, out_ref, r(600), 8000)),
                "fig4" => {
                    experiments::fig4(DataSplit::Homogeneous, out_ref, r(150))?;
                    experiments::fig4(DataSplit::Heterogeneous, out_ref, r(150))?;
                }
                "fig5" => drop(experiments::fig5(out_ref)),
                "fig6" => drop(experiments::fig6(out_ref)),
                "fig7" => drop(experiments::fig7(out_ref, r(1200))),
                "fig8" => drop(experiments::fig_logreg(DataSplit::Homogeneous, false, out_ref, r(600), 8000)),
                "fig9" => drop(experiments::fig_logreg(DataSplit::Homogeneous, true, out_ref, r(600), 8000)),
                "tables" => experiments::tables(),
                "ablations" => {
                    experiments::ablations::topology(out_ref);
                    experiments::ablations::bits(out_ref);
                    experiments::ablations::block_size(out_ref);
                    experiments::ablations::momentum(out_ref);
                }
                "all" => {
                    experiments::tables();
                    experiments::fig1(out_ref, rounds.unwrap_or(1500));
                    experiments::fig_logreg(DataSplit::Heterogeneous, false, out_ref, rounds.unwrap_or(600), 8000);
                    experiments::fig_logreg(DataSplit::Heterogeneous, true, out_ref, rounds.unwrap_or(600), 8000);
                    experiments::fig_logreg(DataSplit::Homogeneous, false, out_ref, rounds.unwrap_or(600), 8000);
                    experiments::fig_logreg(DataSplit::Homogeneous, true, out_ref, rounds.unwrap_or(600), 8000);
                    experiments::fig5(out_ref);
                    experiments::fig6(out_ref);
                    experiments::fig7(out_ref, rounds.unwrap_or(1200));
                    if let Err(e) = experiments::fig4(DataSplit::Homogeneous, out_ref, rounds.unwrap_or(150))
                        .and_then(|_| experiments::fig4(DataSplit::Heterogeneous, out_ref, rounds.unwrap_or(150)))
                    {
                        eprintln!("fig4 skipped (artifacts missing?): {e}");
                    }
                }
                other => return Err(err(format!("unknown experiment {other:?}"))),
            }
        }
        Some("run") => {
            let path = args.get(1).ok_or_else(|| err("usage: lead run <config.toml>"))?;
            let src = std::fs::read_to_string(path)?;
            let cfg = lead::config::RunConfig::from_toml(&src).map_err(err)?;
            let topo = Topology::parse(&cfg.topology, cfg.seed)
                .ok_or_else(|| err(format!("bad topology {:?}", cfg.topology)))?;
            let mix = topo.build(cfg.agents, MixingRule::UniformNeighbors);
            let problem =
                Box::new(lead::problems::linreg::LinReg::synthetic(cfg.agents, 200, 0.1, cfg.seed));
            let algo = lead::config::build_algo(&cfg.algo, cfg.gamma, cfg.alpha)
                .ok_or_else(|| err(format!("unknown algo {:?}", cfg.algo)))?;
            let comp = lead::compress::parse(&cfg.compressor);
            let mut engine = Engine::new(
                EngineConfig {
                    eta: cfg.eta,
                    batch_size: cfg.batch_size,
                    seed: cfg.seed,
                    record_every: (cfg.rounds / 100).max(1),
                    ..Default::default()
                },
                mix,
                problem,
            );
            let rec = engine.run(algo, comp, cfg.rounds);
            println!("{}", rec.to_csv());
            if let Some(dir) = out_ref {
                rec.write_csv(dir, "run")?;
            }
            eprintln!(
                "final: dist={:.3e} consensus={:.3e} bits/agent={:.3e} ({:.2}s)",
                rec.last().dist_opt,
                rec.last().consensus,
                rec.last().bits_per_agent,
                rec.wall_secs
            );
        }
        Some("info") => {
            for name in ["ring", "full", "star", "path"] {
                let t = Topology::parse(name, 0).unwrap();
                let m = t.build(8, MixingRule::UniformNeighbors);
                println!(
                    "{name:<6} n=8  β={:.4}  λmin⁺={:.4}  κ_g={:.3}  gap={:.4}",
                    m.beta(),
                    m.lambda_min_plus(),
                    m.kappa_g(),
                    m.spectral_gap()
                );
            }
        }
        _ => {
            eprintln!("usage: lead <exp|run|info> ... (see README)");
        }
    }
    Ok(())
}
