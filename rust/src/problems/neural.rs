//! PJRT-backed problems: gradients computed by executing AOT artifacts
//! (the L2 JAX graphs) through the runtime — Python never runs here.
//!
//! Three problems live behind this oracle:
//! * [`PjrtLinReg`]  — the paper's linear regression with artifact-computed
//!   gradients; cross-checked against the native oracle in tests.
//! * [`MlpProblem`]  — the Fig. 4 "deep net" substitute: MLP classifier on
//!   synthetic CIFAR-shaped data (3072 → 256 → 10), mini-batch gradients.
//! * [`TransformerProblem`] — byte-level GPT LM for the end-to-end example
//!   (examples/train_transformer.rs).

use super::data::{partition, synth_classification, Dataset};
use super::{DataSplit, Problem};
use crate::error::{err, Result};
use crate::rng::{streams, Rng};
use crate::runtime::{artifact::Value, Artifact, Manifest, ParamSpec};

// No `unsafe impl Send/Sync` here: these problem types are Send + Sync
// automatically because `Artifact` is. Thread safety of the underlying
// (!Send) xla wrappers is owned by `runtime::artifact` — every
// compile/execute/drop holds the process-wide client lock, proved at
// compile time via `runtime::client::ClientGuard` — instead of being
// asserted per problem type with per-problem mutexes as before.

// ---------------------------------------------------------------------------
// Linear regression via PJRT
// ---------------------------------------------------------------------------

/// The native linreg problem with its gradient oracle swapped for the
/// `linreg_grad` artifact. Shapes must match the AOT example (200×200).
pub struct PjrtLinReg {
    pub inner: super::linreg::LinReg,
    grad_art: Artifact,
    loss_art: Artifact,
}

impl PjrtLinReg {
    pub fn new(manifest: &Manifest, inner: super::linreg::LinReg) -> Result<Self> {
        let grad_art = manifest.compile("linreg_grad")?;
        let shape = &grad_art.meta.inputs[0].shape;
        if shape != &vec![inner.m, inner.d] {
            return Err(err(format!(
                "artifact expects A {:?}, problem has {}x{}",
                shape, inner.m, inner.d
            )));
        }
        Ok(PjrtLinReg { inner, grad_art, loss_art: manifest.compile("linreg_loss")? })
    }
}

impl Problem for PjrtLinReg {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn n_agents(&self) -> usize {
        self.inner.n_agents()
    }
    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        // Executions are serialized inside Artifact::execute by the
        // process-wide client lock; no per-problem locking needed.
        let lam = [self.inner.lambda];
        let res = self
            .grad_art
            .execute(&[
                Value::F(&self.inner.a[agent]),
                Value::F(&self.inner.b[agent]),
                Value::F(x),
                Value::F(&lam),
            ])
            .expect("linreg_grad artifact failed");
        out.copy_from_slice(&res[0]);
    }
    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        let lam = [self.inner.lambda];
        let res = self
            .loss_art
            .execute(&[
                Value::F(&self.inner.a[agent]),
                Value::F(&self.inner.b[agent]),
                Value::F(x),
                Value::F(&lam),
            ])
            .expect("linreg_loss artifact failed");
        res[0][0]
    }
    fn optimum(&self) -> Option<&[f64]> {
        self.inner.optimum()
    }
    fn mu_l(&self) -> Option<(f64, f64)> {
        self.inner.mu_l()
    }
    fn name(&self) -> String {
        format!("pjrt-{}", self.inner.name())
    }
}

// ---------------------------------------------------------------------------
// MLP on synthetic CIFAR-shaped data (Fig. 4 substitute)
// ---------------------------------------------------------------------------

pub struct MlpProblem {
    ds: Dataset,
    parts: Vec<Vec<usize>>,
    grad_art: Artifact,
    loss_art: Artifact,
    spec: ParamSpec,
    batch: usize,
    classes: usize,
    x0: Vec<f64>,
}

impl MlpProblem {
    /// `n_per_agent` synthetic CIFAR-shaped samples per agent.
    pub fn new(
        manifest: &Manifest,
        n_agents: usize,
        n_per_agent: usize,
        split: DataSplit,
        seed: u64,
    ) -> Result<Self> {
        let grad_art = manifest.compile("mlp_grad")?;
        let loss_art = manifest.compile("mlp_loss")?;
        let spec = ParamSpec::from_meta(&grad_art.meta);
        let d_feat = grad_art.meta.inputs[0].shape[0]; // 3072
        let classes = grad_art.meta.inputs[2].shape[1]; // 10
        let batch = grad_art.meta.inputs[4].shape[0]; // 64
        let ds = synth_classification(n_agents * n_per_agent, d_feat, classes, 0.8, seed);
        let parts = partition(&ds, n_agents, split, seed);
        // He-style init shared by all agents (consensus start).
        let mut x0 = vec![0.0f64; spec.total];
        let mut rng = Rng::new(seed).derive(streams::INIT);
        for (o, n, shape) in &spec.slots {
            let fan_in = shape[0].max(1) as f64;
            for v in x0[*o..*o + *n].iter_mut() {
                *v = rng.normal() / fan_in.sqrt();
            }
        }
        Ok(MlpProblem { ds, parts, grad_art, loss_art, spec, batch, classes, x0 })
    }

    pub fn initial_point(&self) -> &[f64] {
        &self.x0
    }

    fn batch_tensors(&self, agent: usize, idx: &[usize]) -> (Vec<f64>, Vec<f64>) {
        let d = self.ds.d;
        let mut xb = vec![0.0f64; self.batch * d];
        let mut yb = vec![0.0f64; self.batch * self.classes];
        for (slot, &local) in idx.iter().take(self.batch).enumerate() {
            let s = self.parts[agent][local % self.parts[agent].len()];
            xb[slot * d..(slot + 1) * d].copy_from_slice(self.ds.row(s));
            yb[slot * self.classes + self.ds.labels[s]] = 1.0;
        }
        // Pad short batches by repeating the first sample.
        if idx.len() < self.batch {
            for slot in idx.len()..self.batch {
                let s = self.parts[agent][0];
                xb[slot * d..(slot + 1) * d].copy_from_slice(self.ds.row(s));
                yb[slot * self.classes + self.ds.labels[s]] = 1.0;
            }
        }
        (xb, yb)
    }

    fn run_grad(&self, agent: usize, x: &[f64], idx: &[usize], out: &mut [f64]) {
        let (xb, yb) = self.batch_tensors(agent, idx);
        let parts = self.spec.split(x);
        let mut inputs: Vec<Value> = parts.into_iter().map(Value::F).collect();
        inputs.push(Value::F(&xb));
        inputs.push(Value::F(&yb));
        let res = self.grad_art.execute(&inputs).expect("mlp_grad failed");
        // res[0] = loss; res[1..] = grads in param order.
        let grads: Vec<Vec<f64>> = res[1..].to_vec();
        self.spec.gather(&grads, out);
    }
}

impl Problem for MlpProblem {
    fn dim(&self) -> usize {
        self.spec.total
    }
    fn n_agents(&self) -> usize {
        self.parts.len()
    }
    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        // Fixed-shape artifact: "full" gradient = first `batch` samples
        // (deterministic surrogate; the Fig. 4 experiments are mini-batch).
        let idx: Vec<usize> = (0..self.batch.min(self.parts[agent].len())).collect();
        self.run_grad(agent, x, &idx, out);
    }
    fn grad_batch(&self, agent: usize, x: &[f64], idx: &[usize], out: &mut [f64]) {
        self.run_grad(agent, x, idx, out);
    }
    fn n_samples(&self, agent: usize) -> usize {
        self.parts[agent].len()
    }
    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        let idx: Vec<usize> = (0..self.batch.min(self.parts[agent].len())).collect();
        let (xb, yb) = self.batch_tensors(agent, idx.as_slice());
        let parts = self.spec.split(x);
        let mut inputs: Vec<Value> = parts.into_iter().map(Value::F).collect();
        inputs.push(Value::F(&xb));
        inputs.push(Value::F(&yb));
        self.loss_art.execute(&inputs).expect("mlp_loss failed")[0][0]
    }
    fn optimum(&self) -> Option<&[f64]> {
        None
    }
    fn initial_point(&self) -> Option<Vec<f64>> {
        Some(self.x0.clone())
    }
    fn name(&self) -> String {
        format!("mlp(pjrt, {} agents, {} samples/agent)", self.parts.len(), self.parts[0].len())
    }
}

// ---------------------------------------------------------------------------
// Transformer LM (end-to-end example)
// ---------------------------------------------------------------------------

pub struct TransformerProblem {
    step_art: Artifact,
    spec: ParamSpec,
    /// Per-agent byte corpora (synthetic, heterogeneous by construction:
    /// each agent's text has a different token distribution).
    corpora: Vec<Vec<i32>>,
    batch: usize,
    seq: usize,
    x0: Vec<f64>,
}

impl TransformerProblem {
    pub fn new(manifest: &Manifest, n_agents: usize, corpus_len: usize, seed: u64) -> Result<Self> {
        let step_art = manifest.compile("transformer_tiny_step")?;
        let spec = ParamSpec::from_meta(&step_art.meta);
        let tok = step_art.meta.inputs.last().unwrap();
        let (batch, seq) = (tok.shape[0], tok.shape[1]);
        // Synthetic byte corpus: agent-specific markov-ish patterns so the
        // split is heterogeneous (each agent favors a different byte band).
        let mut corpora = Vec::with_capacity(n_agents);
        for a in 0..n_agents {
            let mut rng = Rng::new(seed).derive(a as u64).derive(streams::DATA);
            let base = (a * 29) % 200;
            let mut cur = base as i32;
            let mut text = Vec::with_capacity(corpus_len);
            for _ in 0..corpus_len {
                // Local structure: mostly small steps within the agent's
                // band, occasional jumps — learnable next-byte statistics.
                let step = if rng.uniform() < 0.85 {
                    rng.below(7) as i32 - 3
                } else {
                    rng.below(56) as i32 - 28
                };
                cur = (base as i32 + (cur - base as i32 + step).rem_euclid(40)).clamp(0, 255);
                text.push(cur);
            }
            corpora.push(text);
        }
        // Parameter init mirroring transformer.init_params: scales = 1,
        // biases = 0, matrices ~ N(0, 1/fan_in).
        let mut x0 = vec![0.0f64; spec.total];
        let mut rng = Rng::new(seed).derive(streams::INIT);
        for ((o, n, shape), port) in spec.slots.iter().zip(
            step_art.meta.param_inputs.iter().map(|&i| &step_art.meta.inputs[i]),
        ) {
            let dst = &mut x0[*o..*o + *n];
            if port.name.ends_with("_scale") {
                dst.fill(1.0);
            } else if port.name.ends_with("_bias") {
                dst.fill(0.0);
            } else {
                let fan_in = shape[0].max(1) as f64;
                for v in dst.iter_mut() {
                    *v = rng.normal() / fan_in.sqrt();
                }
            }
        }
        Ok(TransformerProblem { step_art, spec, corpora, batch, seq, x0 })
    }

    pub fn initial_point(&self) -> &[f64] {
        &self.x0
    }

    pub fn param_count(&self) -> usize {
        self.spec.total
    }

    fn sample_tokens(&self, agent: usize, rng: &mut Rng) -> Vec<i32> {
        let corpus = &self.corpora[agent];
        let mut toks = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = rng.below(corpus.len() - self.seq);
            toks.extend_from_slice(&corpus[start..start + self.seq]);
        }
        toks
    }

    /// One train-step execution: returns (loss, grad_flat).
    pub fn step(&self, agent: usize, x: &[f64], rng: &mut Rng) -> (f64, Vec<f64>) {
        let toks = self.sample_tokens(agent, rng);
        let parts = self.spec.split(x);
        let mut inputs: Vec<Value> = parts.into_iter().map(Value::F).collect();
        inputs.push(Value::I(&toks));
        let res = self.step_art.execute(&inputs).expect("transformer step failed");
        let loss = res[0][0];
        let mut flat = vec![0.0f64; self.spec.total];
        self.spec.gather(&res[1..].to_vec(), &mut flat);
        (loss, flat)
    }
}

impl Problem for TransformerProblem {
    fn dim(&self) -> usize {
        self.spec.total
    }
    fn n_agents(&self) -> usize {
        self.corpora.len()
    }
    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        // Deterministic batch (corpus prefix) as the "full" surrogate.
        // audit:allow(rng_stream): fixed per-agent eval tag, independent of the engine's run-seed stream tree by design so the "full" surrogate batch never varies with run config
        let mut rng = Rng::new(0xF00D).derive(agent as u64);
        let (_, g) = self.step(agent, x, &mut rng);
        out.copy_from_slice(&g);
    }
    fn grad_batch(&self, agent: usize, x: &[f64], idx: &[usize], out: &mut [f64]) {
        // idx carries the engine's per-round randomness; fold it into a
        // sampling seed so batches vary per round.
        let seed = idx.iter().fold(0x5EEDu64, |a, &b| a.wrapping_mul(31).wrapping_add(b as u64));
        // audit:allow(rng_stream): seed is folded from the engine's per-round idx draw, which itself came from a named streams::BATCH child — this is a deterministic function of it, not a new root
        let mut rng = Rng::new(seed).derive(agent as u64);
        let (_, g) = self.step(agent, x, &mut rng);
        out.copy_from_slice(&g);
    }
    fn n_samples(&self, agent: usize) -> usize {
        self.corpora[agent].len() - self.seq
    }
    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        // audit:allow(rng_stream): fixed per-agent loss-eval tag; metric batches must be identical across runs and algorithms, so this deliberately bypasses the run-seed tree
        let mut rng = Rng::new(0xE7A1).derive(agent as u64);
        self.step(agent, x, &mut rng).0
    }
    fn optimum(&self) -> Option<&[f64]> {
        None
    }
    fn initial_point(&self) -> Option<Vec<f64>> {
        Some(self.x0.clone())
    }
    fn name(&self) -> String {
        format!("transformer-lm(pjrt, {:.1}M params)", self.spec.total as f64 / 1e6)
    }
}
