//! Synthetic dataset generators and partitioning.
//!
//! The environment has no network access, so the paper's MNIST and CIFAR10
//! workloads are substituted by synthetic datasets with the same shape and
//! — crucially — the same *heterogeneity structure* (class-clustered
//! features, sort-by-label partitioning). See DESIGN.md §3 for the
//! substitution argument.

use super::DataSplit;
use crate::rng::{streams, Rng};

/// A labelled classification dataset, row-major features.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub features: Vec<f64>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
}

impl Dataset {
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.features[i * self.d..(i + 1) * self.d]
    }
}

/// Generate an "MNIST-like" dataset: `classes` Gaussian prototype vectors
/// in `R^d`, each sample = its class prototype + isotropic noise, features
/// squashed to [0, 1] like pixel intensities. Linearly separable-ish but
/// not trivially so (noise_scale controls overlap).
pub fn synth_classification(
    n: usize,
    d: usize,
    classes: usize,
    noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed).derive(streams::DATA);
    // Class prototypes.
    let mut protos = vec![0.0f64; classes * d];
    rng.fill_normal(&mut protos, 1.0);
    let mut features = vec![0.0f64; n * d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        // Balanced classes in round-robin, then shuffled by the caller's
        // partitioner if needed.
        let c = i % classes;
        labels[i] = c;
        let row = &mut features[i * d..(i + 1) * d];
        for (j, v) in row.iter_mut().enumerate() {
            let raw = protos[c * d + j] + noise * rng.normal_f64();
            // Squash to [0,1] like pixel intensities (sigmoid).
            *v = 1.0 / (1.0 + (-raw).exp());
        }
    }
    Dataset { features, labels, n, d, classes }
}

/// Partition sample indices across `agents` according to the split policy.
/// Returns per-agent index lists of (near-)equal size.
pub fn partition(ds: &Dataset, agents: usize, split: DataSplit, seed: u64) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..ds.n).collect();
    match split {
        DataSplit::Homogeneous => {
            let mut rng = Rng::new(seed).derive(streams::DATA).derive(1);
            rng.shuffle(&mut order);
        }
        DataSplit::Heterogeneous => {
            // Paper §5: sort by label, then partition contiguously so each
            // agent holds only one or two classes.
            order.sort_by_key(|&i| ds.labels[i]);
        }
    }
    let base = ds.n / agents;
    let rem = ds.n % agents;
    let mut out = Vec::with_capacity(agents);
    let mut cursor = 0;
    for a in 0..agents {
        let take = base + usize::from(a < rem);
        out.push(order[cursor..cursor + take].to_vec());
        cursor += take;
    }
    out
}

/// Count distinct labels per agent — heterogeneity diagnostic used by
/// tests and the experiment logs.
pub fn labels_per_agent(ds: &Dataset, parts: &[Vec<usize>]) -> Vec<usize> {
    parts
        .iter()
        .map(|idx| {
            let mut seen = vec![false; ds.classes];
            for &i in idx {
                seen[ds.labels[i]] = true;
            }
            seen.iter().filter(|&&s| s).count()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_shapes_and_range() {
        let ds = synth_classification(100, 20, 10, 0.5, 1);
        assert_eq!(ds.features.len(), 100 * 20);
        assert_eq!(ds.labels.len(), 100);
        assert!(ds.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn hetero_split_concentrates_labels() {
        let ds = synth_classification(800, 16, 10, 0.3, 2);
        let hetero = partition(&ds, 8, DataSplit::Heterogeneous, 3);
        let homo = partition(&ds, 8, DataSplit::Homogeneous, 3);
        let lh = labels_per_agent(&ds, &hetero);
        let lo = labels_per_agent(&ds, &homo);
        // Sorted split: at most 2-3 classes per agent; shuffled: nearly all.
        assert!(lh.iter().all(|&c| c <= 3), "hetero labels/agent = {lh:?}");
        assert!(lo.iter().all(|&c| c >= 8), "homo labels/agent = {lo:?}");
    }

    #[test]
    fn partition_is_exact_cover() {
        let ds = synth_classification(103, 5, 10, 0.3, 4);
        for split in [DataSplit::Homogeneous, DataSplit::Heterogeneous] {
            let parts = partition(&ds, 8, split, 5);
            let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..103).collect::<Vec<_>>());
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = synth_classification(50, 8, 4, 0.2, 9);
        let b = synth_classification(50, 8, 4, 0.2, 9);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }
}
