//! Multinomial logistic regression with ℓ₂ regularization (paper §5,
//! Figs. 2–3, 8–9):
//!
//! ```text
//! f_i(w) = (1/N_i) Σ_{s∈D_i} CE(softmax(W^T x_s), y_s) + (λ/2)‖W‖²
//! ```
//!
//! The paper runs this on MNIST with λ = 1e-4 under homogeneous
//! (shuffled) and heterogeneous (sorted-by-label) splits; we use the
//! synthetic MNIST-like dataset from [`super::data`] (see DESIGN.md §3).
//! The reference optimum is computed at construction by the in-repo
//! L-BFGS solver on the *global* objective.

use super::data::{partition, synth_classification, Dataset};
use super::lbfgs::{minimize, LbfgsOptions};
use super::{DataSplit, Problem};
use crate::linalg;

pub struct LogReg {
    pub n_agents: usize,
    /// Feature dimension (e.g. 784).
    pub d_feat: usize,
    /// Number of classes K; parameter dimension = d_feat * K.
    pub classes: usize,
    pub lambda: f64,
    pub split: DataSplit,
    ds: Dataset,
    /// Per-agent sample indices into `ds`.
    parts: Vec<Vec<usize>>,
    xstar: Option<Vec<f64>>,
}

impl LogReg {
    /// Build the synthetic MNIST-like problem. `n_total` samples of
    /// dimension `d_feat` in `classes` classes, split across `n_agents`.
    /// `solve_optimum = false` skips the L-BFGS solve (cheap tests).
    #[allow(clippy::too_many_arguments)]
    pub fn synthetic(
        n_agents: usize,
        n_total: usize,
        d_feat: usize,
        classes: usize,
        lambda: f64,
        split: DataSplit,
        seed: u64,
        solve_optimum: bool,
    ) -> LogReg {
        let ds = synth_classification(n_total, d_feat, classes, 0.7, seed);
        let parts = partition(&ds, n_agents, split, seed);
        let mut p = LogReg { n_agents, d_feat, classes, lambda, split, ds, parts, xstar: None };
        if solve_optimum {
            p.solve_optimum();
        }
        p
    }

    /// Paper-shaped default: 8 agents, MNIST-like (784 features,
    /// 10 classes), λ = 1e-4.
    pub fn paper_shaped(n_total: usize, split: DataSplit, seed: u64) -> LogReg {
        Self::synthetic(8, n_total, 784, 10, 1e-4, split, seed, true)
    }

    /// Run L-BFGS on the global objective to high precision.
    pub fn solve_optimum(&mut self) {
        let d = self.dim();
        let res = minimize(
            &vec![0.0f64; d],
            &LbfgsOptions { max_iters: 3000, grad_tol: 1e-8, ..Default::default() },
            |x, g| {
                self.global_grad(x, g);
                self.global_loss(x)
            },
        );
        assert!(
            res.grad_norm < 1e-4,
            "L-BFGS failed to reach high precision: ‖g‖={} after {} iters",
            res.grad_norm,
            res.iterations
        );
        self.xstar = Some(res.x);
    }

    /// Softmax cross-entropy gradient accumulated over `idx`, mean-scaled,
    /// plus λw. Parameters laid out feature-major: w[j*K + k].
    fn grad_over(&self, x: &[f64], idx: &[usize], out: &mut [f64]) {
        let k = self.classes;
        let d = self.d_feat;
        for (o, w) in out.iter_mut().zip(x) {
            *o = self.lambda * w;
        }
        if idx.is_empty() {
            return;
        }
        let inv = 1.0f64 / idx.len() as f64;
        let mut logits = vec![0.0f64; k];
        for &s in idx {
            let row = self.ds.row(s);
            // logits = W^T x_s
            logits.fill(0.0);
            for j in 0..d {
                let xj = row[j];
                if xj == 0.0 {
                    continue;
                }
                let wrow = &x[j * k..(j + 1) * k];
                for (l, w) in logits.iter_mut().zip(wrow) {
                    *l += xj * w;
                }
            }
            softmax_inplace(&mut logits);
            logits[self.ds.labels[s]] -= 1.0; // p − onehot(y)
            // out += inv * x_s ⊗ (p − y)
            for j in 0..d {
                let xj = row[j] * inv;
                if xj == 0.0 {
                    continue;
                }
                let orow = &mut out[j * k..(j + 1) * k];
                for (o, l) in orow.iter_mut().zip(&logits) {
                    *o += xj * l;
                }
            }
        }
    }

    fn loss_over(&self, x: &[f64], idx: &[usize]) -> f64 {
        let k = self.classes;
        let d = self.d_feat;
        let mut logits = vec![0.0f64; k];
        let mut ce = 0.0f64;
        for &s in idx {
            let row = self.ds.row(s);
            logits.fill(0.0);
            for j in 0..d {
                let xj = row[j];
                if xj == 0.0 {
                    continue;
                }
                let wrow = &x[j * k..(j + 1) * k];
                for (l, w) in logits.iter_mut().zip(wrow) {
                    *l += xj * w;
                }
            }
            // log-sum-exp, stabilized.
            let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = m as f64
                + logits.iter().map(|&l| ((l - m) as f64).exp()).sum::<f64>().ln();
            ce += lse - logits[self.ds.labels[s]] as f64;
        }
        ce / idx.len().max(1) as f64 + 0.5 * self.lambda as f64 * linalg::norm2_sq(x)
    }

    /// Classification accuracy over all data (experiment logging).
    pub fn accuracy(&self, x: &[f64]) -> f64 {
        let k = self.classes;
        let d = self.d_feat;
        let mut logits = vec![0.0f64; k];
        let mut correct = 0usize;
        for s in 0..self.ds.n {
            let row = self.ds.row(s);
            logits.fill(0.0);
            for j in 0..d {
                let xj = row[j];
                let wrow = &x[j * k..(j + 1) * k];
                for (l, w) in logits.iter_mut().zip(wrow) {
                    *l += xj * w;
                }
            }
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(pred == self.ds.labels[s]);
        }
        correct as f64 / self.ds.n as f64
    }
}

fn softmax_inplace(logits: &mut [f64]) {
    let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0f64;
    for l in logits.iter_mut() {
        *l = (*l - m).exp();
        z += *l;
    }
    for l in logits.iter_mut() {
        *l /= z;
    }
}

impl Problem for LogReg {
    fn dim(&self) -> usize {
        self.d_feat * self.classes
    }

    fn n_agents(&self) -> usize {
        self.n_agents
    }

    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        // Clone of the index list is avoided by passing the slice directly.
        let idx: &[usize] = &self.parts[agent];
        self.grad_over(x, idx, out);
    }

    fn grad_batch(&self, agent: usize, x: &[f64], idx: &[usize], out: &mut [f64]) {
        // idx are *local* positions within the agent's shard.
        let part = &self.parts[agent];
        let mapped: Vec<usize> = idx.iter().map(|&i| part[i]).collect();
        self.grad_over(x, &mapped, out);
    }

    fn n_samples(&self, agent: usize) -> usize {
        self.parts[agent].len()
    }

    fn round_cost_hint(&self) -> Option<usize> {
        // One full-gradient pass streams every local sample's logits and
        // per-class residuals: samples · d_feat · classes elements — the
        // regime where a modest-d problem is still gradient-heavy (the
        // driver's message-size rule alone would call it "small").
        let max_samples = (0..self.n_agents).map(|i| self.parts[i].len()).max().unwrap_or(0);
        Some(max_samples.saturating_mul(self.dim()))
    }

    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        self.loss_over(x, &self.parts[agent])
    }

    fn optimum(&self) -> Option<&[f64]> {
        self.xstar.as_deref()
    }

    fn mu_l(&self) -> Option<(f64, f64)> {
        // μ = λ from the regularizer. L ≤ λ + max_i σmax(X_i)²/(2N_i) for
        // softmax CE (Hessian ≼ ½ XᵀX/N per agent); we report the crude
        // global bound λ + max_s ‖x_s‖²/2 which is cheap and safe.
        let max_row = (0..self.ds.n)
            .map(|s| linalg::norm2_sq(self.ds.row(s)))
            .fold(0.0f64, f64::max);
        Some((self.lambda as f64, self.lambda as f64 + 0.5 * max_row))
    }

    fn name(&self) -> String {
        format!(
            "logreg(n={}, d={}x{}, λ={}, {:?})",
            self.n_agents, self.d_feat, self.classes, self.lambda, self.split
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(split: DataSplit, solve: bool) -> LogReg {
        LogReg::synthetic(4, 240, 12, 4, 1e-3, split, 17, solve)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = small(DataSplit::Heterogeneous, false);
        let d = p.dim();
        let mut rng = crate::rng::Rng::new(2);
        let x: Vec<f64> = (0..d).map(|_| 0.3 * rng.normal_f64()).collect();
        let mut g = vec![0.0f64; d];
        p.grad_full(1, &x, &mut g);
        let h = 1e-2f64;
        for &j in &[0usize, 5, 17, d - 1] {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.loss(1, &xp) - p.loss(1, &xm)) / (2.0 * h as f64);
            assert!(
                (fd - g[j] as f64).abs() < 1e-3 + 0.05 * fd.abs(),
                "coord {j}: fd={fd} analytic={}",
                g[j]
            );
        }
    }

    #[test]
    fn optimum_stationary() {
        let p = small(DataSplit::Homogeneous, true);
        let xs = p.optimum().unwrap().to_vec();
        let mut g = vec![0.0f64; p.dim()];
        p.global_grad(&xs, &mut g);
        assert!(linalg::norm2(&g) < 1e-4, "‖∇f(x*)‖ = {}", linalg::norm2(&g));
    }

    #[test]
    fn training_improves_accuracy() {
        let p = small(DataSplit::Homogeneous, true);
        let xs = p.optimum().unwrap();
        let acc0 = p.accuracy(&vec![0.0; p.dim()]);
        let acc = p.accuracy(xs);
        assert!(acc > acc0 + 0.2, "acc {acc0} -> {acc}");
        assert!(acc > 0.5, "optimum accuracy only {acc}");
    }

    #[test]
    fn hetero_more_heterogeneous_than_homo() {
        let ph = small(DataSplit::Heterogeneous, true);
        let po = small(DataSplit::Homogeneous, true);
        let hh = crate::problems::gradient_heterogeneity(&ph, ph.optimum().unwrap());
        let ho = crate::problems::gradient_heterogeneity(&po, po.optimum().unwrap());
        assert!(
            hh > 3.0 * ho,
            "hetero grad-diversity {hh} not ≫ homo {ho}"
        );
    }

    #[test]
    fn batch_gradient_unbiased_wrt_full() {
        // Average of per-sample batch gradients equals the full gradient.
        let p = small(DataSplit::Heterogeneous, false);
        let d = p.dim();
        let x: Vec<f64> = (0..d).map(|i| ((i % 7) as f64 - 3.0) * 0.05).collect();
        let n = p.n_samples(0);
        let mut full = vec![0.0f64; d];
        p.grad_full(0, &x, &mut full);
        let mut acc = vec![0.0f64; d];
        let mut g = vec![0.0f64; d];
        for s in 0..n {
            p.grad_batch(0, &x, &[s], &mut g);
            for (a, v) in acc.iter_mut().zip(&g) {
                *a += *v as f64;
            }
        }
        for (a, f) in acc.iter().zip(&full) {
            let avg = a / n as f64;
            assert!((avg - *f as f64).abs() < 1e-4, "avg={avg} full={f}");
        }
    }
}
