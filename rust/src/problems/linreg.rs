//! ℓ₂-regularized linear regression (paper §5, Fig. 1):
//!
//! ```text
//! f_i(x) = ‖A_i x − b_i‖² + λ‖x‖²,  A_i ∈ R^{m×d},  b_i = A_i x' + ε
//! ```
//!
//! The paper uses n = 8 agents, A_i ∈ R^{200×200}, λ = 0.1 and the
//! full-batch gradient, so the problem is smooth + strongly convex and
//! LEAD's linear rate is observable directly. The global optimum has the
//! closed form `(Σ A_iᵀA_i + nλI) x* = Σ A_iᵀ b_i`, solved here in f64 via
//! Cholesky at construction time.

use super::Problem;
use crate::linalg::{self, Mat};
use crate::rng::{streams, Rng};

pub struct LinReg {
    pub n_agents: usize,
    pub d: usize,
    pub m: usize,
    pub lambda: f64,
    /// Per-agent data matrices, row-major m×d.
    pub a: Vec<Vec<f64>>,
    /// Per-agent targets, length m.
    pub b: Vec<Vec<f64>>,
    xstar: Vec<f64>,
    mu_l: (f64, f64),
}

impl LinReg {
    /// The paper's synthetic setup: square A_i with N(0, 1/√d) entries,
    /// planted solution x', Gaussian target noise.
    pub fn synthetic(n_agents: usize, d: usize, lambda: f64, seed: u64) -> LinReg {
        Self::synthetic_rect(n_agents, d, d, lambda, seed)
    }

    /// General m×d variant (used by tests with small shapes).
    pub fn synthetic_rect(n_agents: usize, m: usize, d: usize, lambda: f64, seed: u64) -> LinReg {
        let root = Rng::new(seed).derive(streams::DATA);
        let mut xp = vec![0.0f64; d];
        root.derive(1000).fill_normal(&mut xp, 1.0);
        let scale = 1.0 / (d as f64).sqrt();
        let mut a = Vec::with_capacity(n_agents);
        let mut b = Vec::with_capacity(n_agents);
        for i in 0..n_agents {
            let mut rng = root.derive(i as u64);
            let mut ai = vec![0.0f64; m * d];
            rng.fill_normal(&mut ai, scale);
            let mut bi = vec![0.0f64; m];
            for r in 0..m {
                let row = &ai[r * d..(r + 1) * d];
                bi[r] = linalg::dot(row, &xp) as f64 + 0.1 * rng.normal_f64();
            }
            a.push(ai);
            b.push(bi);
        }
        let (xstar, mu_l) = Self::solve_optimum(n_agents, m, d, lambda, &a, &b);
        LinReg { n_agents, d, m, lambda, a, b, xstar, mu_l }
    }

    /// Closed-form optimum and (μ, L) from the per-agent Hessians
    /// `H_i = 2 A_iᵀ A_i + 2λ I`.
    fn solve_optimum(
        n: usize,
        m: usize,
        d: usize,
        lambda: f64,
        a: &[Vec<f64>],
        b: &[Vec<f64>],
    ) -> (Vec<f64>, (f64, f64)) {
        // Accumulate Σ AᵀA and Σ Aᵀb in f64.
        let mut gram = Mat::zeros(d, d);
        let mut rhs = vec![0.0f64; d];
        for i in 0..n {
            for r in 0..m {
                let row = &a[i][r * d..(r + 1) * d];
                let bi = b[i][r] as f64;
                for p in 0..d {
                    let ap = row[p] as f64;
                    rhs[p] += ap * bi;
                    let grow = &mut gram.data[p * d..(p + 1) * d];
                    for q in 0..d {
                        grow[q] += ap * row[q] as f64;
                    }
                }
            }
        }
        // (Σ AᵀA + nλ I) x* = Σ Aᵀ b.
        let mut sys = gram.clone();
        for p in 0..d {
            sys[(p, p)] += n as f64 * lambda as f64;
        }
        let x64 = crate::linalg::solve_spd(&sys, &rhs);
        let xstar: Vec<f64> = x64.iter().map(|&v| v as f64).collect();
        // Assumption 4 is about each local f_i: report the worst-case
        // per-agent constants, μ = min_i λmin(H_i), L = max_i λmax(H_i)
        // with H_i = 2A_iᵀA_i + 2λI. Full Jacobi for small d; power
        // iteration (L only, μ from the regularizer) for large d.
        let per_agent_hessian = |i: usize| {
            let mut h = Mat::zeros(d, d);
            for r in 0..m {
                let row = &a[i][r * d..(r + 1) * d];
                for p in 0..d {
                    let ap = 2.0 * row[p] as f64;
                    let hrow = &mut h.data[p * d..(p + 1) * d];
                    for q in 0..d {
                        hrow[q] += ap * row[q] as f64;
                    }
                }
            }
            for p in 0..d {
                h[(p, p)] += 2.0 * lambda as f64;
            }
            h
        };
        let (mu, l) = if d <= 64 {
            let mut mu = f64::INFINITY;
            let mut l = 0.0f64;
            for i in 0..n {
                let ev = crate::linalg::eigvals_sym(&per_agent_hessian(i));
                mu = mu.min(ev[0]);
                l = l.max(ev[d - 1]);
            }
            (mu, l)
        } else {
            let mut l = 0.0f64;
            for i in 0..n {
                l = l.max(crate::linalg::lambda_max_sym(&per_agent_hessian(i), 200));
            }
            (2.0 * lambda as f64, l) // μ ≥ 2λ always holds
        };
        let _ = gram;
        (xstar, (mu, l))
    }
}

impl Problem for LinReg {
    fn dim(&self) -> usize {
        self.d
    }

    fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// ∇f_i(x) = 2 A_iᵀ (A_i x − b_i) + 2λ x.
    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        let (m, d) = (self.m, self.d);
        let a = &self.a[agent];
        let b = &self.b[agent];
        for (o, xi) in out.iter_mut().zip(x) {
            *o = 2.0 * self.lambda * xi;
        }
        // out += 2 Aᵀ (A x − b), computed row-wise to stay cache-friendly.
        for r in 0..m {
            let row = &a[r * d..(r + 1) * d];
            let resid = 2.0 * (linalg::dot(row, x) as f64 - b[r]);
            linalg::axpy(resid, row, out);
        }
    }

    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        let (m, d) = (self.m, self.d);
        let a = &self.a[agent];
        let mut s = 0.0f64;
        for r in 0..m {
            let row = &a[r * d..(r + 1) * d];
            let e = linalg::dot(row, x) - self.b[agent][r] as f64;
            s += e * e;
        }
        s + self.lambda as f64 * linalg::norm2_sq(x)
    }

    fn optimum(&self) -> Option<&[f64]> {
        Some(&self.xstar)
    }

    fn mu_l(&self) -> Option<(f64, f64)> {
        Some(self.mu_l)
    }

    fn name(&self) -> String {
        format!(
            "linreg(n={}, A=R^{}x{}, λ={})",
            self.n_agents, self.m, self.d, self.lambda
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Central finite-difference check of the analytic gradient.
    #[test]
    fn gradient_matches_finite_difference() {
        let p = LinReg::synthetic_rect(3, 12, 10, 0.1, 11);
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..10).map(|_| rng.normal_f64()).collect();
        let mut g = vec![0.0f64; 10];
        for agent in 0..3 {
            p.grad_full(agent, &x, &mut g);
            let h = 1e-3f64;
            for j in 0..10 {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[j] += h;
                xm[j] -= h;
                let fd = (p.loss(agent, &xp) - p.loss(agent, &xm)) / (2.0 * h as f64);
                assert!(
                    (fd - g[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "agent {agent} coord {j}: fd={fd} analytic={}",
                    g[j]
                );
            }
        }
    }

    #[test]
    fn optimum_is_stationary_and_minimal() {
        let p = LinReg::synthetic(4, 40, 0.1, 21);
        let xs = p.optimum().unwrap().to_vec();
        let mut g = vec![0.0f64; 40];
        p.global_grad(&xs, &mut g);
        assert!(linalg::norm2(&g) < 1e-3);
        // Perturbation increases the global loss.
        let f0 = p.global_loss(&xs);
        let mut rng = Rng::new(5);
        for _ in 0..5 {
            let mut xp = xs.clone();
            for v in xp.iter_mut() {
                *v += 0.1 * rng.normal_f64();
            }
            assert!(p.global_loss(&xp) > f0);
        }
    }

    #[test]
    fn mu_l_bracket_hessian() {
        let p = LinReg::synthetic(3, 20, 0.1, 31);
        let (mu, l) = p.mu_l().unwrap();
        assert!(mu > 0.0 && l >= mu, "mu={mu} l={l}");
        // λmin ≥ 2λ for the regularized problem.
        assert!(mu >= 2.0 * 0.1 - 1e-9);
    }

    #[test]
    fn deterministic() {
        let p1 = LinReg::synthetic(2, 10, 0.1, 7);
        let p2 = LinReg::synthetic(2, 10, 0.1, 7);
        assert_eq!(p1.a, p2.a);
        assert_eq!(p1.xstar, p2.xstar);
    }
}
