//! Separable quadratic `f_i(x) = ½‖x − b_i‖²` with random targets.
//!
//! The cheapest possible heterogeneous problem: the gradient oracle is a
//! single allocation-free O(d) pass, so harnesses that time or audit the
//! *engine* (the hotpath bench's scheduler A/B, the steady-state
//! zero-allocation test) see the communication path, not the problem.
//! The global optimum is the mean of the targets, but it is deliberately
//! not exposed (`optimum() = None`) to keep metric passes O(n·d) with no
//! setup-time solve.

use super::Problem;
use crate::rng::Rng;

pub struct Quad {
    n: usize,
    d: usize,
    targets: Vec<Vec<f64>>,
}

impl Quad {
    /// `n` agents, dimension `d`, targets drawn i.i.d. N(0, 1) from `seed`.
    pub fn new(n: usize, d: usize, seed: u64) -> Self {
        // audit:allow(rng_stream): problem-local synthesis root for the bench/alloc harness problem; the engine's per-run stream tree is untouched
        let mut rng = Rng::new(seed);
        let targets = (0..n)
            .map(|_| {
                let mut b = vec![0.0f64; d];
                rng.fill_normal(&mut b, 1.0);
                b
            })
            .collect();
        Quad { n, d, targets }
    }
}

impl Problem for Quad {
    fn dim(&self) -> usize {
        self.d
    }

    fn n_agents(&self) -> usize {
        self.n
    }

    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]) {
        let b = &self.targets[agent];
        for t in 0..x.len() {
            out[t] = x[t] - b[t];
        }
    }

    fn loss(&self, agent: usize, x: &[f64]) -> f64 {
        0.5 * crate::linalg::dist_sq(x, &self.targets[agent])
    }

    fn optimum(&self) -> Option<&[f64]> {
        None
    }

    fn name(&self) -> String {
        format!("quad(n={}, d={})", self.n, self.d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_and_loss_are_consistent() {
        let p = Quad::new(3, 16, 9);
        let mut x = vec![0.0f64; 16];
        Rng::new(1).fill_normal(&mut x, 1.0);
        let mut g = vec![0.0f64; 16];
        p.grad_full(1, &x, &mut g);
        // f(x) − f(x − εg) ≈ ε‖g‖² for the quadratic.
        let eps = 1e-6;
        let stepped: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi - eps * gi).collect();
        let drop = p.loss(1, &x) - p.loss(1, &stepped);
        let expect = eps * crate::linalg::norm2_sq(&g);
        assert!((drop - expect).abs() < 1e-9, "drop {drop} vs {expect}");
        // At the target the gradient vanishes.
        p.grad_full(1, &p.targets[1].clone(), &mut g);
        assert!(g.iter().all(|&v| v == 0.0));
    }
}
