//! Limited-memory BFGS with Armijo backtracking.
//!
//! Used at problem-setup time to compute high-precision reference optima
//! x* for objectives without a closed form (logistic regression), so the
//! paper's "distance to x*" metric is well defined. Written against a
//! closure interface so it is reusable as a centralized baseline solver.

use crate::linalg;

/// Result of an L-BFGS run.
#[derive(Clone, Debug)]
pub struct LbfgsResult {
    pub x: Vec<f64>,
    pub f: f64,
    pub grad_norm: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Solver options.
#[derive(Clone, Debug)]
pub struct LbfgsOptions {
    /// History size m.
    pub memory: usize,
    pub max_iters: usize,
    /// Stop when ‖∇f‖ falls below this.
    pub grad_tol: f64,
    /// Armijo sufficient-decrease constant.
    pub c1: f64,
    /// Max backtracking steps per iteration.
    pub max_ls: usize,
}

impl Default for LbfgsOptions {
    fn default() -> Self {
        LbfgsOptions { memory: 10, max_iters: 2000, grad_tol: 1e-9, c1: 1e-4, max_ls: 40 }
    }
}

/// Minimize `f` with value+gradient oracle `fg(x, grad_out) -> f(x)`.
pub fn minimize<F>(x0: &[f64], opts: &LbfgsOptions, mut fg: F) -> LbfgsResult
where
    F: FnMut(&[f64], &mut [f64]) -> f64,
{
    let d = x0.len();
    let m = opts.memory;
    let mut x = x0.to_vec();
    let mut g = vec![0.0f64; d];
    let mut f = fg(&x, &mut g);

    // Ring buffers of correction pairs (s, y) and ρ = 1/(yᵀs).
    let mut s_hist: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut y_hist: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rho: Vec<f64> = Vec::with_capacity(m);

    let mut dir = vec![0.0f64; d];
    let mut x_new = vec![0.0f64; d];
    let mut g_new = vec![0.0f64; d];

    for it in 0..opts.max_iters {
        let gnorm = linalg::norm2(&g);
        if gnorm < opts.grad_tol {
            return LbfgsResult { x, f, grad_norm: gnorm, iterations: it, converged: true };
        }

        // Two-loop recursion: dir = −H_k ∇f.
        dir.copy_from_slice(&g);
        let k = s_hist.len();
        let mut alpha = vec![0.0f64; k];
        for i in (0..k).rev() {
            alpha[i] = rho[i] * linalg::dot(&s_hist[i], &dir);
            linalg::axpy(-alpha[i] as f64, &y_hist[i], &mut dir);
        }
        // Initial Hessian scaling γ = sᵀy/yᵀy (Nocedal & Wright eq. 7.20).
        if k > 0 {
            let last = k - 1;
            let gamma = (1.0 / rho[last]) / linalg::norm2_sq(&y_hist[last]).max(1e-300);
            linalg::scale(&mut dir, gamma as f64);
        }
        for i in 0..k {
            let beta = rho[i] * linalg::dot(&y_hist[i], &dir);
            linalg::axpy((alpha[i] - beta) as f64, &s_hist[i], &mut dir);
        }
        linalg::scale(&mut dir, -1.0);

        // Directional derivative; fall back to steepest descent if the
        // two-loop direction is not a descent direction (can happen with
        // f64 roundoff when nearly converged).
        let mut dg = linalg::dot(&dir, &g);
        if dg >= 0.0 {
            dir.copy_from_slice(&g);
            linalg::scale(&mut dir, -1.0);
            dg = -linalg::norm2_sq(&g);
        }

        // Armijo backtracking from t = 1.
        let mut t = 1.0f64;
        let mut accepted = false;
        for _ in 0..opts.max_ls {
            for j in 0..d {
                x_new[j] = x[j] + (t as f64) * dir[j];
            }
            let f_new = fg(&x_new, &mut g_new);
            if f_new <= f + opts.c1 * t * dg {
                // Update history with s = x⁺−x, y = ∇f⁺−∇f.
                let mut s = vec![0.0f64; d];
                let mut yv = vec![0.0f64; d];
                linalg::sub(&x_new, &x, &mut s);
                linalg::sub(&g_new, &g, &mut yv);
                let ys = linalg::dot(&yv, &s);
                if ys > 1e-12 {
                    if s_hist.len() == m {
                        s_hist.remove(0);
                        y_hist.remove(0);
                        rho.remove(0);
                    }
                    rho.push(1.0 / ys);
                    s_hist.push(s);
                    y_hist.push(yv);
                }
                x.copy_from_slice(&x_new);
                g.copy_from_slice(&g_new);
                f = f_new;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            // Line search failed: we are at f64 resolution of the optimum.
            let gnorm = linalg::norm2(&g);
            return LbfgsResult { x, f, grad_norm: gnorm, iterations: it, converged: gnorm < 1e-4 };
        }
    }
    let gnorm = linalg::norm2(&g);
    LbfgsResult { x, f, grad_norm: gnorm, iterations: opts.max_iters, converged: gnorm < opts.grad_tol }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_exact() {
        // f(x) = ½ Σ c_i (x_i − t_i)², solution x = t.
        let c = [1.0f64, 4.0, 0.5, 10.0];
        let t = [2.0f64, -1.0, 0.25, 3.0];
        let res = minimize(&[0.0; 4], &LbfgsOptions::default(), |x, g| {
            let mut f = 0.0f64;
            for i in 0..4 {
                let e = x[i] - t[i];
                g[i] = c[i] * e;
                f += 0.5 * (c[i] * e * e) as f64;
            }
            f
        });
        assert!(res.converged, "{res:?}");
        for i in 0..4 {
            assert!((res.x[i] - t[i]).abs() < 1e-5, "{res:?}");
        }
        assert!(res.iterations < 50);
    }

    #[test]
    fn rosenbrock_2d() {
        // Classic non-quadratic test: min at (1, 1).
        let res = minimize(&[-1.2, 1.0], &LbfgsOptions { max_iters: 5000, grad_tol: 1e-7, ..Default::default() }, |x, g| {
            let (a, b) = (x[0] as f64, x[1] as f64);
            g[0] = (-2.0 * (1.0 - a) - 400.0 * a * (b - a * a)) as f64;
            g[1] = (200.0 * (b - a * a)) as f64;
            (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
        });
        assert!((res.x[0] - 1.0).abs() < 1e-3 && (res.x[1] - 1.0).abs() < 1e-3, "{res:?}");
    }

    #[test]
    fn matches_linreg_closed_form() {
        use crate::problems::{linreg::LinReg, Problem};
        let p = LinReg::synthetic(3, 25, 0.1, 13);
        let d = p.dim();
        let res = minimize(&vec![0.0; d], &LbfgsOptions::default(), |x, g| {
            p.global_grad(x, g);
            p.global_loss(x)
        });
        let xstar = p.optimum().unwrap();
        let err = crate::linalg::dist_sq(&res.x, xstar).sqrt();
        assert!(err < 1e-3, "‖lbfgs − closed form‖ = {err}");
    }
}
