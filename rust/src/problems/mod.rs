//! Optimization problems: local objectives `f_i`, data partitioning, and
//! reference optima.
//!
//! A [`Problem`] owns the data of all `n` agents and exposes per-agent
//! gradients/losses. The coordinator engine calls `grad_full` (Figs. 1–2)
//! or `grad_batch` with engine-sampled indices (Figs. 3–4). Reference
//! optima `x*` (for the paper's "distance to x*" metric) come from a
//! closed-form solve (linear regression) or the in-repo L-BFGS
//! ([`lbfgs`]) run to high precision at setup time.

pub mod data;
pub mod lbfgs;
pub mod linreg;
pub mod logreg;
pub mod neural;
pub mod quad;

/// How data is partitioned across agents (paper §5, logistic regression).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataSplit {
    /// Samples shuffled before uniform partitioning — every agent sees a
    /// near-identical distribution.
    Homogeneous,
    /// Samples sorted by label before partitioning — each agent sees only
    /// one or two classes. This is the regime where DGD-type compressed
    /// algorithms diverge (paper Fig. 4) and LEAD's gradient correction
    /// matters.
    Heterogeneous,
}

impl DataSplit {
    pub fn parse(s: &str) -> Option<DataSplit> {
        match s {
            "homo" | "homogeneous" => Some(DataSplit::Homogeneous),
            "hetero" | "heterogeneous" => Some(DataSplit::Heterogeneous),
            _ => None,
        }
    }
}

/// A decentralized optimization problem: `min (1/n) Σ f_i(x)`.
pub trait Problem: Send + Sync {
    /// Parameter dimension d.
    fn dim(&self) -> usize;

    /// Number of agents n.
    fn n_agents(&self) -> usize;

    /// Full local gradient `∇f_i(x)` written into `out`.
    fn grad_full(&self, agent: usize, x: &[f64], out: &mut [f64]);

    /// Stochastic gradient over local sample indices `idx` (mini-batch).
    /// Problems without sample structure fall back to the full gradient.
    fn grad_batch(&self, agent: usize, x: &[f64], idx: &[usize], out: &mut [f64]) {
        let _ = idx;
        self.grad_full(agent, x, out);
    }

    /// Number of local samples at an agent (0 ⇒ full-batch only).
    fn n_samples(&self, agent: usize) -> usize {
        let _ = agent;
        0
    }

    /// Local objective value `f_i(x)`.
    fn loss(&self, agent: usize, x: &[f64]) -> f64;

    /// Global objective `f(x) = (1/n) Σ f_i(x)`.
    fn global_loss(&self, x: &[f64]) -> f64 {
        let n = self.n_agents();
        (0..n).map(|i| self.loss(i, x)).sum::<f64>() / n as f64
    }

    /// Global gradient `(1/n) Σ ∇f_i(x)` (setup/diagnostics path).
    fn global_grad(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n_agents();
        let mut tmp = vec![0.0f64; self.dim()];
        out.fill(0.0);
        for i in 0..n {
            self.grad_full(i, x, &mut tmp);
            crate::linalg::axpy(1.0 / n as f64, &tmp, out);
        }
    }

    /// Reference optimum x*, if available.
    fn optimum(&self) -> Option<&[f64]>;

    /// Shared initial iterate x⁰ (consensus start). None ⇒ zeros. Neural
    /// problems return a random init (zero-init deep nets don't train).
    fn initial_point(&self) -> Option<Vec<f64>> {
        None
    }

    /// (μ, L) strong-convexity / smoothness constants of the local
    /// objectives, if known (used to check Theorem 1 stepsize ranges).
    fn mu_l(&self) -> Option<(f64, f64)> {
        None
    }

    /// Approximate per-agent cost of one full-gradient evaluation, in
    /// streamed-f64-element equivalents — a scheduling hint, never a
    /// correctness input. The scenario driver classifies runs as
    /// small (outer-sharded) or large (inner-parallel) by
    /// `max(round_cost_hint, channels·dim)`, so gradient-heavy problems
    /// at modest dimension (e.g. full-batch logistic regression over many
    /// samples) can claim the inner parallelism the default n·d message
    /// rule would deny them. `None` ⇒ classify by message size alone.
    fn round_cost_hint(&self) -> Option<usize> {
        None
    }

    fn name(&self) -> String;
}

/// Heterogeneity diagnostic: `(1/n) Σ_i ‖∇f_i(x*) − ∇f(x*)‖²`. Zero for
/// homogeneous objectives; strictly positive in the paper's heterogeneous
/// settings (§3.1: some `∇f_i(x*) ≠ 0` even at the optimum).
pub fn gradient_heterogeneity(p: &dyn Problem, at: &[f64]) -> f64 {
    let n = p.n_agents();
    let d = p.dim();
    let mut grads = crate::linalg::Mat::zeros(n, d);
    for i in 0..n {
        p.grad_full(i, at, grads.row_mut(i));
    }
    let mut mean = vec![0.0f64; d];
    crate::linalg::mean_rows(grads.rows_iter(), &mut mean);
    (0..n).map(|i| crate::linalg::dist_sq(grads.row(i), &mean)).sum::<f64>() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::linreg::LinReg;

    #[test]
    fn split_parse() {
        assert_eq!(DataSplit::parse("homo"), Some(DataSplit::Homogeneous));
        assert_eq!(DataSplit::parse("hetero"), Some(DataSplit::Heterogeneous));
        assert_eq!(DataSplit::parse("x"), None);
    }

    #[test]
    fn global_grad_zero_at_optimum() {
        let p = LinReg::synthetic(4, 30, 0.1, 7);
        let xstar = p.optimum().unwrap().to_vec();
        let mut g = vec![0.0f64; p.dim()];
        p.global_grad(&xstar, &mut g);
        let gn = crate::linalg::norm2(&g);
        assert!(gn < 1e-3, "‖∇f(x*)‖ = {gn}");
    }

    #[test]
    fn heterogeneity_positive_for_random_data() {
        let p = LinReg::synthetic(4, 30, 0.1, 7);
        let xstar = p.optimum().unwrap().to_vec();
        // Local gradients at the global optimum do NOT vanish (paper §3.1).
        let h = gradient_heterogeneity(&p, &xstar);
        assert!(h > 1e-3, "h = {h}");
    }
}
