//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the rust hot path. Python never runs at request time — it
//! only authored the artifacts (see python/compile/aot.py).
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits protos with
//! 64-bit ids that xla_extension 0.5.1 rejects; the text parser reassigns
//! ids — see /opt/xla-example/README.md).

pub mod artifact;
pub mod client;

pub use artifact::{Artifact, Manifest, ParamSpec};
pub use client::client;
