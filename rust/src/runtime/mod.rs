//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the rust hot path. Python never runs at request time — it
//! only authored the artifacts (see python/compile/aot.py).
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits protos with
//! 64-bit ids that xla_extension 0.5.1 rejects; the text parser reassigns
//! ids — see /opt/xla-example/README.md).
//!
//! The backend itself (the vendored `xla` PJRT bindings) is gated behind
//! the `pjrt` cargo feature; without it, manifest parsing still works and
//! `compile`/`execute` return a descriptive error.
//!
//! All PJRT access is serialized through one process-wide lock: see the
//! locking-discipline notes on `runtime::client` and the `Artifact`
//! invariant in [`artifact`]. Callers never lock manually —
//! `compile`/`execute`/drop take the lock internally, and `Artifact` is
//! Send + Sync because of it.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;

pub use artifact::{Artifact, Manifest, ParamSpec};
#[cfg(feature = "pjrt")]
pub use client::{client, lock, ClientGuard};
