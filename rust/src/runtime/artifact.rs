//! Artifact loading: manifest parsing, HLO-text compilation, typed
//! execution, and flat-parameter ↔ tensor mapping.
//!
//! Manifest parsing and the parameter mapping are pure rust and always
//! available; compilation/execution need the PJRT backend (`pjrt`
//! feature + vendored `xla` bindings). Without the feature, `compile`
//! and `execute` return a descriptive error so callers (experiment
//! drivers, integration tests) degrade to a skip instead of failing to
//! build.

use crate::error::{err, Result};
use crate::serialize::json::{self, Json};
use std::path::{Path, PathBuf};

/// One tensor port of an artifact.
#[derive(Clone, Debug)]
pub struct Port {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl Port {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Manifest entry describing one lowered computation.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Port>,
    pub outputs: Vec<Port>,
    /// Indices of inputs that are trainable parameters (for ParamSpec).
    pub param_inputs: Vec<usize>,
    /// Indices of inputs that are per-step data.
    pub data_inputs: Vec<usize>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

fn parse_port(v: &Json) -> Result<Port> {
    let name = v.get("name").and_then(Json::as_str).unwrap_or("").to_string();
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| err("port missing shape"))?
        .iter()
        .map(|s| s.as_usize().ok_or_else(|| err("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = v.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string();
    Ok(Port { name, shape, dtype })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let raw = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            err(format!("reading {}/manifest.json — run `make artifacts`: {e}", dir.display()))
        })?;
        let doc = json::parse(&raw).map_err(|e| err(format!("manifest parse: {e}")))?;
        let mut artifacts = Vec::new();
        for a in doc.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            let idxs = |key: &str| -> Vec<usize> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .map(|v| v.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default()
            };
            artifacts.push(ArtifactMeta {
                name: a.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                file: a.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                inputs: a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_port)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_port)
                    .collect::<Result<Vec<_>>>()?,
                param_inputs: idxs("param_inputs"),
                data_inputs: idxs("data_inputs"),
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| err(format!("artifact {name:?} not in manifest")))
    }
}

/// A runtime input value (f64 host data is converted to the artifact's
/// declared dtype at the FFI boundary).
pub enum Value<'a> {
    F(&'a [f64]),
    I(&'a [i32]),
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::*;
    use std::mem::ManuallyDrop;

    impl Manifest {
        /// Compile one artifact on the shared PJRT client.
        pub fn compile(&self, name: &str) -> Result<Artifact> {
            let meta = self.get(name)?.clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| err(format!("loading {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let guard = crate::runtime::client::lock();
            let exe = crate::runtime::client::client(&guard)
                .compile(&comp)
                .map_err(|e| err(format!("compiling {name}: {e:?}")))?;
            Ok(Artifact { meta, exe: ManuallyDrop::new(exe) })
        }
    }

    /// A compiled computation plus its port metadata.
    ///
    /// Invariant: `exe` (an `Rc`-backed xla wrapper, hence !Send/!Sync)
    /// is only ever touched with the process-wide
    /// [`client::lock`](crate::runtime::client::lock) held — at
    /// construction in [`Manifest::compile`], in [`Artifact::execute`],
    /// and in `Drop`. That serialization is what makes the `Send`/`Sync`
    /// impls below sound, letting the engine's worker pool share
    /// problems that own artifacts.
    pub struct Artifact {
        pub meta: ArtifactMeta,
        /// `ManuallyDrop` so `Drop::drop` can destroy it while still
        /// holding the client lock (a plain field would drop *after* the
        /// drop body returns, once the lock guard is already released).
        exe: ManuallyDrop<xla::PjRtLoadedExecutable>,
    }

    // SAFETY: `xla::PjRtLoadedExecutable` is !Send only because of its
    // non-atomic `Rc` refcounts; the underlying PJRT CPU executable is
    // thread-safe for serialized calls. `exe` is private, never cloned
    // out, and every access (construction, execute, drop) holds the
    // process-wide client lock — see the struct invariant above — so
    // moving an `Artifact` across threads can never race the refcounts.
    unsafe impl Send for Artifact {}
    // SAFETY: same invariant — `execute(&self)` is the only shared-access
    // path to `exe` and it takes the process-wide client lock first, so
    // concurrent `&Artifact` use from the worker pool is fully
    // serialized.
    unsafe impl Sync for Artifact {}

    impl Drop for Artifact {
        fn drop(&mut self) {
            let _guard = crate::runtime::client::lock();
            // SAFETY: `exe` was initialized in `Manifest::compile` and is
            // dropped exactly once, here; `ManuallyDrop` exists precisely
            // so this runs before `_guard` releases the client lock.
            unsafe { ManuallyDrop::drop(&mut self.exe) };
        }
    }

    impl Artifact {
        /// Execute with positional inputs; returns each output flattened to
        /// f64 (scalars come back as length-1 vectors). Serialized against
        /// all other PJRT activity by the process-wide client lock.
        pub fn execute(&self, inputs: &[Value]) -> Result<Vec<Vec<f64>>> {
            let _guard = crate::runtime::client::lock();
            if inputs.len() != self.meta.inputs.len() {
                return Err(err(format!(
                    "{}: {} inputs given, {} expected",
                    self.meta.name,
                    inputs.len(),
                    self.meta.inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (v, port) in inputs.iter().zip(&self.meta.inputs) {
                let lit = match v {
                    Value::F(data) => {
                        if data.len() != port.elements() {
                            return Err(err(format!(
                                "{}: input {} has {} elements, wants {:?}",
                                self.meta.name,
                                port.name,
                                data.len(),
                                port.shape
                            )));
                        }
                        let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
                        shaped(xla::Literal::vec1(&f32s), &port.shape)?
                    }
                    Value::I(data) => {
                        if data.len() != port.elements() {
                            return Err(err(format!(
                                "{}: int input {} wrong size",
                                self.meta.name, port.name
                            )));
                        }
                        shaped(xla::Literal::vec1(data), &port.shape)?
                    }
                };
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| err(format!("{}: execute: {e:?}", self.meta.name)))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| err(format!("{}: to_literal: {e:?}", self.meta.name)))?;
            // aot.py lowers with return_tuple=True: unpack all outputs.
            let parts = tuple
                .to_tuple()
                .map_err(|e| err(format!("{}: to_tuple: {e:?}", self.meta.name)))?;
            let mut out = Vec::with_capacity(parts.len());
            for part in parts {
                let v: Vec<f32> = part
                    .to_vec()
                    .map_err(|e| err(format!("{}: to_vec: {e:?}", self.meta.name)))?;
                out.push(v.into_iter().map(|x| x as f64).collect());
            }
            Ok(out)
        }
    }

    fn shaped(lit: xla::Literal, shape: &[usize]) -> Result<xla::Literal> {
        if shape.len() <= 1 {
            // vec1 already has rank ≤ 1; scalars: reshape to rank 0.
            if shape.is_empty() {
                return lit.reshape(&[]).map_err(|e| err(format!("reshape scalar: {e:?}")));
            }
            return Ok(lit);
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).map_err(|e| err(format!("reshape {shape:?}: {e:?}")))
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::*;

    impl Manifest {
        /// Stub: the PJRT backend is not compiled in. Validates the name
        /// against the manifest, then reports the backend as unavailable so
        /// callers skip gracefully.
        pub fn compile(&self, name: &str) -> Result<Artifact> {
            let _ = self.get(name)?;
            Err(err(format!(
                "artifact {name:?}: PJRT backend not built — enable the `pjrt` feature \
                 with the vendored `xla` bindings"
            )))
        }
    }

    /// Stub artifact (never constructed without the `pjrt` feature; the
    /// type exists so downstream signatures compile unchanged).
    pub struct Artifact {
        pub meta: ArtifactMeta,
    }

    impl Artifact {
        pub fn execute(&self, _inputs: &[Value]) -> Result<Vec<Vec<f64>>> {
            Err(err(format!("{}: PJRT backend not built", self.meta.name)))
        }
    }
}

pub use backend::Artifact;

/// Mapping between a flat f64 parameter vector (what the decentralized
/// algorithms operate on) and the per-tensor inputs of an artifact.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    /// (offset, len, shape) per parameter tensor, in artifact input order.
    pub slots: Vec<(usize, usize, Vec<usize>)>,
    pub total: usize,
}

impl ParamSpec {
    pub fn from_meta(meta: &ArtifactMeta) -> ParamSpec {
        let mut slots = Vec::new();
        let mut off = 0;
        for &i in &meta.param_inputs {
            let n = meta.inputs[i].elements();
            slots.push((off, n, meta.inputs[i].shape.clone()));
            off += n;
        }
        ParamSpec { slots, total: off }
    }

    /// Views of `flat` per parameter tensor.
    pub fn split<'a>(&self, flat: &'a [f64]) -> Vec<&'a [f64]> {
        assert_eq!(flat.len(), self.total, "flat parameter size mismatch");
        self.slots.iter().map(|&(o, n, _)| &flat[o..o + n]).collect()
    }

    /// Concatenate tensor buffers back into `flat`.
    pub fn gather(&self, parts: &[Vec<f64>], flat: &mut [f64]) {
        assert_eq!(parts.len(), self.slots.len());
        for ((o, n, _), p) in self.slots.iter().zip(parts) {
            assert_eq!(p.len(), *n);
            flat[*o..*o + *n].copy_from_slice(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        // Artifact tests only run when `make artifacts` has been executed;
        // pure-unit CI paths skip gracefully.
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn manifest_loads_and_lists() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        assert!(m.artifacts.len() >= 8);
        let lin = m.get("linreg_grad").unwrap();
        assert_eq!(lin.inputs.len(), 4);
        assert_eq!(lin.inputs[0].shape, vec![200, 200]);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn param_spec_roundtrip() {
        let meta = ArtifactMeta {
            name: "t".into(),
            file: "t".into(),
            inputs: vec![
                Port { name: "w1".into(), shape: vec![3, 2], dtype: "float32".into() },
                Port { name: "b1".into(), shape: vec![2], dtype: "float32".into() },
                Port { name: "x".into(), shape: vec![5], dtype: "float32".into() },
            ],
            outputs: vec![],
            param_inputs: vec![0, 1],
            data_inputs: vec![2],
        };
        let spec = ParamSpec::from_meta(&meta);
        assert_eq!(spec.total, 8);
        let flat: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let parts = spec.split(&flat);
        assert_eq!(parts[0], &flat[0..6]);
        assert_eq!(parts[1], &flat[6..8]);
        let owned: Vec<Vec<f64>> = parts.iter().map(|p| p.to_vec()).collect();
        let mut back = vec![0.0; 8];
        spec.gather(&owned, &mut back);
        assert_eq!(back, flat);
    }
}
