//! Process-wide PJRT CPU client (creating one per artifact would leak a
//! thread pool each time; XLA clients are expensive singletons).
//!
//! SAFETY: the `xla` crate wraps the client in a non-atomic `Rc`, so the
//! type is !Send/!Sync even though the PJRT CPU plugin itself is
//! thread-safe. We never clone the wrapper after init and serialize every
//! compile through [`compile_lock`]; executions are serialized by the
//! problem-level mutexes in `problems::neural`.

use std::sync::{Mutex, OnceLock};

struct SharedClient(xla::PjRtClient);
unsafe impl Send for SharedClient {}
unsafe impl Sync for SharedClient {}

static CLIENT: OnceLock<SharedClient> = OnceLock::new();
static COMPILE_LOCK: Mutex<()> = Mutex::new(());

/// The shared PJRT CPU client. Panics if the plugin cannot initialize —
/// there is nothing useful the caller can do without a backend.
pub fn client() -> &'static xla::PjRtClient {
    &CLIENT
        .get_or_init(|| {
            SharedClient(xla::PjRtClient::cpu().expect("failed to initialize PJRT CPU client"))
        })
        .0
}

/// Guards XLA compilation (see module SAFETY note).
pub fn compile_lock() -> std::sync::MutexGuard<'static, ()> {
    COMPILE_LOCK.lock().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initializes_once() {
        let a = super::client();
        let b = super::client();
        assert_eq!(a.platform_name(), b.platform_name());
        assert!(a.device_count() >= 1);
    }
}
