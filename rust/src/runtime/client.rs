//! Process-wide PJRT CPU client (creating one per artifact would leak a
//! thread pool each time; XLA clients are expensive singletons).
//!
//! # Locking discipline
//!
//! The `xla` crate wraps the client and its executables in non-atomic
//! `Rc` refcounts, so the types are !Send/!Sync even though the PJRT CPU
//! plugin itself is thread-safe. Rather than asserting thread safety per
//! problem type (the old blanket impls in `problems::neural`), every
//! access to the client now goes through a single process-wide mutex:
//! [`lock`] returns a [`ClientGuard`], and [`client`] *requires* a
//! `&ClientGuard` argument, so "the lock is held" is proved at compile
//! time instead of by convention. `Artifact` (the only other holder of
//! an `Rc`-backed xla value) takes the same lock around execute and
//! drop — see `runtime::artifact`.

use std::sync::{Mutex, MutexGuard, OnceLock};

struct SharedClient(xla::PjRtClient);

// SAFETY: `xla::PjRtClient` is !Send only because of its non-atomic `Rc`
// refcount. The one instance lives in the private `CLIENT` static below,
// is never cloned, and is only reachable through `client(&ClientGuard)`,
// so every touch — including the refcount bump a hypothetical clone would
// do — happens under `CLIENT_LOCK` and cannot race across threads.
unsafe impl Send for SharedClient {}
// SAFETY: same invariant as the `Send` impl above — all shared (`&`)
// access is serialized by `CLIENT_LOCK` via the `ClientGuard` proof
// token, and the PJRT CPU plugin itself is thread-safe for serialized
// compile/execute calls.
unsafe impl Sync for SharedClient {}

static CLIENT: OnceLock<SharedClient> = OnceLock::new();
static CLIENT_LOCK: Mutex<()> = Mutex::new(());

/// Proof token that the process-wide PJRT lock is held.
///
/// Obtainable only from [`lock`]; the lock releases when the guard
/// drops. APIs that touch xla's `Rc`-backed values take `&ClientGuard`
/// so the borrow checker enforces the serialization invariant.
pub struct ClientGuard {
    _held: MutexGuard<'static, ()>,
}

/// Acquire the process-wide PJRT lock.
pub fn lock() -> ClientGuard {
    // A failed artifact execute panics (`expect`) while holding the lock,
    // which poisons it; the client itself is left in a usable state by a
    // failed call, so recover instead of cascading poison errors.
    ClientGuard { _held: CLIENT_LOCK.lock().unwrap_or_else(|poison| poison.into_inner()) }
}

/// The shared PJRT CPU client; the `ClientGuard` is compile-time proof
/// that the caller holds the process-wide lock. Panics if the plugin
/// cannot initialize — there is nothing useful the caller can do
/// without a backend.
pub fn client<'g>(_proof: &'g ClientGuard) -> &'g xla::PjRtClient {
    &CLIENT
        .get_or_init(|| {
            SharedClient(xla::PjRtClient::cpu().expect("failed to initialize PJRT CPU client"))
        })
        .0
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initializes_once() {
        let g = super::lock();
        let a = super::client(&g);
        let b = super::client(&g);
        assert_eq!(a.platform_name(), b.platform_name());
        assert!(a.device_count() >= 1);
    }
}
