//! Binary-heap event queue with deterministic tie-breaking.
//!
//! The simulator's only ordering structure: a min-heap of transfer
//! events keyed by `(time, edge, attempt)`. Times are compared with
//! [`f64::total_cmp`], so the order is total even in the presence of
//! equal keys, and ties are broken by edge id then attempt number —
//! **never** by insertion order or heap internals. Two simulations fed
//! the same events therefore pop them in exactly the same sequence,
//! which is what makes the whole timing overlay reproducible
//! (`rust/tests/simnet.rs` pins this across thread counts and reruns).

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// One scheduled event: transfer attempt `attempt` on directed edge
/// `edge` completing at `at` seconds after the round started.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Round-relative completion time, seconds (finite, ≥ 0).
    pub at: f64,
    /// Directed-edge id ([`RoundTimer`](crate::simnet::round::RoundTimer)
    /// enumeration order).
    pub edge: u32,
    /// 0 for the first attempt; +1 per retransmit.
    pub attempt: u32,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.edge.cmp(&other.edge))
            .then(self.attempt.cmp(&other.attempt))
    }
}

/// Min-heap over [`Event`]s; reusable across rounds ([`EventQueue::clear`]
/// keeps the backing allocation, §Perf: no per-round heap growth after
/// warm-up).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Drop all pending events, keeping capacity.
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    pub fn push(&mut self, ev: Event) {
        debug_assert!(ev.at.is_finite() && ev.at >= 0.0, "event at t = {}", ev.at);
        self.heap.push(Reverse(ev));
    }

    /// Pop the earliest event (ties: lowest edge id, then lowest attempt).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(ev)| ev)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        for (at, edge) in [(3.0, 0u32), (1.0, 1), (2.0, 2), (1.5, 3)] {
            q.push(Event { at, edge, attempt: 0 });
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.edge).collect();
        assert_eq!(order, vec![1, 3, 2, 0]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_times_break_ties_by_edge_then_attempt() {
        // Push in scrambled order; equal times must still pop in
        // (edge, attempt) order regardless of insertion sequence.
        let mut q = EventQueue::new();
        let evs = [
            Event { at: 1.0, edge: 2, attempt: 0 },
            Event { at: 1.0, edge: 0, attempt: 1 },
            Event { at: 1.0, edge: 0, attempt: 0 },
            Event { at: 1.0, edge: 1, attempt: 0 },
        ];
        for &e in &evs {
            q.push(e);
        }
        let order: Vec<(u32, u32)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.edge, e.attempt)).collect();
        assert_eq!(order, vec![(0, 0), (0, 1), (1, 0), (2, 0)]);
    }

    #[test]
    fn clear_keeps_reuse_working() {
        let mut q = EventQueue::new();
        q.push(Event { at: 1.0, edge: 0, attempt: 0 });
        q.clear();
        assert!(q.is_empty());
        q.push(Event { at: 2.0, edge: 7, attempt: 3 });
        assert_eq!(q.len(), 1);
        let e = q.pop().unwrap();
        assert_eq!((e.edge, e.attempt), (7, 3));
        assert_eq!(e.at, 2.0);
    }
}
