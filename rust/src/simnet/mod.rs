//! Discrete-event heterogeneous network simulation (`simnet`).
//!
//! The paper's plots put communication on a *bits* axis; deployments care
//! about *wall-clock time* under real link conditions — heterogeneous
//! bandwidth, stragglers, jitter, lossy edges. This subsystem replaces the
//! coordinator's uniform `latency + max_bits / bandwidth` round formula
//! with an event-driven model: every synchronous round simulates all
//! `n · deg` directed payload transfers through a binary-heap event queue
//! ([`queue::EventQueue`], deterministic tie-breaking), over per-edge link
//! parameters drawn once from a seeded distribution ([`LinkDist`]), with
//! optional per-attempt jitter and drop-with-retransmit. The output is a
//! per-round completion time plus per-agent idle/straggler statistics and
//! network-wide utilization ([`NetStats`]).
//!
//! # §Timing contract — the overlay never perturbs trajectories
//!
//! `simnet` is a **timing-only overlay**. It observes the per-agent wire
//! bits the engine already accounts and produces *durations*; it never
//! touches payloads, messages, mixing, or any algorithm state, and all of
//! its randomness comes from a dedicated stream
//! ([`crate::rng::streams::NET`], derived — not drawn — from the engine
//! seed), so enabling it cannot shift any existing RNG stream. Iterate
//! series (`dist_opt`/`consensus`/`comp_err`/`bits_per_agent`) are
//! therefore **bitwise-identical** with the overlay on or off, pinned by
//! `rust/tests/simnet.rs` across codecs and thread counts. Additionally,
//! the degenerate homogeneous model — [`LinkDist::Uniform`] with zero
//! jitter and zero drop — reproduces the legacy
//! [`TrafficStats`](crate::coordinator::network::TrafficStats) `sim_time`
//! **bit-for-bit** (every transfer evaluates the exact legacy float
//! expression `latency ⊕ bits ⊘ bandwidth`, and the round max over those
//! monotone images equals the legacy max-bits formula exactly — see
//! [`round::RoundTimer`]); a property test in `rust/tests/proptests.rs`
//! pins this over random topologies, links, and bit patterns.
//!
//! The timer itself always runs sequentially on the coordinator thread
//! (n · deg events per round is negligible next to the gradient work), so
//! its event order and draws are independent of the engine's worker
//! count by construction.
//!
//! # Link-model specs
//!
//! [`NetModel::parse`] accepts colon-separated specs, mirroring
//! [`Topology::parse`](crate::topology::Topology::parse) /
//! [`compress::parse`](crate::compress::parse):
//!
//! ```text
//! uniform:LAT:BW               every edge identical (LAT seconds one-way,
//!                              BW bits/s) — degenerate == legacy formula
//! lognormal:LAT:BW:SIGMA       per-link latency/bandwidth multiplied by
//!                              independent exp(SIGMA·N(0,1)) factors
//!                              (median LAT / BW)
//! straggler:LAT:BW:FRAC:SLOW   bimodal: each *agent* is a straggler with
//!                              probability FRAC; every edge touching one
//!                              runs SLOW× slower (latency ×SLOW,
//!                              bandwidth ÷SLOW)
//! ```
//!
//! Any spec may append `key=value` modifiers:
//!
//! ```text
//! jitter=X    per-attempt multiplicative delay, uniform in [1, 1+X)
//! drop=P      per-attempt loss probability in [0, 1); dropped transfers
//!             retransmit immediately (each attempt re-billed)
//! seed=N      nonzero N pins the drawn network across run seeds
//!             (omitted/0: the network re-draws from each run's seed)
//! ```
//!
//! e.g. `straggler:1e-4:1e9:0.25:10:drop=0.01:seed=7`. The scenario layer
//! exposes exactly these strings as the `link` grid axis
//! (`crate::scenarios` TOML format).

pub mod queue;
pub mod round;

pub use round::RoundTimer;

use crate::serialize::json;

/// Per-edge link parameter distribution (drawn once per run at
/// [`RoundTimer::new`] from the model's seeded stream). Undirected
/// neighbors share parameters: the pair (i, j) is drawn once and both
/// directed edges i→j and j→i use it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkDist {
    /// Every edge identical — with zero jitter/drop this is the
    /// degenerate model that reproduces the legacy uniform formula
    /// bit-for-bit (§Timing contract).
    Uniform { latency_s: f64, bandwidth_bps: f64 },
    /// Heavy-tailed heterogeneity: per-pair latency and bandwidth are the
    /// nominal values times independent `exp(sigma · N(0,1))` factors
    /// (log-normal with median at the nominal value).
    LogNormal { latency_s: f64, bandwidth_bps: f64, sigma: f64 },
    /// Bimodal stragglers: each *agent* is flagged with probability
    /// `frac`; edges touching a flagged agent get `latency × slow` and
    /// `bandwidth / slow`. `frac = 0` (or `slow = 1`) degenerates to
    /// [`LinkDist::Uniform`] exactly (×1.0 and ÷1.0 are bitwise no-ops).
    Straggler { latency_s: f64, bandwidth_bps: f64, frac: f64, slow: f64 },
}

/// A parsed network model: the link distribution plus the stochastic
/// per-attempt modifiers. Plain copyable data — lives inside
/// [`EngineConfig`](crate::coordinator::engine::EngineConfig).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetModel {
    pub dist: LinkDist,
    /// Per-attempt multiplicative delay amplitude: each transfer's time is
    /// scaled by `1 + jitter · U[0,1)`. 0 ⇒ no draw, exact base time.
    pub jitter: f64,
    /// Per-attempt drop probability in [0, 1); dropped transfers
    /// retransmit from the drop time (capped, see [`round::MAX_ATTEMPTS`]).
    pub drop: f64,
    /// Link-parameter seed. 0 (the default): link draws derive from the
    /// engine seed, so a `seed` grid axis re-draws the network per run —
    /// trajectory and network variance move together. Nonzero: the
    /// network derives from this value *alone*, pinning one drawn
    /// network (straggler flags, per-pair params) across every run seed,
    /// so seed-axis bands isolate trajectory variance.
    pub seed: u64,
}

impl NetModel {
    /// The degenerate homogeneous model (legacy-formula twin).
    pub fn uniform(latency_s: f64, bandwidth_bps: f64) -> NetModel {
        NetModel {
            dist: LinkDist::Uniform { latency_s, bandwidth_bps },
            jitter: 0.0,
            drop: 0.0,
            seed: 0,
        }
    }

    /// Parse a link-model spec string (module docs). Returns `None` on
    /// unknown kinds, malformed numbers, or out-of-range parameters —
    /// mirroring the other spec parsers so config typos fail loudly
    /// upstream.
    pub fn parse(spec: &str) -> Option<NetModel> {
        let mut parts = spec.split(':');
        let kind = parts.next()?;
        // Positional numeric arguments come first; trailing key=value
        // segments are modifiers.
        let mut pos: Vec<f64> = Vec::new();
        let mut jitter = 0.0f64;
        let mut drop = 0.0f64;
        let mut seed = 0u64;
        for part in parts {
            if let Some((k, v)) = part.split_once('=') {
                match k {
                    "jitter" => jitter = v.parse().ok()?,
                    "drop" => drop = v.parse().ok()?,
                    "seed" => seed = v.parse().ok()?,
                    _ => return None,
                }
            } else {
                if pos.len() == 4 {
                    return None; // no kind takes more than 4 positionals
                }
                pos.push(part.parse().ok()?);
            }
        }
        if !(jitter.is_finite() && jitter >= 0.0) || !(drop >= 0.0 && drop < 1.0) {
            return None;
        }
        let ok_link = |lat: f64, bw: f64| lat.is_finite() && lat >= 0.0 && bw.is_finite() && bw > 0.0;
        let dist = match (kind, pos.as_slice()) {
            ("uniform", &[lat, bw]) if ok_link(lat, bw) => {
                LinkDist::Uniform { latency_s: lat, bandwidth_bps: bw }
            }
            ("lognormal", &[lat, bw, sigma]) if ok_link(lat, bw) && sigma.is_finite() && sigma >= 0.0 => {
                LinkDist::LogNormal { latency_s: lat, bandwidth_bps: bw, sigma }
            }
            ("straggler", &[lat, bw, frac, slow])
                if ok_link(lat, bw) && (0.0..=1.0).contains(&frac) && slow >= 1.0 && slow.is_finite() =>
            {
                LinkDist::Straggler { latency_s: lat, bandwidth_bps: bw, frac, slow }
            }
            _ => return None,
        };
        Some(NetModel { dist, jitter, drop, seed })
    }

    /// Canonical spec string; round-trips through [`NetModel::parse`].
    pub fn label(&self) -> String {
        let mut s = match self.dist {
            LinkDist::Uniform { latency_s, bandwidth_bps } => {
                format!("uniform:{latency_s:e}:{bandwidth_bps:e}")
            }
            LinkDist::LogNormal { latency_s, bandwidth_bps, sigma } => {
                format!("lognormal:{latency_s:e}:{bandwidth_bps:e}:{sigma:e}")
            }
            LinkDist::Straggler { latency_s, bandwidth_bps, frac, slow } => {
                format!("straggler:{latency_s:e}:{bandwidth_bps:e}:{frac:e}:{slow:e}")
            }
        };
        if self.jitter > 0.0 {
            s.push_str(&format!(":jitter={:e}", self.jitter));
        }
        if self.drop > 0.0 {
            s.push_str(&format!(":drop={:e}", self.drop));
        }
        if self.seed != 0 {
            s.push_str(&format!(":seed={}", self.seed));
        }
        s
    }
}

/// Cumulative network statistics over a run's simulated rounds.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub rounds: usize,
    /// Total simulated communication time, seconds.
    pub sim_time: f64,
    /// Per-agent cumulative barrier-wait (idle) seconds: each round, the
    /// gap between an agent's last incoming transfer and the round's
    /// global completion.
    pub idle_s: Vec<f64>,
    /// Rounds in which the agent was the round's straggler (its last
    /// arrival defined the round end; ties go to the lowest agent id).
    pub straggler_rounds: Vec<u64>,
    /// Total retransmitted (dropped) attempts.
    pub retransmits: u64,
    /// Transfers force-delivered at the [`round::MAX_ATTEMPTS`]
    /// retransmit cap (previously a silent fiction of delivery; under a
    /// fault plan the engine demotes these to real losses).
    pub capped: u64,
    /// Total link-active seconds (every attempt's duration, including
    /// dropped ones), summed over all directed edges.
    pub busy_link_s: f64,
}

impl NetStats {
    pub fn new(n: usize) -> NetStats {
        NetStats {
            idle_s: vec![0.0; n],
            straggler_rounds: vec![0; n],
            ..NetStats::default()
        }
    }

    /// Mean fraction of the run's duration each directed link spent
    /// actively transferring: `busy / (links · sim_time)`. 0 when nothing
    /// was simulated.
    pub fn utilization(&self, links: usize) -> f64 {
        if links == 0 || self.sim_time <= 0.0 {
            return 0.0;
        }
        self.busy_link_s / (links as f64 * self.sim_time)
    }

    /// Max over agents of cumulative idle seconds (the top straggler-wait
    /// series recorded into [`RoundMetrics`]).
    ///
    /// [`RoundMetrics`]: crate::coordinator::metrics::RoundMetrics
    pub fn max_idle(&self) -> f64 {
        self.idle_s.iter().copied().fold(0.0f64, f64::max)
    }
}

/// Per-run network summary attached to
/// [`RunRecord`](crate::coordinator::metrics::RunRecord) when the engine
/// ran with a simnet overlay.
#[derive(Clone, Debug)]
pub struct NetSummary {
    /// Canonical model spec ([`NetModel::label`]).
    pub link: String,
    /// Per-agent cumulative idle (barrier-wait) seconds.
    pub idle_s: Vec<f64>,
    /// Per-agent count of rounds where the agent was the straggler.
    pub straggler_rounds: Vec<u64>,
    pub retransmits: u64,
    /// Transfers force-delivered at the retransmit cap ([`NetStats::capped`]).
    pub capped: u64,
    /// Mean directed-link utilization over the run.
    pub utilization: f64,
}

impl NetSummary {
    pub fn from_stats(model: &NetModel, stats: &NetStats, links: usize) -> NetSummary {
        NetSummary {
            link: model.label(),
            utilization: stats.utilization(links),
            idle_s: stats.idle_s.clone(),
            straggler_rounds: stats.straggler_rounds.clone(),
            retransmits: stats.retransmits,
            capped: stats.capped,
        }
    }

    /// Compact JSON object (embedded in the run record artifact).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        json::write_str(&mut out, "link");
        out.push(':');
        json::write_str(&mut out, &self.link);
        out.push_str(",\"idle_s\":[");
        for (i, v) in self.idle_s.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            json::write_num(&mut out, *v);
        }
        out.push_str("],\"straggler_rounds\":[");
        for (i, v) in self.straggler_rounds.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&v.to_string());
        }
        out.push_str(&format!(
            "],\"retransmits\":{},\"capped\":{},\"utilization\":",
            self.retransmits, self.capped
        ));
        json::write_num(&mut out, self.utilization);
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_kinds() {
        let u = NetModel::parse("uniform:1e-4:1e9").unwrap();
        assert_eq!(u, NetModel::uniform(1e-4, 1e9));
        let l = NetModel::parse("lognormal:1e-3:1e8:0.5").unwrap();
        assert_eq!(
            l.dist,
            LinkDist::LogNormal { latency_s: 1e-3, bandwidth_bps: 1e8, sigma: 0.5 }
        );
        let s = NetModel::parse("straggler:1e-4:1e9:0.25:10").unwrap();
        assert_eq!(
            s.dist,
            LinkDist::Straggler { latency_s: 1e-4, bandwidth_bps: 1e9, frac: 0.25, slow: 10.0 }
        );
    }

    #[test]
    fn parse_modifiers_and_roundtrip() {
        let m = NetModel::parse("straggler:1e-4:1e9:0.25:10:drop=0.01:jitter=0.05:seed=7").unwrap();
        assert_eq!(m.drop, 0.01);
        assert_eq!(m.jitter, 0.05);
        assert_eq!(m.seed, 7);
        // label() is canonical and parses back to the same model.
        assert_eq!(NetModel::parse(&m.label()), Some(m));
        let plain = NetModel::uniform(1e-4, 1e9);
        assert_eq!(NetModel::parse(&plain.label()), Some(plain));
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "",
            "uniform",
            "uniform:1e-4",              // missing bandwidth
            "uniform:1e-4:0",            // zero bandwidth
            "uniform:-1:1e9",            // negative latency
            "uniform:1e-4:1e9:0.5",      // stray positional
            "lognormal:1e-4:1e9",        // missing sigma
            "lognormal:1e-4:1e9:-0.5",   // negative sigma
            "straggler:1e-4:1e9:1.5:10", // frac > 1
            "straggler:1e-4:1e9:0.2:0.5",// slow < 1
            "uniform:1e-4:1e9:drop=1.0", // drop must be < 1
            "uniform:1e-4:1e9:jitter=-1",
            "uniform:1e-4:1e9:wat=3",
            "wat:1:2",
            "uniform:abc:1e9",
        ] {
            assert!(NetModel::parse(bad).is_none(), "accepted {bad:?}");
        }
    }

    #[test]
    fn stats_utilization_and_max_idle() {
        let mut st = NetStats::new(3);
        assert_eq!(st.utilization(6), 0.0);
        st.sim_time = 2.0;
        st.busy_link_s = 6.0;
        st.idle_s = vec![0.5, 0.0, 1.25];
        assert!((st.utilization(6) - 0.5).abs() < 1e-12);
        assert_eq!(st.max_idle(), 1.25);
        assert_eq!(st.utilization(0), 0.0);
    }

    #[test]
    fn summary_json_parses() {
        let s = NetSummary {
            link: "uniform:1e-4:1e9".into(),
            idle_s: vec![0.0, 0.5],
            straggler_rounds: vec![3, 1],
            retransmits: 4,
            capped: 2,
            utilization: 0.75,
        };
        let js = crate::serialize::json::parse(&s.to_json()).unwrap();
        assert_eq!(js.get("link").unwrap().as_str(), Some("uniform:1e-4:1e9"));
        assert_eq!(js.get("idle_s").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(js.get("retransmits").unwrap().as_f64(), Some(4.0));
        assert_eq!(js.get("capped").unwrap().as_f64(), Some(2.0));
    }
}
