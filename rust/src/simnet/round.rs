//! The per-round discrete-event simulation: n·deg directed transfers
//! through the event queue, producing round completion times and
//! per-agent idle/straggler statistics.
//!
//! One [`RoundTimer`] is built per engine run (per-edge link parameters
//! drawn once from the model's seeded stream) and then fed each round's
//! per-agent wire bits. Rounds are simulated in *round-relative* time
//! (every round starts at t = 0 and the returned duration is accumulated
//! by the caller), which is both simpler and what makes the degenerate
//! homogeneous model bit-exact against the legacy formula: a first
//! attempt's completion is literally `latency + bits as f64 / bandwidth`
//! — the legacy expression — and the round max over those values equals
//! `latency + max_bits / bandwidth` exactly because `b ↦ lat ⊕ (b ⊘ bw)`
//! is weakly monotone under IEEE-754 round-to-nearest, so the max over
//! monotone images is the image of the max (see the module-level §Timing
//! contract and the proptest in `rust/tests/proptests.rs`).
//!
//! Determinism: edges are enumerated in a fixed order (pairs (i, j),
//! i < j ascending, neighbor-list order; both directions adjacent), all
//! jitter/drop draws come from *per-edge* streams consumed in attempt
//! order, and the event queue breaks time ties by (edge, attempt) — so
//! the event order, timings, and stats are identical across reruns and
//! engine thread counts (the timer itself always runs on the coordinator
//! thread).

use super::queue::{Event, EventQueue};
use super::{LinkDist, NetModel, NetStats};
use crate::rng::{streams, Rng};
use crate::topology::MixingMatrix;

/// Retransmit cap per directed edge per round: a transfer is force-
/// delivered on its `MAX_ATTEMPTS`-th attempt even if the drop draw
/// fails again. With `drop < 1` enforced at parse time this is
/// unreachable in practice (p ≤ 0.99 ⇒ P(cap) ≤ 0.99⁶³ ≈ 0.53 per
/// pathological edge-round, and realistic drop rates make it
/// astronomically small); the cap only bounds the worst case.
pub const MAX_ATTEMPTS: u32 = 64;

/// One directed edge with its drawn link parameters.
#[derive(Clone, Copy, Debug)]
pub struct EdgeLink {
    pub src: u32,
    pub dst: u32,
    pub latency_s: f64,
    pub bandwidth_bps: f64,
}

/// Attempt duration for `bits` over `link`. The jitter multiplier is
/// only applied (and its uniform only drawn) when the model carries
/// jitter, so deterministic models evaluate the exact legacy expression.
fn xfer_time(link: &EdgeLink, bits: u64, jitter: f64, rng: Option<&mut Rng>) -> f64 {
    let base = link.latency_s + bits as f64 / link.bandwidth_bps;
    match rng {
        Some(r) if jitter > 0.0 => base * (1.0 + jitter * r.uniform()),
        _ => base,
    }
}

/// Discrete-event round simulator (module docs). Build once per run,
/// call [`RoundTimer::round`] once per synchronous gossip round.
pub struct RoundTimer {
    model: NetModel,
    /// Directed edges in canonical order; index = edge id.
    edges: Vec<EdgeLink>,
    /// Per-directed-edge jitter/drop stream (empty for deterministic
    /// models — no draws, no allocation).
    rngs: Vec<Rng>,
    queue: EventQueue,
    /// Per-agent latest-arrival scratch, reset each round.
    arrival: Vec<f64>,
    /// (src, dst) of transfers force-delivered at [`MAX_ATTEMPTS`] this
    /// round; the engine demotes these to real losses under a fault plan.
    round_capped: Vec<(u32, u32)>,
    pub stats: NetStats,
}

impl RoundTimer {
    /// Draw the per-edge link parameters for `mix`'s graph under `model`.
    /// The draws root at the engine seed by default (a `seed` grid axis
    /// re-draws the network per run) or at the model's own nonzero
    /// `seed`, which pins one network across run seeds (`NetModel::seed`
    /// docs). Either way everything lives on the dedicated
    /// [`streams::NET`] stream, so building a timer never perturbs any
    /// other stream of the run.
    pub fn new(mix: &MixingMatrix, model: NetModel, engine_seed: u64) -> RoundTimer {
        let n = mix.n;
        let base = if model.seed == 0 { engine_seed } else { model.seed };
        let root = Rng::new(base).derive(streams::NET);
        let mut prng = root.derive(0);
        // Straggler models flag whole agents (one draw per agent, in
        // agent order) so that every edge touching a slow agent slows.
        let flags: Vec<bool> = match model.dist {
            LinkDist::Straggler { frac, .. } => (0..n).map(|_| prng.uniform() < frac).collect(),
            _ => Vec::new(),
        };
        let mut edges: Vec<EdgeLink> = Vec::new();
        for i in 0..n {
            for &j in &mix.neighbors[i] {
                if j <= i {
                    continue; // each undirected pair drawn exactly once
                }
                let (lat, bw) = match model.dist {
                    LinkDist::Uniform { latency_s, bandwidth_bps } => (latency_s, bandwidth_bps),
                    LinkDist::LogNormal { latency_s, bandwidth_bps, sigma } => {
                        let lat = latency_s * (sigma * prng.normal()).exp();
                        let bw = bandwidth_bps * (sigma * prng.normal()).exp();
                        (lat, bw)
                    }
                    LinkDist::Straggler { latency_s, bandwidth_bps, slow, .. } => {
                        // ×1.0 / ÷1.0 are bitwise no-ops, so an all-fast
                        // draw degenerates to Uniform exactly.
                        let s = if flags[i] || flags[j] { slow } else { 1.0 };
                        (latency_s * s, bandwidth_bps / s)
                    }
                };
                let (si, sj) = (i as u32, j as u32);
                edges.push(EdgeLink { src: si, dst: sj, latency_s: lat, bandwidth_bps: bw });
                edges.push(EdgeLink { src: sj, dst: si, latency_s: lat, bandwidth_bps: bw });
            }
        }
        let stochastic = model.jitter > 0.0 || model.drop > 0.0;
        let rngs: Vec<Rng> = if stochastic {
            (0..edges.len()).map(|e| root.derive(1 + e as u64)).collect()
        } else {
            Vec::new()
        };
        RoundTimer {
            model,
            edges,
            rngs,
            queue: EventQueue::new(),
            arrival: vec![0.0; n],
            round_capped: Vec::new(),
            stats: NetStats::new(n),
        }
    }

    /// Number of directed links (the utilization denominator).
    pub fn n_links(&self) -> usize {
        self.edges.len()
    }

    pub fn links(&self) -> &[EdgeLink] {
        &self.edges
    }

    /// Simulate one synchronous round in which agent `i` broadcasts
    /// `bits[i]` wire bits to each neighbor. Returns the round duration
    /// (seconds) and accumulates [`NetStats`]. Zero heap allocations in
    /// the steady state: the queue and arrival scratch are reused.
    pub fn round(&mut self, bits: &[u64]) -> f64 {
        self.round_faulted(bits, None)
    }

    /// [`RoundTimer::round`] with a fault overlay (`crate::faults`): a
    /// directed transfer whose `lost(src, dst)` returns true is charged
    /// on the wire exactly like a first attempt (its duration — and
    /// jitter draw, if any — happens as usual, keeping the per-edge
    /// streams aligned with the fault-free run) but never arrives: no
    /// event is queued, so it neither retransmits nor strains the
    /// barrier. Transfers force-delivered at [`MAX_ATTEMPTS`] are
    /// recorded in [`RoundTimer::capped_this_round`] so the caller can
    /// demote them to real losses instead of today's fiction of
    /// delivery.
    pub fn round_faulted(
        &mut self,
        bits: &[u64],
        lost: Option<&dyn Fn(usize, usize) -> bool>,
    ) -> f64 {
        let n = self.arrival.len();
        debug_assert_eq!(bits.len(), n);
        self.queue.clear();
        self.arrival.fill(0.0);
        self.round_capped.clear();
        // Every transfer starts at the round barrier (t = 0); first
        // attempts are scheduled in edge order so jitter draws are
        // position-independent of queue behavior.
        for e in 0..self.edges.len() {
            let b = bits[self.edges[e].src as usize];
            let dur = xfer_time(&self.edges[e], b, self.model.jitter, self.rngs.get_mut(e));
            self.stats.busy_link_s += dur;
            let faulted = lost
                .is_some_and(|f| f(self.edges[e].src as usize, self.edges[e].dst as usize));
            if !faulted {
                self.queue.push(Event { at: dur, edge: e as u32, attempt: 0 });
            }
        }
        let mut t_end = 0.0f64;
        while let Some(ev) = self.queue.pop() {
            let e = ev.edge as usize;
            // Drop draws come from the edge's own stream in attempt
            // order, so the outcome is independent of how attempts from
            // different edges interleave in the queue.
            let dropped = self.model.drop > 0.0
                && ev.attempt + 1 < MAX_ATTEMPTS
                && self.rngs[e].uniform() < self.model.drop;
            if dropped {
                self.stats.retransmits += 1;
                let b = bits[self.edges[e].src as usize];
                let dur = xfer_time(&self.edges[e], b, self.model.jitter, self.rngs.get_mut(e));
                self.stats.busy_link_s += dur;
                self.queue.push(Event { at: ev.at + dur, edge: ev.edge, attempt: ev.attempt + 1 });
            } else {
                // A delivery on the cap attempt skipped its drop draw
                // (the short-circuit above adds no draw here, so capped
                // accounting cannot shift any stream): it was forced
                // through, not genuinely delivered. Surface it.
                if self.model.drop > 0.0 && ev.attempt + 1 >= MAX_ATTEMPTS {
                    self.stats.capped += 1;
                    self.round_capped.push((self.edges[e].src, self.edges[e].dst));
                }
                let dst = self.edges[e].dst as usize;
                if ev.at > self.arrival[dst] {
                    self.arrival[dst] = ev.at;
                }
                if ev.at > t_end {
                    t_end = ev.at;
                }
            }
        }
        // Barrier accounting: everyone waits for the slowest arrival.
        let mut worst = 0usize;
        for i in 0..n {
            self.stats.idle_s[i] += t_end - self.arrival[i];
            if self.arrival[i] > self.arrival[worst] {
                worst = i;
            }
        }
        self.stats.straggler_rounds[worst] += 1;
        self.stats.sim_time += t_end;
        self.stats.rounds += 1;
        t_end
    }

    /// (src, dst) of transfers force-delivered at the retransmit cap in
    /// the most recent round (empty unless `drop` is pathological).
    pub fn capped_this_round(&self) -> &[(u32, u32)] {
        &self.round_capped
    }

    /// Per-agent latest-arrival offsets (seconds from the most recent
    /// round's start) — each agent's barrier-entry time within the
    /// round. Read by the engine's tracing layer to stamp `net_arrival`
    /// instants on the virtual timeline (`crate::trace`); observation
    /// only, reset on the next `round`/`round_faulted` call.
    pub fn arrivals(&self) -> &[f64] {
        &self.arrival
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::network::{LinkModel, TrafficStats};
    use crate::topology::{MixingRule, Topology};

    fn ring(n: usize) -> MixingMatrix {
        Topology::Ring.build(n, MixingRule::UniformNeighbors)
    }

    #[test]
    fn homogeneous_round_matches_legacy_formula_bitwise() {
        let mix = ring(6);
        let link = LinkModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let mut timer = RoundTimer::new(&mix, NetModel::uniform(1e-3, 1e6), 42);
        let mut traffic = TrafficStats::new(6);
        let mut sim = 0.0f64;
        for round in 0..5u64 {
            let bits: Vec<u64> = (0..6).map(|i| 1000 + 137 * i * (round + 1)).collect();
            traffic.record_round(&mix, &link, &bits);
            sim += timer.round(&bits);
        }
        assert_eq!(sim.to_bits(), traffic.sim_time.to_bits());
        assert_eq!(timer.stats.rounds, 5);
    }

    #[test]
    fn straggler_frac_zero_degenerates_to_uniform() {
        let mix = ring(5);
        let bits = vec![1000u64; 5];
        let mut uni = RoundTimer::new(&mix, NetModel::uniform(1e-4, 1e9), 1);
        let m = NetModel::parse("straggler:1e-4:1e9:0:50").unwrap();
        let mut st = RoundTimer::new(&mix, m, 1);
        assert_eq!(uni.round(&bits).to_bits(), st.round(&bits).to_bits());
    }

    #[test]
    fn straggler_agents_slow_the_round_and_show_in_stats() {
        let mix = ring(8);
        let bits = vec![10_000u64; 8];
        let mut uni = RoundTimer::new(&mix, NetModel::uniform(1e-4, 1e6), 3);
        let fast = uni.round(&bits);
        // Scan for a seed whose flag draws produce ≥1 straggler but not
        // all 8 (frac=0.5 at n=8 makes both failure modes rare, but the
        // test must not depend on one seed's luck).
        let m = NetModel::parse("straggler:1e-4:1e6:0.5:20").unwrap();
        let mut st = (0..100u64)
            .map(|seed| RoundTimer::new(&mix, m, seed))
            .find(|t| {
                let slowed = t.links().iter().filter(|l| l.latency_s > 1e-4).count();
                slowed > 0 && slowed < t.n_links()
            })
            .expect("no seed in 0..100 drew a mixed straggler set");
        let slow = st.round(&bits);
        assert!(
            slow > fast,
            "straggler round ({slow}) not slower than uniform ({fast})"
        );
        // Someone strained the barrier; idle is nonzero for the fast side.
        assert!(st.stats.max_idle() > 0.0);
        assert_eq!(st.stats.straggler_rounds.iter().sum::<u64>(), 1);
    }

    #[test]
    fn drop_retransmits_and_extends_rounds() {
        let mix = ring(6);
        let bits = vec![100_000u64; 6];
        let m = NetModel::parse("uniform:1e-4:1e6:drop=0.4").unwrap();
        let mut lossy = RoundTimer::new(&mix, m, 9);
        let mut clean = RoundTimer::new(&mix, NetModel::uniform(1e-4, 1e6), 9);
        let mut lossy_t = 0.0;
        let mut clean_t = 0.0;
        for _ in 0..20 {
            lossy_t += lossy.round(&bits);
            clean_t += clean.round(&bits);
        }
        assert!(lossy.stats.retransmits > 0, "drop=0.4 over 240 transfers never dropped");
        assert!(lossy_t > clean_t);
        // Busy time grows with every attempt; utilization stays in (0, 1].
        let u = lossy.stats.utilization(lossy.n_links());
        assert!(u > 0.0 && u <= 1.0, "utilization {u}");
    }

    #[test]
    fn same_seed_same_timings_fresh_timer() {
        let mix = ring(7);
        let m = NetModel::parse("lognormal:1e-4:1e8:0.7:jitter=0.3:drop=0.2").unwrap();
        let run = || {
            let mut t = RoundTimer::new(&mix, m, 17);
            let durs: Vec<u64> = (0..15u64)
                .map(|r| {
                    let bits: Vec<u64> = (0..7).map(|i| 500 + 999 * i * (r + 1)).collect();
                    t.round(&bits).to_bits()
                })
                .collect();
            (durs, t.stats.clone())
        };
        let (d1, s1) = run();
        let (d2, s2) = run();
        assert_eq!(d1, d2);
        assert_eq!(s1.retransmits, s2.retransmits);
        assert_eq!(s1.straggler_rounds, s2.straggler_rounds);
        for (a, b) in s1.idle_s.iter().zip(&s2.idle_s) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn model_seed_pins_network_across_run_seeds() {
        let mix = ring(6);
        let params = |m: NetModel, engine_seed: u64| -> Vec<(u64, u64)> {
            RoundTimer::new(&mix, m, engine_seed)
                .links()
                .iter()
                .map(|l| (l.latency_s.to_bits(), l.bandwidth_bps.to_bits()))
                .collect()
        };
        let pinned = NetModel::parse("lognormal:1e-4:1e9:0.8:seed=7").unwrap();
        assert_eq!(params(pinned, 1), params(pinned, 2), "seed=7 must pin the network");
        let unpinned = NetModel::parse("lognormal:1e-4:1e9:0.8").unwrap();
        assert_ne!(
            params(unpinned, 1),
            params(unpinned, 2),
            "default must re-draw per run seed"
        );
    }

    #[test]
    fn undirected_pairs_share_parameters() {
        let mix = ring(5);
        let m = NetModel::parse("lognormal:1e-4:1e9:1.0").unwrap();
        let t = RoundTimer::new(&mix, m, 5);
        assert_eq!(t.n_links(), 10, "5-ring has 5 undirected = 10 directed edges");
        // Consecutive entries are the two directions of one pair.
        for pair in t.links().chunks(2) {
            assert_eq!(pair[0].src, pair[1].dst);
            assert_eq!(pair[0].dst, pair[1].src);
            assert_eq!(pair[0].latency_s.to_bits(), pair[1].latency_s.to_bits());
            assert_eq!(pair[0].bandwidth_bps.to_bits(), pair[1].bandwidth_bps.to_bits());
        }
    }

    #[test]
    fn faulted_overlay_none_is_bitwise_round() {
        // `round_faulted(bits, None)` and a `Some` overlay that loses
        // nothing must both be pure plumbing: same draws, same timings,
        // same stats as the plain path.
        let mix = ring(7);
        let m = NetModel::parse("lognormal:1e-4:1e8:0.7:jitter=0.3:drop=0.2").unwrap();
        let mut plain = RoundTimer::new(&mix, m, 23);
        let mut overlay = RoundTimer::new(&mix, m, 23);
        let no_loss = |_src: usize, _dst: usize| false;
        for r in 0..10u64 {
            let bits: Vec<u64> = (0..7).map(|i| 700 + 311 * i * (r + 1)).collect();
            let a = plain.round(&bits);
            let b = overlay.round_faulted(&bits, Some(&no_loss));
            assert_eq!(a.to_bits(), b.to_bits(), "round {r}");
        }
        assert_eq!(plain.stats.retransmits, overlay.stats.retransmits);
        assert_eq!(plain.stats.busy_link_s.to_bits(), overlay.stats.busy_link_s.to_bits());
    }

    #[test]
    fn faulted_transfers_charge_the_wire_but_never_arrive() {
        // Star, zero latency: every round normally ends on agent 3's
        // big payload into the hub. Losing that one directed link must
        // shorten the round (no arrival, no retransmit) while still
        // charging its duration to busy time.
        let mix = Topology::Star.build(4, MixingRule::UniformNeighbors);
        let mut t = RoundTimer::new(&mix, NetModel::uniform(0.0, 1e3), 2);
        let bits = [10u64, 10, 10, 1000];
        let lose_heavy = |src: usize, dst: usize| src == 3 && dst == 0;
        let dur = t.round_faulted(&bits, Some(&lose_heavy));
        // Hub now ends on a 10-bit leaf payload; leaves still wait on
        // the hub's 10-bit broadcast.
        assert_eq!(dur.to_bits(), (10.0f64 / 1e3).to_bits());
        assert_eq!(t.stats.retransmits, 0);
        // Wire charge includes the lost 1000-bit attempt exactly once
        // (tolerance: six-term f64 summation vs one division).
        assert!((t.stats.busy_link_s - (10.0 * 5.0 + 1000.0) / 1e3).abs() < 1e-12);
    }

    #[test]
    fn capped_transfers_are_counted_not_silent() {
        // drop=0.99 makes P(hit the 64-attempt cap) ≈ 0.99^63 ≈ 0.53
        // per edge-round: over a 6-ring (12 directed edges) × 10 rounds
        // the cap fires with overwhelming probability. Each cap must
        // show up both in the cumulative counter and the per-round list.
        let mix = ring(6);
        let m = NetModel::parse("uniform:1e-4:1e6:drop=0.99").unwrap();
        let mut t = RoundTimer::new(&mix, m, 4);
        let bits = vec![1000u64; 6];
        let mut listed = 0u64;
        for _ in 0..10 {
            t.round(&bits);
            listed += t.capped_this_round().len() as u64;
        }
        assert!(t.stats.capped > 0, "no transfer hit the cap at drop=0.99");
        assert_eq!(listed, t.stats.capped, "per-round list disagrees with counter");
        assert!(t.stats.retransmits >= t.stats.capped * (MAX_ATTEMPTS as u64 - 1));
    }

    #[test]
    fn idle_and_straggler_accounting() {
        // Star: agent 0 talks to everyone. Give agent 3 a huge payload so
        // every round ends on its transfer into agent 0.
        let mix = Topology::Star.build(4, MixingRule::UniformNeighbors);
        let mut t = RoundTimer::new(&mix, NetModel::uniform(0.0, 1e3), 2);
        let bits = [10u64, 10, 10, 1000];
        for _ in 0..3 {
            let dur = t.round(&bits);
            assert_eq!(dur.to_bits(), 1.0f64.to_bits(), "1000 bits / 1e3 bps");
        }
        // Agent 0 receives the straggler payload last ⇒ zero idle; the
        // leaves only receive agent 0's small payload ⇒ big idle.
        assert_eq!(t.stats.idle_s[0], 0.0);
        for leaf in 1..4 {
            assert!(t.stats.idle_s[leaf] > 0.0, "leaf {leaf} should wait at the barrier");
        }
        // The round ends on an arrival at agent 0, so agent 0 is the
        // "straggler" (latest arrival) every round.
        assert_eq!(t.stats.straggler_rounds[0], 3);
        assert_eq!(t.stats.sim_time, 3.0);
    }
}
