//! Declarative scenario grids and the sharded multi-run executor.
//!
//! The paper's evaluation is a *grid* — algorithms × topologies ×
//! compressors × seeds (Figs. 1–9, the (α, γ) sensitivity sweep, the
//! ablations) — so "what to run" is separated from "how to run it":
//!
//! * [`RunSpec`] — one cell as plain data: problem, topology + mixing
//!   rule + agent count, algorithm setup, compressor, rounds, stepsize
//!   schedule, seed. Buildable from presets ([`specs_from_setups`]) or
//!   parsed from the `toml_mini` config format ([`Grid::from_toml`]).
//! * [`Grid`] — a base spec plus axes (cartesian products over any scalar
//!   field), expanded to a deterministic batch of specs.
//! * [`Driver`] — executes a batch under one shared thread budget with
//!   *outer* parallelism: runs below the engine's inner fan-out threshold
//!   (`coordinator::engine` §Scheduling) are sharded across the pool as
//!   whole-run tasks ([`crate::pool::par_dynamic`]); larger runs execute
//!   one at a time with the full pool as their inner [`Exec`]. Identical
//!   problems (compared as specs) are built once and shared as
//!   `Arc<dyn Problem>` across all their runs.
//!
//! Determinism: every run derives all randomness from its own seed, so a
//! grid executed with any outer thread count is **bitwise-identical** to
//! serial execution (pinned by `sharded_grid_bitwise_equals_serial`).
//!
//! # TOML grid format
//!
//! ```toml
//! [grid]                       # scalar base spec (all keys optional)
//! name = "sweep"
//! algo = "lead"                # config::build_algo name
//! eta = 0.1
//! gamma = 1.0
//! alpha = 0.5
//! compressor = "qinf:2:512"    # compress::parse spec; "raw" = none
//! topology = "ring"            # Topology::parse; e.g. "er:0.4:3"
//! mixing = "uniform"           # uniform | metropolis | lazy
//! agents = 8
//! rounds = 800
//! seed = 42
//! record_every = 10
//! # batch_size = 512           # omit for full gradient
//! # t0 = 200.0                 # diminishing stepsize η·t0/(t0+k)
//! # link = "uniform:1e-4:1e9"  # simnet::NetModel spec; omit (or "legacy")
//!                              # for the uniform round-time formula
//! # faults = "loss:0.05"       # faults::FaultPlan spec; omit (or "none")
//!                              # for the fault-free engine path
//! # time_budget = 2.5          # stop once sim_time reaches this many
//!                              # seconds; the record sets stopped_early
//! # transport = "channel"      # transport::TransportMode spec: mem |
//!                              # channel | mux:<N>; omit (or "mem") for
//!                              # the shared-memory reference. Lossless
//!                              # channel runs are bitwise-identical to
//!                              # mem; compressed cells need a
//!                              # wire-complete codec (topk, q*)
//! # tol = 1e-6                 # dist(x*) tolerance: emits time_to_tol
//!                              # per run into <grid>.json
//!
//! [problem]                    # omit for the paper's linreg workload
//! kind = "linreg"              # linreg | logreg | quad
//! dim = 200
//! reg = 0.1
//! seed = 42
//!
//! [axes]                       # arrays expand as a cartesian product,
//! alpha = [0.1, 0.3, 0.5]      # in alphabetical key order (first key
//! gamma = [0.5, 1.0]           # outermost); any [grid] scalar key works,
//! link = ["uniform:1e-4:1e9",  # including network conditions — the
//!         "straggler:1e-4:1e9:0.25:10"]   # time-to-accuracy axis
//! ```
//!
//! # Seed-axis aggregation
//!
//! When a grid sweeps a `seed` axis, the `<grid>.json` artifact also
//! carries an `aggregates` array: cells identical except for their seed
//! are grouped and their per-round metrics reduced to mean ± std bands
//! (population std over the seeds), plus mean ± std of `time_to_tol`
//! when `tol` is set — so variance and time-to-accuracy plots come from
//! one artifact instead of re-reducing per-run records downstream.

use crate::compress::Compressor;
use crate::config::{self, AlgoSetup};
use crate::coordinator::engine::{phase_threads, Engine, EngineConfig, Schedule};
use crate::coordinator::metrics::{RoundMetrics, RunRecord};
use crate::error::{err, Result};
use crate::faults::FaultPlan;
use crate::pool::{par_dynamic, Exec, SendPtr, WorkerPool};
use crate::problems::{linreg::LinReg, logreg::LogReg, quad::Quad, DataSplit, Problem};
use crate::serialize::{json, toml_mini};
use crate::simnet::NetModel;
use crate::topology::{MixingMatrix, MixingRule, Topology};
use crate::transport::TransportMode;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Plain-data description of a problem instance. Plain variants are
/// parseable from TOML and compared structurally so the driver can build
/// each distinct problem exactly once per grid (reference-optimum solves
/// are the expensive part); [`ProblemSpec::Shared`] is the escape hatch
/// for problems that are not plain data (e.g. the PJRT-backed MLP),
/// compared by pointer identity.
#[derive(Clone)]
pub enum ProblemSpec {
    /// `LinReg::synthetic(agents, dim, reg, seed)`.
    LinReg { dim: usize, reg: f64, seed: u64 },
    /// `LogReg::paper_shaped(n_total, split, seed)` (8 agents).
    LogReg { n_total: usize, split: DataSplit, seed: u64 },
    /// `Quad::new(agents, dim, seed)` — the engine-audit workload.
    Quad { dim: usize, seed: u64 },
    /// A pre-built shared problem.
    Shared(Arc<dyn Problem>),
}

impl ProblemSpec {
    /// Build the problem for `agents` agents.
    pub fn build(&self, agents: usize) -> Arc<dyn Problem> {
        match self {
            ProblemSpec::LinReg { dim, reg, seed } => {
                Arc::new(LinReg::synthetic(agents, *dim, *reg, *seed))
            }
            ProblemSpec::LogReg { n_total, split, seed } => {
                Arc::new(LogReg::paper_shaped(*n_total, *split, *seed))
            }
            ProblemSpec::Quad { dim, seed } => Arc::new(Quad::new(agents, *dim, *seed)),
            ProblemSpec::Shared(p) => Arc::clone(p),
        }
    }

    /// Structural equality (pointer identity for [`ProblemSpec::Shared`]):
    /// the driver's dedupe key, together with the agent count.
    pub fn same(&self, other: &ProblemSpec) -> bool {
        match (self, other) {
            (
                ProblemSpec::LinReg { dim: a, reg: b, seed: c },
                ProblemSpec::LinReg { dim: x, reg: y, seed: z },
            ) => a == x && b == y && c == z,
            (
                ProblemSpec::LogReg { n_total: a, split: b, seed: c },
                ProblemSpec::LogReg { n_total: x, split: y, seed: z },
            ) => a == x && b == y && c == z,
            (ProblemSpec::Quad { dim: a, seed: b }, ProblemSpec::Quad { dim: x, seed: y }) => {
                a == x && b == y
            }
            (ProblemSpec::Shared(a), ProblemSpec::Shared(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Short human/JSON label.
    pub fn label(&self) -> String {
        match self {
            ProblemSpec::LinReg { dim, reg, seed } => format!("linreg(d={dim},reg={reg},seed={seed})"),
            ProblemSpec::LogReg { n_total, split, seed } => format!(
                "logreg(n={n_total},{},seed={seed})",
                if *split == DataSplit::Heterogeneous { "hetero" } else { "homo" }
            ),
            ProblemSpec::Quad { dim, seed } => format!("quad(d={dim},seed={seed})"),
            ProblemSpec::Shared(p) => format!("shared({})", p.name()),
        }
    }

    /// Parse a `[problem]` TOML section.
    pub fn from_doc(sec: &std::collections::BTreeMap<String, toml_mini::Value>) -> Result<ProblemSpec> {
        let get_usize = |k: &str, default: usize| -> Result<usize> {
            match sec.get(k) {
                Some(v) => Ok(v.as_i64().ok_or_else(|| err(format!("problem.{k}: int expected")))?
                    as usize),
                None => Ok(default),
            }
        };
        let get_f64 = |k: &str, default: f64| -> Result<f64> {
            match sec.get(k) {
                Some(v) => v.as_f64().ok_or_else(|| err(format!("problem.{k}: number expected"))),
                None => Ok(default),
            }
        };
        let get_u64 = |k: &str, default: u64| -> Result<u64> {
            match sec.get(k) {
                Some(v) => Ok(v.as_i64().ok_or_else(|| err(format!("problem.{k}: int expected")))?
                    as u64),
                None => Ok(default),
            }
        };
        let kind = sec
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| err("problem.kind: \"linreg\" | \"logreg\" | \"quad\" expected"))?;
        match kind {
            "linreg" => Ok(ProblemSpec::LinReg {
                dim: get_usize("dim", 200)?,
                reg: get_f64("reg", 0.1)?,
                seed: get_u64("seed", 42)?,
            }),
            "logreg" => {
                let split = match sec.get("split").and_then(|v| v.as_str()) {
                    None => DataSplit::Heterogeneous,
                    Some(s) => DataSplit::parse(s)
                        .ok_or_else(|| err(format!("problem.split: bad value {s:?}")))?,
                };
                Ok(ProblemSpec::LogReg {
                    n_total: get_usize("n_total", 8000)?,
                    split,
                    seed: get_u64("seed", 42)?,
                })
            }
            "quad" => Ok(ProblemSpec::Quad { dim: get_usize("dim", 1000)?, seed: get_u64("seed", 42)? }),
            other => Err(err(format!("problem.kind: unknown kind {other:?}"))),
        }
    }
}

/// One run of the coordinator engine as plain data — "what to run",
/// fully decoupled from "how" (threads, scheduling, artifacts), which is
/// the [`Driver`]'s business.
#[derive(Clone)]
pub struct RunSpec {
    /// Cell label; also the CSV/JSON artifact stem.
    pub name: String,
    pub problem: ProblemSpec,
    /// [`Topology::parse`] string (seeded with `seed` unless the string
    /// carries its own, e.g. `er:0.4:3`).
    pub topology: String,
    pub mixing: MixingRule,
    pub agents: usize,
    /// [`config::build_algo`] name.
    pub algo: String,
    pub eta: f64,
    pub gamma: f64,
    pub alpha: f64,
    /// [`crate::compress::parse`] spec; `"raw"` (or empty) disables the
    /// compressor entirely. Whether it applies is the algorithm's call
    /// (`AlgoSpec::compressed`), exactly as in the engine.
    pub compressor: String,
    pub rounds: usize,
    pub batch_size: Option<usize>,
    /// Engine seed: the root of every RNG stream of the run.
    pub seed: u64,
    pub record_every: usize,
    /// `Some(t0)` ⇒ diminishing stepsize η·t0/(t0+k) (Theorem 2).
    pub t0: Option<f64>,
    /// [`NetModel::parse`] spec for the simnet timing overlay; `""` (or
    /// `"legacy"`) keeps the uniform round-time formula. Timing-only:
    /// the trajectory is identical for every value of this field.
    pub link: String,
    /// [`FaultPlan::parse`] spec for the fault-injection layer; `""` (or
    /// `"none"`) keeps the fault-free engine path bit-for-bit. Unlike
    /// `link`, this field *does* perturb trajectories.
    pub faults: String,
    /// Simulated-time budget in seconds: the engine stops a run early
    /// once `sim_time` crosses it (the crossing round still completes
    /// and is observed; the record sets `stopped_early`).
    pub time_budget: Option<f64>,
    /// [`TransportMode::parse`] spec (`mem` | `channel` | `mux:<N>`); `""`
    /// (or `"mem"`) keeps the shared-memory reference path. Lossless
    /// channel transports leave trajectories bitwise-identical
    /// (`rust/tests/transport.rs`); compressed cells require a
    /// wire-complete codec (`topk:*`, `q*`) — validated before any run.
    pub transport: String,
}

impl RunSpec {
    /// The paper's baseline cell: LEAD (γ=1, α=0.5) + 2-bit q∞ on the
    /// 8-agent uniform ring over the Fig. 1 linear-regression workload.
    pub fn paper_default() -> RunSpec {
        RunSpec {
            name: "run".into(),
            problem: ProblemSpec::LinReg { dim: 200, reg: 0.1, seed: 42 },
            topology: "ring".into(),
            mixing: MixingRule::UniformNeighbors,
            agents: 8,
            algo: "lead".into(),
            eta: 0.1,
            gamma: 1.0,
            alpha: 0.5,
            compressor: "qinf:2:512".into(),
            rounds: 500,
            batch_size: None,
            seed: 42,
            record_every: 10,
            t0: None,
            link: String::new(),
            faults: String::new(),
            time_budget: None,
            transport: String::new(),
        }
    }

    /// This spec with one preset table row applied (algorithm name, η, γ,
    /// α — compression participation is the algorithm's own
    /// `AlgoSpec::compressed`, which the preset tables mirror).
    pub fn with_setup(&self, s: &AlgoSetup) -> RunSpec {
        let mut spec = self.clone();
        spec.algo = s.algo.clone();
        spec.eta = s.eta;
        spec.gamma = s.gamma;
        spec.alpha = s.alpha;
        spec
    }

    pub fn schedule(&self) -> Schedule {
        match self.t0 {
            Some(t0) => Schedule::Diminishing { t0 },
            None => Schedule::Constant,
        }
    }

    /// Engine configuration for this spec, network model included (fails
    /// on a malformed `link` spec, like the other builders). `threads`
    /// stays at 1: the [`Driver`] supplies the execution backend via
    /// [`Engine::run_on`].
    pub fn engine_config(&self) -> Result<EngineConfig> {
        Ok(EngineConfig {
            eta: self.eta,
            schedule: self.schedule(),
            batch_size: self.batch_size,
            seed: self.seed,
            record_every: self.record_every.max(1),
            net: self.build_net()?,
            faults: self.build_faults()?,
            time_budget: self.time_budget,
            transport: self.build_transport()?,
            ..EngineConfig::default()
        })
    }

    pub fn build_mix(&self) -> Result<MixingMatrix> {
        let topo = Topology::parse(&self.topology, self.seed)
            .ok_or_else(|| err(format!("{}: bad topology {:?}", self.name, self.topology)))?;
        Ok(topo.build(self.agents, self.mixing))
    }

    pub fn build_algo(&self) -> Result<Box<dyn crate::algorithms::Algorithm>> {
        config::build_algo(&self.algo, self.gamma, self.alpha)
            .ok_or_else(|| err(format!("{}: unknown algorithm {:?}", self.name, self.algo)))
    }

    pub fn build_compressor(&self) -> Result<Option<Box<dyn Compressor>>> {
        if self.compressor.is_empty() || self.compressor == "raw" {
            return Ok(None);
        }
        crate::compress::parse(&self.compressor)
            .map(Some)
            .ok_or_else(|| err(format!("{}: bad compressor spec {:?}", self.name, self.compressor)))
    }

    /// Parse the `link` field into a simnet model (None ⇒ legacy uniform
    /// round-time formula).
    pub fn build_net(&self) -> Result<Option<NetModel>> {
        if self.link.is_empty() || self.link == "legacy" {
            return Ok(None);
        }
        NetModel::parse(&self.link)
            .map(Some)
            .ok_or_else(|| err(format!("{}: bad link model spec {:?}", self.name, self.link)))
    }

    /// Parse the `faults` field into a fault plan (None ⇒ the fault-free
    /// engine path, bit-for-bit identical to builds without this layer).
    pub fn build_faults(&self) -> Result<Option<FaultPlan>> {
        if self.faults.is_empty() || self.faults == "none" {
            return Ok(None);
        }
        FaultPlan::parse(&self.faults)
            .map(Some)
            .ok_or_else(|| err(format!("{}: bad fault plan spec {:?}", self.name, self.faults)))
    }

    /// Parse the `transport` field into a mode (`Mem` ⇒ the shared-memory
    /// reference path, byte-for-byte the pre-transport engine). The
    /// wire-completeness requirement for compressed channel cells is
    /// checked by the [`Driver`]'s prevalidation, where the algorithm and
    /// compressor are in hand.
    pub fn build_transport(&self) -> Result<TransportMode> {
        TransportMode::parse(&self.transport).ok_or_else(|| {
            err(format!(
                "{}: bad transport spec {:?} (mem | channel | mux:<N>)",
                self.name, self.transport
            ))
        })
    }

    /// Set one scalar field by its TOML key (axis application).
    pub fn apply_axis(&mut self, key: &str, v: &toml_mini::Value) -> Result<()> {
        let want_f64 =
            || v.as_f64().ok_or_else(|| err(format!("axis {key:?}: number expected")));
        let want_int = || v.as_i64().ok_or_else(|| err(format!("axis {key:?}: int expected")));
        let want_str =
            || v.as_str().map(String::from).ok_or_else(|| err(format!("axis {key:?}: string expected")));
        match key {
            "eta" => self.eta = want_f64()?,
            "gamma" => self.gamma = want_f64()?,
            "alpha" => self.alpha = want_f64()?,
            "t0" => self.t0 = Some(want_f64()?),
            "rounds" => self.rounds = want_int()? as usize,
            "agents" => self.agents = want_int()? as usize,
            "seed" => self.seed = want_int()? as u64,
            "record_every" => self.record_every = want_int()? as usize,
            "batch_size" => self.batch_size = Some(want_int()? as usize),
            "algo" => self.algo = want_str()?,
            "topology" => self.topology = want_str()?,
            "compressor" => self.compressor = want_str()?,
            "link" => self.link = want_str()?,
            "faults" => self.faults = want_str()?,
            "time_budget" => self.time_budget = Some(want_f64()?),
            "transport" => self.transport = want_str()?,
            "mixing" => {
                let s = want_str()?;
                self.mixing = MixingRule::parse(&s)
                    .ok_or_else(|| err(format!("axis mixing: bad rule {s:?}")))?;
            }
            other => return Err(err(format!("unknown spec key {other:?}"))),
        }
        Ok(())
    }

    /// Compact JSON description (for the per-grid artifact).
    fn spec_json(&self) -> String {
        let mut o = String::from("{");
        let kv_str = |out: &mut String, k: &str, v: &str, comma: bool| {
            if comma {
                out.push(',');
            }
            json::write_str(out, k);
            out.push(':');
            json::write_str(out, v);
        };
        kv_str(&mut o, "algo", &self.algo, false);
        kv_str(&mut o, "problem", &self.problem.label(), true);
        kv_str(&mut o, "topology", &self.topology, true);
        kv_str(&mut o, "compressor", &self.compressor, true);
        kv_str(&mut o, "link", &self.link, true);
        kv_str(&mut o, "faults", &self.faults, true);
        kv_str(&mut o, "transport", &self.transport, true);
        for (k, v) in [("eta", self.eta), ("gamma", self.gamma), ("alpha", self.alpha)] {
            o.push(',');
            json::write_str(&mut o, k);
            o.push(':');
            json::write_num(&mut o, v);
        }
        // Integer fields are emitted directly: routing u64 seeds through
        // f64 would silently round values above 2^53, and the artifact
        // must describe the run exactly (spec JSON round-trips).
        o.push_str(&format!(
            ",\"agents\":{},\"rounds\":{},\"seed\":{},\"record_every\":{}",
            self.agents, self.rounds, self.seed, self.record_every
        ));
        o.push(',');
        json::write_str(&mut o, "batch_size");
        o.push(':');
        match self.batch_size {
            Some(b) => o.push_str(&b.to_string()),
            None => o.push_str("null"),
        }
        o.push(',');
        json::write_str(&mut o, "time_budget");
        o.push(':');
        match self.time_budget {
            Some(t) => json::write_num(&mut o, t),
            None => o.push_str("null"),
        }
        o.push('}');
        o
    }
}

/// Expand preset table rows over a base spec — the shape of the paper's
/// per-figure comparison tables (one row per algorithm, applied jointly:
/// name, η, γ, α move together, so this is a *tuple* axis rather than a
/// cartesian one). Cell names follow the historical CSV naming,
/// `<tag>_<algo>`.
pub fn specs_from_setups(tag: &str, base: &RunSpec, setups: &[AlgoSetup]) -> Vec<RunSpec> {
    setups
        .iter()
        .map(|s| {
            let mut spec = base.with_setup(s);
            spec.name = format!("{tag}_{}", s.algo);
            spec
        })
        .collect()
}

/// A base spec plus cartesian axes over scalar spec keys.
pub struct Grid {
    pub name: String,
    pub base: RunSpec,
    /// `(key, values)` — first axis outermost. Keys are the
    /// [`RunSpec::apply_axis`] scalar keys.
    pub axes: Vec<(String, Vec<toml_mini::Value>)>,
    /// dist(x*) tolerance for time-to-accuracy reporting: when set, the
    /// driver emits each run's `time_to_tol` (and its seed-axis mean ±
    /// std) into the `<grid>.json` artifact.
    pub tol: Option<f64>,
}

impl Grid {
    /// Expand to the full cartesian batch, first axis outermost. Cell
    /// names are `<grid>_<key><value>_…`, deterministic in expansion
    /// order.
    pub fn expand(&self) -> Result<Vec<RunSpec>> {
        for (k, vals) in &self.axes {
            if vals.is_empty() {
                return Err(err(format!("grid {}: axis {k:?} is empty", self.name)));
            }
        }
        let total: usize = self.axes.iter().map(|(_, v)| v.len()).product();
        let mut specs = Vec::with_capacity(total);
        for flat in 0..total {
            let mut spec = self.base.clone();
            let mut name = self.name.clone();
            // Row-major odometer: decode indices innermost-last.
            let mut rem = flat;
            let mut idxs = vec![0usize; self.axes.len()];
            for ax in (0..self.axes.len()).rev() {
                let len = self.axes[ax].1.len();
                idxs[ax] = rem % len;
                rem /= len;
            }
            for (ax, (key, vals)) in self.axes.iter().enumerate() {
                let v = &vals[idxs[ax]];
                spec.apply_axis(key, v)?;
                name.push('_');
                name.push_str(key);
                name.push_str(&fmt_value(v));
            }
            spec.name = name;
            specs.push(spec);
        }
        Ok(specs)
    }

    /// Parse the TOML grid format (module docs): scalar base keys in
    /// `[grid]` (or at top level), an optional `[problem]` section, and
    /// `[axes]` arrays expanded in alphabetical key order.
    pub fn from_toml(src: &str) -> Result<Grid> {
        let doc = toml_mini::parse(src).map_err(err)?;
        let mut base = RunSpec::paper_default();
        let mut name = String::from("grid");
        let mut tol = None;
        for section in ["", "grid"] {
            let Some(sec) = doc.get(section) else { continue };
            for (k, v) in sec {
                match k.as_str() {
                    "name" => {
                        name = v
                            .as_str()
                            .ok_or_else(|| err("grid.name: string expected"))?
                            .to_string()
                    }
                    "tol" => {
                        tol = Some(
                            v.as_f64().ok_or_else(|| err("grid.tol: number expected"))?,
                        )
                    }
                    other => base
                        .apply_axis(other, v)
                        .map_err(|e| err(format!("grid.{other}: {e}")))?,
                }
            }
        }
        if let Some(sec) = doc.get("problem") {
            base.problem = ProblemSpec::from_doc(sec)?;
        }
        let axes = match doc.get("axes") {
            None => Vec::new(),
            Some(sec) => sec
                .iter()
                .map(|(k, v)| {
                    let vals = v
                        .as_arr()
                        .ok_or_else(|| err(format!("axes.{k}: array expected")))?
                        .to_vec();
                    Ok((k.clone(), vals))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        base.name = name.clone();
        Ok(Grid { name, base, axes, tol })
    }
}

fn fmt_value(v: &toml_mini::Value) -> String {
    match v {
        toml_mini::Value::Str(s) => s.clone(),
        toml_mini::Value::Bool(b) => b.to_string(),
        toml_mini::Value::Int(i) => i.to_string(),
        toml_mini::Value::Float(f) => format!("{f}"),
        toml_mini::Value::Arr(_) => "[..]".into(),
    }
}

/// Executes batches of [`RunSpec`]s under one shared thread budget — see
/// the module docs and `coordinator::engine` §Scheduling for the
/// outer/inner rule.
pub struct Driver {
    threads: usize,
    out: Option<PathBuf>,
    tol: Option<f64>,
}

/// Per-agent work estimate (streamed f64-element equivalents) used to
/// classify a run as small (outer-sharded) or large (inner-parallel).
/// The floor is the message traffic (`channels · d`); problems that are
/// gradient-heavy at modest dimension raise it via
/// [`Problem::round_cost_hint`], and mini-batch runs cap the gradient
/// term at `batch · d` (the hint describes the full-gradient sweep).
pub(crate) fn run_work_estimate(
    p: &dyn Problem,
    channels: usize,
    batch_size: Option<usize>,
) -> usize {
    let msg = channels * p.dim();
    let grad = match (p.round_cost_hint(), batch_size) {
        (Some(c), None) => c,
        (Some(_), Some(b)) => b.saturating_mul(p.dim()),
        (None, _) => 0,
    };
    grad.max(msg)
}

/// Everything a single run needs, prebuilt and prevalidated so the
/// parallel section is infallible.
struct Prepared {
    problem: Arc<dyn Problem>,
    /// Whether inner (per-agent) parallelism would actually engage for
    /// this run — the small/large classifier.
    inner_useful: bool,
}

impl Driver {
    pub fn new(threads: usize) -> Driver {
        Driver { threads: threads.max(1), out: None, tol: None }
    }

    /// Write one CSV per run plus the unified `<grid>.json` artifact into
    /// `dir` (no artifacts when `None`).
    pub fn with_out(mut self, dir: Option<&Path>) -> Driver {
        self.out = dir.map(Path::to_path_buf);
        self
    }

    /// dist(x*) tolerance for time-to-accuracy reporting: emits per-run
    /// `time_to_tol` (and seed-axis aggregate bands) into `<grid>.json`.
    pub fn with_tol(mut self, tol: Option<f64>) -> Driver {
        self.tol = tol;
        self
    }

    /// Run every spec and return the records, index-aligned with `specs`.
    ///
    /// The cheap string-level validation (topology/algorithm/compressor
    /// specs) happens before any problem is built, so a typo'd cell can
    /// never cost a reference-optimum solve first; identical problems are
    /// then built once and shared, and agent counts checked. Results are
    /// bitwise-independent of `threads`.
    pub fn run(&self, grid_name: &str, specs: &[RunSpec]) -> Result<Vec<RunRecord>> {
        // Cheap validation first: parse/build every spec's strings before
        // paying for any problem construction.
        let mut channels = Vec::with_capacity(specs.len());
        for s in specs {
            s.build_mix()?;
            let algo = s.build_algo()?;
            let comp = s.build_compressor()?;
            s.build_net()?;
            s.build_faults()?;
            // Codec gate (§Transport rule 5): a compressed cell on a
            // channel transport must use a wire-complete codec — rejected
            // here, before any problem build, instead of panicking inside
            // the engine or silently diverging.
            let mode = s.build_transport()?;
            if !mode.is_mem() && algo.spec().compressed {
                if let Some(c) = &comp {
                    if c.wire_format().is_none() {
                        return Err(err(format!(
                            "{}: transport {:?} needs a wire-complete compressor (topk, q*); {:?} does not decode from its payload alone",
                            s.name, s.transport, s.compressor
                        )));
                    }
                }
            }
            channels.push(algo.spec().channels);
        }
        // Resolve problems with structural dedupe, check agent counts,
        // and classify small vs large.
        let mut problems: Vec<Arc<dyn Problem>> = Vec::with_capacity(specs.len());
        for (i, s) in specs.iter().enumerate() {
            let found = specs[..i]
                .iter()
                .position(|t| t.problem.same(&s.problem) && t.agents == s.agents);
            match found {
                Some(j) => problems.push(Arc::clone(&problems[j])),
                None => problems.push(s.problem.build(s.agents)),
            }
        }
        let mut prepared = Vec::with_capacity(specs.len());
        for ((s, p), &ch) in specs.iter().zip(&problems).zip(&channels) {
            if p.n_agents() != s.agents {
                return Err(err(format!(
                    "{}: problem has {} agents but spec says {}",
                    s.name,
                    p.n_agents(),
                    s.agents
                )));
            }
            let work = run_work_estimate(&**p, ch, s.batch_size);
            let inner_useful = phase_threads(self.threads, s.agents, work) > 1;
            prepared.push(Prepared { problem: Arc::clone(p), inner_useful });
        }

        let run_one = |i: usize, exec: Exec<'_>| -> RunRecord {
            let s = &specs[i];
            let mix = s.build_mix().expect("prevalidated");
            let algo = s.build_algo().expect("prevalidated");
            let comp = s.build_compressor().expect("prevalidated");
            let cfg = s.engine_config().expect("prevalidated");
            let mut engine = Engine::new(cfg, mix, Arc::clone(&prepared[i].problem));
            engine.run_on(exec, algo, comp, s.rounds)
        };

        let mut results: Vec<Option<RunRecord>> = (0..specs.len()).map(|_| None).collect();
        let pool = (self.threads > 1).then(|| WorkerPool::new(self.threads));
        let small: Vec<usize> =
            (0..specs.len()).filter(|&i| !prepared[i].inner_useful).collect();
        // Large runs: one at a time on the calling thread, full inner
        // budget (§Scheduling).
        let inner_exec = match &pool {
            Some(p) => Exec::pool(p),
            None => Exec::seq(),
        };
        for i in 0..specs.len() {
            if prepared[i].inner_useful {
                results[i] = Some(run_one(i, inner_exec));
            }
        }
        // Small runs: outer-sharded as whole-run tasks. Each index is
        // claimed by exactly one worker (par_dynamic), so the per-slot
        // writes below are never aliased; runs inside a pool worker use
        // Exec::seq() (nested-budget rule).
        match &pool {
            Some(p) if small.len() > 1 => {
                let res_ptr = SendPtr(results.as_mut_ptr());
                let small_ref = &small;
                par_dynamic(Exec::pool(p), small.len(), |q| {
                    let i = small_ref[q];
                    let rec = run_one(i, Exec::seq());
                    // SAFETY: distinct q ⇒ distinct i (small holds unique
                    // indices); the dispatch barrier orders these writes
                    // before the caller reads them.
                    unsafe {
                        *res_ptr.0.add(i) = Some(rec);
                    }
                });
            }
            _ => {
                for &i in &small {
                    results[i] = Some(run_one(i, Exec::seq()));
                }
            }
        }
        let records: Vec<RunRecord> =
            results.into_iter().map(|r| r.expect("every spec ran")).collect();

        if let Some(dir) = &self.out {
            std::fs::create_dir_all(dir)?;
            for (s, rec) in specs.iter().zip(&records) {
                rec.write_csv(dir, &s.name)?;
            }
            std::fs::write(
                dir.join(format!("{grid_name}.json")),
                grid_json(grid_name, self.threads, self.tol, specs, &records),
            )?;
        }
        Ok(records)
    }
}

/// Run every spec with tracing enabled and export one Chrome trace-event
/// JSON file per run into `dir` (`<name>.trace.json`), returning the
/// written paths in spec order. This is the `lead trace` backend
/// (§Observability, `crate::trace`): runs execute one at a time on the
/// shared pool (captures are per-engine, and trace wall times are not a
/// benchmark), every artifact is re-validated through
/// [`crate::trace::validate_chrome_json`] before it is written — an
/// exporter regression fails the command instead of shipping a file
/// `chrome://tracing` rejects — and the trajectory stays bitwise-equal
/// to an untraced run (`rust/tests/trace.rs`).
pub fn trace_runs(specs: &[RunSpec], threads: usize, dir: &Path) -> Result<Vec<PathBuf>> {
    // Same prevalidation order as [`Driver::run`]: reject typo'd cells
    // (and the §Transport codec gate) before building any problem.
    for s in specs {
        s.build_mix()?;
        let algo = s.build_algo()?;
        let comp = s.build_compressor()?;
        let mode = s.build_transport()?;
        if !mode.is_mem() && algo.spec().compressed {
            if let Some(c) = &comp {
                if c.wire_format().is_none() {
                    return Err(err(format!(
                        "{}: transport {:?} needs a wire-complete compressor (topk, q*); {:?} does not decode from its payload alone",
                        s.name, s.transport, s.compressor
                    )));
                }
            }
        }
    }
    std::fs::create_dir_all(dir)?;
    let pool = (threads > 1).then(|| WorkerPool::new(threads));
    let exec = match &pool {
        Some(p) => Exec::pool(p),
        None => Exec::seq(),
    };
    let mut written = Vec::with_capacity(specs.len());
    for s in specs {
        let mix = s.build_mix().expect("prevalidated");
        let algo = s.build_algo().expect("prevalidated");
        let comp = s.build_compressor().expect("prevalidated");
        let mut cfg = s.engine_config()?;
        cfg.trace = true;
        let mut engine = Engine::new(cfg, mix, s.problem.build(s.agents));
        engine.run_on(exec, algo, comp, s.rounds);
        let cap = engine.take_trace().expect("trace enabled for this run");
        let js = crate::trace::chrome_json(&cap, &s.name);
        crate::trace::validate_chrome_json(&js)
            .map_err(|e| err(format!("{}: exporter produced invalid Chrome JSON: {e}", s.name)))?;
        let path = dir.join(format!("{}.trace.json", s.name));
        std::fs::write(&path, js)?;
        written.push(path);
    }
    Ok(written)
}

/// The unified per-grid JSON artifact: spec + full record per run, plus
/// optional per-run `time_to_tol` (when a tolerance is configured) and
/// seed-axis aggregates (module docs §Seed-axis aggregation).
fn grid_json(
    grid_name: &str,
    threads: usize,
    tol: Option<f64>,
    specs: &[RunSpec],
    records: &[RunRecord],
) -> String {
    let mut out = String::from("{\"schema\":2,\"grid\":");
    json::write_str(&mut out, grid_name);
    out.push_str(&format!(",\"threads\":{threads}"));
    if let Some(t) = tol {
        out.push_str(",\"tol\":");
        json::write_num(&mut out, t);
    }
    out.push_str(",\"runs\":[");
    for (i, (s, rec)) in specs.iter().zip(records).enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"name\":");
        json::write_str(&mut out, &s.name);
        if let Some(t) = tol {
            out.push_str(",\"time_to_tol\":");
            match rec.time_to_tol(t) {
                Some(v) => json::write_num(&mut out, v),
                None => out.push_str("null"),
            }
        }
        out.push_str(",\"spec\":");
        out.push_str(&s.spec_json());
        out.push_str(",\"record\":");
        out.push_str(&rec.to_json());
        out.push('}');
    }
    out.push(']');
    if let Some(agg) = aggregates_json(tol, specs, records) {
        out.push_str(",\"aggregates\":");
        out.push_str(&agg);
    }
    out.push_str("}\n");
    out
}

/// Two specs describe the same cell iff they differ at most in `seed`
/// (and the derived `name`). Float fields compare by bits so NaN preset
/// placeholders (γ/α for algorithms that ignore them) group correctly.
fn same_cell_ignoring_seed(a: &RunSpec, b: &RunSpec) -> bool {
    a.problem.same(&b.problem)
        && a.topology == b.topology
        && a.mixing == b.mixing
        && a.agents == b.agents
        && a.algo == b.algo
        && a.eta.to_bits() == b.eta.to_bits()
        && a.gamma.to_bits() == b.gamma.to_bits()
        && a.alpha.to_bits() == b.alpha.to_bits()
        && a.compressor == b.compressor
        && a.rounds == b.rounds
        && a.batch_size == b.batch_size
        && a.record_every == b.record_every
        && a.t0.map(f64::to_bits) == b.t0.map(f64::to_bits)
        && a.link == b.link
        && a.faults == b.faults
        && a.time_budget.map(f64::to_bits) == b.time_budget.map(f64::to_bits)
        && a.transport == b.transport
}

/// Mean ± population std per recorded round over a cell's seed group,
/// for every metric a variance/time-to-accuracy plot needs. Returns
/// `None` when no cell has ≥ 2 seeds (no `seed` axis ⇒ no aggregates).
fn aggregates_json(tol: Option<f64>, specs: &[RunSpec], records: &[RunRecord]) -> Option<String> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for i in 0..specs.len() {
        match groups.iter_mut().find(|g| same_cell_ignoring_seed(&specs[g[0]], &specs[i])) {
            Some(g) => g.push(i),
            None => groups.push(vec![i]),
        }
    }
    groups.retain(|g| g.len() > 1);
    // Series must be round-aligned — guaranteed for same-cell specs
    // (identical rounds/record_every); drop any group that is not.
    groups.retain(|g| {
        let first = &records[g[0]].series;
        g.iter().all(|&i| {
            let s = &records[i].series;
            s.len() == first.len() && s.iter().zip(first).all(|(a, b)| a.round == b.round)
        })
    });
    if groups.is_empty() {
        return None;
    }

    let mean_std = |vals: &[f64]| -> (f64, f64) {
        let k = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / k;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / k;
        (mean, var.max(0.0).sqrt())
    };
    let write_band =
        |out: &mut String, key: &str, g: &[usize], metric: &dyn Fn(&RoundMetrics) -> f64| {
            out.push(',');
            json::write_str(out, key);
            out.push_str(":{\"mean\":[");
            let rounds = records[g[0]].series.len();
            let mut means = Vec::with_capacity(rounds);
            let mut stds = Vec::with_capacity(rounds);
            for r in 0..rounds {
                let vals: Vec<f64> = g.iter().map(|&i| metric(&records[i].series[r])).collect();
                let (m, s) = mean_std(&vals);
                means.push(m);
                stds.push(s);
            }
            for (i, m) in means.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json::write_num(out, *m);
            }
            out.push_str("],\"std\":[");
            for (i, s) in stds.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json::write_num(out, *s);
            }
            out.push_str("]}");
        };
    // Run-level scalar band: one mean ± std per cell (not per round) for
    // whole-run quantities — phase wall times and fleet counters.
    let write_scalar_band =
        |out: &mut String, key: &str, g: &[usize], metric: &dyn Fn(&RunRecord) -> f64| {
            let vals: Vec<f64> = g.iter().map(|&i| metric(&records[i])).collect();
            let (m, s) = mean_std(&vals);
            out.push(',');
            json::write_str(out, key);
            out.push_str(":{\"mean\":");
            json::write_num(out, m);
            out.push_str(",\"std\":");
            json::write_num(out, s);
            out.push('}');
        };

    let mut out = String::from("[");
    for (gi, g) in groups.iter().enumerate() {
        if gi > 0 {
            out.push(',');
        }
        let first = &specs[g[0]];
        // Cell label: the first member's name with its axis-generated
        // `_seed<k>` segment stripped. Only the LAST occurrence goes —
        // axes append after the user-chosen grid name, so a grid name
        // that happens to contain the same substring stays intact.
        let seg = format!("_seed{}", first.seed);
        let label = match first.name.rfind(&seg) {
            Some(pos) => {
                let mut s = first.name.clone();
                s.replace_range(pos..pos + seg.len(), "");
                s
            }
            None => first.name.clone(),
        };
        out.push_str("{\"cell\":");
        json::write_str(&mut out, &label);
        out.push_str(",\"seeds\":[");
        for (i, &j) in g.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&specs[j].seed.to_string());
        }
        out.push_str("],\"rounds\":[");
        for (i, m) in records[g[0]].series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&m.round.to_string());
        }
        out.push(']');
        write_band(&mut out, "dist_opt", g, &|m| m.dist_opt);
        write_band(&mut out, "consensus", g, &|m| m.consensus);
        write_band(&mut out, "loss", g, &|m| m.loss);
        write_band(&mut out, "comp_err", g, &|m| m.comp_err);
        write_band(&mut out, "sim_time", g, &|m| m.sim_time);
        write_band(&mut out, "idle_max", g, &|m| m.idle_max);
        // Scalar bands (§Observability): per-phase wall times always;
        // transport/fault/net counters only when the cell actually ran
        // that subsystem — an absent subsystem omits its keys rather
        // than emitting a fake zero band.
        write_scalar_band(&mut out, "phase_produce", g, &|r| r.phases.produce);
        write_scalar_band(&mut out, "phase_mix", g, &|r| r.phases.mix);
        write_scalar_band(&mut out, "phase_apply", g, &|r| r.phases.apply);
        write_scalar_band(&mut out, "phase_observe", g, &|r| r.phases.observe);
        if g.iter().any(|&i| records[i].transport.is_some()) {
            write_scalar_band(&mut out, "frames_sent", g, &|r| {
                r.transport.as_ref().map_or(0.0, |t| t.frames_sent as f64)
            });
            write_scalar_band(&mut out, "frames_dropped", g, &|r| {
                r.transport.as_ref().map_or(0.0, |t| t.frames_dropped as f64)
            });
            write_scalar_band(&mut out, "bytes_on_wire", g, &|r| {
                r.transport.as_ref().map_or(0.0, |t| t.bytes_on_wire as f64)
            });
        }
        if g.iter().any(|&i| records[i].faults.is_some()) {
            write_scalar_band(&mut out, "lost_messages", g, &|r| {
                r.faults.as_ref().map_or(0.0, |f| f.lost as f64)
            });
            write_scalar_band(&mut out, "stale_deliveries", g, &|r| {
                r.faults.as_ref().map_or(0.0, |f| f.stale as f64)
            });
            write_scalar_band(&mut out, "crashed_agent_rounds", g, &|r| {
                r.faults.as_ref().map_or(0.0, |f| f.crashed_agent_rounds as f64)
            });
        }
        if g.iter().any(|&i| records[i].net.is_some()) {
            write_scalar_band(&mut out, "retransmits", g, &|r| {
                r.net.as_ref().map_or(0.0, |s| s.retransmits as f64)
            });
        }
        if let Some(t) = tol {
            let reached: Vec<f64> =
                g.iter().filter_map(|&i| records[i].time_to_tol(t)).collect();
            out.push_str(&format!(
                ",\"time_to_tol\":{{\"reached\":{},\"of\":{}",
                reached.len(),
                g.len()
            ));
            if reached.is_empty() {
                out.push_str(",\"mean\":null,\"std\":null}");
            } else {
                let (m, s) = mean_std(&reached);
                out.push_str(",\"mean\":");
                json::write_num(&mut out, m);
                out.push_str(",\"std\":");
                json::write_num(&mut out, s);
                out.push('}');
            }
        }
        out.push('}');
    }
    out.push(']');
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments;

    #[test]
    fn grid_expands_cartesian_first_axis_outermost() {
        let grid = Grid {
            name: "t".into(),
            base: RunSpec::paper_default(),
            axes: vec![
                ("alpha".into(), vec![toml_mini::Value::Float(0.1), toml_mini::Value::Float(0.9)]),
                (
                    "gamma".into(),
                    vec![
                        toml_mini::Value::Float(0.5),
                        toml_mini::Value::Int(1),
                        toml_mini::Value::Float(2.0),
                    ],
                ),
            ],
            tol: None,
        };
        let specs = grid.expand().unwrap();
        assert_eq!(specs.len(), 6);
        assert_eq!(specs[0].alpha, 0.1);
        assert_eq!(specs[0].gamma, 0.5);
        assert_eq!(specs[1].gamma, 1.0, "ints coerce on numeric axes");
        assert_eq!(specs[3].alpha, 0.9, "first axis is outermost");
        assert_eq!(specs[0].name, "t_alpha0.1_gamma0.5");
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 6, "cell names must be unique");
    }

    #[test]
    fn grid_from_toml_parses_and_rejects() {
        let src = r#"
[grid]
name = "sweep"
rounds = 120
compressor = "topk:10"
mixing = "metropolis"

[problem]
kind = "quad"
dim = 64
seed = 7

[axes]
alpha = [0.1, 0.5]
seed = [1, 2, 3]
"#;
        let g = Grid::from_toml(src).unwrap();
        assert_eq!(g.name, "sweep");
        assert_eq!(g.base.rounds, 120);
        assert_eq!(g.base.mixing, MixingRule::MetropolisHastings);
        assert!(matches!(g.base.problem, ProblemSpec::Quad { dim: 64, seed: 7 }));
        let specs = g.expand().unwrap();
        assert_eq!(specs.len(), 6);
        // Axes expand in alphabetical key order: alpha outermost.
        assert_eq!(specs[0].seed, 1);
        assert_eq!(specs[2].seed, 3);
        assert_eq!(specs[3].alpha, 0.5);

        assert!(Grid::from_toml("bogus_key = 1").is_err(), "unknown keys fail loudly");
        assert!(
            Grid::from_toml("[problem]\nkind = \"wat\"").is_err(),
            "unknown problem kind fails"
        );
        assert!(
            Grid::from_toml("[axes]\nalpha = 0.1").is_err(),
            "non-array axis fails"
        );
    }

    #[test]
    fn grid_toml_link_and_tol_parse() {
        let src = r#"
[grid]
name = "net"
rounds = 20
tol = 1e-5
link = "uniform:1e-4:1e9"

[axes]
link = ["legacy", "uniform:1e-3:1e6", "straggler:1e-4:1e9:0.25:10:drop=0.01"]
"#;
        let g = Grid::from_toml(src).unwrap();
        assert_eq!(g.tol, Some(1e-5));
        assert_eq!(g.base.link, "uniform:1e-4:1e9");
        let specs = g.expand().unwrap();
        assert_eq!(specs.len(), 3);
        assert!(specs[0].build_net().unwrap().is_none(), "legacy ⇒ no overlay");
        assert!(specs[1].build_net().unwrap().is_some());
        assert_eq!(specs[2].build_net().unwrap().unwrap().drop, 0.01);
        assert_eq!(specs[1].name, "net_linkuniform:1e-3:1e6");
    }

    #[test]
    fn grid_toml_faults_and_time_budget_parse() {
        let src = r#"
[grid]
name = "ft"
rounds = 20
time_budget = 2.5

[axes]
faults = ["none", "loss:0.05", "crash:0.25:5:down=10+loss:0.02"]
"#;
        let g = Grid::from_toml(src).unwrap();
        assert_eq!(g.base.time_budget, Some(2.5));
        let specs = g.expand().unwrap();
        assert_eq!(specs.len(), 3);
        assert!(specs[0].build_faults().unwrap().is_none(), "none ⇒ fault-free path");
        assert_eq!(specs[1].build_faults().unwrap().unwrap().loss, 0.05);
        let plan = specs[2].build_faults().unwrap().unwrap();
        assert_eq!(plan.crash_frac, 0.25);
        assert_eq!(plan.loss, 0.02);
        assert_eq!(specs[1].name, "ft_faultsloss:0.05");
        // Engine config carries both through.
        let cfg = specs[1].engine_config().unwrap();
        assert!(cfg.faults.is_some());
        assert_eq!(cfg.time_budget, Some(2.5));
        // Same-cell grouping splits on the faults axis.
        assert!(!same_cell_ignoring_seed(&specs[0], &specs[1]));
        let mut reseed = specs[1].clone();
        reseed.seed = 99;
        assert!(same_cell_ignoring_seed(&specs[1], &reseed));
    }

    #[test]
    fn run_work_estimate_uses_cost_hint() {
        // LogReg's full-gradient sweep is samples·d per agent — far above
        // the channels·d message floor the old classifier used.
        let p = crate::problems::logreg::LogReg::synthetic(
            4, 400, 10, 3, 1e-2, DataSplit::Homogeneous, 5, false,
        );
        let d = p.dim();
        let samples = (0..4).map(|i| p.n_samples(i)).max().unwrap();
        assert_eq!(run_work_estimate(&p, 2, None), (samples * d).max(2 * d));
        // Mini-batch runs cap the gradient term at batch·d.
        assert_eq!(run_work_estimate(&p, 2, Some(8)), (8 * d).max(2 * d));
        // Problems without a hint keep the message-size classifier.
        let q = crate::problems::quad::Quad::new(4, 100, 1);
        assert_eq!(run_work_estimate(&q, 2, None), 200);
    }

    /// Seed-axis aggregation: cells differing only by seed reduce to one
    /// aggregate with mean ± std bands and a time_to_tol summary.
    #[test]
    fn grid_json_aggregates_over_seed_axis() {
        let dir = std::env::temp_dir().join(format!("lead_agg_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let grid = Grid::from_toml(
            r#"
[grid]
name = "agg"
rounds = 40
record_every = 10
tol = 1e-3

[problem]
kind = "linreg"
dim = 30
reg = 0.1
seed = 7

[axes]
compressor = ["qinf:2:512", "raw"]
seed = [1, 2, 3]
"#,
        )
        .unwrap();
        let specs = grid.expand().unwrap();
        assert_eq!(specs.len(), 6);
        Driver::new(2)
            .with_out(Some(dir.as_path()))
            .with_tol(grid.tol)
            .run(&grid.name, &specs)
            .unwrap();
        let js = json::parse(&std::fs::read_to_string(dir.join("agg.json")).unwrap()).unwrap();
        assert_eq!(js.get("tol").unwrap().as_f64(), Some(1e-3));
        let runs = js.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 6);
        for r in runs {
            assert!(r.get("time_to_tol").is_some(), "per-run time_to_tol emitted");
        }
        let aggs = js.get("aggregates").unwrap().as_arr().unwrap();
        assert_eq!(aggs.len(), 2, "one aggregate per compressor cell");
        for a in aggs {
            assert_eq!(a.get("seeds").unwrap().as_arr().unwrap().len(), 3);
            let rounds = a.get("rounds").unwrap().as_arr().unwrap().len();
            assert_eq!(a.get("dist_opt").unwrap().get("mean").unwrap().as_arr().unwrap().len(), rounds);
            assert_eq!(a.get("dist_opt").unwrap().get("std").unwrap().as_arr().unwrap().len(), rounds);
            assert!(a.get("sim_time").unwrap().get("mean").is_some());
            let ttt = a.get("time_to_tol").unwrap();
            assert_eq!(ttt.get("of").unwrap().as_f64(), Some(3.0));
            let cell = a.get("cell").unwrap().as_str().unwrap();
            assert!(!cell.contains("seed"), "cell label must drop the seed segment: {cell}");
            // Scalar bands: phase wall times are always present; the
            // subsystems this grid never ran emit no counter bands.
            for key in ["phase_produce", "phase_mix", "phase_apply", "phase_observe"] {
                let band = a.get(key).unwrap_or_else(|| panic!("missing scalar band {key}"));
                assert!(band.get("mean").unwrap().as_f64().is_some(), "{key} mean");
                assert!(band.get("std").unwrap().as_f64().is_some(), "{key} std");
            }
            assert!(a.get("frames_sent").is_none(), "mem transport => no frame bands");
            assert!(a.get("lost_messages").is_none(), "no fault plan => no fault bands");
            assert!(a.get("retransmits").is_none(), "no simnet => no retransmit band");
        }
        // Different seeds actually differ (std > 0 somewhere): the bands
        // carry real variance, not copies of one run.
        let band = aggs[0].get("dist_opt").unwrap().get("std").unwrap().as_arr().unwrap();
        assert!(
            band.iter().any(|v| v.as_f64().is_some_and(|x| x > 0.0)),
            "zero variance across seeds"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// §Observability: a seed group that ran a channel transport emits
    /// frames_sent/frames_dropped/bytes_on_wire scalar bands — and the
    /// frame count is a deterministic topology quantity, so its std
    /// across seeds is exactly zero.
    #[test]
    fn aggregates_include_transport_counter_bands() {
        let mut a = RunSpec::paper_default();
        a.name = "t_seed1".into();
        a.problem = ProblemSpec::Quad { dim: 16, seed: 1 };
        a.rounds = 6;
        a.record_every = 3;
        a.transport = "channel".into();
        a.seed = 1;
        let mut b = a.clone();
        b.name = "t_seed2".into();
        b.seed = 2;
        let specs = vec![a, b];
        let recs = Driver::new(1).run("t", &specs).unwrap();
        let agg = aggregates_json(None, &specs, &recs).unwrap();
        let js = json::parse(&agg).unwrap();
        let cells = js.as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.get("phase_mix").unwrap().get("mean").is_some());
        let fs = c.get("frames_sent").unwrap();
        assert!(fs.get("mean").unwrap().as_f64().unwrap() > 0.0, "frames flowed");
        assert_eq!(fs.get("std").unwrap().as_f64(), Some(0.0), "frame count is seed-invariant");
        assert!(c.get("bytes_on_wire").unwrap().get("mean").unwrap().as_f64().unwrap() > 0.0);
        assert!(c.get("retransmits").is_none(), "no simnet => no retransmit band");
    }

    /// Grids without a seed axis emit no aggregates array.
    #[test]
    fn no_seed_axis_no_aggregates() {
        let mut a = RunSpec::paper_default();
        a.name = "a".into();
        a.problem = ProblemSpec::Quad { dim: 16, seed: 1 };
        a.rounds = 4;
        a.record_every = 2;
        let mut b = a.clone();
        b.name = "b".into();
        b.eta = 0.2;
        let recs = Driver::new(1).run("t", &[a.clone(), b.clone()]).unwrap();
        assert!(aggregates_json(None, &[a, b], &recs).is_none());
    }

    #[test]
    fn driver_validates_before_running() {
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.topology = "er:1.5".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err());
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.link = "uniform:1e-4".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err(), "bad link spec must fail loudly");
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.algo = "nope".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err());
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.compressor = "q9000".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err());
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.faults = "crash:2.0".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err(), "bad fault plan must fail loudly");
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.transport = "udp".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err(), "bad transport spec must fail loudly");
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.transport = "mux:0".into();
        assert!(Driver::new(1).run("t", &[bad]).is_err(), "mux needs >= 1 agent per slot");
        // Codec gate: rand-k is not wire-complete (receiver-side RNG
        // indices), so a compressed channel cell must be rejected before
        // any problem is built.
        let mut bad = RunSpec::paper_default();
        bad.rounds = 5;
        bad.compressor = "randk:10".into();
        bad.transport = "channel".into();
        assert!(
            Driver::new(1).run("t", &[bad.clone()]).is_err(),
            "rand-k over a channel transport must fail loudly"
        );
        // The same cell on the shared-memory reference stays valid.
        bad.transport = "mem".into();
        bad.rounds = 2;
        bad.problem = ProblemSpec::Quad { dim: 16, seed: 1 };
        assert!(Driver::new(1).run("t", &[bad]).is_ok());
    }

    #[test]
    fn grid_toml_transport_axis_parses() {
        let src = r#"
[grid]
name = "tp"
rounds = 20
compressor = "topk:10"

[axes]
transport = ["mem", "channel", "mux:8"]
"#;
        let g = Grid::from_toml(src).unwrap();
        let specs = g.expand().unwrap();
        assert_eq!(specs.len(), 3);
        assert!(specs[0].build_transport().unwrap().is_mem());
        assert_eq!(specs[1].build_transport().unwrap(), TransportMode::Channel);
        assert_eq!(
            specs[2].build_transport().unwrap(),
            TransportMode::Mux { per_worker: 8 }
        );
        assert_eq!(specs[1].name, "tp_transportchannel");
        // Engine config carries the mode through.
        assert_eq!(specs[2].engine_config().unwrap().transport, TransportMode::Mux { per_worker: 8 });
        // The transport axis splits seed-aggregation cells.
        assert!(!same_cell_ignoring_seed(&specs[0], &specs[1]));
        let mut reseed = specs[1].clone();
        reseed.seed = 99;
        assert!(same_cell_ignoring_seed(&specs[1], &reseed));
        // Spec JSON records the axis value.
        assert!(specs[1].spec_json().contains("\"transport\":\"channel\""));
    }

    /// The acceptance pin: the fig7 25-cell (α, γ) sweep through the
    /// sharded driver is bitwise-identical to serial execution — both the
    /// driver at threads = 1 and a hand-rolled per-cell engine loop (the
    /// pre-grid drivers' shape).
    #[test]
    fn sharded_grid_bitwise_equals_serial() {
        let grid = experiments::fig7_grid(40);
        let specs = grid.expand().unwrap();
        assert_eq!(specs.len(), 25);

        // Hand-rolled serial baseline: fresh engine per cell, in order.
        let baseline: Vec<RunRecord> = specs
            .iter()
            .map(|s| {
                let mut e = Engine::new(
                    s.engine_config().unwrap(),
                    s.build_mix().unwrap(),
                    s.problem.build(s.agents),
                );
                e.run(s.build_algo().unwrap(), s.build_compressor().unwrap(), s.rounds)
            })
            .collect();

        let serial = Driver::new(1).run("fig7", &specs).unwrap();
        let sharded = Driver::new(8).run("fig7", &specs).unwrap();
        for ((a, b), c) in baseline.iter().zip(&serial).zip(&sharded) {
            assert_eq!(a.series.len(), b.series.len());
            assert_eq!(a.series.len(), c.series.len());
            for ((ma, mb), mc) in a.series.iter().zip(&b.series).zip(&c.series) {
                assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
                assert_eq!(ma.dist_opt.to_bits(), mc.dist_opt.to_bits(), "round {}", ma.round);
                assert_eq!(ma.consensus.to_bits(), mc.consensus.to_bits());
                assert_eq!(ma.comp_err.to_bits(), mc.comp_err.to_bits());
                assert_eq!(ma.bits_per_agent, mc.bits_per_agent);
            }
        }
    }

    /// Mixed batches — small (outer-sharded) and large (inner-parallel)
    /// runs in one grid — still reproduce serial results bitwise, and
    /// problem dedupe shares one instance across equal specs.
    #[test]
    fn mixed_small_large_batch_matches_serial() {
        let mut small = RunSpec::paper_default();
        small.name = "small".into();
        small.problem = ProblemSpec::Quad { dim: 64, seed: 7 };
        small.rounds = 30;
        small.record_every = 10;
        // n·d = 8·6000 ≥ 32768 ⇒ classified large (inner-parallel).
        let mut large = RunSpec::paper_default();
        large.name = "large".into();
        large.problem = ProblemSpec::Quad { dim: 6000, seed: 7 };
        large.rounds = 10;
        large.record_every = 5;
        let mut small2 = small.clone();
        small2.name = "small2".into();
        small2.seed = 43;
        let specs = vec![small, large, small2];
        let serial = Driver::new(1).run("mix", &specs).unwrap();
        let sharded = Driver::new(4).run("mix", &specs).unwrap();
        for (a, b) in serial.iter().zip(&sharded) {
            for (ma, mb) in a.series.iter().zip(&b.series) {
                assert_eq!(ma.consensus.to_bits(), mb.consensus.to_bits(), "round {}", ma.round);
                assert_eq!(ma.loss.to_bits(), mb.loss.to_bits());
                assert_eq!(ma.bits_per_agent, mb.bits_per_agent);
            }
        }
        // Different seeds on the same problem spec still share the data.
        assert_eq!(serial[0].problem, serial[2].problem);
        assert!(
            serial[0].series.last().unwrap().consensus.to_bits()
                != serial[2].series.last().unwrap().consensus.to_bits(),
            "different engine seeds must differ"
        );
    }

    #[test]
    fn grid_artifacts_written() {
        let dir = std::env::temp_dir().join(format!("lead_grid_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = RunSpec::paper_default();
        spec.name = "cell_a".into();
        spec.problem = ProblemSpec::Quad { dim: 32, seed: 3 };
        spec.rounds = 10;
        spec.record_every = 5;
        let recs =
            Driver::new(2).with_out(Some(dir.as_path())).run("artifact_grid", &[spec]).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(dir.join("cell_a.csv").is_file());
        let js = std::fs::read_to_string(dir.join("artifact_grid.json")).unwrap();
        let parsed = json::parse(&js).unwrap();
        assert_eq!(parsed.get("grid").unwrap().as_str(), Some("artifact_grid"));
        let runs = parsed.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].get("name").unwrap().as_str(), Some("cell_a"));
        assert!(runs[0].get("spec").unwrap().get("algo").is_some());
        assert!(runs[0].get("record").unwrap().get("series").is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
