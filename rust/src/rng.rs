//! Deterministic pseudo-random number generation.
//!
//! Every stochastic choice in the system (data synthesis, quantization
//! dither, mini-batch sampling) draws from an independent, seeded stream so
//! that the sequential and thread-parallel coordinator engines produce
//! bitwise-identical trajectories regardless of scheduling.
//!
//! The core generator is SplitMix64 (Steele et al., 2014): tiny state, full
//! 64-bit period, passes BigCrush when used as a mixer, and — critically for
//! us — supports O(1) stream derivation via [`Rng::derive`].

/// SplitMix64 generator. 8 bytes of state, copyable, serializable by hand.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

/// Golden-ratio increment for SplitMix64.
const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

impl Rng {
    /// Create a generator from a seed. Two different seeds give streams that
    /// are statistically independent for our purposes.
    pub fn new(seed: u64) -> Self {
        // Avalanche the seed once so that small seeds (0, 1, 2...) do not
        // produce correlated early outputs.
        let mut r = Rng { state: seed ^ 0x5DEE_CE66_D1CE_4E5B };
        r.next_u64();
        r
    }

    /// Derive an independent child stream identified by `tag`.
    ///
    /// Used to give each (agent, purpose) pair its own stream:
    /// `root.derive(agent as u64).derive(PURPOSE_DITHER)`.
    pub fn derive(&self, tag: u64) -> Rng {
        let mut r = Rng { state: self.state ^ tag.wrapping_mul(GAMMA) ^ 0xA076_1D64_78BD_642F };
        r.next_u64();
        r
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's unbiased multiply-shift
    /// rejection method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: only loop when lo < n (probability < n/2^64).
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (both outputs used alternately would
    /// complicate state; we use one and keep the generator allocation-free).
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill `out` with i.i.d. N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f64], sigma: f64) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Fill `out` with i.i.d. U[0,1) samples (used for quantization dither).
    pub fn fill_uniform(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.uniform();
        }
    }

    /// Alias for [`Rng::normal`] used by generated numeric code.
    #[inline]
    pub fn normal_f64(&mut self) -> f64 {
        self.normal()
    }

    /// Alias for [`Rng::uniform`].
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        self.uniform()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm, then
    /// shuffled so order is also random). Requires k <= n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        self.sample_indices_into(n, k, &mut chosen);
        chosen
    }

    /// [`Rng::sample_indices`] into a caller-owned buffer (§Perf: the
    /// engine's steady-state loop reuses codec scratch instead of
    /// allocating per call). Draw-for-draw identical to
    /// [`Rng::sample_indices`] — same Floyd selection, same shuffle — so
    /// the two paths consume the stream identically and any mix of them
    /// stays bitwise-reproducible.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        out.clear();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        self.shuffle(out);
    }
}

/// Stream tags for purpose-separated child streams (see DESIGN.md §6).
pub mod streams {
    pub const DATA: u64 = 0x01;
    pub const DITHER: u64 = 0x02;
    pub const BATCH: u64 = 0x03;
    pub const INIT: u64 = 0x04;
    pub const TOPOLOGY: u64 = 0x05;
    pub const GRADIENT_NOISE: u64 = 0x06;
    /// Simulated-network link parameters, jitter, and drop draws
    /// (`crate::simnet`). Derived — never drawn — from the engine seed,
    /// so enabling the timing overlay cannot shift any other stream.
    pub const NET: u64 = 0x07;
    /// Fault-injection draws (`crate::faults`): crash sets, churn, and
    /// per-link message loss. Derived — never drawn — from the engine
    /// seed, so enabling fault injection cannot shift any other stream.
    pub const FAULT: u64 = 0x08;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn derive_independent() {
        let root = Rng::new(7);
        let mut a = root.derive(0);
        let mut b = root.derive(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            let expected = n / 7;
            assert!(
                (c as i64 - expected as i64).abs() < (expected as i64) / 10,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_into_matches_alloc_path() {
        // Same seed ⇒ same draws, same output, same post-call stream; the
        // buffer variant must be a pure allocation change.
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        let mut buf = Vec::new();
        for (n, k) in [(10usize, 3usize), (100, 100), (7, 1), (50, 49)] {
            let alloc = a.sample_indices(n, k);
            b.sample_indices_into(n, k, &mut buf);
            assert_eq!(alloc, buf, "n={n} k={k}");
        }
        assert_eq!(a.next_u64(), b.next_u64(), "streams diverged");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let k = 1 + r.below(50);
            let s = r.sample_indices(100, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 100));
        }
    }
}
