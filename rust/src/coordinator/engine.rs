//! The coordinator engine: drives algorithms over a simulated gossip
//! network with exact wire-bit accounting.
//!
//! # Round phases and scheduling
//!
//! One engine instance owns the problem, the topology, and the round loop.
//! With the default [`Scheduler::Persistent`] a round is **three**
//! barrier-synchronized dispatches on a [`WorkerPool`] whose workers are
//! spawned once per run:
//!
//! 1. **produce** — [`Algorithm::produce_all`]: one task per agent fusing
//!    gradient evaluation (`∇f_i`, mini-batch indices pre-drawn in agent
//!    order so the RNG stream is schedule-independent), payload assembly,
//!    and channel-0 compression (one dither RNG stream per agent) with
//!    wire-bit accounting;
//! 2. **mix** — W-weighted neighborhood mixes. Messages that publish a
//!    sparse view ([`CompressedMsg::sparse`]: top-k / rand-k) are
//!    accumulated by scatter-add in O(deg·k) instead of O(deg·d) — see
//!    [`mix_msgs`] for the bitwise-equality argument;
//! 3. **apply** — [`Algorithm::recv_all`]: per-agent state is disjoint
//!    row-major rows, so agents update independently; own messages are
//!    consumed through the sparse-aware `Inbox::own_view` (no dense
//!    own-decode in the sparse steady state).
//!
//! [`Scheduler::SpawnPerPhase`] preserves the pre-pool behavior (scoped
//! thread spawns per phase, sequential send, separate compress dispatch,
//! per-round compression-error pass) as the A/B baseline for
//! `benches/hotpath.rs`; both schedulers produce bitwise-identical
//! trajectories (`scheduler_modes_bitwise_identical`).
//!
//! Determinism is scheduling-independent because every stochastic choice
//! draws from a per-(agent, purpose) RNG stream and the parallel phases
//! touch disjoint per-agent data; the `parallel_equals_sequential` tests
//! assert bitwise equality for both dense (quantizer) and sparse (top-k)
//! messages.
//!
//! # §Perf — steady-state zero-allocation contract
//!
//! After warm-up (first round or two: lazy buffer growth), a
//! non-observed round of the persistent scheduler performs **zero heap
//! allocations** on both the dense (quantizer) and sparse (top-k) paths —
//! enforced by the counting-allocator test
//! `rust/tests/alloc_steady_state.rs`. The conventions that make this
//! hold:
//!
//! * every per-round buffer (`g`, `payload`, `msgs`, `mixed_all`,
//!   `round_bits`, mini-batch index sets, codec scratch) is hoisted out
//!   of the loop and reused; codecs reuse their payload/sparse buffers
//!   ([`Compressor::compress_into`] + [`CodecScratch`]);
//! * [`Inbox`] is a zero-copy *view* over those buffers, rebuilt each
//!   round by copying three references;
//! * sparse codecs may skip the O(d) dense decode entirely: the apply
//!   phase hands each algorithm its own message as an
//!   [`OwnView`](crate::algorithms::OwnView) (the k published entries for
//!   a stale sparse message), so in the top-k/rand-k steady state **no
//!   O(n·d) own-decode pass survives**. The engine materializes the dense
//!   vector inside the produce task only when the algorithm opts out with
//!   [`OwnAccess::Dense`] (or for codecs without a sparse view, where the
//!   eager `compress` already fills it), and otherwise only on observed
//!   rounds (`record_every`) for the compression-error metric — which is
//!   the error of the *observed* round, computed on demand. Sparse-own
//!   apply is pinned bitwise-identical to the dense decode path and to
//!   the legacy scheduler by `rust/tests/sparse_own.rs` (the ±0.0
//!   bit-exactness rule lives on `OwnView`);
//! * pool dispatches and the [`par_agents`]-family row bundles are
//!   allocation-free ([`crate::pool`] docs).
//!
//! Observed rounds (metrics passes allocate scratch) are the one
//! documented exception; every in-tree codec — quantizers, top-k, and
//! rand-k — has a scratch-carrying `compress_into` fast path.
//!
//! # §Network timing — uniform formula vs. simnet overlay
//!
//! Round durations come from one of two interchangeable time models:
//! the legacy uniform formula (`cfg.link`: `latency + max_bits /
//! bandwidth` per synchronous round) or, when `cfg.net` is set, the
//! discrete-event simulator [`crate::simnet`] (per-edge heterogeneous
//! links, stragglers, jitter, drop-with-retransmit). Both are **timing
//! overlays**: they observe the already-accounted `round_bits` and add
//! seconds to [`TrafficStats`], and neither touches payloads or any RNG
//! stream an algorithm consumes — so the trajectory series
//! (dist/consensus/comp_err/bits) are bitwise-identical across time
//! models, and the degenerate homogeneous simnet model reproduces the
//! uniform formula's `sim_time` bit-for-bit (`rust/tests/simnet.rs`,
//! plus a proptest over random topologies/links). The timer always runs
//! sequentially on the coordinator thread, so its event order is
//! independent of `exec`.
//!
//! # §Fault injection — the degraded-inbox contract
//!
//! When `cfg.faults` carries a non-no-op [`FaultPlan`], the engine
//! compiles it into a [`FaultSchedule`] on the dedicated
//! `streams::FAULT` stream and runs a *graceful-degradation* round
//! loop. Unlike the timing overlays this **changes trajectories by
//! design**; determinism is preserved the same way as everywhere else
//! (fixed draw counts on a dedicated stream, all schedule mutation on
//! the coordinator thread, workers only read). The contract per round:
//!
//! * **produce** runs for *every* agent — crashed included — so every
//!   dither/batch stream advances exactly as in the fault-free run;
//!   a crashed agent's message simply never leaves the node (its wire
//!   bits are zeroed, its in-links and out-links resolve Lost).
//! * **mix** consults the schedule per directed in-link: `Delivered`
//!   accumulates at the nominal weight, `Stale` replays the sender's
//!   last delivered decode (bounded age), and `Lost` is skipped with
//!   the missing mass folded into the self weight
//!   ([`crate::faults::folded_self_weight`]) — every live row stays
//!   row-stochastic (proptest in `crate::faults`).
//! * **apply** skips crashed agents wholesale (`Inbox::live`): their
//!   algorithm state — including LEAD/CHOCO difference-compression
//!   reference points — is frozen, not corrupted, and resumes on
//!   recovery.
//!
//! With `cfg.faults` None (or no-op) none of these paths run and the
//! loop is bitwise-identical to today's engine; `rust/tests/faults.rs`
//! pins both directions plus thread-count determinism with faults on.
//!
//! # §Transport — message-passing backends
//!
//! `cfg.transport` selects how messages move between agents. The default
//! ([`TransportMode::Mem`]) is the shared-memory model above: the mix
//! phase reads neighbors' messages straight out of the coordinator's
//! buffers. Channel modes ([`TransportMode::Channel`],
//! [`TransportMode::Mux`]) replace exactly the mix phase's *data motion*:
//! after the fault schedule resolves, the coordinator thread frames each
//! deliverable directed edge's wire bytes and enqueues them
//! (`send_round`), then receive slots drain, decode, and mix in parallel
//! (`recv_and_mix`) — everything else (produce, accounting, timing,
//! store-delivered, apply, comp-err) is untouched, and each agent's own
//! message never crosses the transport. The full delivery / ordering /
//! bitwise contract — including why lossless channel runs reproduce the
//! `Mem` trajectory series bit-for-bit, the frame-asserted `round_bits`
//! accounting, and the fault drop path — is the §Transport contract in
//! [`crate::transport`]; the differential harness is
//! `rust/tests/transport.rs`. Channel modes relax the §Perf zero-alloc
//! contract by exactly one `Vec<u8>` per frame in flight (`Mem` runs are
//! unaffected).
//!
//! # §Observability — tracing is trajectory-invisible
//!
//! With `cfg.trace` on, [`Engine::run_on`] stands up a per-run
//! [`Recorder`] (pre-allocated per-lane event rings — the §Perf
//! zero-alloc contract holds with tracing enabled) and attaches it to
//! the run's [`Exec`], so phase spans, pool dispatch/wake latencies,
//! transport frame events, fault transitions, and simnet arrivals all
//! land in one dual-timeline capture (wall µs + simnet virtual time).
//! The recorder only ever *observes* — no engine decision branches on
//! trace state, and every wall-clock stamp in this file goes through
//! the [`crate::trace::clock`] choke point (audit rule R7) — so traced
//! runs are bitwise-identical to untraced runs (`rust/tests/trace.rs`).
//! The constant-size rollup lands in `RunRecord.trace`; the full event
//! capture is fetched separately via [`Engine::take_trace`] (the one
//! rounds-proportional allocation, deliberately outside the round
//! loop). See the §Observability contract in [`crate::trace`].
//!
//! # §Scheduling — outer vs. inner parallelism
//!
//! A single engine run parallelizes *inside* the round (per-agent tasks)
//! — that is the **inner** level, driven by whatever [`Exec`] the caller
//! hands to [`Engine::run_on`] ([`Engine::run`] stands up a private pool
//! from `cfg.threads`). Batches of runs (scenario grids, see
//! `crate::scenarios`) add an **outer** level: whole runs dispatched as
//! single tasks across one shared [`WorkerPool`]
//! ([`crate::pool::par_dynamic`]).
//!
//! The budget rule that keeps `threads` the total parallelism: a run is
//! either *outer-sharded* — it occupies one pool worker and its inner
//! dispatches run inline (the driver passes `Exec::seq()`; a nested
//! dispatch on the same pool would degrade to inline anyway) — or
//! *inner-parallel* — it executes on the dispatching thread with the full
//! pool as its `Exec`, one run at a time. The driver picks per run:
//! below the [`phase_threads`] work threshold (`n · channels · d <
//! 32768` elements) inner fan-out loses to dispatch overhead, so small
//! runs shard outward and large runs keep today's per-agent parallelism.
//! Trajectories never depend on the choice: every stochastic draw derives
//! from the run's own seed, so outer-sharded, inner-parallel, and fully
//! serial execution are bitwise-identical (pinned by
//! `scenarios::tests::sharded_grid_bitwise_equals_serial`).
//!
//! [`OwnAccess::Dense`]: crate::algorithms::OwnAccess::Dense
//! [`CodecScratch`]: crate::compress::CodecScratch
//! [`Compressor::compress_into`]: crate::compress::Compressor::compress_into
//! [`par_agents`]: crate::pool::par_agents

use super::metrics::{PhaseTimes, RoundMetrics, RunRecord};
use super::network::{LinkModel, TrafficStats};
use crate::faults::{FaultPlan, FaultSchedule, FaultTotals, LinkState};
use crate::simnet::{NetModel, NetSummary, RoundTimer};
use crate::algorithms::{Algorithm, Ctx, Inbox, OwnAccess};
use crate::compress::{CodecScratch, CompressedMsg, Compressor};
use crate::pool::{par_chunks, Exec, SendPtr, WorkerPool};
use crate::problems::Problem;
use crate::rng::{streams, Rng};
use crate::topology::MixingMatrix;
use crate::trace::{clock, EventKind, Recorder, TraceCapture};
use crate::transport::{ChannelTransport, TransportMode};
use std::sync::Arc;

/// Stepsize schedule (Theorem 1 uses constant; Theorem 2 diminishing).
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant,
    /// η_k = η · t0 / (t0 + k) — the O(1/k) decay of Theorem 2.
    Diminishing { t0: f64 },
}

/// Which execution backend drives the parallel phases (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scheduler {
    /// Persistent worker pool, fused produce phase, zero-alloc loop.
    #[default]
    Persistent,
    /// Pre-pool behavior: scoped thread spawns per phase, sequential
    /// send, separate compress dispatch, per-round compression-error
    /// pass. Kept as the A/B baseline; trajectories are bitwise-identical
    /// to [`Scheduler::Persistent`].
    SpawnPerPhase,
}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Base stepsize η.
    pub eta: f64,
    pub schedule: Schedule,
    /// Mini-batch size per agent; None ⇒ full gradient.
    pub batch_size: Option<usize>,
    pub seed: u64,
    /// Record metrics every k rounds (metrics cost a full loss pass).
    pub record_every: usize,
    /// Worker threads for the produce, mix, and apply phases (1 = inline).
    pub threads: usize,
    /// Uniform link model for the legacy round-time formula (used when
    /// `net` is None).
    pub link: LinkModel,
    /// Discrete-event network model (`crate::simnet`). `Some` replaces
    /// the uniform formula with per-round event simulation of all
    /// directed transfers — a timing-only overlay: trajectories are
    /// bitwise-identical either way, and the degenerate homogeneous
    /// model reproduces the legacy `sim_time` exactly (§Network timing).
    pub net: Option<NetModel>,
    /// Fault-injection plan (`crate::faults`). Unlike `net` this is NOT
    /// a timing-only overlay: faults change trajectories by design
    /// (§Fault injection). `None` (or a no-op plan) keeps the engine
    /// bitwise-identical to the fault-free round loop.
    pub faults: Option<FaultPlan>,
    /// Stop after this many simulated seconds (`sim_time`) instead of
    /// running all scheduled rounds; the record is flagged
    /// `stopped_early`. The budget is checked after each round's timing,
    /// so the final round that crosses the budget is still completed and
    /// observed.
    pub time_budget: Option<f64>,
    /// How messages move between agents (§Transport): shared memory (the
    /// default and bitwise reference) or framed wire bytes over
    /// in-process channels ([`crate::transport`]). Lossless channel
    /// transports are bitwise-invisible; compressed runs require a
    /// wire-complete codec
    /// ([`Compressor::wire_format`](crate::compress::Compressor::wire_format)).
    pub transport: TransportMode,
    /// Execution backend (default: persistent pool).
    pub scheduler: Scheduler,
    /// Record a structured trace of the run (§Observability):
    /// per-phase spans, pool wake latencies, transport frame events,
    /// fault transitions, and simnet arrivals. Trajectory-invisible by
    /// contract (`rust/tests/trace.rs`); summary in `RunRecord.trace`,
    /// full capture via [`Engine::take_trace`].
    pub trace: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            eta: 0.1,
            schedule: Schedule::Constant,
            batch_size: None,
            seed: 42,
            record_every: 10,
            threads: 1,
            link: LinkModel::default(),
            net: None,
            faults: None,
            time_budget: None,
            transport: TransportMode::default(),
            scheduler: Scheduler::default(),
            trace: false,
        }
    }
}

/// W-weighted mix of decoded channel-0 messages for agent `i`, written
/// into `out` (which must be zero-filled by the caller).
///
/// Messages carrying a sparse view are scatter-added in O(k); dense
/// messages fall back to `axpy` over `values`. The result is bitwise
/// identical to dense accumulation for every message: the sparse list
/// holds every nonzero of the (possibly lazily materialized) dense
/// vector, plus at most some explicitly-selected ±0.0 entries, and ±0.0
/// additions cannot change an accumulator that starts at +0.0 (IEEE 754
/// round-to-nearest yields −0.0 only from `(−0.0) + (−0.0)`, which a
/// +0.0 start makes unreachable — so the accumulator is never −0.0, and
/// both omitted and explicit zero terms are no-ops). The sparse-vs-dense
/// proptest in `rust/tests/proptests.rs` pins this down across
/// codecs/topologies.
pub fn mix_msgs(mix: &MixingMatrix, i: usize, msgs: &[CompressedMsg], out: &mut [f64]) {
    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
        let w = mix.weight(i, j);
        match &msgs[j].sparse {
            Some(entries) => crate::linalg::scatter_axpy(w, entries, out),
            None => {
                debug_assert!(!msgs[j].dense_stale, "dense mix over a stale message");
                crate::linalg::axpy(w, &msgs[j].values, out)
            }
        }
    }
}

/// [`mix_msgs`] under a fault schedule: the degraded-inbox mix for
/// receiver `i` (all channels). Crashed receivers get zeroed mixes
/// (never read — apply skips them); live receivers accumulate their own
/// message at the *folded* self weight (lost in-links' mass
/// renormalized in, keeping the row stochastic), delivered neighbors at
/// nominal weights, and stale neighbors from the schedule's replay
/// buffer. Read-only over the schedule, so the mix phase fans out
/// exactly like the fault-free path.
fn mix_degraded(
    mix: &MixingMatrix,
    i: usize,
    fs: &FaultSchedule,
    use_comp: bool,
    msgs: &[CompressedMsg],
    payload: &[Vec<Vec<f64>>],
    out: &mut [Vec<f64>],
) {
    if fs.is_down(i) {
        for mx in out.iter_mut() {
            mx.fill(0.0);
        }
        return;
    }
    let w_self =
        crate::faults::folded_self_weight(mix, i, |j| fs.link(i, j) == LinkState::Lost);
    for (c, mx) in out.iter_mut().enumerate() {
        mx.fill(0.0);
        if c == 0 && use_comp {
            match &msgs[i].sparse {
                Some(entries) => crate::linalg::scatter_axpy(w_self, entries, mx),
                None => {
                    debug_assert!(!msgs[i].dense_stale, "dense mix over a stale message");
                    crate::linalg::axpy(w_self, &msgs[i].values, mx)
                }
            }
        } else {
            crate::linalg::axpy(w_self, &payload[i][c], mx);
        }
        for &j in &mix.neighbors[i] {
            match fs.link(i, j) {
                LinkState::Lost => {}
                LinkState::Delivered => {
                    if c == 0 && use_comp {
                        match &msgs[j].sparse {
                            Some(entries) => {
                                crate::linalg::scatter_axpy(mix.weight(i, j), entries, mx)
                            }
                            None => {
                                debug_assert!(
                                    !msgs[j].dense_stale,
                                    "dense mix over a stale message"
                                );
                                crate::linalg::axpy(mix.weight(i, j), &msgs[j].values, mx)
                            }
                        }
                    } else {
                        crate::linalg::axpy(mix.weight(i, j), &payload[j][c], mx);
                    }
                }
                LinkState::Stale => {
                    crate::linalg::axpy(mix.weight(i, j), fs.stale_payload(i, j, c), mx);
                }
            }
        }
    }
}

/// Worker threads actually worth using for a phase that streams
/// `work_per_agent` f64 elements per agent: even pool dispatch (two
/// condvar hops) costs more than the loop itself on tiny problems (fig1
/// shape: n·d ≈ 1600), so below the threshold the phase runs inline.
/// Thread count never affects trajectories (the
/// `parallel_equals_sequential` tests), so this is purely a perf knob.
/// Also the scenario driver's small/large run classifier (§Scheduling).
pub(crate) fn phase_threads(threads: usize, n: usize, work_per_agent: usize) -> usize {
    const MIN_ELEMS: usize = 32_768;
    if n.saturating_mul(work_per_agent) < MIN_ELEMS {
        1
    } else {
        threads.max(1).min(n.max(1))
    }
}

/// One engine instance owns the mixing matrix and a *shared* problem
/// (`Arc` — grids run many engines over one expensive problem instance
/// without re-solving reference optima), and drives the round loop on an
/// execution backend supplied per run ([`Engine::run_on`]) or stood up
/// internally from `cfg.threads` ([`Engine::run`]).
pub struct Engine {
    pub cfg: EngineConfig,
    pub mix: MixingMatrix,
    pub problem: Arc<dyn Problem>,
    /// The last traced run's recorder, parked here so the
    /// rounds-proportional capture happens outside the round loop
    /// ([`Engine::take_trace`]). Always `None` when `cfg.trace` is off.
    last_trace: Option<Recorder>,
}

impl Engine {
    pub fn new(cfg: EngineConfig, mix: MixingMatrix, problem: Arc<dyn Problem>) -> Self {
        assert_eq!(mix.n, problem.n_agents(), "topology/problem agent mismatch");
        Engine { cfg, mix, problem, last_trace: None }
    }

    /// Detach the last traced run's event capture (§Observability).
    /// This is the tracing layer's one rounds-proportional allocation,
    /// deliberately outside [`Engine::run_on`] so the steady-state
    /// zero-alloc contract holds with tracing on. `None` when the last
    /// run had `cfg.trace` off (or nothing ran yet); a second call
    /// returns `None` until another traced run completes.
    pub fn take_trace(&mut self) -> Option<TraceCapture> {
        self.last_trace.take().map(|r| r.capture())
    }

    fn eta_at(&self, round: usize) -> f64 {
        match self.cfg.schedule {
            Schedule::Constant => self.cfg.eta,
            Schedule::Diminishing { t0 } => self.cfg.eta * t0 / (t0 + round as f64),
        }
    }

    /// Draw this round's mini-batch indices for every agent into the
    /// reused per-agent scratch (§Perf: no per-round allocation), in
    /// agent order — the single sampling site for round 0 and the round
    /// loop, so both consume the per-agent BATCH streams identically.
    /// No-op (indices unused) when `batch_size` is None.
    fn draw_batches(&self, batch_rngs: &mut [Rng], batch_idx: &mut [Vec<usize>]) {
        let Some(b) = self.cfg.batch_size else { return };
        for (i, idx) in batch_idx.iter_mut().enumerate() {
            idx.clear();
            let ns = self.problem.n_samples(i);
            for _ in 0..b.min(ns) {
                idx.push(batch_rngs[i].below(ns));
            }
        }
    }

    /// Run `algo` for `rounds` rounds. `compressor` applies to channel 0
    /// when the algorithm's spec opts in; other channels (and opted-out
    /// algorithms) are billed the raw 32 bits/element.
    ///
    /// Stands up a private execution backend from `cfg.threads` (a
    /// [`WorkerPool`] whose workers live for exactly this run, or scoped
    /// spawns under [`Scheduler::SpawnPerPhase`]) and delegates to
    /// [`Engine::run_on`]. Batch drivers that reuse one pool across many
    /// runs call `run_on` directly.
    pub fn run(
        &mut self,
        algo: Box<dyn Algorithm>,
        compressor: Option<Box<dyn Compressor>>,
        rounds: usize,
    ) -> RunRecord {
        let legacy = self.cfg.scheduler == Scheduler::SpawnPerPhase;
        // One pool per run: workers outlive every phase dispatch.
        let pool = (!legacy && self.cfg.threads > 1).then(|| WorkerPool::new(self.cfg.threads));
        let exec = match &pool {
            Some(p) => Exec::pool(p),
            None if legacy => Exec::spawn(self.cfg.threads),
            None => Exec::seq(),
        };
        self.run_on(exec, algo, compressor, rounds)
    }

    /// [`Engine::run`] on a caller-supplied execution backend. The engine
    /// does not own any threads here — `exec` carries the whole budget
    /// (§Scheduling), so a shared pool can serve many sequential runs
    /// without re-spawning workers, and an outer-sharded run passes
    /// `Exec::seq()`. `cfg.threads` is ignored on this path. Trajectories
    /// are independent of `exec` (module docs); engines are reusable —
    /// every run re-derives all state from `cfg.seed`
    /// (`engine_reuse_leaks_no_state`).
    pub fn run_on(
        &mut self,
        exec: Exec<'_>,
        mut algo: Box<dyn Algorithm>,
        compressor: Option<Box<dyn Compressor>>,
        rounds: usize,
    ) -> RunRecord {
        let wall_start = clock::now();
        let n = self.mix.n;
        let d = self.problem.dim();
        let spec = algo.spec();
        let use_comp = spec.compressed && compressor.is_some();
        let legacy = self.cfg.scheduler == Scheduler::SpawnPerPhase;
        // §Observability: the optional per-run recorder. Created up front
        // so its epoch precedes every stamp and its rings are allocated
        // before the round loop (zero-alloc steady state with tracing
        // on); attached to `exec` so pool dispatch/wake and transport
        // frame events land in per-thread lanes. Trace state is written,
        // never read, by everything below — tracing cannot perturb a
        // trajectory (rust/tests/trace.rs).
        let recorder = self.cfg.trace.then(|| Recorder::new(exec.threads()));
        let exec = match recorder.as_ref() {
            Some(r) => exec.with_trace(r),
            None => exec,
        };
        #[cfg(debug_assertions)]
        let dense_decodes_at_start = crate::compress::CompressedMsg::dense_decode_count();
        // audit:allow(rng_stream): the root of the per-run stream tree — every consumer below derives a named per-(agent, purpose) streams::* child
        let root = Rng::new(self.cfg.seed);
        let mut dither_rngs: Vec<Rng> =
            (0..n).map(|i| root.derive(i as u64).derive(streams::DITHER)).collect();
        let mut batch_rngs: Vec<Rng> =
            (0..n).map(|i| root.derive(i as u64).derive(streams::BATCH)).collect();
        let batching = self.cfg.batch_size.is_some();
        let mut batch_idx: Vec<Vec<usize>> = vec![Vec::new(); n];

        // x⁰ = problem-provided init (or zeros — the paper's setup for
        // convex problems), identical for every agent: consensus start.
        let x0_vec = self.problem.initial_point().unwrap_or_else(|| vec![0.0f64; d]);
        let x0 = vec![x0_vec; n];
        let mut g = vec![vec![0.0f64; d]; n];
        // Round-0 gradients go through the same batch-drawing path as the
        // round loop (identical RNG stream and clamping).
        self.draw_batches(&mut batch_rngs, &mut batch_idx);
        for i in 0..n {
            if batching {
                self.problem.grad_batch(i, &x0[i], &batch_idx[i], &mut g[i]);
            } else {
                self.problem.grad_full(i, &x0[i], &mut g[i]);
            }
        }
        let ctx0 = Ctx { mix: &self.mix, round: 0, eta: self.eta_at(0) };
        algo.init(&ctx0, &x0, &g);

        // Reusable round scratch (§Perf: allocated once, zero allocations
        // per steady-state round).
        let mut payload = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut msgs: Vec<CompressedMsg> = (0..n).map(|_| CompressedMsg::with_dim(d)).collect();
        let mut codec_scratch: Vec<CodecScratch> =
            (0..n).map(|_| CodecScratch::default()).collect();
        // Per-agent mixes, materialized so the mix and apply phases can
        // both fan out over agents (n·channels·d, allocated once).
        let mut mixed_all = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut traffic = TrafficStats::new(n);
        // §Network timing: the optional discrete-event overlay. It only
        // ever *observes* round_bits and produces durations from its own
        // dedicated RNG stream, so enabling it cannot perturb any
        // trajectory (pinned by rust/tests/simnet.rs).
        let mut timer = self.cfg.net.map(|m| RoundTimer::new(&self.mix, m, self.cfg.seed));
        // §Fault injection: compiled once per run on the dedicated FAULT
        // stream; a no-op plan compiles to nothing so it cannot perturb
        // the fault-free loop.
        let mut faults = self
            .cfg
            .faults
            .and_then(|p| (!p.is_noop()).then(|| FaultSchedule::new(&self.mix, p, self.cfg.seed, spec.channels, d)));
        // §Transport: non-Mem modes stand up per-slot receive queues once
        // per run; `None` keeps the shared-memory mix path byte-for-byte
        // as before. Compressed runs on a channel transport require a
        // wire-complete codec — the scenario driver rejects others up
        // front, and `for_mode` asserts as the engine-API backstop.
        let codec_label =
            compressor.as_deref().map_or_else(|| "none".to_string(), |c| c.name());
        let mut transport = ChannelTransport::for_mode(
            self.cfg.transport,
            &self.mix,
            d,
            spec.channels,
            use_comp,
            compressor.as_deref().and_then(|c| c.wire_format()),
            &codec_label,
        );
        let mut stopped_early = false;
        let mut series = Vec::new();
        let mut round_bits = vec![0u64; n];
        let mut phases = PhaseTimes::default();
        // Whether the apply phase needs each agent's own decoded DENSE
        // vector. Under the sparse-own contract this only triggers when
        // the algorithm explicitly opts out of `OwnView` consumption
        // (`OwnAccess::Dense`); codecs without a sparse fast path leave
        // the dense vector valid anyway, and `OwnAccess::{None, Sparse}`
        // algorithms never need the O(n·d) decode pass (§Perf).
        let need_own_dense = spec.own == OwnAccess::Dense;
        let raw_bits_all = (spec.channels as u64) * (d as u64) * 32;
        let extra_channel_bits = (spec.channels as u64 - 1) * (d as u64) * 32;

        // §Observability: previous round's crash mask, diffed after each
        // fault-schedule draw to emit fault_down/fault_up transition
        // instants. Allocated once; only read when both tracing and
        // faults are active.
        let mut prev_down = vec![false; n];

        // Record the initial state as round 0 — stamped into the observe
        // bucket like every other snapshot, so `phases.observe_n` always
        // equals `series.len()` (regression: phase_counts_* tests).
        {
            let t = clock::now();
            series.push(self.observe(&*algo, 0, 0.0, &traffic, 0.0, FaultTotals::default()));
            phases.observe += clock::secs_since(t);
            phases.observe_n += 1;
            if let Some(r) = &recorder {
                r.span(EventKind::PhaseObserve, t, 0);
            }
        }

        for round in 1..=rounds {
            let eta = self.eta_at(round);
            let ctx = Ctx { mix: &self.mix, round, eta };
            // Mini-batch draws stay sequential in agent order (RNG must
            // advance deterministically regardless of thread scheduling).
            self.draw_batches(&mut batch_rngs, &mut batch_idx);
            // Legacy-only: the pre-PR loop paid a compression-error pass
            // every round; observed values are identical either way.
            let mut comp_err_legacy = 0.0f64;
            if let Some(r) = &recorder {
                r.set_round(round);
            }
            let t_produce = clock::now();

            if legacy {
                // (1) gradients (parallel across spawned workers)
                let t = clock::now();
                {
                    let problem = &*self.problem;
                    let bi = &batch_idx;
                    let algo_ref: &dyn Algorithm = &*algo;
                    par_chunks(exec, &mut g, |i, gi| {
                        if batching {
                            problem.grad_batch(i, algo_ref.x(i), &bi[i], gi);
                        } else {
                            problem.grad_full(i, algo_ref.x(i), gi);
                        }
                    });
                }
                phases.gradient += clock::secs_since(t);

                // (2) local sends (sequential)
                let t = clock::now();
                for i in 0..n {
                    algo.send(&ctx, i, &g[i], &mut payload[i]);
                }
                phases.send += clock::secs_since(t);

                // (3) compression of channel 0 (parallel; per-agent
                // dither RNG; eager dense decode)
                let t = clock::now();
                if use_comp {
                    let comp = compressor.as_deref().unwrap();
                    {
                        let payload_ref = &payload;
                        let mut pairs: Vec<(&mut CompressedMsg, &mut Rng)> =
                            msgs.iter_mut().zip(dither_rngs.iter_mut()).collect();
                        par_chunks(exec, &mut pairs, |i, (m, r)| {
                            comp.compress(&payload_ref[i][0], r, m);
                        });
                    }
                    for i in 0..n {
                        comp_err_legacy +=
                            crate::linalg::dist_sq(&payload[i][0], &msgs[i].values).sqrt();
                        round_bits[i] = msgs[i].wire_bits + extra_channel_bits;
                    }
                    comp_err_legacy /= n as f64;
                } else {
                    for i in 0..n {
                        round_bits[i] = raw_bits_all;
                    }
                }
                phases.compress += clock::secs_since(t);
            } else {
                // (1) fused produce: gradient → send → compress, one task
                // per agent, one barrier.
                let problem = &*self.problem;
                let bi = &batch_idx;
                let grad = |i: usize, x: &[f64], out: &mut [f64]| {
                    if batching {
                        problem.grad_batch(i, x, &bi[i], out);
                    } else {
                        problem.grad_full(i, x, out);
                    }
                };
                let comp = compressor.as_deref();
                let msgs_p = SendPtr(msgs.as_mut_ptr());
                let rngs_p = SendPtr(dither_rngs.as_mut_ptr());
                let scratch_p = SendPtr(codec_scratch.as_mut_ptr());
                let bits_p = SendPtr(round_bits.as_mut_ptr());
                let sink = move |i: usize, p: &mut [Vec<f64>]| {
                    // SAFETY: produce_all invokes the sink exactly once
                    // per agent, each agent from a single worker, so the
                    // per-agent entries written through these pointers are
                    // never aliased (contract on Algorithm::produce_all).
                    unsafe {
                        if use_comp {
                            let m = &mut *msgs_p.0.add(i);
                            comp.unwrap().compress_into(
                                &p[0],
                                &mut *rngs_p.0.add(i),
                                m,
                                &mut *scratch_p.0.add(i),
                            );
                            if need_own_dense {
                                m.ensure_dense();
                            }
                            *bits_p.0.add(i) = m.wire_bits + extra_channel_bits;
                        } else {
                            *bits_p.0.add(i) = raw_bits_all;
                        }
                    }
                };
                algo.produce_all(&ctx, &grad, &mut g, &mut payload, &sink, exec);
                phases.produce += clock::secs_since(t_produce);
            }
            // Both schedulers funnel into one structural counter — the
            // legacy gradient/send/compress buckets above are one produce
            // phase's worth of work.
            phases.produce_n += 1;
            if let Some(r) = &recorder {
                r.span(EventKind::PhaseProduce, t_produce, n as u64);
            }
            // §Fault injection: draw this round's fault events. Crashed
            // agents produced as usual (stream alignment) but transmit
            // nothing — their wire bits are zeroed before accounting.
            if let Some(fs) = &mut faults {
                fs.begin_round(round);
                for i in 0..n {
                    if fs.is_down(i) {
                        round_bits[i] = 0;
                    }
                }
                // §Observability: crash-mask edges become fault_down /
                // fault_up instants (coordinator lane, arg = agent).
                if let Some(r) = &recorder {
                    for (a, pd) in prev_down.iter_mut().enumerate() {
                        let down = fs.is_down(a);
                        if down != *pd {
                            let kind =
                                if down { EventKind::FaultDown } else { EventKind::FaultUp };
                            r.instant(kind, a as u64);
                            *pd = down;
                        }
                    }
                }
            }
            traffic.record_bits(&self.mix, &round_bits);
            let sim_before = traffic.sim_time;
            traffic.sim_time += match &mut timer {
                Some(t) => match &faults {
                    // A preliminarily-lost transfer is charged on the
                    // wire but never queued: no arrival, no retransmit.
                    Some(fs) => {
                        let lost =
                            |src: usize, dst: usize| fs.link(dst, src) == LinkState::Lost;
                        t.round_faulted(&round_bits, Some(&lost))
                    }
                    None => t.round(&round_bits),
                },
                None => TrafficStats::uniform_round_time(&self.cfg.link, &round_bits),
            };
            traffic.rounds += 1;
            // §Observability: advance the virtual timeline and emit the
            // simnet round marker plus per-agent arrival instants (each
            // stamped with its own virtual time — the dual timeline).
            if let Some(r) = &recorder {
                r.set_vt(traffic.sim_time);
                r.instant(
                    EventKind::NetRound,
                    ((traffic.sim_time - sim_before) * 1e6) as u64,
                );
                if let Some(tm) = &timer {
                    for (a, &arr) in tm.arrivals().iter().enumerate() {
                        r.instant_vt(
                            EventKind::NetArrival,
                            ((sim_before + arr) * 1e6) as u64,
                            a as u64,
                        );
                    }
                }
            }
            if let Some(fs) = &mut faults {
                // Under a fault plan a transfer that hit the simnet
                // retransmit cap is a real loss, not a fiction of
                // delivery.
                if let Some(t) = &timer {
                    for &(src, dst) in t.capped_this_round() {
                        fs.force_lose(dst as usize, src as usize);
                    }
                }
                fs.resolve_round();
            }
            let stop_now = self.cfg.time_budget.is_some_and(|tb| traffic.sim_time >= tb);

            // (2) mix (parallel over agents; sparse-aware on channel 0).
            let mix_apply_exec =
                exec.with_threads(phase_threads(exec.threads(), n, spec.channels * d));
            let t = clock::now();
            {
                let mix = &self.mix;
                let payload_ref = &payload;
                let msgs_ref = &msgs;
                let fs_ref = faults.as_ref();
                match &mut transport {
                    // §Transport: the round's frames leave sequentially on
                    // the coordinator thread (the drop path consults the
                    // just-resolved fault schedule), then receive slots
                    // drain/decode/mix in parallel. Bitwise-equal to the
                    // shared-memory arm below (rust/tests/transport.rs).
                    Some(tr) => {
                        tr.send_round(
                            round,
                            mix,
                            fs_ref,
                            msgs_ref,
                            payload_ref,
                            &round_bits,
                            recorder.as_ref(),
                        );
                        tr.recv_and_mix(
                            mix_apply_exec,
                            round,
                            mix,
                            fs_ref,
                            msgs_ref,
                            payload_ref,
                            &mut mixed_all,
                        );
                    }
                    None => par_chunks(mix_apply_exec, &mut mixed_all, |i, out| match fs_ref {
                        Some(fs) => {
                            mix_degraded(mix, i, fs, use_comp, msgs_ref, payload_ref, out)
                        }
                        None => {
                            for (c, mx) in out.iter_mut().enumerate() {
                                mx.fill(0.0);
                                if c == 0 && use_comp {
                                    mix_msgs(mix, i, msgs_ref, mx);
                                } else {
                                    for j in
                                        std::iter::once(i).chain(mix.neighbors[i].iter().copied())
                                    {
                                        crate::linalg::axpy(
                                            mix.weight(i, j),
                                            &payload_ref[j][c],
                                            mx,
                                        );
                                    }
                                }
                            }
                        }
                    }),
                }
            }
            // Record delivered decodes for future stale replay (no-op
            // unless the plan enables it).
            if let Some(fs) = &mut faults {
                fs.store_delivered(|j, c, buf| {
                    if c == 0 && use_comp {
                        match &msgs[j].sparse {
                            Some(entries) => {
                                buf.fill(0.0);
                                for &(idx, v) in entries.iter() {
                                    buf[idx as usize] = v;
                                }
                            }
                            None => buf.copy_from_slice(&msgs[j].values),
                        }
                    } else {
                        buf.copy_from_slice(&payload[j][c]);
                    }
                });
            }
            phases.mix += clock::secs_since(t);
            phases.mix_n += 1;
            if let Some(r) = &recorder {
                r.span(EventKind::PhaseMix, t, n as u64);
            }

            // (3) apply (parallel inside recv_all; per-agent state rows
            // are disjoint). The inbox is a zero-copy view over the round
            // buffers; own decoded channel-0 payloads are borrowed — no
            // copies on the hot path (§Perf).
            let t = clock::now();
            let inbox = if use_comp {
                Inbox::with_decoded0(&payload, &mixed_all, &msgs)
            } else {
                Inbox::from_payloads(&payload, &mixed_all)
            };
            // §Fault injection: crashed agents' apply is skipped
            // wholesale — their state (including difference-compression
            // reference points) is frozen until recovery.
            let inbox = match &faults {
                Some(fs) => inbox.with_faults(fs.down_mask()),
                None => inbox,
            };
            algo.recv_all(&ctx, &g, &inbox, mix_apply_exec);
            drop(inbox);
            phases.apply += clock::secs_since(t);
            phases.apply_n += 1;
            if let Some(r) = &recorder {
                r.span(EventKind::PhaseApply, t, n as u64);
            }

            if round % self.cfg.record_every == 0 || round == rounds || stop_now {
                let t = clock::now();
                // The recorded compression error is the error of the
                // *observed* round — never a stale accumulation across
                // unobserved rounds (regression:
                // `comp_err_is_per_observed_round`). The persistent
                // scheduler computes it lazily here (§Perf: skips the
                // O(n·d) pass on unobserved rounds).
                let comp_err = if legacy {
                    comp_err_legacy
                } else if use_comp {
                    let mut acc = 0.0f64;
                    for i in 0..n {
                        msgs[i].ensure_dense();
                        acc += crate::linalg::dist_sq(&payload[i][0], &msgs[i].values).sqrt();
                    }
                    acc / n as f64
                } else {
                    0.0
                };
                let idle_max = timer.as_ref().map_or(0.0, |tm| tm.stats.max_idle());
                let ft = faults.as_ref().map_or(FaultTotals::default(), |f| f.totals());
                series.push(self.observe(&*algo, round, comp_err, &traffic, idle_max, ft));
                phases.observe += clock::secs_since(t);
                phases.observe_n += 1;
                if let Some(r) = &recorder {
                    r.span(EventKind::PhaseObserve, t, round as u64);
                }
            }
            if stop_now {
                stopped_early = round < rounds;
                break;
            }
        }

        let net = timer.as_ref().map(|t| {
            NetSummary::from_stats(&self.cfg.net.expect("timer implies model"), &t.stats, t.n_links())
        });
        let fault_sum = faults.as_ref().map(|f| f.summary());
        let transport_sum = transport.as_ref().map(|t| t.summary());
        // §Observability: dense-decode rebuilds over this run. The
        // counter is crate-global (debug builds only; 0 in release), so
        // concurrent runs in one process inflate each other's delta —
        // fine for the observability rollup, which is not a trajectory
        // artifact.
        #[cfg(debug_assertions)]
        let dense_decodes = crate::compress::CompressedMsg::dense_decode_count()
            .saturating_sub(dense_decodes_at_start);
        #[cfg(not(debug_assertions))]
        let dense_decodes = 0u64;
        let trace = recorder.as_ref().map(|r| {
            let ts = transport_sum.as_ref();
            let fs = fault_sum.as_ref();
            let ns = net.as_ref();
            r.summary(&[
                ("frames_sent", ts.map_or(0, |t| t.frames_sent)),
                ("frames_dropped", ts.map_or(0, |t| t.frames_dropped)),
                ("bytes_on_wire", ts.map_or(0, |t| t.bytes_on_wire)),
                ("crashed_agent_rounds", fs.map_or(0, |f| f.crashed_agent_rounds)),
                ("lost_messages", fs.map_or(0, |f| f.lost)),
                ("stale_deliveries", fs.map_or(0, |f| f.stale)),
                ("capped_losses", fs.map_or(0, |f| f.capped_losses)),
                ("retransmits", ns.map_or(0, |s| s.retransmits)),
                ("capped_transfers", ns.map_or(0, |s| s.capped)),
                ("dense_decodes", dense_decodes),
            ])
        });
        self.last_trace = recorder;
        RunRecord {
            algo: algo.name(),
            problem: self.problem.name(),
            compressor: match (&compressor, use_comp) {
                (Some(c), true) => c.name(),
                _ => "none".into(),
            },
            series,
            wall_secs: clock::secs_since(wall_start),
            phases,
            net,
            faults: fault_sum,
            transport: transport_sum,
            trace,
            stopped_early,
        }
    }

    fn observe(
        &self,
        algo: &dyn Algorithm,
        round: usize,
        comp_err: f64,
        traffic: &TrafficStats,
        idle_max: f64,
        faults: FaultTotals,
    ) -> RoundMetrics {
        let n = self.mix.n;
        let d = self.problem.dim();
        let mut xbar = vec![0.0f64; d];
        crate::linalg::mean_rows((0..n).map(|i| algo.x(i)), &mut xbar);
        let consensus = ((0..n)
            .map(|i| crate::linalg::dist_sq(algo.x(i), &xbar))
            .sum::<f64>()
            / n as f64)
            .sqrt();
        let dist_opt = match self.problem.optimum() {
            Some(opt) => ((0..n)
                .map(|i| crate::linalg::dist_sq(algo.x(i), opt))
                .sum::<f64>()
                / n as f64)
                .sqrt(),
            None => f64::NAN,
        };
        RoundMetrics {
            round,
            dist_opt,
            consensus,
            loss: self.problem.global_loss(&xbar),
            comp_err,
            bits_per_agent: traffic.mean_bits_per_agent(),
            sim_time: traffic.sim_time,
            idle_max,
            crashed: faults.crashed_agent_rounds,
            lost: faults.lost_messages,
            stale: faults.stale_deliveries,
            renormed: faults.renormalized_rows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::lead::{Lead, LeadParams};
    use crate::algorithms::nids::Nids;
    use crate::compress::identity::Identity;
    use crate::compress::quantize::QuantizeP;
    use crate::compress::topk::TopK;
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    fn ring_engine(threads: usize) -> Engine {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        Engine::new(
            EngineConfig { threads, record_every: 5, ..Default::default() },
            mix,
            std::sync::Arc::new(p),
        )
    }

    #[test]
    fn lead_linear_convergence_with_2bit_quantization() {
        // The headline claim: linear convergence *with* compression.
        let mut e = ring_engine(1);
        let rec = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 512))),
            600,
        );
        assert!(
            rec.last().dist_opt < 1e-6,
            "LEAD+2bit did not converge: {}",
            rec.last().dist_opt
        );
        // And it converged *linearly*: fitted ρ̂ must be < 1 decisively.
        let rho = rec.empirical_rho(1e-9).unwrap();
        assert!(rho < 0.97, "no linear decay, ρ̂ = {rho}");
        // Compression error vanishes (Fig. 1d).
        assert!(rec.last().comp_err < 1e-6, "comp err {}", rec.last().comp_err);
    }

    #[test]
    fn lead_identity_equals_nids() {
        // Proposition 1 / Corollary 3, verified on full trajectories.
        let mut e1 = ring_engine(1);
        let rec_lead = e1.run(
            Box::new(Lead::new(LeadParams { gamma: 1.0, alpha: 0.5 })),
            Some(Box::new(Identity)),
            120,
        );
        let mut e2 = ring_engine(1);
        let rec_nids = e2.run(Box::new(Nids::new()), None, 120);
        for (a, b) in rec_lead.series.iter().zip(&rec_nids.series) {
            assert!(
                (a.dist_opt - b.dist_opt).abs() <= 1e-9 * (1.0 + a.dist_opt),
                "round {}: LEAD {} vs NIDS {}",
                a.round,
                a.dist_opt,
                b.dist_opt
            );
        }
    }

    #[test]
    fn parallel_equals_sequential() {
        // 4 pool workers must reproduce the single-thread trajectory
        // bit-for-bit (dense quantizer messages). At this problem size the
        // fused produce phase fans out; mix/apply run inline via
        // phase_threads — their parallel paths are pinned by
        // par_chunks_mix_equals_inline and by
        // algorithms::tests::all_algorithms_recv_all_parallel_equals_sequential.
        let run = |threads: usize| {
            let mut e = ring_engine(threads);
            e.run(
                Box::new(Lead::paper_default()),
                Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 64))),
                80,
            )
        };
        let a = run(1);
        let b = run(4);
        for (ma, mb) in a.series.iter().zip(&b.series) {
            assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.bits_per_agent, mb.bits_per_agent);
        }
    }

    #[test]
    fn parallel_equals_sequential_sparse_topk() {
        // Same guarantee with sparse top-k messages in flight, including
        // a thread count that does not divide n.
        let run = |threads: usize| {
            let mut e = ring_engine(threads);
            e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 60)
        };
        let a = run(1);
        let b = run(3);
        let c = run(8);
        for ((ma, mb), mc) in a.series.iter().zip(&b.series).zip(&c.series) {
            assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.dist_opt.to_bits(), mc.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.bits_per_agent, mb.bits_per_agent);
        }
    }

    /// The persistent pool scheduler must reproduce the legacy
    /// spawn-per-phase loop bit-for-bit — metrics included — on the dense
    /// (quantize) and both sparse (top-k, rand-k; rand-k also exercises
    /// RNG-stream parity of its `compress_into` fast path) message paths.
    /// This is the old-vs-new scheduler A/B pinned as a correctness
    /// property. The sparse codecs drive the persistent scheduler through
    /// the sparse-own apply path (`Inbox::own_view` sparse arm) while the
    /// legacy loop decodes eagerly, so the A/B also pins sparse-own apply
    /// against the dense decode; codec 3 (`EagerDense`-wrapped top-k)
    /// covers the persistent scheduler's *materialized-dense* own path
    /// against the same legacy reference.
    #[test]
    fn scheduler_modes_bitwise_identical() {
        let run = |scheduler: Scheduler, codec: usize, threads: usize| {
            let p = LinReg::synthetic(8, 30, 0.1, 3);
            let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
            let mut e = Engine::new(
                EngineConfig { threads, record_every: 7, scheduler, ..Default::default() },
                mix,
                std::sync::Arc::new(p),
            );
            let comp: Box<dyn crate::compress::Compressor> = match codec {
                0 => Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 64)),
                1 => Box::new(TopK::new(10)),
                2 => Box::new(crate::compress::randk::RandK::new(10, true)),
                _ => Box::new(crate::compress::EagerDense(TopK::new(10))),
            };
            e.run(Box::new(Lead::paper_default()), Some(comp), 50)
        };
        for codec in 0..4 {
            for threads in [1usize, 3] {
                let old = run(Scheduler::SpawnPerPhase, codec, threads);
                let new = run(Scheduler::Persistent, codec, threads);
                assert_eq!(old.series.len(), new.series.len());
                for (a, b) in old.series.iter().zip(&new.series) {
                    assert_eq!(
                        a.dist_opt.to_bits(),
                        b.dist_opt.to_bits(),
                        "codec {codec} round {}",
                        a.round
                    );
                    assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
                    assert_eq!(a.comp_err.to_bits(), b.comp_err.to_bits(), "round {}", a.round);
                    assert_eq!(a.bits_per_agent, b.bits_per_agent);
                }
            }
        }
    }

    /// Engines are reusable: one engine (and, through `run_on`, one
    /// shared pool) serving several sequential runs must leak no state
    /// between them — the second run is bitwise-identical to the first
    /// and to a fresh-engine run.
    #[test]
    fn engine_reuse_leaks_no_state() {
        let make = || ring_engine(1);
        let run = |e: &mut Engine, exec: Exec<'_>| {
            e.run_on(
                exec,
                Box::new(Lead::paper_default()),
                Some(Box::new(TopK::new(10))),
                40,
            )
        };
        let mut fresh = make();
        let reference = run(&mut fresh, Exec::seq());

        let mut reused = make();
        let pool = WorkerPool::new(3);
        let first = run(&mut reused, Exec::pool(&pool));
        let second = run(&mut reused, Exec::pool(&pool));
        for rec in [&first, &second] {
            assert_eq!(rec.series.len(), reference.series.len());
            for (a, b) in reference.series.iter().zip(&rec.series) {
                assert_eq!(a.dist_opt.to_bits(), b.dist_opt.to_bits(), "round {}", a.round);
                assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
                assert_eq!(a.comp_err.to_bits(), b.comp_err.to_bits());
                assert_eq!(a.bits_per_agent, b.bits_per_agent);
            }
        }
    }

    /// Regression (comp_err bugfix): the recorded compression error must
    /// be the error of the observed round itself — a run that skips
    /// observations must report exactly what a record-every-round run
    /// reports at the same rounds, including the final partial round
    /// (rounds % record_every != 0).
    #[test]
    fn comp_err_is_per_observed_round() {
        let run = |record_every: usize| {
            let p = LinReg::synthetic(8, 30, 0.1, 3);
            let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
            let mut e = Engine::new(
                EngineConfig { record_every, ..Default::default() },
                mix,
                std::sync::Arc::new(p),
            );
            e.run(
                Box::new(Lead::paper_default()),
                Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 64))),
                10,
            )
        };
        let every = run(1);
        let sparse_obs = run(4); // observes rounds 4, 8 and the partial 10
        for m in &sparse_obs.series {
            let reference = every
                .series
                .iter()
                .find(|r| r.round == m.round)
                .expect("observed round missing from the every-round run");
            assert_eq!(
                m.comp_err.to_bits(),
                reference.comp_err.to_bits(),
                "round {}: comp_err {} != per-round reference {}",
                m.round,
                m.comp_err,
                reference.comp_err
            );
            assert!(m.comp_err > 0.0, "round {}: quantization error cannot be zero", m.round);
        }
        assert_eq!(sparse_obs.series.last().unwrap().round, 10);
    }

    /// The chunked fan-out itself: mixing through par_chunks at several
    /// thread counts and on both backends must be bitwise-equal to the
    /// inline loop (the engine tests above run small problems, which
    /// phase_threads keeps inline — this pins the parallel path directly).
    #[test]
    fn par_chunks_mix_equals_inline() {
        let n = 8;
        let d = 257; // not a multiple of any chunk size
        let mix = Topology::Ring.build(n, MixingRule::MetropolisHastings);
        let topk = TopK::new(19);
        let mut rng = crate::rng::Rng::new(77);
        let msgs: Vec<CompressedMsg> = (0..n)
            .map(|_| {
                let mut x = vec![0.0f64; d];
                rng.fill_normal(&mut x, 1.0);
                topk.compress_alloc(&x, &mut rng)
            })
            .collect();
        let mut inline = vec![vec![0.0f64; d]; n];
        for (i, out) in inline.iter_mut().enumerate() {
            mix_msgs(&mix, i, &msgs, out);
        }
        for threads in [2usize, 3, 8] {
            let pool = WorkerPool::new(threads);
            for exec in [Exec::pool(&pool), Exec::spawn(threads)] {
                let mut par = vec![vec![0.0f64; d]; n];
                par_chunks(exec, &mut par, |i, out| mix_msgs(&mix, i, &msgs, out));
                for (a, b) in inline.iter().zip(&par) {
                    for (u, v) in a.iter().zip(b) {
                        assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
                    }
                }
            }
        }
    }

    /// §Transport smoke: a lossless channel run reproduces the
    /// shared-memory trajectory bit-for-bit and reports a frame-count
    /// summary. The full algorithm × codec × topology × thread ×
    /// multiplex matrix lives in `rust/tests/transport.rs`.
    #[test]
    fn channel_transport_bitwise_equals_mem() {
        let run = |transport: TransportMode| {
            let p = LinReg::synthetic(8, 30, 0.1, 3);
            let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
            let mut e = Engine::new(
                EngineConfig { record_every: 5, transport, ..Default::default() },
                mix,
                std::sync::Arc::new(p),
            );
            e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 40)
        };
        let mem = run(TransportMode::Mem);
        let chan = run(TransportMode::Channel);
        assert!(mem.transport.is_none());
        let ts = chan.transport.as_ref().expect("channel run carries a summary");
        assert_eq!(ts.mode, "channel");
        // ring of 8: 16 directed edges, one frame each, 40 rounds.
        assert_eq!(ts.frames_sent, 16 * 40);
        assert_eq!(ts.frames_dropped, 0);
        assert_eq!(mem.series.len(), chan.series.len());
        for (a, b) in mem.series.iter().zip(&chan.series) {
            assert_eq!(a.dist_opt.to_bits(), b.dist_opt.to_bits(), "round {}", a.round);
            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
            assert_eq!(a.comp_err.to_bits(), b.comp_err.to_bits());
            assert_eq!(a.bits_per_agent, b.bits_per_agent);
        }
    }

    #[test]
    fn phase_threads_gates_small_work() {
        assert_eq!(phase_threads(8, 8, 200), 1, "fig1 shape stays inline");
        assert_eq!(phase_threads(8, 32, 100_000), 8, "bench shape fans out");
        assert_eq!(phase_threads(8, 2, 100_000), 2, "clamped to n");
    }

    #[test]
    fn sparse_and_dense_messages_same_trajectory() {
        // Forcing the dense fallback (sparse = None) must not change the
        // run at all: the sparse view is a pure representation change.
        use crate::compress::StripSparse;
        let mut e1 = ring_engine(1);
        let rec_sparse = e1.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 60);
        let mut e2 = ring_engine(1);
        let rec_dense = e2.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(StripSparse(TopK::new(10)))),
            60,
        );
        for (a, b) in rec_sparse.series.iter().zip(&rec_dense.series) {
            assert_eq!(a.dist_opt.to_bits(), b.dist_opt.to_bits(), "round {}", a.round);
            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
        }
    }

    #[test]
    fn bits_accounting_compressed_vs_raw() {
        let mut e = ring_engine(1);
        let rec_q = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 512))),
            50,
        );
        let mut e2 = ring_engine(1);
        let rec_raw = e2.run(Box::new(Nids::new()), None, 50);
        // d = 30, one block: wire = 32 + 30·(2+1) = 122 bits vs 960 raw.
        let ratio = rec_raw.last().bits_per_agent / rec_q.last().bits_per_agent;
        let expect = 960.0 / 122.0;
        assert!(
            (ratio - expect).abs() < 1e-6,
            "compression ratio {ratio}, expected {expect}"
        );
    }

    #[test]
    fn diminishing_schedule_converges_with_minibatch() {
        // Theorem 2 regime: stochastic gradients + O(1/k) stepsizes.
        let p = crate::problems::logreg::LogReg::synthetic(
            4, 160, 10, 4, 1e-2, crate::problems::DataSplit::Heterogeneous, 5, true,
        );
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig {
                eta: 0.5,
                schedule: Schedule::Diminishing { t0: 200.0 },
                batch_size: Some(8),
                record_every: 50,
                ..Default::default()
            },
            mix,
            std::sync::Arc::new(p),
        );
        let rec = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(4, crate::compress::quantize::PNorm::Inf, 512))),
            2000,
        );
        let first = rec.series.first().unwrap().dist_opt;
        let last = rec.last().dist_opt;
        assert!(last < 0.2 * first, "no progress: {first} -> {last}");
    }

    /// §Observability regression: the deterministic phase counters. A
    /// full run executes produce/mix/apply exactly `rounds` times and
    /// observes exactly `series.len()` times (round 0 included — the
    /// pre-loop baseline observation is stamped too).
    #[test]
    fn phase_counts_full_run() {
        let mut e = ring_engine(1);
        let rec = e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 40);
        assert!(!rec.stopped_early);
        assert_eq!(rec.phases.produce_n, 40);
        assert_eq!(rec.phases.mix_n, 40);
        assert_eq!(rec.phases.apply_n, 40);
        // record_every = 5: baseline round 0 plus rounds 5..=40.
        assert_eq!(rec.series.len(), 9);
        assert_eq!(rec.phases.observe_n, rec.series.len() as u64);
    }

    /// §Observability regression: a `time_budget` run counts the
    /// budget-crossing round's phases exactly once — the crossing round
    /// still mixes, applies, and is observed before the loop breaks, so
    /// every counter equals the executed round count (not `rounds`, not
    /// one more).
    #[test]
    fn phase_counts_time_budget_run() {
        let run = |time_budget: Option<f64>| {
            let p = LinReg::synthetic(8, 30, 0.1, 3);
            let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
            let mut e = Engine::new(
                EngineConfig { record_every: 7, time_budget, ..Default::default() },
                mix,
                std::sync::Arc::new(p),
            );
            e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 40)
        };
        // The legacy uniform link formula makes sim_time a deterministic
        // staircase of equal steps; a budget of 19.5 steps stops the run
        // on round 20, the first whose cumulative time crosses it (half a
        // step of slack absorbs accumulation ulps).
        let full = run(None);
        let tb = full
            .series
            .iter()
            .find(|m| m.round == 21)
            .map(|m| m.sim_time * (19.5 / 21.0))
            .expect("round 21 observed");
        let budget = run(Some(tb));
        assert!(budget.stopped_early);
        let crossing = budget.series.last().unwrap().round as u64;
        assert_eq!(crossing, 20, "budget must bite on round 20");
        assert_eq!(budget.phases.produce_n, crossing);
        assert_eq!(budget.phases.mix_n, crossing);
        assert_eq!(budget.phases.apply_n, crossing);
        assert_eq!(budget.phases.observe_n, budget.series.len() as u64);
        // The crossing round is observed exactly once, even off the
        // record_every lattice (20 % 7 != 0): baseline 0, rounds 7, 14,
        // then the crossing round 20.
        assert_eq!(
            budget.series.iter().map(|m| m.round).collect::<Vec<_>>(),
            vec![0, 7, 14, 20]
        );
    }

    /// §Observability smoke: a traced run carries a summary with live
    /// counters, the capture is claimable exactly once, and tracing does
    /// not perturb the trajectory (the full matrix differential lives in
    /// `rust/tests/trace.rs`).
    #[test]
    fn traced_run_summary_and_capture() {
        let run = |trace: bool| {
            let p = LinReg::synthetic(8, 30, 0.1, 3);
            let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
            let mut e = Engine::new(
                EngineConfig { record_every: 5, trace, ..Default::default() },
                mix,
                std::sync::Arc::new(p),
            );
            let rec = e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 30);
            (rec, e.take_trace())
        };
        let (plain, no_cap) = run(false);
        assert!(plain.trace.is_none());
        assert!(no_cap.is_none(), "untraced run yields no capture");
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig { record_every: 5, trace: true, ..Default::default() },
            mix,
            std::sync::Arc::new(p),
        );
        let traced = e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 30);
        let sum = traced.trace.as_ref().expect("traced run carries a summary");
        assert!(sum.counter("events") > 0);
        assert_eq!(sum.counter("pool_dispatches"), 0, "inline run never dispatches");
        for (a, b) in plain.series.iter().zip(&traced.series) {
            assert_eq!(a.dist_opt.to_bits(), b.dist_opt.to_bits(), "round {}", a.round);
            assert_eq!(a.comp_err.to_bits(), b.comp_err.to_bits());
        }
        let cap = e.take_trace().expect("capture claimable after a traced run");
        assert!(cap.total_events() > 0);
        assert!(e.take_trace().is_none(), "capture is take-once");
        // And the capture round-trips through the Chrome exporter.
        let js = crate::trace::chrome_json(&cap, "smoke");
        crate::trace::validate_chrome_json(&js).unwrap();
    }
}
