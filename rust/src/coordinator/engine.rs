//! The coordinator engine: drives algorithms over a simulated gossip
//! network with exact wire-bit accounting.
//!
//! One engine instance owns the problem, the topology, and the round loop.
//! Per round it (1) evaluates per-agent gradients — in parallel across a
//! worker pool when `threads > 1`, mirroring the leader/worker split of a
//! real deployment — (2) collects per-agent broadcasts, (3) compresses
//! channel 0 when the algorithm opts in, (4) forms the W-weighted mixes,
//! and (5) applies the local updates. Determinism is scheduling-independent
//! because every stochastic choice draws from a per-(agent, purpose) RNG
//! stream; the `parallel_equals_sequential` test asserts bitwise equality.

use super::metrics::{RoundMetrics, RunRecord};
use super::network::{LinkModel, TrafficStats};
use crate::algorithms::{Algorithm, Ctx};
use crate::compress::{CompressedMsg, Compressor};
use crate::problems::Problem;
use crate::rng::{streams, Rng};
use crate::topology::MixingMatrix;

/// Stepsize schedule (Theorem 1 uses constant; Theorem 2 diminishing).
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant,
    /// η_k = η · t0 / (t0 + k) — the O(1/k) decay of Theorem 2.
    Diminishing { t0: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Base stepsize η.
    pub eta: f64,
    pub schedule: Schedule,
    /// Mini-batch size per agent; None ⇒ full gradient.
    pub batch_size: Option<usize>,
    pub seed: u64,
    /// Record metrics every k rounds (metrics cost a full loss pass).
    pub record_every: usize,
    /// Worker threads for gradient evaluation + compression (1 = inline).
    pub threads: usize,
    pub link: LinkModel,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            eta: 0.1,
            schedule: Schedule::Constant,
            batch_size: None,
            seed: 42,
            record_every: 10,
            threads: 1,
            link: LinkModel::default(),
        }
    }
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub mix: MixingMatrix,
    pub problem: Box<dyn Problem>,
}

impl Engine {
    pub fn new(cfg: EngineConfig, mix: MixingMatrix, problem: Box<dyn Problem>) -> Self {
        assert_eq!(mix.n, problem.n_agents(), "topology/problem agent mismatch");
        Engine { cfg, mix, problem }
    }

    fn eta_at(&self, round: usize) -> f64 {
        match self.cfg.schedule {
            Schedule::Constant => self.cfg.eta,
            Schedule::Diminishing { t0 } => self.cfg.eta * t0 / (t0 + round as f64),
        }
    }

    /// Evaluate all agents' gradients at their current iterates into `g`.
    fn gradients(
        &self,
        algo: &dyn Algorithm,
        g: &mut [Vec<f64>],
        batch_rngs: &mut [Rng],
    ) {
        let n = self.mix.n;
        let problem = &*self.problem;
        let batch = self.cfg.batch_size;
        // Draw batch indices first (RNG must advance deterministically in
        // agent order regardless of thread scheduling).
        let batches: Vec<Option<Vec<usize>>> = (0..n)
            .map(|i| {
                batch.map(|b| {
                    let ns = problem.n_samples(i);
                    let b = b.min(ns.max(1));
                    if ns == 0 {
                        vec![]
                    } else {
                        (0..b).map(|_| batch_rngs[i].below(ns)).collect()
                    }
                })
            })
            .collect();
        let threads = self.cfg.threads.max(1).min(n);
        if threads == 1 {
            for i in 0..n {
                match &batches[i] {
                    Some(idx) => problem.grad_batch(i, algo.x(i), idx, &mut g[i]),
                    None => problem.grad_full(i, algo.x(i), &mut g[i]),
                }
            }
        } else {
            // Leader/worker split: chunk agents across a scoped pool.
            let chunk = n.div_ceil(threads);
            let algo_ref: &dyn Algorithm = algo;
            std::thread::scope(|s| {
                for (t, gs) in g.chunks_mut(chunk).enumerate() {
                    let base = t * chunk;
                    let batches = &batches;
                    s.spawn(move || {
                        for (off, gi) in gs.iter_mut().enumerate() {
                            let i = base + off;
                            match &batches[i] {
                                Some(idx) => problem.grad_batch(i, algo_ref.x(i), idx, gi),
                                None => problem.grad_full(i, algo_ref.x(i), gi),
                            }
                        }
                    });
                }
            });
        }
    }

    /// Run `algo` for `rounds` rounds. `compressor` applies to channel 0
    /// when the algorithm's spec opts in; other channels (and opted-out
    /// algorithms) are billed the raw 32 bits/element.
    pub fn run(
        &mut self,
        mut algo: Box<dyn Algorithm>,
        compressor: Option<Box<dyn Compressor>>,
        rounds: usize,
    ) -> RunRecord {
        let wall_start = std::time::Instant::now();
        let n = self.mix.n;
        let d = self.problem.dim();
        let spec = algo.spec();
        let use_comp = spec.compressed && compressor.is_some();
        let root = Rng::new(self.cfg.seed);
        let mut dither_rngs: Vec<Rng> =
            (0..n).map(|i| root.derive(i as u64).derive(streams::DITHER)).collect();
        let mut batch_rngs: Vec<Rng> =
            (0..n).map(|i| root.derive(i as u64).derive(streams::BATCH)).collect();

        // x⁰ = problem-provided init (or zeros — the paper's setup for
        // convex problems), identical for every agent: consensus start.
        let x0_vec = self.problem.initial_point().unwrap_or_else(|| vec![0.0f64; d]);
        let x0 = vec![x0_vec; n];
        let mut g = vec![vec![0.0f64; d]; n];
        for i in 0..n {
            match self.cfg.batch_size {
                Some(b) => {
                    let ns = self.problem.n_samples(i);
                    let idx: Vec<usize> = if ns == 0 {
                        vec![]
                    } else {
                        (0..b.min(ns)).map(|_| batch_rngs[i].below(ns)).collect()
                    };
                    self.problem.grad_batch(i, &x0[i], &idx, &mut g[i]);
                }
                None => self.problem.grad_full(i, &x0[i], &mut g[i]),
            }
        }
        let ctx0 = Ctx { mix: &self.mix, round: 0, eta: self.eta_at(0) };
        algo.init(&ctx0, &x0, &g);

        let mut payload = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut msgs: Vec<CompressedMsg> = (0..n).map(|_| CompressedMsg::with_dim(d)).collect();
        let mut mixed = vec![vec![0.0f64; d]; spec.channels];
        let mut traffic = TrafficStats::new(n);
        let mut series = Vec::new();
        let mut round_bits = vec![0u64; n];

        // Record the initial state as round 0.
        series.push(self.observe(&*algo, 0, 0.0, &traffic));

        for round in 1..=rounds {
            let eta = self.eta_at(round);
            let ctx = Ctx { mix: &self.mix, round, eta };

            // (1) gradients (parallel across workers)
            self.gradients(&*algo, &mut g, &mut batch_rngs);

            // (2) local sends
            for i in 0..n {
                algo.send(&ctx, i, &g[i], &mut payload[i]);
            }

            // (3) compression of channel 0 (parallel; per-agent dither RNG)
            let mut comp_err_acc = 0.0f64;
            if use_comp {
                let comp = compressor.as_deref().unwrap();
                let threads = self.cfg.threads.max(1).min(n);
                if threads == 1 {
                    for i in 0..n {
                        comp.compress(&payload[i][0], &mut dither_rngs[i], &mut msgs[i]);
                    }
                } else {
                    let chunk = n.div_ceil(threads);
                    let payload_ref = &payload;
                    std::thread::scope(|s| {
                        for ((t, ms), rs) in
                            msgs.chunks_mut(chunk).enumerate().zip(dither_rngs.chunks_mut(chunk))
                        {
                            let base = t * chunk;
                            s.spawn(move || {
                                for (off, (m, r)) in ms.iter_mut().zip(rs.iter_mut()).enumerate() {
                                    comp.compress(&payload_ref[base + off][0], r, m);
                                }
                            });
                        }
                    });
                }
                for i in 0..n {
                    comp_err_acc += crate::linalg::dist_sq(&payload[i][0], &msgs[i].values).sqrt();
                    // Extra channels (none of the compressed algorithms use
                    // them today) would be billed raw.
                    round_bits[i] =
                        msgs[i].wire_bits + (spec.channels as u64 - 1) * (d as u64) * 32;
                }
            } else {
                for i in 0..n {
                    round_bits[i] = (spec.channels as u64) * (d as u64) * 32;
                }
            }
            traffic.record_round(&self.mix, &self.cfg.link, &round_bits);

            // (4)+(5) mix and apply per agent.
            for i in 0..n {
                for (c, mx) in mixed.iter_mut().enumerate() {
                    mx.fill(0.0);
                    for j in std::iter::once(i).chain(self.mix.neighbors[i].iter().copied()) {
                        let w = self.mix.weight(i, j);
                        let src: &[f64] =
                            if c == 0 && use_comp { &msgs[j].values } else { &payload[j][c] };
                        crate::linalg::axpy(w, src, mx);
                    }
                }
                // Own decoded channel-0 payload — borrowed, no copies on
                // the hot path (§Perf: saves n·d clones per round).
                let self_dec: Vec<&[f64]> = (0..spec.channels)
                    .map(|c| {
                        if c == 0 && use_comp {
                            msgs[i].values.as_slice()
                        } else {
                            payload[i][c].as_slice()
                        }
                    })
                    .collect();
                let mixed_refs: Vec<&[f64]> = mixed.iter().map(|v| v.as_slice()).collect();
                algo.recv(&ctx, i, &g[i], &self_dec, &mixed_refs);
            }

            if round % self.cfg.record_every == 0 || round == rounds {
                series.push(self.observe(&*algo, round, comp_err_acc / n as f64, &traffic));
            }
        }

        RunRecord {
            algo: algo.name(),
            problem: self.problem.name(),
            compressor: match (&compressor, use_comp) {
                (Some(c), true) => c.name(),
                _ => "none".into(),
            },
            series,
            wall_secs: wall_start.elapsed().as_secs_f64(),
        }
    }

    fn observe(
        &self,
        algo: &dyn Algorithm,
        round: usize,
        comp_err: f64,
        traffic: &TrafficStats,
    ) -> RoundMetrics {
        let n = self.mix.n;
        let d = self.problem.dim();
        let mut xbar = vec![0.0f64; d];
        for i in 0..n {
            crate::linalg::axpy(1.0 / n as f64, algo.x(i), &mut xbar);
        }
        let consensus = ((0..n)
            .map(|i| crate::linalg::dist_sq(algo.x(i), &xbar))
            .sum::<f64>()
            / n as f64)
            .sqrt();
        let dist_opt = match self.problem.optimum() {
            Some(opt) => ((0..n)
                .map(|i| crate::linalg::dist_sq(algo.x(i), opt))
                .sum::<f64>()
                / n as f64)
                .sqrt(),
            None => f64::NAN,
        };
        RoundMetrics {
            round,
            dist_opt,
            consensus,
            loss: self.problem.global_loss(&xbar),
            comp_err,
            bits_per_agent: traffic.mean_bits_per_agent(),
            sim_time: traffic.sim_time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::lead::{Lead, LeadParams};
    use crate::algorithms::nids::Nids;
    use crate::compress::identity::Identity;
    use crate::compress::quantize::QuantizeP;
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    fn ring_engine(threads: usize) -> Engine {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        Engine::new(
            EngineConfig { threads, record_every: 5, ..Default::default() },
            mix,
            Box::new(p),
        )
    }

    #[test]
    fn lead_linear_convergence_with_2bit_quantization() {
        // The headline claim: linear convergence *with* compression.
        let mut e = ring_engine(1);
        let rec = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 512))),
            600,
        );
        assert!(
            rec.last().dist_opt < 1e-6,
            "LEAD+2bit did not converge: {}",
            rec.last().dist_opt
        );
        // And it converged *linearly*: fitted ρ̂ must be < 1 decisively.
        let rho = rec.empirical_rho(1e-9).unwrap();
        assert!(rho < 0.97, "no linear decay, ρ̂ = {rho}");
        // Compression error vanishes (Fig. 1d).
        assert!(rec.last().comp_err < 1e-6, "comp err {}", rec.last().comp_err);
    }

    #[test]
    fn lead_identity_equals_nids() {
        // Proposition 1 / Corollary 3, verified on full trajectories.
        let mut e1 = ring_engine(1);
        let rec_lead = e1.run(
            Box::new(Lead::new(LeadParams { gamma: 1.0, alpha: 0.5 })),
            Some(Box::new(Identity)),
            120,
        );
        let mut e2 = ring_engine(1);
        let rec_nids = e2.run(Box::new(Nids::new()), None, 120);
        for (a, b) in rec_lead.series.iter().zip(&rec_nids.series) {
            assert!(
                (a.dist_opt - b.dist_opt).abs() <= 1e-9 * (1.0 + a.dist_opt),
                "round {}: LEAD {} vs NIDS {}",
                a.round,
                a.dist_opt,
                b.dist_opt
            );
        }
    }

    #[test]
    fn parallel_equals_sequential() {
        let run = |threads: usize| {
            let mut e = ring_engine(threads);
            e.run(
                Box::new(Lead::paper_default()),
                Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 64))),
                80,
            )
        };
        let a = run(1);
        let b = run(4);
        for (ma, mb) in a.series.iter().zip(&b.series) {
            assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.bits_per_agent, mb.bits_per_agent);
        }
    }

    #[test]
    fn bits_accounting_compressed_vs_raw() {
        let mut e = ring_engine(1);
        let rec_q = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 512))),
            50,
        );
        let mut e2 = ring_engine(1);
        let rec_raw = e2.run(Box::new(Nids::new()), None, 50);
        // d = 30, one block: wire = 32 + 30·(2+1) = 122 bits vs 960 raw.
        let ratio = rec_raw.last().bits_per_agent / rec_q.last().bits_per_agent;
        let expect = 960.0 / 122.0;
        assert!(
            (ratio - expect).abs() < 1e-6,
            "compression ratio {ratio}, expected {expect}"
        );
    }

    #[test]
    fn diminishing_schedule_converges_with_minibatch() {
        // Theorem 2 regime: stochastic gradients + O(1/k) stepsizes.
        let p = crate::problems::logreg::LogReg::synthetic(
            4, 160, 10, 4, 1e-2, crate::problems::DataSplit::Heterogeneous, 5, true,
        );
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig {
                eta: 0.5,
                schedule: Schedule::Diminishing { t0: 200.0 },
                batch_size: Some(8),
                record_every: 50,
                ..Default::default()
            },
            mix,
            Box::new(p),
        );
        let rec = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(4, crate::compress::quantize::PNorm::Inf, 512))),
            2000,
        );
        let first = rec.series.first().unwrap().dist_opt;
        let last = rec.last().dist_opt;
        assert!(last < 0.2 * first, "no progress: {first} -> {last}");
    }
}
