//! The coordinator engine: drives algorithms over a simulated gossip
//! network with exact wire-bit accounting.
//!
//! # Round phases and threading model
//!
//! One engine instance owns the problem, the topology, and the round loop.
//! Per round it runs five phases; three of them fan out over the same
//! scoped worker pool when `threads > 1`:
//!
//! 1. **gradients** — per-agent `∇f_i` at the current iterates
//!    *(parallel)*; mini-batch indices are drawn up front in agent order
//!    so the RNG stream is schedule-independent.
//! 2. **send** — per-agent payload assembly (sequential; cheap, and the
//!    only phase that may touch shared scratch inside an algorithm).
//! 3. **compress** — channel 0 through the configured codec, one dither
//!    RNG stream per agent *(parallel)*.
//! 4. **mix** — W-weighted neighborhood mixes *(parallel)*. Messages that
//!    publish a sparse view ([`CompressedMsg::sparse`]: top-k / rand-k)
//!    are accumulated by scatter-add in O(deg·k) instead of O(deg·d) —
//!    see [`mix_msgs`] for the bitwise-equality argument.
//! 5. **apply** — [`Algorithm::recv_all`] *(parallel)*: per-agent state is
//!    disjoint row-major rows, so agents update independently.
//!
//! Determinism is scheduling-independent because every stochastic choice
//! draws from a per-(agent, purpose) RNG stream and the parallel phases
//! touch disjoint per-agent data; the `parallel_equals_sequential` tests
//! assert bitwise equality for both dense (quantizer) and sparse (top-k)
//! messages.

use super::metrics::{RoundMetrics, RunRecord};
use super::network::{LinkModel, TrafficStats};
use crate::algorithms::{Algorithm, Ctx, Inbox};
use crate::compress::{CompressedMsg, Compressor};
use crate::problems::Problem;
use crate::rng::{streams, Rng};
use crate::topology::MixingMatrix;

/// Stepsize schedule (Theorem 1 uses constant; Theorem 2 diminishing).
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant,
    /// η_k = η · t0 / (t0 + k) — the O(1/k) decay of Theorem 2.
    Diminishing { t0: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Base stepsize η.
    pub eta: f64,
    pub schedule: Schedule,
    /// Mini-batch size per agent; None ⇒ full gradient.
    pub batch_size: Option<usize>,
    pub seed: u64,
    /// Record metrics every k rounds (metrics cost a full loss pass).
    pub record_every: usize,
    /// Worker threads for the gradient, compression, mix, and apply
    /// phases (1 = inline).
    pub threads: usize,
    pub link: LinkModel,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            eta: 0.1,
            schedule: Schedule::Constant,
            batch_size: None,
            seed: 42,
            record_every: 10,
            threads: 1,
            link: LinkModel::default(),
        }
    }
}

/// W-weighted mix of decoded channel-0 messages for agent `i`, written
/// into `out` (which must be zero-filled by the caller).
///
/// Messages carrying a sparse view are scatter-added in O(k); dense
/// messages fall back to `axpy` over `values`. The result is bitwise
/// identical to dense accumulation for every message: the sparse list
/// holds exactly the nonzeros of `values`, and adding the omitted ±0.0
/// terms cannot change an accumulator that starts at +0.0 (IEEE 754
/// round-to-nearest yields −0.0 only from `(−0.0) + (−0.0)`, which a
/// +0.0 start makes unreachable). The sparse-vs-dense proptest in
/// `rust/tests/proptests.rs` pins this down across codecs/topologies.
pub fn mix_msgs(mix: &MixingMatrix, i: usize, msgs: &[CompressedMsg], out: &mut [f64]) {
    for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
        let w = mix.weight(i, j);
        match &msgs[j].sparse {
            Some(entries) => crate::linalg::scatter_axpy(w, entries, out),
            None => crate::linalg::axpy(w, &msgs[j].values, out),
        }
    }
}

/// Worker threads actually worth using for a phase that streams
/// `work_per_agent` f64 elements per agent: `thread::scope` re-spawns OS
/// threads every round, which costs more than the loop itself on small
/// problems (fig1 shape: n·d ≈ 1600), so below the threshold the phase
/// runs inline. Thread count never affects trajectories (the
/// `parallel_equals_sequential` tests), so this is purely a perf knob.
fn phase_threads(threads: usize, n: usize, work_per_agent: usize) -> usize {
    const MIN_ELEMS: usize = 32_768;
    if n.saturating_mul(work_per_agent) < MIN_ELEMS {
        1
    } else {
        threads.max(1).min(n.max(1))
    }
}

/// Run `f(i, &mut items[i])` for every item — inline when `threads == 1`,
/// otherwise chunked across a scoped worker pool. The single scheduling
/// site for the engine's gradient, compression, and mix fan-outs (the
/// apply phase uses the row-splitting [`crate::algorithms::par_agents`]).
/// `f` must be independent per item for the schedule to be
/// trajectory-invariant.
fn par_chunks<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        for (i, it) in items.iter_mut().enumerate() {
            f(i, it);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, ch) in items.chunks_mut(chunk).enumerate() {
            let base = t * chunk;
            let f = &f;
            s.spawn(move || {
                for (off, it) in ch.iter_mut().enumerate() {
                    f(base + off, it);
                }
            });
        }
    });
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub mix: MixingMatrix,
    pub problem: Box<dyn Problem>,
}

impl Engine {
    pub fn new(cfg: EngineConfig, mix: MixingMatrix, problem: Box<dyn Problem>) -> Self {
        assert_eq!(mix.n, problem.n_agents(), "topology/problem agent mismatch");
        Engine { cfg, mix, problem }
    }

    fn eta_at(&self, round: usize) -> f64 {
        match self.cfg.schedule {
            Schedule::Constant => self.cfg.eta,
            Schedule::Diminishing { t0 } => self.cfg.eta * t0 / (t0 + round as f64),
        }
    }

    /// Draw this round's mini-batch indices for every agent, in agent
    /// order. The single sampling site for round 0 and the round loop, so
    /// both consume the per-agent BATCH streams identically (a duplicated
    /// round-0 draw used to clamp the batch size differently).
    fn draw_batches(&self, batch_rngs: &mut [Rng]) -> Vec<Option<Vec<usize>>> {
        let n = self.mix.n;
        let batch = self.cfg.batch_size;
        (0..n)
            .map(|i| {
                batch.map(|b| {
                    let ns = self.problem.n_samples(i);
                    if ns == 0 {
                        return vec![];
                    }
                    (0..b.min(ns)).map(|_| batch_rngs[i].below(ns)).collect()
                })
            })
            .collect()
    }

    /// Evaluate all agents' gradients at their current iterates into `g`.
    fn gradients(
        &self,
        algo: &dyn Algorithm,
        g: &mut [Vec<f64>],
        batch_rngs: &mut [Rng],
    ) {
        let problem = &*self.problem;
        // Draw batch indices first (RNG must advance deterministically in
        // agent order regardless of thread scheduling).
        let batches = self.draw_batches(batch_rngs);
        par_chunks(self.cfg.threads, g, |i, gi| match &batches[i] {
            Some(idx) => problem.grad_batch(i, algo.x(i), idx, gi),
            None => problem.grad_full(i, algo.x(i), gi),
        });
    }

    /// Run `algo` for `rounds` rounds. `compressor` applies to channel 0
    /// when the algorithm's spec opts in; other channels (and opted-out
    /// algorithms) are billed the raw 32 bits/element.
    pub fn run(
        &mut self,
        mut algo: Box<dyn Algorithm>,
        compressor: Option<Box<dyn Compressor>>,
        rounds: usize,
    ) -> RunRecord {
        let wall_start = std::time::Instant::now();
        let n = self.mix.n;
        let d = self.problem.dim();
        let spec = algo.spec();
        let use_comp = spec.compressed && compressor.is_some();
        let root = Rng::new(self.cfg.seed);
        let mut dither_rngs: Vec<Rng> =
            (0..n).map(|i| root.derive(i as u64).derive(streams::DITHER)).collect();
        let mut batch_rngs: Vec<Rng> =
            (0..n).map(|i| root.derive(i as u64).derive(streams::BATCH)).collect();

        // x⁰ = problem-provided init (or zeros — the paper's setup for
        // convex problems), identical for every agent: consensus start.
        let x0_vec = self.problem.initial_point().unwrap_or_else(|| vec![0.0f64; d]);
        let x0 = vec![x0_vec; n];
        let mut g = vec![vec![0.0f64; d]; n];
        // Round-0 gradients go through the same batch-drawing path as the
        // round loop (identical RNG stream and clamping).
        let batches0 = self.draw_batches(&mut batch_rngs);
        for i in 0..n {
            match &batches0[i] {
                Some(idx) => self.problem.grad_batch(i, &x0[i], idx, &mut g[i]),
                None => self.problem.grad_full(i, &x0[i], &mut g[i]),
            }
        }
        let ctx0 = Ctx { mix: &self.mix, round: 0, eta: self.eta_at(0) };
        algo.init(&ctx0, &x0, &g);

        let mut payload = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut msgs: Vec<CompressedMsg> = (0..n).map(|_| CompressedMsg::with_dim(d)).collect();
        // Per-agent mixes, materialized so the mix and apply phases can
        // both fan out over agents (n·channels·d, allocated once).
        let mut mixed_all = vec![vec![vec![0.0f64; d]; spec.channels]; n];
        let mut traffic = TrafficStats::new(n);
        let mut series = Vec::new();
        let mut round_bits = vec![0u64; n];

        // Record the initial state as round 0.
        series.push(self.observe(&*algo, 0, 0.0, &traffic));

        for round in 1..=rounds {
            let eta = self.eta_at(round);
            let ctx = Ctx { mix: &self.mix, round, eta };

            // (1) gradients (parallel across workers)
            self.gradients(&*algo, &mut g, &mut batch_rngs);

            // (2) local sends
            for i in 0..n {
                algo.send(&ctx, i, &g[i], &mut payload[i]);
            }

            // (3) compression of channel 0 (parallel; per-agent dither RNG)
            let mut comp_err_acc = 0.0f64;
            if use_comp {
                let comp = compressor.as_deref().unwrap();
                {
                    let payload_ref = &payload;
                    let mut pairs: Vec<(&mut CompressedMsg, &mut Rng)> =
                        msgs.iter_mut().zip(dither_rngs.iter_mut()).collect();
                    par_chunks(self.cfg.threads, &mut pairs, |i, (m, r)| {
                        comp.compress(&payload_ref[i][0], r, m);
                    });
                }
                for i in 0..n {
                    comp_err_acc += crate::linalg::dist_sq(&payload[i][0], &msgs[i].values).sqrt();
                    // Extra channels (none of the compressed algorithms use
                    // them today) would be billed raw.
                    round_bits[i] =
                        msgs[i].wire_bits + (spec.channels as u64 - 1) * (d as u64) * 32;
                }
            } else {
                for i in 0..n {
                    round_bits[i] = (spec.channels as u64) * (d as u64) * 32;
                }
            }
            traffic.record_round(&self.mix, &self.cfg.link, &round_bits);

            // (4) mix (parallel over agents; sparse-aware on channel 0).
            let mix_apply_threads = phase_threads(self.cfg.threads, n, spec.channels * d);
            {
                let mix = &self.mix;
                let payload_ref = &payload;
                let msgs_ref = &msgs;
                par_chunks(mix_apply_threads, &mut mixed_all, |i, out| {
                    for (c, mx) in out.iter_mut().enumerate() {
                        mx.fill(0.0);
                        if c == 0 && use_comp {
                            mix_msgs(mix, i, msgs_ref, mx);
                        } else {
                            for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                                crate::linalg::axpy(mix.weight(i, j), &payload_ref[j][c], mx);
                            }
                        }
                    }
                });
            }

            // (5) apply (parallel inside recv_all; per-agent state rows
            // are disjoint). Own decoded channel-0 payload is borrowed —
            // no copies on the hot path (§Perf: saves n·d clones/round).
            let inbox = Inbox {
                self_dec: (0..n)
                    .map(|i| {
                        (0..spec.channels)
                            .map(|c| {
                                if c == 0 && use_comp {
                                    msgs[i].values.as_slice()
                                } else {
                                    payload[i][c].as_slice()
                                }
                            })
                            .collect()
                    })
                    .collect(),
                mixed: mixed_all
                    .iter()
                    .map(|a| a.iter().map(|v| v.as_slice()).collect())
                    .collect(),
            };
            algo.recv_all(&ctx, &g, &inbox, mix_apply_threads);
            drop(inbox);

            if round % self.cfg.record_every == 0 || round == rounds {
                series.push(self.observe(&*algo, round, comp_err_acc / n as f64, &traffic));
            }
        }

        RunRecord {
            algo: algo.name(),
            problem: self.problem.name(),
            compressor: match (&compressor, use_comp) {
                (Some(c), true) => c.name(),
                _ => "none".into(),
            },
            series,
            wall_secs: wall_start.elapsed().as_secs_f64(),
        }
    }

    fn observe(
        &self,
        algo: &dyn Algorithm,
        round: usize,
        comp_err: f64,
        traffic: &TrafficStats,
    ) -> RoundMetrics {
        let n = self.mix.n;
        let d = self.problem.dim();
        let mut xbar = vec![0.0f64; d];
        for i in 0..n {
            crate::linalg::axpy(1.0 / n as f64, algo.x(i), &mut xbar);
        }
        let consensus = ((0..n)
            .map(|i| crate::linalg::dist_sq(algo.x(i), &xbar))
            .sum::<f64>()
            / n as f64)
            .sqrt();
        let dist_opt = match self.problem.optimum() {
            Some(opt) => ((0..n)
                .map(|i| crate::linalg::dist_sq(algo.x(i), opt))
                .sum::<f64>()
                / n as f64)
                .sqrt(),
            None => f64::NAN,
        };
        RoundMetrics {
            round,
            dist_opt,
            consensus,
            loss: self.problem.global_loss(&xbar),
            comp_err,
            bits_per_agent: traffic.mean_bits_per_agent(),
            sim_time: traffic.sim_time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::lead::{Lead, LeadParams};
    use crate::algorithms::nids::Nids;
    use crate::compress::identity::Identity;
    use crate::compress::quantize::QuantizeP;
    use crate::compress::topk::TopK;
    use crate::problems::linreg::LinReg;
    use crate::topology::{MixingRule, Topology};

    fn ring_engine(threads: usize) -> Engine {
        let p = LinReg::synthetic(8, 30, 0.1, 3);
        let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
        Engine::new(
            EngineConfig { threads, record_every: 5, ..Default::default() },
            mix,
            Box::new(p),
        )
    }

    #[test]
    fn lead_linear_convergence_with_2bit_quantization() {
        // The headline claim: linear convergence *with* compression.
        let mut e = ring_engine(1);
        let rec = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 512))),
            600,
        );
        assert!(
            rec.last().dist_opt < 1e-6,
            "LEAD+2bit did not converge: {}",
            rec.last().dist_opt
        );
        // And it converged *linearly*: fitted ρ̂ must be < 1 decisively.
        let rho = rec.empirical_rho(1e-9).unwrap();
        assert!(rho < 0.97, "no linear decay, ρ̂ = {rho}");
        // Compression error vanishes (Fig. 1d).
        assert!(rec.last().comp_err < 1e-6, "comp err {}", rec.last().comp_err);
    }

    #[test]
    fn lead_identity_equals_nids() {
        // Proposition 1 / Corollary 3, verified on full trajectories.
        let mut e1 = ring_engine(1);
        let rec_lead = e1.run(
            Box::new(Lead::new(LeadParams { gamma: 1.0, alpha: 0.5 })),
            Some(Box::new(Identity)),
            120,
        );
        let mut e2 = ring_engine(1);
        let rec_nids = e2.run(Box::new(Nids::new()), None, 120);
        for (a, b) in rec_lead.series.iter().zip(&rec_nids.series) {
            assert!(
                (a.dist_opt - b.dist_opt).abs() <= 1e-9 * (1.0 + a.dist_opt),
                "round {}: LEAD {} vs NIDS {}",
                a.round,
                a.dist_opt,
                b.dist_opt
            );
        }
    }

    #[test]
    fn parallel_equals_sequential() {
        // 4 worker threads must reproduce the single-thread trajectory
        // bit-for-bit (dense quantizer messages). At this problem size the
        // gradient and compression phases fan out; mix/apply run inline
        // via phase_threads — their parallel paths are pinned by
        // par_chunks_mix_equals_inline and by
        // algorithms::tests::all_algorithms_recv_all_parallel_equals_sequential.
        let run = |threads: usize| {
            let mut e = ring_engine(threads);
            e.run(
                Box::new(Lead::paper_default()),
                Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 64))),
                80,
            )
        };
        let a = run(1);
        let b = run(4);
        for (ma, mb) in a.series.iter().zip(&b.series) {
            assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.bits_per_agent, mb.bits_per_agent);
        }
    }

    #[test]
    fn parallel_equals_sequential_sparse_topk() {
        // Same guarantee with sparse top-k messages in flight, including
        // a thread count that does not divide n.
        let run = |threads: usize| {
            let mut e = ring_engine(threads);
            e.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 60)
        };
        let a = run(1);
        let b = run(3);
        let c = run(8);
        for ((ma, mb), mc) in a.series.iter().zip(&b.series).zip(&c.series) {
            assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.dist_opt.to_bits(), mc.dist_opt.to_bits(), "round {}", ma.round);
            assert_eq!(ma.bits_per_agent, mb.bits_per_agent);
        }
    }

    /// The chunked fan-out itself: mixing through par_chunks at several
    /// thread counts must be bitwise-equal to the inline loop (the engine
    /// tests above run small problems, which phase_threads keeps inline —
    /// this pins the parallel path directly).
    #[test]
    fn par_chunks_mix_equals_inline() {
        let n = 8;
        let d = 257; // not a multiple of any chunk size
        let mix = Topology::Ring.build(n, MixingRule::MetropolisHastings);
        let topk = TopK::new(19);
        let mut rng = crate::rng::Rng::new(77);
        let msgs: Vec<CompressedMsg> = (0..n)
            .map(|_| {
                let mut x = vec![0.0f64; d];
                rng.fill_normal(&mut x, 1.0);
                topk.compress_alloc(&x, &mut rng)
            })
            .collect();
        let mut inline = vec![vec![0.0f64; d]; n];
        for (i, out) in inline.iter_mut().enumerate() {
            mix_msgs(&mix, i, &msgs, out);
        }
        for threads in [2usize, 3, 8] {
            let mut par = vec![vec![0.0f64; d]; n];
            par_chunks(threads, &mut par, |i, out| mix_msgs(&mix, i, &msgs, out));
            for (a, b) in inline.iter().zip(&par) {
                for (u, v) in a.iter().zip(b) {
                    assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn phase_threads_gates_small_work() {
        assert_eq!(phase_threads(8, 8, 200), 1, "fig1 shape stays inline");
        assert_eq!(phase_threads(8, 32, 100_000), 8, "bench shape fans out");
        assert_eq!(phase_threads(8, 2, 100_000), 2, "clamped to n");
    }

    #[test]
    fn sparse_and_dense_messages_same_trajectory() {
        // Forcing the dense fallback (sparse = None) must not change the
        // run at all: the sparse view is a pure representation change.
        use crate::compress::StripSparse;
        let mut e1 = ring_engine(1);
        let rec_sparse = e1.run(Box::new(Lead::paper_default()), Some(Box::new(TopK::new(10))), 60);
        let mut e2 = ring_engine(1);
        let rec_dense = e2.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(StripSparse(TopK::new(10)))),
            60,
        );
        for (a, b) in rec_sparse.series.iter().zip(&rec_dense.series) {
            assert_eq!(a.dist_opt.to_bits(), b.dist_opt.to_bits(), "round {}", a.round);
            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
        }
    }

    #[test]
    fn bits_accounting_compressed_vs_raw() {
        let mut e = ring_engine(1);
        let rec_q = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, crate::compress::quantize::PNorm::Inf, 512))),
            50,
        );
        let mut e2 = ring_engine(1);
        let rec_raw = e2.run(Box::new(Nids::new()), None, 50);
        // d = 30, one block: wire = 32 + 30·(2+1) = 122 bits vs 960 raw.
        let ratio = rec_raw.last().bits_per_agent / rec_q.last().bits_per_agent;
        let expect = 960.0 / 122.0;
        assert!(
            (ratio - expect).abs() < 1e-6,
            "compression ratio {ratio}, expected {expect}"
        );
    }

    #[test]
    fn diminishing_schedule_converges_with_minibatch() {
        // Theorem 2 regime: stochastic gradients + O(1/k) stepsizes.
        let p = crate::problems::logreg::LogReg::synthetic(
            4, 160, 10, 4, 1e-2, crate::problems::DataSplit::Heterogeneous, 5, true,
        );
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig {
                eta: 0.5,
                schedule: Schedule::Diminishing { t0: 200.0 },
                batch_size: Some(8),
                record_every: 50,
                ..Default::default()
            },
            mix,
            Box::new(p),
        );
        let rec = e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(4, crate::compress::quantize::PNorm::Inf, 512))),
            2000,
        );
        let first = rec.series.first().unwrap().dist_opt;
        let last = rec.last().dist_opt;
        assert!(last < 0.2 * first, "no progress: {first} -> {last}");
    }
}
