//! Layer-3 coordinator: the round loop, simulated network, and metrics.
//!
//! [`engine::Engine`] is the single entry point examples and benches use;
//! it owns the problem and topology and drives any [`crate::algorithms::
//! Algorithm`] with any [`crate::compress::Compressor`] under identical
//! accounting rules (see DESIGN.md §6).

pub mod engine;
pub mod metrics;
pub mod network;
