//! Layer-3 coordinator: the round loop, simulated network, and metrics.
//!
//! [`engine::Engine`] is the single entry point examples and benches use;
//! it owns the problem and topology and drives any [`crate::algorithms::
//! Algorithm`] with any [`crate::compress::Compressor`] under identical
//! accounting rules (see DESIGN.md §6). Round *time* comes from either
//! [`network`]'s uniform formula or the discrete-event heterogeneous
//! simulator [`crate::simnet`] (engine §Network timing) — a timing-only
//! choice that never affects trajectories.

pub mod engine;
pub mod metrics;
pub mod network;
