//! Simulated gossip network: per-edge traffic accounting and a simple
//! latency/bandwidth time model.
//!
//! The paper's communication plots use bits; real deployments care about
//! time. Each round every agent broadcasts its payload to each neighbor;
//! since all links operate in parallel in a synchronous gossip round, the
//! round's simulated duration is `latency + max_link_bits / bandwidth`.

use crate::topology::MixingMatrix;

/// Link characteristics applied uniformly to all edges.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// One-way latency per round, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bits/second.
    pub bandwidth_bps: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 1 Gb/s, 0.1 ms — a typical cluster interconnect.
        LinkModel { latency_s: 1e-4, bandwidth_bps: 1e9 }
    }
}

/// Traffic statistics accumulated over a run.
#[derive(Clone, Debug, Default)]
pub struct TrafficStats {
    /// Total bits broadcast per agent (sum over rounds of its payload size;
    /// one broadcast serves all neighbors on a shared medium — for
    /// point-to-point links multiply by the agent's degree).
    pub broadcast_bits: Vec<u64>,
    /// Total directed link-bits (payload × degree), network-wide.
    pub link_bits: u64,
    /// Simulated elapsed communication time, seconds.
    pub sim_time: f64,
    pub rounds: usize,
}

impl TrafficStats {
    pub fn new(n: usize) -> Self {
        TrafficStats { broadcast_bits: vec![0; n], ..Default::default() }
    }

    /// Account one synchronous gossip round. `bits[i]` is the payload size
    /// agent i broadcast this round.
    pub fn record_round(&mut self, mix: &MixingMatrix, link: &LinkModel, bits: &[u64]) {
        debug_assert_eq!(bits.len(), self.broadcast_bits.len());
        let mut max_bits = 0u64;
        for (i, &b) in bits.iter().enumerate() {
            self.broadcast_bits[i] += b;
            self.link_bits += b * mix.neighbors[i].len() as u64;
            max_bits = max_bits.max(b);
        }
        self.sim_time += link.latency_s + max_bits as f64 / link.bandwidth_bps;
        self.rounds += 1;
    }

    /// Mean broadcast bits per agent so far.
    pub fn mean_bits_per_agent(&self) -> f64 {
        if self.broadcast_bits.is_empty() {
            return 0.0;
        }
        self.broadcast_bits.iter().sum::<u64>() as f64 / self.broadcast_bits.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn accounting() {
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let link = LinkModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let mut t = TrafficStats::new(4);
        t.record_round(&mix, &link, &[1000, 2000, 1000, 1000]);
        t.record_round(&mix, &link, &[1000, 1000, 1000, 1000]);
        assert_eq!(t.broadcast_bits, vec![2000, 3000, 2000, 2000]);
        // Each ring agent has 2 neighbors ⇒ link bits = 2 × broadcast.
        assert_eq!(t.link_bits, 2 * 9000);
        // time = 2 × latency + (2000 + 1000)/1e6
        assert!((t.sim_time - (2e-3 + 3000.0 / 1e6)).abs() < 1e-12);
        assert_eq!(t.rounds, 2);
        assert!((t.mean_bits_per_agent() - 2250.0).abs() < 1e-9);
    }
}
