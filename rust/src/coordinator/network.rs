//! Simulated gossip network: per-edge traffic accounting and a simple
//! latency/bandwidth time model.
//!
//! The paper's communication plots use bits; real deployments care about
//! time. Each round every agent broadcasts its payload to each neighbor;
//! since all links operate in parallel in a synchronous gossip round, the
//! round's simulated duration is `latency + max_link_bits / bandwidth`.
//!
//! This uniform formula is the *homogeneous* time model. Heterogeneous
//! networks (per-edge bandwidth/latency, stragglers, jitter, lossy
//! links) are simulated event-by-event by [`crate::simnet`], which plugs
//! into the same [`TrafficStats`] accounting via
//! [`TrafficStats::record_bits`] + an externally computed round duration
//! and degenerates to this formula bit-for-bit on a homogeneous network.

use crate::topology::MixingMatrix;

/// Link characteristics applied uniformly to all edges.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    /// One-way latency per round, seconds.
    pub latency_s: f64,
    /// Link bandwidth, bits/second.
    pub bandwidth_bps: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // 1 Gb/s, 0.1 ms — a typical cluster interconnect.
        LinkModel { latency_s: 1e-4, bandwidth_bps: 1e9 }
    }
}

/// Traffic statistics accumulated over a run.
#[derive(Clone, Debug, Default)]
pub struct TrafficStats {
    /// Total bits broadcast per agent (sum over rounds of its payload size;
    /// one broadcast serves all neighbors on a shared medium — for
    /// point-to-point links multiply by the agent's degree).
    pub broadcast_bits: Vec<u64>,
    /// Total directed link-bits (payload × degree), network-wide.
    pub link_bits: u64,
    /// Simulated elapsed communication time, seconds.
    pub sim_time: f64,
    pub rounds: usize,
}

impl TrafficStats {
    pub fn new(n: usize) -> Self {
        TrafficStats { broadcast_bits: vec![0; n], ..Default::default() }
    }

    /// Account one synchronous gossip round under the uniform link-time
    /// model. `bits[i]` is the payload size agent i broadcast this round.
    /// The engine decomposes this into [`TrafficStats::record_bits`] plus
    /// a round duration — either [`TrafficStats::uniform_round_time`]
    /// (this model) or a simulated one from
    /// [`crate::simnet::RoundTimer::round`]; both paths produce identical
    /// accounting for a homogeneous network (the simnet §Timing
    /// contract).
    pub fn record_round(&mut self, mix: &MixingMatrix, link: &LinkModel, bits: &[u64]) {
        self.record_bits(mix, bits);
        self.sim_time += Self::uniform_round_time(link, bits);
        self.rounds += 1;
    }

    /// Bit accounting only (no time model): per-agent broadcast bits and
    /// network-wide directed link-bits.
    pub fn record_bits(&mut self, mix: &MixingMatrix, bits: &[u64]) {
        debug_assert_eq!(bits.len(), self.broadcast_bits.len());
        for (i, &b) in bits.iter().enumerate() {
            self.broadcast_bits[i] += b;
            self.link_bits += b * mix.neighbors[i].len() as u64;
        }
    }

    /// The legacy uniform round duration: all links run in parallel, so a
    /// synchronous round costs `latency + max_bits / bandwidth`.
    pub fn uniform_round_time(link: &LinkModel, bits: &[u64]) -> f64 {
        let max_bits = bits.iter().copied().max().unwrap_or(0);
        link.latency_s + max_bits as f64 / link.bandwidth_bps
    }

    /// Mean broadcast bits per agent so far.
    pub fn mean_bits_per_agent(&self) -> f64 {
        if self.broadcast_bits.is_empty() {
            return 0.0;
        }
        self.broadcast_bits.iter().sum::<u64>() as f64 / self.broadcast_bits.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{MixingRule, Topology};

    #[test]
    fn accounting() {
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let link = LinkModel { latency_s: 1e-3, bandwidth_bps: 1e6 };
        let mut t = TrafficStats::new(4);
        t.record_round(&mix, &link, &[1000, 2000, 1000, 1000]);
        t.record_round(&mix, &link, &[1000, 1000, 1000, 1000]);
        assert_eq!(t.broadcast_bits, vec![2000, 3000, 2000, 2000]);
        // Each ring agent has 2 neighbors ⇒ link bits = 2 × broadcast.
        assert_eq!(t.link_bits, 2 * 9000);
        // time = 2 × latency + (2000 + 1000)/1e6
        assert!((t.sim_time - (2e-3 + 3000.0 / 1e6)).abs() < 1e-12);
        assert_eq!(t.rounds, 2);
        assert!((t.mean_bits_per_agent() - 2250.0).abs() < 1e-9);
    }
}
