//! Run metrics: the quantities the paper plots, recorded per round, plus
//! the time-axis queries (`time_to_tol`) and per-agent network summaries
//! that the simnet overlay adds for time-to-accuracy studies.

use crate::faults::FaultSummary;
use crate::serialize::json;
use crate::simnet::NetSummary;
use crate::trace::TraceSummary;
use crate::transport::TransportSummary;

/// Metrics snapshot at one recorded round.
#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub round: usize,
    /// √((1/n) Σ_i ‖x_i − x*‖²) — the paper's "distance to x*"
    /// (NaN when the problem exposes no optimum).
    pub dist_opt: f64,
    /// √((1/n) Σ_i ‖x_i − x̄‖²) — consensus error (Fig. 1c / Cor. 2).
    pub consensus: f64,
    /// Global objective f(x̄) at the averaged model.
    pub loss: f64,
    /// Mean absolute compression error of this round,
    /// (1/n) Σ_i ‖decode(Q(p_i)) − p_i‖₂ (Fig. 1d). Zero when uncompressed.
    pub comp_err: f64,
    /// Cumulative wire bits transmitted per agent (i.e. total/n), so plots
    /// against "bits" match the paper's per-agent budget axis.
    pub bits_per_agent: f64,
    /// Simulated communication time so far (network model), seconds.
    pub sim_time: f64,
    /// Max over agents of cumulative barrier-wait (idle) seconds so far.
    /// Always 0 under the legacy uniform time model; populated by the
    /// simnet overlay (`crate::simnet` §Timing contract: extra
    /// observability, never a trajectory change).
    pub idle_max: f64,
    /// Cumulative crashed agent-rounds so far (`crate::faults`; all four
    /// fault counters are zero when fault injection is off).
    pub crashed: u64,
    /// Cumulative messages lost outright (dropped, crashed endpoint, or
    /// partitioned — and not replaced by a stale replay).
    pub lost: u64,
    /// Cumulative stale replays consumed in place of lost messages.
    pub stale: u64,
    /// Cumulative mixing rows renormalized by the degraded-inbox path.
    pub renormed: u64,
}

/// Wall-clock totals per engine phase, accumulated over a run (§Perf —
/// the raw signal behind `benches/hotpath.rs`' per-phase breakdown and
/// `BENCH_hotpath.json`).
///
/// With [`Scheduler::Persistent`] the gradient/send/compress work is one
/// fused dispatch and lands in `produce`; the legacy
/// [`Scheduler::SpawnPerPhase`] scheduler fills the `gradient`/`send`/
/// `compress` buckets individually instead.
///
/// [`Scheduler::Persistent`]: crate::coordinator::engine::Scheduler::Persistent
/// [`Scheduler::SpawnPerPhase`]: crate::coordinator::engine::Scheduler::SpawnPerPhase
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// Fused gradient+send+compress dispatch (persistent scheduler).
    pub produce: f64,
    pub gradient: f64,
    pub send: f64,
    pub compress: f64,
    pub mix: f64,
    pub apply: f64,
    /// Metric observation (loss/consensus passes on recorded rounds).
    pub observe: f64,
    /// How many stamp accumulations each bucket received. Unlike the
    /// wall durations above these are *deterministic* structure
    /// counters: a full run has `produce_n == mix_n == apply_n ==
    /// rounds`, a `time_budget`-stopped run counts the budget-crossing
    /// round exactly once (its stamps land before the stop check), and
    /// `observe_n == series.len()` — the round-0 snapshot included.
    /// Pinned by `engine::tests::phase_counts_*`.
    pub produce_n: u64,
    pub mix_n: u64,
    pub apply_n: u64,
    pub observe_n: u64,
}

impl PhaseTimes {
    /// Render as a compact JSON object (for `BENCH_hotpath.json`). Routes
    /// numbers through the same non-finite-to-null mapping as
    /// [`RunRecord::to_json`] so the emitted file always parses.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"produce\":{},\"gradient\":{},\"send\":{},\"compress\":{},\"mix\":{},\"apply\":{},\"observe\":{},\"produce_n\":{},\"mix_n\":{},\"apply_n\":{},\"observe_n\":{}}}",
            fin(self.produce),
            fin(self.gradient),
            fin(self.send),
            fin(self.compress),
            fin(self.mix),
            fin(self.apply),
            fin(self.observe),
            self.produce_n,
            self.mix_n,
            self.apply_n,
            self.observe_n
        )
    }
}

/// A full run: per-round series plus identification.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub algo: String,
    pub problem: String,
    pub compressor: String,
    pub series: Vec<RoundMetrics>,
    pub wall_secs: f64,
    /// Per-phase wall-clock totals for this run.
    pub phases: PhaseTimes,
    /// Network summary (per-agent idle/straggler stats, retransmits,
    /// utilization) — `Some` iff the run used the simnet overlay.
    pub net: Option<NetSummary>,
    /// Fault-injection summary — `Some` iff the run used a fault plan.
    pub faults: Option<FaultSummary>,
    /// Transport summary (frames sent/dropped, actual bytes on the wire,
    /// envelope included) — `Some` iff the run used a non-`Mem`
    /// [`TransportMode`](crate::transport::TransportMode).
    pub transport: Option<TransportSummary>,
    /// Trace summary (fleet counters + pool wake-latency histogram,
    /// `crate::trace` §Observability contract) — `Some` iff the run had
    /// `EngineConfig.trace` on. The raw event capture is *not* stored
    /// here (it is rounds-proportional); fetch it once via
    /// [`Engine::take_trace`](crate::coordinator::engine::Engine::take_trace).
    pub trace: Option<TraceSummary>,
    /// True iff the run stopped at `EngineConfig.time_budget` before
    /// completing its scheduled rounds.
    pub stopped_early: bool,
}

impl RunRecord {
    pub fn last(&self) -> &RoundMetrics {
        self.series.last().expect("empty run record")
    }

    /// First recorded round whose dist_opt ≤ tol; None if never reached.
    pub fn rounds_to_tol(&self, tol: f64) -> Option<usize> {
        self.series.iter().find(|m| m.dist_opt <= tol).map(|m| m.round)
    }

    /// Bits/agent spent when dist_opt first ≤ tol.
    pub fn bits_to_tol(&self, tol: f64) -> Option<f64> {
        self.series.iter().find(|m| m.dist_opt <= tol).map(|m| m.bits_per_agent)
    }

    /// Simulated seconds elapsed when dist_opt first ≤ tol — the
    /// time-to-accuracy metric the `examples/time_to_accuracy.toml` grid
    /// sweeps across link models. None if the tolerance is never reached.
    pub fn time_to_tol(&self, tol: f64) -> Option<f64> {
        self.series.iter().find(|m| m.dist_opt <= tol).map(|m| m.sim_time)
    }

    /// Empirical contraction factor ρ̂ fitted over the linear-decay segment
    /// (least-squares slope of log dist_opt between the first round and the
    /// first round below `floor`).
    pub fn empirical_rho(&self, floor: f64) -> Option<f64> {
        self.empirical_rho_of(|m| m.dist_opt, floor)
    }

    /// [`RunRecord::empirical_rho`] generalized to any recorded metric:
    /// the per-round geometric contraction factor of `metric` fitted by
    /// least squares on its log over the decay segment (observed points
    /// with a finite value above `floor`). Used by the theory tests to
    /// pin that e.g. LEAD's compression error decays geometrically
    /// alongside the primal error.
    pub fn empirical_rho_of(&self, metric: impl Fn(&RoundMetrics) -> f64, floor: f64) -> Option<f64> {
        let pts: Vec<(f64, f64)> = self
            .series
            .iter()
            .map(|m| (m, metric(m)))
            .filter(|(_, v)| v.is_finite() && *v > floor)
            .map(|(m, v)| (m.round as f64, v.ln()))
            .collect();
        if pts.len() < 3 {
            return None;
        }
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        Some(slope.exp())
    }

    /// CSV with a header row (one line per recorded round).
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,dist_opt,consensus,loss,comp_err,bits_per_agent,sim_time,idle_max,crashed,lost,stale,renormed\n",
        );
        for m in &self.series {
            s.push_str(&format!(
                "{},{:e},{:e},{:e},{:e},{},{:e},{:e},{},{},{},{}\n",
                m.round,
                m.dist_opt,
                m.consensus,
                m.loss,
                m.comp_err,
                m.bits_per_agent,
                m.sim_time,
                m.idle_max,
                m.crashed,
                m.lost,
                m.stale,
                m.renormed
            ));
        }
        s
    }

    /// Compact JSON (machine-readable record for EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{");
        json::write_str(&mut out, "algo");
        out.push(':');
        json::write_str(&mut out, &self.algo);
        out.push(',');
        json::write_str(&mut out, "problem");
        out.push(':');
        json::write_str(&mut out, &self.problem);
        out.push(',');
        json::write_str(&mut out, "compressor");
        out.push(':');
        json::write_str(&mut out, &self.compressor);
        out.push(',');
        json::write_str(&mut out, "wall_secs");
        out.push(':');
        json::write_num(&mut out, self.wall_secs);
        out.push(',');
        json::write_str(&mut out, "net");
        out.push(':');
        match &self.net {
            Some(n) => out.push_str(&n.to_json()),
            None => out.push_str("null"),
        }
        out.push(',');
        json::write_str(&mut out, "faults");
        out.push(':');
        match &self.faults {
            Some(f) => out.push_str(&f.to_json()),
            None => out.push_str("null"),
        }
        out.push(',');
        json::write_str(&mut out, "transport");
        out.push(':');
        match &self.transport {
            Some(t) => out.push_str(&t.to_json()),
            None => out.push_str("null"),
        }
        out.push(',');
        json::write_str(&mut out, "phases");
        out.push(':');
        out.push_str(&self.phases.to_json());
        out.push(',');
        json::write_str(&mut out, "trace");
        out.push(':');
        match &self.trace {
            Some(t) => out.push_str(&t.to_json()),
            None => out.push_str("null"),
        }
        out.push(',');
        json::write_str(&mut out, "stopped_early");
        out.push(':');
        out.push_str(if self.stopped_early { "true" } else { "false" });
        out.push(',');
        json::write_str(&mut out, "series");
        out.push_str(":[");
        for (i, m) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "[{},{},{},{},{},{},{},{},{},{},{},{}]",
                m.round,
                fin(m.dist_opt),
                fin(m.consensus),
                fin(m.loss),
                fin(m.comp_err),
                m.bits_per_agent,
                fin(m.sim_time),
                fin(m.idle_max),
                m.crashed,
                m.lost,
                m.stale,
                m.renormed
            ));
        }
        out.push_str("]}");
        out
    }

    /// Write CSV to `dir/<name>.csv`.
    pub fn write_csv(&self, dir: &std::path::Path, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{name}.csv")), self.to_csv())
    }
}

fn fin(x: f64) -> String {
    if x.is_finite() {
        format!("{x:e}")
    } else {
        "null".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(dists: &[f64]) -> RunRecord {
        RunRecord {
            algo: "test".into(),
            problem: "p".into(),
            compressor: "none".into(),
            wall_secs: 0.1,
            phases: PhaseTimes::default(),
            net: None,
            faults: None,
            transport: None,
            trace: None,
            stopped_early: false,
            series: dists
                .iter()
                .enumerate()
                .map(|(i, &d)| RoundMetrics {
                    round: i,
                    dist_opt: d,
                    consensus: d / 2.0,
                    loss: d,
                    comp_err: 0.0,
                    bits_per_agent: (i as f64) * 100.0,
                    sim_time: i as f64,
                    idle_max: 0.0,
                    crashed: 0,
                    lost: 0,
                    stale: 0,
                    renormed: 0,
                })
                .collect(),
        }
    }

    #[test]
    fn tol_queries() {
        let r = rec(&[1.0, 0.1, 0.01, 0.001]);
        assert_eq!(r.rounds_to_tol(0.05), Some(2));
        assert_eq!(r.bits_to_tol(0.05), Some(200.0));
        assert_eq!(r.time_to_tol(0.05), Some(2.0));
        assert_eq!(r.rounds_to_tol(1e-9), None);
        assert_eq!(r.time_to_tol(1e-9), None);
    }

    #[test]
    fn empirical_rho_of_geometric_series() {
        // dist = 0.5^k ⇒ ρ̂ = 0.5.
        let d: Vec<f64> = (0..30).map(|k| 0.5f64.powi(k)).collect();
        let r = rec(&d);
        let rho = r.empirical_rho(1e-12).unwrap();
        assert!((rho - 0.5).abs() < 1e-6, "rho = {rho}");
    }

    #[test]
    fn csv_and_json_shape() {
        let mut r = rec(&[1.0, 0.5]);
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("round,"));
        assert!(csv.lines().next().unwrap().ends_with(",renormed"));
        let js = crate::serialize::json::parse(&r.to_json()).unwrap();
        assert_eq!(js.get("algo").unwrap().as_str(), Some("test"));
        assert_eq!(js.get("series").unwrap().as_arr().unwrap().len(), 2);
        // Each series row carries 12 columns (…, crashed, lost, stale,
        // renormed).
        let row = js.get("series").unwrap().as_arr().unwrap()[0].as_arr().unwrap().len();
        assert_eq!(row, 12);
        assert!(js.get("net").is_some(), "legacy runs serialize net as null");
        assert!(js.get("faults").is_some(), "fault-free runs serialize faults as null");
        assert!(js.get("transport").is_some(), "mem runs serialize transport as null");
        assert!(js.get("trace").is_some(), "untraced runs serialize trace as null");
        let ph = js.get("phases").expect("phases object always present");
        assert_eq!(ph.get("produce_n").unwrap().as_f64(), Some(0.0));
        assert!(ph.get("observe").is_some());

        // With a simnet summary attached the JSON embeds it.
        r.net = Some(NetSummary {
            link: "uniform:1e-4:1e9".into(),
            idle_s: vec![0.0, 0.25],
            straggler_rounds: vec![1, 1],
            retransmits: 0,
            capped: 0,
            utilization: 0.5,
        });
        let js = crate::serialize::json::parse(&r.to_json()).unwrap();
        let net = js.get("net").unwrap();
        assert_eq!(net.get("link").unwrap().as_str(), Some("uniform:1e-4:1e9"));
        assert_eq!(net.get("idle_s").unwrap().as_arr().unwrap().len(), 2);

        // With a fault summary attached the JSON embeds that too.
        r.faults = Some(FaultSummary {
            plan: "loss:5e-2".into(),
            crashed_agent_rounds: 0,
            lost: 7,
            stale: 0,
            renormalized_rows: 7,
            capped_losses: 0,
            down_rounds: vec![0, 0],
        });
        r.stopped_early = true;
        let js = crate::serialize::json::parse(&r.to_json()).unwrap();
        let f = js.get("faults").unwrap();
        assert_eq!(f.get("plan").unwrap().as_str(), Some("loss:5e-2"));
        assert_eq!(f.get("lost").unwrap().as_f64(), Some(7.0));
        assert_eq!(js.get("stopped_early"), Some(&crate::serialize::json::Json::Bool(true)));

        // With a transport summary attached the JSON embeds it too.
        r.transport = Some(TransportSummary {
            mode: "mux:8".into(),
            frames_sent: 640,
            frames_dropped: 3,
            bytes_on_wire: 81920,
        });
        let js = crate::serialize::json::parse(&r.to_json()).unwrap();
        let t = js.get("transport").unwrap();
        assert_eq!(t.get("mode").unwrap().as_str(), Some("mux:8"));
        assert_eq!(t.get("frames_dropped").unwrap().as_f64(), Some(3.0));

        // And a trace summary round-trips with ordered counters.
        r.trace = Some(TraceSummary {
            counters: vec![("events", 12), ("frames_sent", 640)],
            wake_hist_ns: vec![0, 2, 5],
        });
        let js = crate::serialize::json::parse(&r.to_json()).unwrap();
        let tr = js.get("trace").unwrap();
        assert_eq!(tr.get("counters").unwrap().get("frames_sent").unwrap().as_f64(), Some(640.0));
        assert_eq!(tr.get("wake_hist_ns").unwrap().as_arr().unwrap().len(), 3);
    }
}
