//! Cross-algorithm integration tests: the orderings the paper's figures
//! report, reproduced on the synthetic linear-regression workload (Fig. 1
//! regime: 8-agent ring, full gradient, heterogeneous data).

use lead::algorithms::{
    choco::ChocoSgd, d2::D2, deepsqueeze::DeepSqueeze, dgd::Dgd, diging::DiGing,
    exact_diffusion::ExactDiffusion, lead::Lead, nids::Nids, qdgd::Qdgd, Algorithm,
};
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::problems::linreg::LinReg;
use lead::topology::{MixingRule, Topology};

fn run(algo: Box<dyn Algorithm>, compressed: bool, rounds: usize, eta: f64) -> lead::coordinator::metrics::RunRecord {
    let p = LinReg::synthetic(8, 30, 0.1, 101);
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig { eta, record_every: 20, ..Default::default() },
        mix,
        std::sync::Arc::new(p),
    );
    let comp: Option<Box<dyn lead::compress::Compressor>> = if compressed {
        Some(Box::new(QuantizeP::new(2, PNorm::Inf, 512)))
    } else {
        None
    };
    e.run(algo, comp, rounds)
}

/// Fig. 1a ordering: exact methods (LEAD, NIDS, D², ExactDiffusion,
/// DIGing) reach high precision; DGD-family (DGD, QDGD, DeepSqueeze,
/// CHOCO) stall at a bias.
#[test]
fn figure1_ordering() {
    let exact: Vec<(&str, f64)> = vec![
        ("LEAD+2bit", run(Box::new(Lead::paper_default()), true, 1200, 0.1).last().dist_opt),
        ("NIDS", run(Box::new(Nids::new()), false, 1200, 0.1).last().dist_opt),
        ("D2", run(Box::new(D2::new()), false, 1200, 0.1).last().dist_opt),
        ("ExactDiffusion", run(Box::new(ExactDiffusion::new()), false, 1200, 0.1).last().dist_opt),
        ("DIGing", run(Box::new(DiGing::new()), false, 4000, 0.02).last().dist_opt),
    ];
    for (name, err) in &exact {
        assert!(*err < 1e-7, "{name} should be exact, got {err}");
    }
    let biased: Vec<(&str, f64)> = vec![
        ("DGD", run(Box::new(Dgd::new()), false, 1200, 0.1).last().dist_opt),
        ("QDGD", run(Box::new(Qdgd::new(0.2)), true, 1200, 0.1).last().dist_opt),
        ("DeepSqueeze", run(Box::new(DeepSqueeze::new(0.2)), true, 1200, 0.1).last().dist_opt),
        ("CHOCO-SGD", run(Box::new(ChocoSgd::new(0.8)), true, 1200, 0.1).last().dist_opt),
    ];
    for (name, err) in &biased {
        assert!(
            *err > 1e-6,
            "{name} is a DGD-type method and should retain bias, got {err}"
        );
        assert!(*err < 10.0, "{name} diverged: {err}");
    }
}

/// Fig. 1b: per *bit*, LEAD dominates the non-compressed exact methods.
#[test]
fn figure1_bits_efficiency() {
    let lead_rec = run(Box::new(Lead::paper_default()), true, 1500, 0.1);
    let nids_rec = run(Box::new(Nids::new()), false, 1500, 0.1);
    let tol = 1e-6;
    let lead_bits = lead_rec.bits_to_tol(tol).expect("LEAD reached tol");
    let nids_bits = nids_rec.bits_to_tol(tol).expect("NIDS reached tol");
    assert!(
        lead_bits < 0.25 * nids_bits,
        "LEAD {lead_bits:.3e} bits vs NIDS {nids_bits:.3e} — expected ≥4× saving"
    );
}

/// Fig. 1d: compression error vanishes for LEAD and CHOCO (difference
/// compression) but stays large for QDGD and DeepSqueeze (model
/// compression).
#[test]
fn figure1_compression_error_contrast() {
    let lead_rec = run(Box::new(Lead::paper_default()), true, 800, 0.1);
    let choco_rec = run(Box::new(ChocoSgd::new(0.8)), true, 800, 0.1);
    let qdgd_rec = run(Box::new(Qdgd::new(0.2)), true, 800, 0.1);
    let ds_rec = run(Box::new(DeepSqueeze::new(0.2)), true, 800, 0.1);
    assert!(lead_rec.last().comp_err < 1e-6, "LEAD comp err {}", lead_rec.last().comp_err);
    assert!(choco_rec.last().comp_err < 1e-2, "CHOCO comp err {}", choco_rec.last().comp_err);
    assert!(
        qdgd_rec.last().comp_err > 10.0 * lead_rec.last().comp_err.max(1e-9),
        "QDGD comp err should stay large: {}",
        qdgd_rec.last().comp_err
    );
    assert!(
        ds_rec.last().comp_err > 10.0 * lead_rec.last().comp_err.max(1e-9),
        "DeepSqueeze comp err should stay large: {}",
        ds_rec.last().comp_err
    );
}

/// DIGing transmits two channels ⇒ exactly 2× the bits of NIDS per round.
#[test]
fn diging_pays_double_bits() {
    let nids_rec = run(Box::new(Nids::new()), false, 100, 0.1);
    let diging_rec = run(Box::new(DiGing::new()), false, 100, 0.05);
    let ratio = diging_rec.last().bits_per_agent / nids_rec.last().bits_per_agent;
    assert!((ratio - 2.0).abs() < 1e-9, "ratio {ratio}");
}
