//! Differential harness for the simnet timing overlay (`lead::simnet`).
//!
//! Pins the two halves of the §Timing contract:
//!
//! 1. **Timing-only**: enabling any network model — homogeneous or
//!    heterogeneous, lossy or clean — leaves the trajectory series
//!    (dist_opt / consensus / comp_err / bits_per_agent) bitwise-
//!    identical to the legacy uniform-formula accounting, across codecs
//!    and thread counts.
//! 2. **Degenerate exactness**: the homogeneous `uniform` model with no
//!    jitter/drop reproduces the legacy `TrafficStats` `sim_time`
//!    bit-for-bit (the companion proptest in `proptests.rs` covers the
//!    raw RoundTimer-vs-formula identity over random topologies).
//!
//! Plus simnet determinism: same seed ⇒ identical timings, idle series,
//! and straggler/retransmit counts across thread counts and reruns.

use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::topk::TopK;
use lead::compress::Compressor;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::coordinator::metrics::RunRecord;
use lead::coordinator::network::LinkModel;
use lead::problems::linreg::LinReg;
use lead::simnet::NetModel;
use lead::topology::{MixingRule, Topology};
use std::sync::Arc;

fn codec(name: &str) -> Box<dyn Compressor> {
    match name {
        "topk" => Box::new(TopK::new(5)),
        "qinf" => Box::new(QuantizeP::new(2, PNorm::Inf, 64)),
        other => panic!("unknown test codec {other}"),
    }
}

/// One short LEAD run on the Fig. 1-shaped workload with an optional
/// simnet model (None ⇒ legacy accounting via `link`).
fn run_with(
    net: Option<&str>,
    link: LinkModel,
    codec_name: &str,
    topology: Topology,
    threads: usize,
) -> RunRecord {
    let n = 8;
    let p = LinReg::synthetic(n, 40, 0.1, 3);
    let mix = topology.build(n, MixingRule::UniformNeighbors);
    let cfg = EngineConfig {
        threads,
        record_every: 7,
        link,
        net: net.map(|s| NetModel::parse(s).expect("bad test model")),
        ..Default::default()
    };
    let mut e = Engine::new(cfg, mix, Arc::new(p));
    e.run(
        Box::new(lead::algorithms::lead::Lead::paper_default()),
        Some(codec(codec_name)),
        50,
    )
}

fn assert_trajectory_bitwise_equal(a: &RunRecord, b: &RunRecord, tag: &str) {
    assert_eq!(a.series.len(), b.series.len(), "{tag}: series length");
    for (ma, mb) in a.series.iter().zip(&b.series) {
        assert_eq!(ma.round, mb.round, "{tag}");
        assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.consensus.to_bits(), mb.consensus.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.comp_err.to_bits(), mb.comp_err.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.bits_per_agent, mb.bits_per_agent, "{tag} round {}", ma.round);
    }
}

/// Acceptance pin: degenerate homogeneous simnet == legacy accounting,
/// *including* sim_time, bit for bit — at non-default link parameters
/// too, and for both dense and sparse codecs.
#[test]
fn homogeneous_simnet_reproduces_legacy_exactly() {
    for (spec, link) in [
        ("uniform:1e-4:1e9", LinkModel { latency_s: 1e-4, bandwidth_bps: 1e9 }),
        ("uniform:2.5e-3:1.5e7", LinkModel { latency_s: 2.5e-3, bandwidth_bps: 1.5e7 }),
    ] {
        for codec_name in ["topk", "qinf"] {
            let legacy = run_with(None, link, codec_name, Topology::Ring, 1);
            let sim = run_with(Some(spec), link, codec_name, Topology::Ring, 1);
            assert_trajectory_bitwise_equal(&legacy, &sim, codec_name);
            for (ma, mb) in legacy.series.iter().zip(&sim.series) {
                assert_eq!(
                    ma.sim_time.to_bits(),
                    mb.sim_time.to_bits(),
                    "{codec_name}/{spec} round {}: legacy {} vs simnet {}",
                    ma.round,
                    ma.sim_time,
                    mb.sim_time
                );
            }
            assert!(sim.net.is_some(), "simnet run must carry a net summary");
            assert!(legacy.net.is_none(), "legacy run must not carry a net summary");
        }
    }
}

/// The overlay is timing-only for *every* model: heterogeneous links,
/// stragglers, jitter, and packet loss change sim_time but never the
/// trajectory, across codecs, topologies, and thread counts.
#[test]
fn heterogeneous_models_never_perturb_trajectories() {
    let link = LinkModel::default();
    let models = [
        "lognormal:1e-3:1e8:0.75",
        "straggler:1e-4:1e9:0.5:10",
        "uniform:1e-4:1e9:drop=0.2",
        "uniform:1e-4:1e9:jitter=0.5",
        "straggler:1e-3:1e7:0.25:20:drop=0.1:jitter=0.2:seed=9",
    ];
    for (codec_name, topology) in [("topk", Topology::Ring), ("qinf", Topology::Star)] {
        let legacy = run_with(None, link, codec_name, topology.clone(), 1);
        for model in models {
            for threads in [1usize, 3] {
                let sim = run_with(Some(model), link, codec_name, topology.clone(), threads);
                assert_trajectory_bitwise_equal(&legacy, &sim, &format!("{codec_name}/{model}"));
            }
        }
    }
}

/// Lossy/jittery models actually move the clock (and count retransmits)
/// — the overlay is observable where it should be.
#[test]
fn lossy_models_extend_time_and_count_retransmits() {
    let link = LinkModel::default();
    let legacy = run_with(None, link, "topk", Topology::Ring, 1);
    let dropped = run_with(Some("uniform:1e-4:1e9:drop=0.3"), link, "topk", Topology::Ring, 1);
    let legacy_t = legacy.last().sim_time;
    let lossy_t = dropped.last().sim_time;
    assert!(
        lossy_t > legacy_t,
        "drop=0.3 did not extend sim_time ({lossy_t} vs {legacy_t})"
    );
    let net = dropped.net.as_ref().unwrap();
    assert!(net.retransmits > 0, "800 transfers at drop=0.3 never retransmitted");
    assert!(net.utilization > 0.0 && net.utilization <= 1.0);
    // Straggler barrier waits surface in the idle series and metrics.
    let straggled = run_with(Some("straggler:1e-4:1e6:0.5:20:seed=3"), link, "topk", Topology::Ring, 1);
    let snet = straggled.net.as_ref().unwrap();
    assert_eq!(snet.idle_s.len(), 8);
    assert_eq!(
        snet.straggler_rounds.iter().sum::<u64>(),
        50,
        "exactly one straggler per simulated round"
    );
    if snet.idle_s.iter().any(|&v| v > 0.0) {
        assert!(
            straggled.last().idle_max > 0.0,
            "idle_max metric must reflect nonzero idle"
        );
    }
    assert_eq!(legacy.last().idle_max, 0.0, "legacy accounting reports no idle");
}

/// Same seed ⇒ identical event order, timings, and stats — across engine
/// thread counts (the timer is coordinator-side) and across reruns.
#[test]
fn simnet_determinism_across_thread_counts_and_reruns() {
    let link = LinkModel::default();
    let model = "straggler:1e-3:1e7:0.25:20:drop=0.1:jitter=0.2";
    let reference = run_with(Some(model), link, "topk", Topology::Ring, 1);
    for threads in [1usize, 3, 8] {
        let rerun = run_with(Some(model), link, "topk", Topology::Ring, threads);
        assert_trajectory_bitwise_equal(&reference, &rerun, &format!("threads={threads}"));
        for (ma, mb) in reference.series.iter().zip(&rerun.series) {
            assert_eq!(
                ma.sim_time.to_bits(),
                mb.sim_time.to_bits(),
                "threads={threads} round {}",
                ma.round
            );
            assert_eq!(ma.idle_max.to_bits(), mb.idle_max.to_bits(), "threads={threads}");
        }
        let (a, b) = (reference.net.as_ref().unwrap(), rerun.net.as_ref().unwrap());
        assert_eq!(a.retransmits, b.retransmits, "threads={threads}");
        assert_eq!(a.straggler_rounds, b.straggler_rounds, "threads={threads}");
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits(), "threads={threads}");
        for (x, y) in a.idle_s.iter().zip(&b.idle_s) {
            assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
        }
    }
}

/// time_to_tol reads the sim_time of the first observed round at
/// tolerance — so the same trajectory crosses tol at different *times*
/// under different link models (the whole point of the time axis).
#[test]
fn time_to_tol_follows_the_link_model() {
    let link = LinkModel::default();
    let fast = run_with(Some("uniform:1e-4:1e9"), link, "qinf", Topology::Ring, 1);
    let slow = run_with(Some("uniform:1e-2:1e6"), link, "qinf", Topology::Ring, 1);
    // Pick a tolerance both runs reach: the dist at the midpoint of the
    // (shared) trajectory.
    let tol = fast.series[fast.series.len() / 2].dist_opt;
    let (rf, rs) = (fast.rounds_to_tol(tol), slow.rounds_to_tol(tol));
    assert_eq!(rf, rs, "same trajectory ⇒ same round count");
    let (tf, ts) = (fast.time_to_tol(tol).unwrap(), slow.time_to_tol(tol).unwrap());
    assert!(
        ts > tf,
        "slower network must take longer to the same accuracy ({ts} vs {tf})"
    );
    // And the value is exactly the sim_time recorded at that round.
    let at = fast.series.iter().find(|m| m.dist_opt <= tol).unwrap();
    assert_eq!(tf.to_bits(), at.sim_time.to_bits());
}
