//! PJRT runtime integration tests: the three-layer contract.
//!
//! These tests require `make artifacts` to have run (they skip with a
//! message otherwise, so pure-rust CI still passes).

use lead::algorithms::lead::Lead;
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::Compressor;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::problems::linreg::LinReg;
use lead::problems::neural::{MlpProblem, PjrtLinReg, TransformerProblem};
use lead::problems::{DataSplit, Problem};
use lead::rng::Rng;
use lead::runtime::{artifact::Value, Manifest};
use lead::topology::{MixingRule, Topology};

fn manifest() -> Option<Manifest> {
    if cfg!(not(feature = "pjrt")) {
        // The backend is stubbed out; compile()/execute() would error even
        // with artifacts present (see rust/Cargo.toml `pjrt` feature).
        eprintln!("SKIP (build with --features pjrt and the vendored xla bindings)");
        return None;
    }
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

/// L2 contract: artifact gradient == native rust gradient (1e-4, f32 FFI).
#[test]
fn pjrt_linreg_grad_matches_native() {
    let Some(m) = manifest() else { return };
    let native = LinReg::synthetic(8, 200, 0.1, 7);
    let native2 = LinReg::synthetic(8, 200, 0.1, 7);
    let pjrt = PjrtLinReg::new(&m, native2).unwrap();
    let mut rng = Rng::new(1);
    let mut x = vec![0.0f64; 200];
    rng.fill_normal(&mut x, 1.0);
    let mut g_native = vec![0.0f64; 200];
    let mut g_pjrt = vec![0.0f64; 200];
    for agent in [0usize, 3, 7] {
        native.grad_full(agent, &x, &mut g_native);
        pjrt.grad_full(agent, &x, &mut g_pjrt);
        let denom = lead::linalg::norm2(&g_native).max(1.0);
        let rel = lead::linalg::dist_sq(&g_native, &g_pjrt).sqrt() / denom;
        assert!(rel < 1e-4, "agent {agent}: relative grad diff {rel}");
        let l_native = native.loss(agent, &x);
        let l_pjrt = pjrt.loss(agent, &x);
        assert!(
            (l_native - l_pjrt).abs() / l_native.abs().max(1.0) < 1e-4,
            "loss {l_native} vs {l_pjrt}"
        );
    }
}

/// L1 contract: the Pallas quantization kernel (via PJRT) == the rust wire
/// codec given the same dither sequence.
#[test]
fn pjrt_quantize_kernel_matches_rust_codec() {
    let Some(m) = manifest() else { return };
    let art = m.compile("quantize_2bit_4096").unwrap();
    let d = 4096;
    let mut rng = Rng::new(9);
    let mut x = vec![0.0f64; d];
    rng.fill_normal(&mut x, 2.0);
    // The rust codec consumes one uniform draw per element in order; replay
    // the identical dither into the kernel.
    let mut dither_rng = Rng::new(0xD17E4);
    let mut u = vec![0.0f64; d];
    dither_rng.fill_uniform(&mut u);
    let res = art.execute(&[Value::F(&x), Value::F(&u)]).unwrap();
    let kernel_vals = &res[0];

    let q = QuantizeP::new(2, PNorm::Inf, 512);
    let mut codec_rng = Rng::new(0xD17E4);
    let msg = q.compress_alloc(&x, &mut codec_rng);

    // f32 (kernel) vs f64-with-f32-norm (codec): identical up to one
    // quantization level at f32 resolution; count exact matches.
    let unit: f64 = x.iter().fold(0.0f64, |a, b| a.max(b.abs())) / 2.0;
    let mut mismatched = 0usize;
    for i in 0..d {
        let diff = (kernel_vals[i] - msg.values[i]).abs();
        if diff > 1e-5 * unit {
            // floor-boundary flips: at most one level apart
            assert!(diff <= unit * 1.001, "elem {i}: kernel {} codec {}", kernel_vals[i], msg.values[i]);
            mismatched += 1;
        }
    }
    assert!(
        (mismatched as f64) < 0.001 * d as f64,
        "{mismatched}/{d} boundary mismatches — formula drift?"
    );
}

/// L1 contract: fused lead_step artifact == rust composition.
#[test]
fn pjrt_lead_step_matches_rust() {
    let Some(m) = manifest() else { return };
    let art = m.compile("lead_step_4096").unwrap();
    let d = 4096;
    let mut rng = Rng::new(21);
    let mut x = vec![0.0f64; d];
    let mut g = vec![0.0f64; d];
    let mut dv = vec![0.0f64; d];
    let mut h = vec![0.0f64; d];
    let mut u = vec![0.0f64; d];
    rng.fill_normal(&mut x, 1.0);
    rng.fill_normal(&mut g, 1.0);
    rng.fill_normal(&mut dv, 0.2);
    rng.fill_normal(&mut h, 1.0);
    rng.fill_uniform(&mut u);
    let (eta, alpha) = (0.1f64, 0.5f64);
    let res = art
        .execute(&[
            Value::F(&x),
            Value::F(&g),
            Value::F(&dv),
            Value::F(&h),
            Value::F(&u),
            Value::F(&[eta]),
            Value::F(&[alpha]),
        ])
        .unwrap();
    let (y_k, q_k, h_k) = (&res[0], &res[1], &res[2]);
    // Rust reference composition.
    let mut y = vec![0.0f64; d];
    for t in 0..d {
        y[t] = x[t] - eta * g[t] - eta * dv[t];
    }
    for t in 0..d {
        assert!((y_k[t] - y[t]).abs() < 1e-5 * (1.0 + y[t].abs()), "y[{t}]");
    }
    // h_new = h + α q must hold between the kernel's own outputs.
    for t in 0..d {
        let want = h[t] + alpha * q_k[t];
        assert!((h_k[t] - want).abs() < 1e-5 * (1.0 + want.abs()), "h[{t}]");
    }
    // q is a valid 2-bit/512-block quantization of y − h: every value is
    // a multiple of its block's unit.
    for blk in 0..d / 512 {
        let lo = blk * 512;
        let norm = (lo..lo + 512).fold(0.0f64, |a, t| a.max((y[t] - h[t]).abs()));
        let unit = norm / 2.0;
        if unit < 1e-12 {
            continue;
        }
        for t in lo..lo + 512 {
            let lev = q_k[t].abs() / unit;
            assert!(
                (lev - lev.round()).abs() < 1e-3 && lev.round() <= 2.0,
                "q[{t}] = {} not on the grid (unit {unit})",
                q_k[t]
            );
        }
    }
}

/// End-to-end: LEAD + 2-bit quantization on the PJRT gradient oracle
/// converges identically in character to the native-oracle run.
#[test]
fn pjrt_engine_run_converges() {
    let Some(m) = manifest() else { return };
    let native = LinReg::synthetic(8, 200, 0.1, 55);
    let pjrt = PjrtLinReg::new(&m, native).unwrap();
    let mix = Topology::Ring.build(8, MixingRule::UniformNeighbors);
    let mut e = Engine::new(
        EngineConfig { record_every: 20, ..Default::default() },
        mix,
        std::sync::Arc::new(pjrt),
    );
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::paper_default())),
        200,
    );
    // f32 gradients ⇒ floor around 1e-5 relative; linear decay before it.
    assert!(rec.last().dist_opt < 1e-3, "pjrt run: {}", rec.last().dist_opt);
    let rho = rec.empirical_rho(1e-4).unwrap();
    assert!(rho < 0.99, "ρ̂ = {rho}");
}

/// MLP problem: gradients flow, one engine round of LEAD improves loss.
#[test]
fn mlp_problem_trains() {
    let Some(m) = manifest() else { return };
    let p = MlpProblem::new(&m, 4, 128, DataSplit::Heterogeneous, 3).unwrap();
    let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
    let loss0 = {
        let x0 = p.initial_point().to_vec();
        (0..4).map(|i| p.loss(i, &x0)).sum::<f64>() / 4.0
    };
    let mut e = Engine::new(
        EngineConfig { eta: 0.05, batch_size: Some(64), record_every: 5, ..Default::default() },
        mix,
        std::sync::Arc::new(p),
    );
    let rec = e.run(
        Box::new(Lead::paper_default()),
        Some(Box::new(QuantizeP::paper_default())),
        15,
    );
    assert!(
        rec.last().loss < loss0,
        "loss should drop: {loss0} -> {}",
        rec.last().loss
    );
}

/// Transformer problem loads, inits, and one step produces finite loss
/// near ln(vocab) plus non-trivial gradients.
#[test]
fn transformer_problem_step() {
    let Some(m) = manifest() else { return };
    let p = TransformerProblem::new(&m, 2, 4096, 11).unwrap();
    assert!(p.param_count() > 100_000);
    let x0 = p.initial_point().to_vec();
    let mut rng = Rng::new(3);
    let (loss, grad) = p.step(0, &x0, &mut rng);
    assert!(loss.is_finite() && (loss - (256f64).ln()).abs() < 1.5, "loss {loss}");
    let gnorm = lead::linalg::norm2(&grad);
    assert!(gnorm > 1e-3 && gnorm.is_finite(), "‖g‖ = {gnorm}");
}
