//! Tracing-on vs tracing-off bitwise differential (`lead::trace`
//! §Observability contract).
//!
//! The recorder's core promise is that it is a pure *observer*: flipping
//! `EngineConfig.trace` must not move a single trajectory bit. This
//! harness pins that promise across the acceptance matrix —
//! {lead, choco} × {topk, qinf 2-bit} × threads {1, 3} × {mem, channel}
//! — and then checks the observer actually observed something useful:
//!
//! 1. **Invisibility**: every recorded series (dist/consensus/comp_err
//!    and the bits accounting) is bitwise-identical with tracing on.
//! 2. **Presence**: traced runs carry a `TraceSummary` with live event
//!    counters; untraced runs carry `None` and yield no capture.
//! 3. **Consistency**: the summary's transport counters equal the
//!    engine's own `TransportSummary`, and multi-thread runs record pool
//!    dispatches with one event lane per worker.
//! 4. **Export**: every capture round-trips through the Chrome
//!    trace-event exporter and its validator (`validate_chrome_json`).

use lead::algorithms::{choco::ChocoSgd, lead::Lead, Algorithm};
use lead::compress::quantize::{PNorm, QuantizeP};
use lead::compress::topk::TopK;
use lead::compress::Compressor;
use lead::coordinator::engine::{Engine, EngineConfig};
use lead::coordinator::metrics::RunRecord;
use lead::problems::linreg::LinReg;
use lead::topology::{MixingRule, Topology};
use lead::trace::{chrome_json, validate_chrome_json, TraceCapture};
use lead::transport::TransportMode;
use std::sync::Arc;

fn algo(name: &str) -> Box<dyn Algorithm> {
    match name {
        "lead" => Box::new(Lead::paper_default()),
        "choco" => Box::new(ChocoSgd::new(0.8)),
        other => panic!("unknown test algo {other:?}"),
    }
}

fn codec(name: &str) -> Option<Box<dyn Compressor>> {
    match name {
        "topk" => Some(Box::new(TopK::new(10))),
        "qinf" => Some(Box::new(QuantizeP::new(2, PNorm::Inf, 512))),
        other => panic!("unknown test codec {other:?}"),
    }
}

/// One short run on the Fig. 1-shaped synthetic linreg workload,
/// returning the record and (for traced runs) the claimed capture.
fn run(
    algo_name: &str,
    codec_name: &str,
    threads: usize,
    transport: TransportMode,
    trace: bool,
) -> (RunRecord, Option<TraceCapture>) {
    let n = 8;
    let p = LinReg::synthetic(n, 30, 0.1, 3);
    let mix = Topology::Ring.build(n, MixingRule::UniformNeighbors);
    let cfg = EngineConfig { threads, record_every: 4, transport, trace, ..Default::default() };
    let mut e = Engine::new(cfg, mix, Arc::new(p));
    let rec = e.run(algo(algo_name), codec(codec_name), 24);
    (rec, e.take_trace())
}

fn assert_series_bitwise(a: &RunRecord, b: &RunRecord, tag: &str) {
    assert_eq!(a.series.len(), b.series.len(), "{tag}: series length");
    for (ma, mb) in a.series.iter().zip(&b.series) {
        assert_eq!(ma.round, mb.round, "{tag}");
        assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.consensus.to_bits(), mb.consensus.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.comp_err.to_bits(), mb.comp_err.to_bits(), "{tag} round {}", ma.round);
        assert_eq!(ma.bits_per_agent, mb.bits_per_agent, "{tag} round {}", ma.round);
    }
}

/// Acceptance pin: the full matrix is trajectory-invisible, and every
/// traced cell carries a summary, a capture, and a valid Chrome export.
#[test]
fn tracing_is_bitwise_invisible_across_matrix() {
    for algo_name in ["lead", "choco"] {
        for codec_name in ["topk", "qinf"] {
            for threads in [1usize, 3] {
                for mode in [TransportMode::Mem, TransportMode::Channel] {
                    let tag = format!(
                        "{algo_name}/{codec_name}/threads={threads}/{}",
                        mode.label()
                    );
                    let (off, off_cap) = run(algo_name, codec_name, threads, mode, false);
                    assert!(off.trace.is_none(), "{tag}: untraced run carries no summary");
                    assert!(off_cap.is_none(), "{tag}: untraced run yields no capture");
                    let (on, on_cap) = run(algo_name, codec_name, threads, mode, true);
                    assert_series_bitwise(&off, &on, &tag);

                    let sum = on.trace.as_ref().unwrap_or_else(|| panic!("{tag}: summary"));
                    assert!(sum.counter("events") > 0, "{tag}: recorder saw events");
                    let cap = on_cap.unwrap_or_else(|| panic!("{tag}: capture"));
                    assert_eq!(cap.lanes.len(), threads, "{tag}: one lane per worker");
                    assert!(cap.total_events() > 0, "{tag}");
                    assert!(
                        sum.counter("events") >= cap.total_events() as u64,
                        "{tag}: recorded >= retained"
                    );
                    let js = chrome_json(&cap, &tag);
                    validate_chrome_json(&js)
                        .unwrap_or_else(|e| panic!("{tag}: invalid Chrome JSON: {e}"));
                }
            }
        }
    }
}

/// The summary's fleet counters agree with the engine's own transport
/// accounting: frames and wire bytes come from the same round loop, so
/// they must match exactly, and a mem run reports them as zero.
#[test]
fn trace_counters_match_transport_summary() {
    let (chan, _) = run("lead", "topk", 1, TransportMode::Channel, true);
    let ts = chan.transport.as_ref().expect("channel summary");
    let sum = chan.trace.as_ref().expect("trace summary");
    assert_eq!(sum.counter("frames_sent"), ts.frames_sent);
    assert_eq!(sum.counter("frames_dropped"), ts.frames_dropped);
    assert_eq!(sum.counter("bytes_on_wire"), ts.bytes_on_wire);
    assert!(ts.frames_sent > 0, "frames actually flowed");

    let (mem, _) = run("lead", "topk", 1, TransportMode::Mem, true);
    let sum = mem.trace.as_ref().expect("trace summary");
    assert_eq!(sum.counter("frames_sent"), 0, "mem transport sends no frames");
    assert_eq!(sum.counter("bytes_on_wire"), 0);
}

/// Multi-thread traced runs record pool activity: the fused produce
/// phase fans out at this problem shape, so dispatches are counted, the
/// wake-latency histogram is populated, and worker lanes carry events.
#[test]
fn pool_lanes_record_dispatches_and_wakes() {
    let (on, cap) = run("lead", "qinf", 3, TransportMode::Mem, true);
    let sum = on.trace.as_ref().expect("trace summary");
    assert!(sum.counter("pool_dispatches") > 0, "produce fan-out dispatches the pool");
    assert!(
        sum.wake_hist_ns.iter().sum::<u64>() > 0,
        "wake latencies land in the histogram"
    );
    let cap = cap.expect("capture");
    assert_eq!(cap.lanes.len(), 3);
    assert!(
        cap.lanes[1..].iter().any(|l| !l.is_empty()),
        "worker lanes (not just the coordinator) carry events"
    );
    // The Chrome export names every lane's thread and stays valid.
    let js = chrome_json(&cap, "pool");
    validate_chrome_json(&js).unwrap();
    assert!(js.contains("lead-pool-1"), "worker lane thread metadata present");
}
