//! Randomized property tests over the whole stack (the in-repo `prop`
//! harness stands in for proptest; failures print a replay seed).

use lead::compress::quantize::{decode, PNorm, QuantizeP};
use lead::compress::{identity::Identity, randk::RandK, topk::TopK, CompressedMsg, Compressor};
use lead::prop::forall;
use lead::prop_assert;
use lead::rng::Rng;
use lead::topology::{MixingMatrix, MixingRule, Topology};

/// Any topology × any mixing rule yields a matrix satisfying Assumption 1,
/// and the cached spectral constants are consistent with the eigenvalues.
#[test]
fn mixing_matrices_satisfy_assumption1() {
    forall(60, 0x701, |g| {
        let n = g.usize_in(2..=24);
        let topo = match g.usize_in(0..=4) {
            0 => Topology::Ring,
            1 => Topology::FullyConnected,
            2 => Topology::Star,
            3 => Topology::Path,
            _ => Topology::ErdosRenyi { p: 0.5, seed: g.case_seed },
        };
        let rule = *g.choose(&[
            MixingRule::UniformNeighbors,
            MixingRule::MetropolisHastings,
            MixingRule::LazyMetropolis,
        ]);
        let m = topo.build(n, rule); // validate() runs inside
        prop_assert!(m.beta() > 0.0 && m.beta() < 2.0, "β = {}", m.beta());
        prop_assert!(m.kappa_g() >= 1.0 - 1e-9, "κ_g = {}", m.kappa_g());
        // Mixing preserves the average: 1ᵀW = 1ᵀ.
        for j in 0..n {
            let col: f64 = (0..n).map(|i| m.w[(i, j)]).sum();
            prop_assert!((col - 1.0).abs() < 1e-9, "column {j} sums to {col}");
        }
        Ok(())
    });
}

/// Gossip with any valid W converges to consensus on the average
/// (primitivity ⇒ W^k → 11ᵀ/n).
#[test]
fn gossip_converges_to_average() {
    forall(30, 0x702, |g| {
        let n = g.usize_in(3..=12);
        let topo = g.choose(&[Topology::Ring, Topology::Star, Topology::Path]).clone();
        let m: MixingMatrix = topo.build(n, MixingRule::LazyMetropolis);
        let mut x: Vec<f64> = (0..n).map(|_| g.f64_in(-5.0, 5.0)).collect();
        let avg: f64 = x.iter().sum::<f64>() / n as f64;
        for _ in 0..2000 {
            let mut nx = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    nx[i] += m.w[(i, j)] * x[j];
                }
            }
            x = nx;
        }
        for (i, xi) in x.iter().enumerate() {
            prop_assert!((xi - avg).abs() < 1e-6, "agent {i}: {xi} vs avg {avg}");
        }
        Ok(())
    });
}

/// Wire-format completeness: decode(payload) == values for every codec
/// that ships packed bytes, across random shapes and parameters.
#[test]
fn quantizer_wire_roundtrip_random() {
    forall(120, 0x703, |g| {
        let bits = g.usize_in(1..=12) as u32;
        let block = *g.choose(&[1usize, 2, 7, 64, 512, 4096]);
        let q = QuantizeP::new(bits, if g.bool_with(0.5) { PNorm::Inf } else { PNorm::P(2.0) }, block);
        let x = g.vec_f64(1..=2000, 100.0);
        let mut rng = Rng::new(g.case_seed);
        let msg = q.compress_alloc(&x, &mut rng);
        // Exact bit count.
        let blocks = x.len().div_ceil(block) as u64;
        prop_assert!(
            msg.wire_bits == blocks * 32 + (x.len() as u64) * (1 + bits as u64),
            "bits {} != formula",
            msg.wire_bits
        );
        prop_assert!(msg.payload.len() as u64 == msg.wire_bits.div_ceil(8));
        let mut dec = Vec::new();
        decode(&q, &msg.payload, x.len(), &mut dec);
        prop_assert!(dec == msg.values, "decode mismatch");
        Ok(())
    });
}

/// Unbiased codecs: averaging many compressions approaches the input
/// (law of large numbers with bounded variance C‖x‖²).
#[test]
fn unbiasedness_across_codecs() {
    forall(8, 0x704, |g| {
        let d = g.usize_in(16..=64);
        let x = g.vec_normal(d);
        let codecs: Vec<Box<dyn Compressor>> = vec![
            Box::new(QuantizeP::new(2, PNorm::Inf, 32)),
            Box::new(RandK::new((d / 3).max(1), true)),
        ];
        let mut rng = Rng::new(g.case_seed);
        for c in &codecs {
            let trials = 4000;
            let mut mean = vec![0.0f64; d];
            let mut msg = CompressedMsg::with_dim(d);
            for _ in 0..trials {
                c.compress(&x, &mut rng, &mut msg);
                for (m, v) in mean.iter_mut().zip(&msg.values) {
                    *m += v / trials as f64;
                }
            }
            let cconst = c.variance_constant(d).unwrap().max(0.25);
            let norm = lead::linalg::norm2(&x);
            let tol = 6.0 * (cconst.sqrt() * norm) / (trials as f64).sqrt();
            let bias = lead::linalg::dist_sq(&mean, &x).sqrt();
            prop_assert!(bias < tol, "{}: bias {bias} > {tol}", c.name());
        }
        Ok(())
    });
}

/// Top-k is a contraction: ‖x − Q(x)‖² ≤ (1 − k/d)‖x‖², and never expands.
#[test]
fn topk_contraction_random() {
    forall(80, 0x705, |g| {
        let x = g.vec_f64(1..=400, 10.0);
        let k = g.usize_in(1..=x.len());
        let t = TopK::new(k);
        let mut rng = Rng::new(1);
        let msg = t.compress_alloc(&x, &mut rng);
        let err = lead::linalg::dist_sq(&x, &msg.values);
        let bound = (1.0 - k as f64 / x.len() as f64) * lead::linalg::norm2_sq(&x);
        prop_assert!(err <= bound + 1e-9, "err {err} > bound {bound}");
        Ok(())
    });
}

/// Sparse-aware mixing is *bitwise* equal to the dense path, for random
/// topologies × {TopK, RandK, QuantizeP, Identity}: the engine's
/// `mix_msgs` (scatter-add over each message's sparse view when present)
/// must reproduce plain dense `axpy` accumulation over `msgs[j].values`
/// exactly — this is what licenses the O(deg·k) hot path.
#[test]
fn sparse_mixing_bitwise_equals_dense() {
    use lead::coordinator::engine::mix_msgs;
    forall(60, 0x706, |g| {
        let n = g.usize_in(2..=12);
        let d = g.usize_in(1..=120);
        let topo = g
            .choose(&[Topology::Ring, Topology::Star, Topology::Path, Topology::FullyConnected])
            .clone();
        let rule = *g.choose(&[
            MixingRule::UniformNeighbors,
            MixingRule::MetropolisHastings,
            MixingRule::LazyMetropolis,
        ]);
        let mix = topo.build(n, rule);
        let k = g.usize_in(1..=d);
        let codecs: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(k)),
            Box::new(RandK::new(k, true)),
            Box::new(QuantizeP::new(2, PNorm::Inf, 32)),
            Box::new(Identity),
        ];
        for c in &codecs {
            let mut rng = Rng::new(g.case_seed ^ 0xD15C);
            let msgs: Vec<CompressedMsg> = (0..n)
                .map(|_| {
                    let x: Vec<f64> = (0..d).map(|_| g.f64_in(-5.0, 5.0)).collect();
                    c.compress_alloc(&x, &mut rng)
                })
                .collect();
            for i in 0..n {
                // Reference: dense accumulation over decoded values, in
                // the same closed-neighborhood order.
                let mut dense = vec![0.0f64; d];
                for j in std::iter::once(i).chain(mix.neighbors[i].iter().copied()) {
                    lead::linalg::axpy(mix.weight(i, j), &msgs[j].values, &mut dense);
                }
                let mut sparse = vec![0.0f64; d];
                mix_msgs(&mix, i, &msgs, &mut sparse);
                for (t, (a, b)) in dense.iter().zip(&sparse).enumerate() {
                    prop_assert!(
                        a.to_bits() == b.to_bits(),
                        "{}: agent {i} coord {t}: dense {a} vs sparse {b}",
                        c.name()
                    );
                }
            }
        }
        Ok(())
    });
}

/// The sparse view, when present, is exactly the nonzeros of `values` in
/// ascending index order — the invariant `mix_msgs` relies on.
#[test]
fn sparse_view_is_canonical_nonzeros() {
    forall(80, 0x707, |g| {
        let d = g.usize_in(0..=200);
        let k = g.usize_in(1..=d.max(1));
        let codecs: Vec<Box<dyn Compressor>> =
            vec![Box::new(TopK::new(k)), Box::new(RandK::new(k, g.bool_with(0.5)))];
        let x: Vec<f64> = (0..d).map(|_| g.f64_in(-8.0, 8.0)).collect();
        for c in &codecs {
            let mut rng = Rng::new(g.case_seed);
            let msg = c.compress_alloc(&x, &mut rng);
            let sp = msg.sparse.as_ref().expect("sparsifiers must publish a sparse view");
            let expected: Vec<(u32, f64)> = msg
                .values
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, &v)| (i as u32, v))
                .collect();
            prop_assert!(
                sp.len() == expected.len()
                    && sp
                        .iter()
                        .zip(&expected)
                        .all(|(a, b)| a.0 == b.0 && a.1.to_bits() == b.1.to_bits()),
                "{}: sparse view not canonical (d={d}, k={k})",
                c.name()
            );
            prop_assert!(sp.len() <= k, "{}: more than k entries", c.name());
        }
        Ok(())
    });
}

/// Engine determinism: same seed ⇒ identical runs; different seed ⇒
/// different dither draws (compressed runs diverge in their randomness but
/// both converge).
#[test]
fn engine_seed_determinism() {
    use lead::algorithms::lead::Lead;
    use lead::coordinator::engine::{Engine, EngineConfig};
    use lead::problems::linreg::LinReg;
    let run = |seed: u64| {
        let p = LinReg::synthetic(4, 16, 0.1, 3);
        let mix = Topology::Ring.build(4, MixingRule::UniformNeighbors);
        let mut e = Engine::new(
            EngineConfig { seed, record_every: 10, ..Default::default() },
            mix,
            std::sync::Arc::new(p),
        );
        e.run(
            Box::new(Lead::paper_default()),
            Some(Box::new(QuantizeP::new(2, PNorm::Inf, 16))),
            100,
        )
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    for (ma, mb) in a.series.iter().zip(&b.series) {
        assert_eq!(ma.dist_opt.to_bits(), mb.dist_opt.to_bits());
    }
    assert!(
        a.series.iter().zip(&c.series).any(|(x, y)| x.dist_opt != y.dist_opt),
        "different seeds should give different dither"
    );
}

/// Homogeneous simnet == legacy `TrafficStats.sim_time`, bit for bit:
/// for any topology, link parameters, round count, and per-agent bit
/// pattern, the event-driven round duration under the degenerate
/// `uniform` model accumulates to exactly the legacy formula's time
/// (the simnet §Timing contract, half 2; the engine-level differential
/// lives in `tests/simnet.rs`).
#[test]
fn prop_homogeneous_simnet_matches_legacy_sim_time() {
    use lead::coordinator::network::{LinkModel, TrafficStats};
    use lead::simnet::{NetModel, RoundTimer};
    forall(48, 0x5117_ED, |g| {
        let n = g.usize_in(2..=12);
        let topo = match g.usize_in(0..=3) {
            0 => Topology::Ring,
            1 => Topology::FullyConnected,
            2 => Topology::Star,
            _ => Topology::Path,
        };
        let rule = *g.choose(&[
            MixingRule::UniformNeighbors,
            MixingRule::MetropolisHastings,
            MixingRule::LazyMetropolis,
        ]);
        let mix = topo.build(n, rule);
        let lat = g.f64_in(0.0, 1e-2);
        let bw = g.f64_in(1e3, 1e12);
        let link = LinkModel { latency_s: lat, bandwidth_bps: bw };
        let mut timer = RoundTimer::new(&mix, NetModel::uniform(lat, bw), g.case_seed);
        let mut traffic = TrafficStats::new(n);
        let mut sim = 0.0f64;
        let rounds = g.usize_in(1..=6);
        for _ in 0..rounds {
            let bits: Vec<u64> = (0..n).map(|_| g.rng.below(1_000_000_000) as u64).collect();
            traffic.record_round(&mix, &link, &bits);
            sim += timer.round(&bits);
        }
        prop_assert!(
            sim.to_bits() == traffic.sim_time.to_bits(),
            "simnet {sim} != legacy {} (n={n}, lat={lat}, bw={bw})",
            traffic.sim_time
        );
        prop_assert!(timer.stats.rounds == rounds, "round count drifted");
        Ok(())
    });
}

/// Randomly composed fault plans round-trip through their canonical
/// label (`parse(label(p)) == p`), and the canonical form is a fixed
/// point — the property `Grid` cell names and `FaultSummary.plan` rely
/// on (the enumerated spellings live in `lead::faults`' unit tests).
#[test]
fn prop_fault_plan_label_roundtrips() {
    use lead::faults::FaultPlan;
    forall(200, 0xFA_B1E, |g| {
        let mut p = FaultPlan::default();
        if g.bool_with(0.6) {
            p.loss = g.f64_in(1e-4, 0.99);
        }
        if g.bool_with(0.5) {
            p.crash_frac = g.f64_in(1e-3, 1.0);
            p.crash_round = g.usize_in(1..=1000);
            p.crash_down = g.usize_in(1..=60);
        }
        if g.bool_with(0.4) {
            p.churn = g.f64_in(1e-4, 0.99);
            p.churn_down = g.usize_in(1..=30);
        }
        if g.bool_with(0.4) {
            p.part_cut = g.usize_in(1..=16);
            p.part_from = g.usize_in(0..=500);
            p.part_to = p.part_from + g.usize_in(1..=500);
        }
        if p.is_noop() {
            // `label()` of a no-op plan is the sentinel "none", which
            // parse (by design) does not accept — the scenario layer
            // maps it to `faults: None` before parse ever runs.
            prop_assert!(p.label() == "none", "noop label: {}", p.label());
            return Ok(());
        }
        p.stale = g.usize_in(0..=4);
        p.seed = if g.bool_with(0.3) { g.case_seed } else { 0 };
        let label = p.label();
        let back = FaultPlan::parse(&label);
        prop_assert!(back == Some(p), "roundtrip failed: {label:?} -> {back:?}");
        let canon = back.unwrap().label();
        prop_assert!(canon == label, "label not a fixed point: {label:?} vs {canon:?}");
        Ok(())
    });
}
